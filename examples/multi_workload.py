"""Multiple dynamic workloads — the paper's headline scenario.

Three different jobs launch asynchronously on one device; the Global
Controller captures each graph at launch (cold-start latency prediction —
no passive mode), plans over the MERGED timeline, re-plans when measured
latencies drift (EWMA, §IV-E), and the shared Swap Executor serializes
host transfers on the single channel (paper Fig. 3/4).

    PYTHONPATH=src python examples/multi_workload.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp

from repro.core import (GlobalController, MachineProfile, SchedulerConfig,
                        format_bytes)
from repro.optim.adam import adamw_init, adamw_update
from repro.service import JobSpec


def make_mlp_job(key, sizes, batch):
    params = []
    for i in range(len(sizes) - 1):
        key, k = jax.random.split(key)
        params.append({"w": jax.random.normal(k, (sizes[i], sizes[i + 1]))
                       * 0.02, "b": jnp.zeros(sizes[i + 1])})
    opt = adamw_init(params)
    key, kx, ky = jax.random.split(key, 3)
    data = (jax.random.normal(kx, (batch, sizes[0])),
            jax.random.normal(ky, (batch, sizes[-1])))
    return params, opt, data


def train_step(params, opt_state, batch):
    x, y = batch

    def fwd(p, h):
        for i, layer in enumerate(p):
            h = h @ layer["w"] + layer["b"]
            if i < len(p) - 1:
                h = jnp.tanh(h)
        return h

    loss, grads = jax.value_and_grad(
        lambda p: jnp.mean((fwd(p, x) - y) ** 2))(params)
    params, opt_state = adamw_update(params, grads, opt_state, lr=1e-3)
    return params, opt_state, loss


def main():
    profile = MachineProfile(host_link_bw=16e9, compute_flops=5e10,
                             mem_bw=1e10)
    gc = GlobalController(
        profile=profile, async_swap=True,
        scheduler_config=SchedulerConfig(update_threshold=0.25))

    shapes = [([128, 512, 512, 16], 32),     # job 0: wide
              ([256, 256, 256, 256, 8], 64),  # job 1: deep
              ([64, 1024, 4], 16)]            # job 2: squat
    for j, (sizes, batch) in enumerate(shapes):
        p, o, d = make_mlp_job(jax.random.PRNGKey(j), sizes, batch)
        h = gc.submit(JobSpec(f"job{j}", iterations=3,
                              payload=(train_step, p, o, d)))
        print(f"launched {h.job_id}: {len(h.seq.operators)} ops, "
              f"{format_bytes(h.seq.total_tensor_bytes())} tensors")

    gc.wait(timeout=600)
    print(f"\nall jobs done; global device peak "
          f"{format_bytes(gc.global_peak_bytes)}; "
          f"{gc.replan_count} scheduler passes (incl. drift re-plans)")
    for j, h in gc.jobs.items():
        s = h.stats[-1]
        print(f"  {j}: peak {format_bytes(h.peak_bytes)}, "
              f"{s.swap_out_count} swap-outs/iter, "
              f"steps {[f'{t:.2f}s' for t in h.step_times]}")
    assert all(h.done and h.error is None for h in gc.jobs.values())


if __name__ == "__main__":
    main()
