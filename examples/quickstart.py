"""Quickstart: TENSILE in five minutes.

Capture a training step, let the Memory Scheduler plan swaps /
recomputation under a device-memory budget, execute the plan with the
interpreting Executor, and verify both the memory saving and the numerics.

    PYTHONPATH=src python examples/quickstart.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (JaxprExecutor, MachineProfile, capture_train_step,
                        evaluate, format_bytes, reference_outputs,
                        schedule_single)
from repro.optim.adam import adamw_init, adamw_update


# ----- 1. any JAX training step ---------------------------------------
def init_params(key, sizes):
    params = []
    for i in range(len(sizes) - 1):
        key, k = jax.random.split(key)
        params.append({"w": jax.random.normal(k, (sizes[i], sizes[i + 1]))
                       * 0.02, "b": jnp.zeros(sizes[i + 1])})
    return params


def forward(params, x):
    h = x
    for i, p in enumerate(params):
        h = h @ p["w"] + p["b"]
        if i < len(params) - 1:
            h = jnp.tanh(h)
    return h


def train_step(params, opt_state, batch):
    x, y = batch
    loss, grads = jax.value_and_grad(
        lambda p: jnp.mean((forward(p, x) - y) ** 2))(params)
    params, opt_state = adamw_update(params, grads, opt_state, lr=1e-3)
    return params, opt_state, loss


def main():
    params = init_params(jax.random.PRNGKey(0), [256, 1024, 1024, 1024, 16])
    opt_state = adamw_init(params)
    batch = (jax.random.normal(jax.random.PRNGKey(1), (64, 256)),
             jax.random.normal(jax.random.PRNGKey(2), (64, 16)))

    # ----- 2. capture the compute graph → Tensor Access Sequence -------
    seq, closed = capture_train_step(train_step, params, opt_state, batch)
    print(f"captured: {len(seq.operators)} operators, "
          f"{len(seq.tensors)} tensors")

    # ----- 3. plan under a memory budget (Algorithms 1-3) ---------------
    profile = MachineProfile(host_link_bw=16e9, compute_flops=5e10,
                             mem_bw=1e10)
    result = schedule_single(seq, profile=profile)
    plan = result.plans[seq.job_id]
    print(f"plan: {result.swaps_scheduled} swaps, "
          f"{result.recomputes_scheduled} recomputes, "
          f"{sum(1 for e in plan.events if e.crosses_iteration)} "
          f"across-iteration events")
    print(f"predicted peak: "
          f"{format_bytes(result.initial_report.peak_bytes)} -> "
          f"{format_bytes(result.final_report.peak_bytes)} "
          f"(MSR {result.memory_saving_ratio:.2%})")

    # ----- 4. simulated cost/benefit (paper metrics) --------------------
    metrics = evaluate([seq], result.plans, profile)
    print(f"simulated: MSR={metrics['MSR']:.3f} EOR={metrics['EOR']:.3f} "
          f"CBR={metrics['CBR']:.2f}")

    # ----- 5. really execute the plan + verify --------------------------
    ref = reference_outputs(closed, params, opt_state, batch)
    ex = JaxprExecutor(closed, seq, plan)
    out = ex.run(params, opt_state, batch)
    ok = all(np.allclose(np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-6)
             for a, b in zip(ref, out))
    ex0 = JaxprExecutor(closed, seq, None)
    ex0.run(params, opt_state, batch)
    print(f"executed: outputs match reference = {ok}; real peak "
          f"{format_bytes(ex0.stats.peak_bytes)} -> "
          f"{format_bytes(ex.stats.peak_bytes)} "
          f"({ex.stats.swap_out_count} swap-outs, "
          f"{ex.stats.swap_in_count} swap-ins)")
    assert ok
    ex.close(), ex0.close()


if __name__ == "__main__":
    main()
