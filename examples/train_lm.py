"""End-to-end LM training driver (deliverable (b)): trains a reduced
assigned-architecture config for a few hundred steps with the full
substrate — sharded data pipeline with prefetch, AdamW, TENSILE memory
planning, async checkpointing and restart-on-failure.

    PYTHONPATH=src python examples/train_lm.py --arch tinyllama-1.1b \
        --steps 300 --tensile-budget-mb 64
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.launch.train import main

if __name__ == "__main__":
    sys.exit(main())
