"""Batched autoregressive serving (deliverable (b)): prefill + KV/SSM-cache
decode with the same serve_step the decode_* dry-run cells lower.

    PYTHONPATH=src python examples/serve_lm.py --arch mamba2-780m \
        --batch 4 --prompt-len 32 --gen 64
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.launch.serve import main

if __name__ == "__main__":
    sys.exit(main())
