"""Continuous-batching LM serving over the ServingEngine (deliverable (b)):
prefill -> insert -> chunked cohort decode, with KV-cache residency
scheduling when a budget is given.

    PYTHONPATH=src python examples/serve_lm.py --arch tinyllama-1.1b \
        --max-sequences 4 --prompt-len 8 --gen 8 --trace burst --budget-kb 24
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.serving.cli import main

if __name__ == "__main__":
    sys.exit(main())
