"""tensile-trace — record, validate and summarize scheduling traces.

    PYTHONPATH=src python tools/tensile_trace.py record --out t.trace.json \
        [--size small|medium|large] [--iterations N] [--real] [--job-id j]
    PYTHONPATH=src python tools/tensile_trace.py validate t.trace.json
    PYTHONPATH=src python tools/tensile_trace.py summary  t.trace.json
    PYTHONPATH=src python tools/tensile_trace.py metrics-smoke --root <dir>

`record` captures the builtin "mlp" workload, plans it with the tensile
pipeline, runs the plan through the discrete-event simulator (default)
or the real ``JaxprExecutor`` (``--real``) with a ``TraceRecorder``
attached, and writes Chrome Trace Event Format JSON loadable in
Perfetto / chrome://tracing.  Both paths emit through the same
``TelemetryHub`` schemas, so a sim trace and a real trace of the same
job + plan diff side-by-side.  Safe points ride along as instants:
modeled (ledger-derived) for the sim run, measured (telemetry-derived)
for the real run — each on the clock the rest of that trace uses.

`metrics-smoke` is the CI self-check for the metrics endpoint: an
in-process ``SchedulerDaemon`` runs one small job to completion, and the
Prometheus text file it writes next to its heartbeat must parse and
carry the core gauge set.
"""
from __future__ import annotations

import argparse
import json
import os
import shutil
import sys

sys.path.insert(0, os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "src"))

from repro.core import (JaxprExecutor, MachineProfile,  # noqa: E402
                        MemoryEngine, TelemetryHub, capture_train_step,
                        find_safe_points, schedule_single, simulate)
from repro.obs import (TraceRecorder, format_summary,  # noqa: E402
                       load_trace, parse_metrics_text, summarize_trace,
                       validate_chrome_trace)

# the CPU-sized device class the test and scenario suites use
PROFILE = MachineProfile(host_link_bw=16e9, compute_flops=5e10, mem_bw=1e10)


def _capture(size: str, job_id: str):
    """Capture the builtin "mlp" workload at a size class."""
    from repro.service.workloads import make_mlp

    step_fn, params, opt_state, batch = make_mlp(size=size)
    seq, closed = capture_train_step(step_fn, params, opt_state, batch,
                                     job_id=job_id)
    return seq, closed, (params, opt_state, batch)


def cmd_record(args: argparse.Namespace) -> int:
    seq, closed, call_args = _capture(args.size, args.job_id)
    res = schedule_single(seq, profile=PROFILE)
    plan = res.plans[seq.job_id]
    budget = plan.planned_peak_bytes or None

    clock = "real" if args.real else "virtual"
    rec = TraceRecorder(clock=clock, budget_bytes=budget)
    rec.meta.update({"workload": f"mlp/{args.size}", "job_id": seq.job_id,
                     "runtime": "executor" if args.real else "simulator"})
    hub = TelemetryHub(clock=clock)
    eng = MemoryEngine(PROFILE, telemetry=hub)
    eng.attach_recorder(rec)

    if args.real:
        ex = JaxprExecutor(closed, seq, plan, engine=eng)
        for _ in range(args.iterations):
            ex.run(*call_args)
        ex.close()
        # measured safe points: detected from the run's own telemetry,
        # timestamped on the same wall clock as the rest of the trace
        sps = find_safe_points(seq, plan, source="measured", telemetry=hub)
    else:
        simulate([seq], {seq.job_id: plan}, PROFILE,
                 iterations=args.iterations, engine=eng, telemetry=hub)
        sps = find_safe_points(seq, plan)
    for sp in sps:
        rec.instant("safe_point", sp.time, job_id=seq.job_id,
                    op_idx=sp.op_idx)

    trace = rec.dump(args.out)
    errs = validate_chrome_trace(trace)
    if errs:
        for e in errs:
            print(f"INVALID: {e}", file=sys.stderr)
        return 1
    n = len(trace["traceEvents"])
    print(f"wrote {args.out}: {n} events ({clock} clock, "
          f"{args.iterations} iteration(s))")
    print(format_summary(summarize_trace(trace)))
    return 0


def cmd_validate(args: argparse.Namespace) -> int:
    trace = load_trace(args.path)
    errs = validate_chrome_trace(trace)
    for e in errs:
        print(f"INVALID: {e}", file=sys.stderr)
    if errs:
        return 1
    print(f"{args.path}: valid ({len(trace['traceEvents'])} events)")
    return 0


def cmd_summary(args: argparse.Namespace) -> int:
    trace = load_trace(args.path)
    summary = summarize_trace(trace, top=args.top)
    if args.json:
        print(json.dumps(summary, indent=1))
    else:
        print(format_summary(summary))
    return 0


# core gauges the daemon must always expose, whatever the workload did
_REQUIRED_METRICS = ("tensile_queue_depth", "tensile_capacity_bytes",
                     "tensile_reserved_bytes",
                     "tensile_state_transitions_total")


def cmd_metrics_smoke(args: argparse.Namespace) -> int:
    """CI self-check: an in-process daemon runs one job; its Prometheus
    text file must exist, parse, and carry the core gauge set."""
    from repro.service import JobSpec, JobState, SchedulerDaemon

    os.makedirs(args.root, exist_ok=True)
    daemon = SchedulerDaemon(args.root, poll_interval=0.01)
    daemon.submit(JobSpec("metrics-smoke", workload="mlp",
                          workload_params={"size": "small"}, iterations=1))
    ok = daemon.drain(timeout=args.timeout)
    if not ok:
        print("FAIL: daemon did not drain", file=sys.stderr)
        return 1
    rec = daemon.store.get("metrics-smoke")
    if rec is None or rec.state is not JobState.DONE:
        state = rec.state.value if rec else "missing"
        print(f"FAIL: smoke job ended {state}", file=sys.stderr)
        return 1
    if not os.path.exists(daemon.metrics_path):
        print(f"FAIL: {daemon.metrics_path} not written", file=sys.stderr)
        return 1
    with open(daemon.metrics_path) as f:
        text = f.read()
    try:
        parsed = parse_metrics_text(text)
    except ValueError as exc:
        print(f"FAIL: metrics file does not parse: {exc}", file=sys.stderr)
        return 1
    names = {name for name, _labels in parsed}
    missing = [m for m in _REQUIRED_METRICS if m not in names]
    if missing:
        print(f"FAIL: metrics missing {missing} (got {sorted(names)})",
              file=sys.stderr)
        return 1
    if args.out:
        shutil.copyfile(daemon.metrics_path, args.out)
        print(f"copied metrics to {args.out}")
    print(f"metrics smoke OK: {len(parsed)} samples, "
          f"{len(names)} metrics ({daemon.metrics_path})")
    return 0


def main() -> int:
    ap = argparse.ArgumentParser(prog="tensile-trace", description=__doc__)
    sub = ap.add_subparsers(dest="cmd", required=True)

    p = sub.add_parser("record", help="run a workload and export a trace")
    p.add_argument("--out", default="tensile.trace.json")
    p.add_argument("--size", default="small",
                   choices=("small", "medium", "large"))
    p.add_argument("--iterations", type=int, default=2)
    p.add_argument("--job-id", default="trace0")
    p.add_argument("--real", action="store_true",
                   help="run the real JaxprExecutor instead of the "
                        "virtual-time simulator")
    p.set_defaults(fn=cmd_record)

    p = sub.add_parser("validate", help="schema-check a trace file")
    p.add_argument("path")
    p.set_defaults(fn=cmd_validate)

    p = sub.add_parser("summary", help="human summary of a trace file")
    p.add_argument("path")
    p.add_argument("--top", type=int, default=5,
                   help="swaps to list, by duration")
    p.add_argument("--json", action="store_true")
    p.set_defaults(fn=cmd_summary)

    p = sub.add_parser("metrics-smoke",
                       help="CI self-check of the daemon metrics endpoint")
    p.add_argument("--root", required=True)
    p.add_argument("--out", default=None,
                   help="also copy the metrics file here (CI artifact)")
    p.add_argument("--timeout", type=float, default=300.0)
    p.set_defaults(fn=cmd_metrics_smoke)

    args = ap.parse_args()
    return args.fn(args)


if __name__ == "__main__":
    sys.exit(main())
