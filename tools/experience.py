"""Inspect / maintain an on-disk ExperienceStore (the experience plane's
persistent cross-run state — see src/repro/core/experience.py).

    PYTHONPATH=src python tools/experience.py inspect --dir <store>
    PYTHONPATH=src python tools/experience.py prune   --dir <store> \
        [--min-samples N] [--max-age-days D]
    PYTHONPATH=src python tools/experience.py export  --dir <store> \
        --out bundle.json
    PYTHONPATH=src python tools/experience.py import  --dir <store> \
        --bundle bundle.json

`inspect` prints one row per fingerprint (samples, iterations, stall
share, measured peak, cached plans with their certified peaks, last
update).  `prune` drops stale / low-sample entries.  `export`/`import`
move a store between machines of the same device class as one JSON
bundle (imports merge under the store's usual last-writer-wins /
monotonic-sample rules, so importing an older bundle never regresses a
newer store).
"""
from __future__ import annotations

import argparse
import datetime
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "src"))

from repro.core.experience import ExperienceStore  # noqa: E402


def _fmt_bytes(n: float) -> str:
    for unit in ("B", "KiB", "MiB", "GiB"):
        if n < 1024 or unit == "GiB":
            return f"{n:.1f}{unit}"
        n /= 1024
    return f"{n:.1f}GiB"


def _fmt_when(ts: float) -> str:
    if ts <= 0:
        return "—"
    return datetime.datetime.fromtimestamp(ts).strftime("%Y-%m-%d %H:%M")


def cmd_inspect(store: ExperienceStore, args: argparse.Namespace) -> int:
    rows = []
    for fp, entry in store.entries():
        ts = entry.telemetry
        plans = sorted(entry.plans.values(), key=lambda r: r.peak_bytes)
        rows.append((
            fp[:12],
            str(ts.samples if ts else 0),
            str(ts.iterations if ts else 0),
            f"{ts.stall_share:.3f}" if ts else "—",
            _fmt_bytes(ts.peak_bytes) if ts else "—",
            str(len(plans)),
            (f"{plans[0].pipeline}@{plans[0].bucket}:"
             f"{_fmt_bytes(plans[0].peak_bytes)}" if plans else "—"),
            _fmt_when(entry.updated_at),
        ))
    header = ("fingerprint", "samples", "iters", "stall", "peak",
              "plans", "best plan", "updated")
    widths = [max(len(h), *(len(r[i]) for r in rows)) if rows else len(h)
              for i, h in enumerate(header)]
    def line(cells):
        return "  ".join(c.ljust(w) for c, w in zip(cells, widths))
    print(line(header))
    print(line(["-" * w for w in widths]))
    for r in rows:
        print(line(r))
    dev = store.device_record()
    if dev is not None and dev.calibration is not None:
        c = dev.calibration
        print(f"\ndevice calibration: flops={c.flops:.3g} "
              f"mem_bw={c.mem_bw:.3g} (samples={c.samples}, "
              f"updated {_fmt_when(c.updated_at)})")
    for path in ("full", "compressed"):
        bw = store.bandwidth(compressed=(path == "compressed"))
        if bw:
            print(f"device DMA bandwidth ({path}): {_fmt_bytes(bw)}/s")
    if not rows:
        print(f"\n(no entries under {store.dir})")
    return 0


def cmd_prune(store: ExperienceStore, args: argparse.Namespace) -> int:
    dropped = store.prune(min_samples=args.min_samples,
                          max_age_days=args.max_age_days)
    for fp in dropped:
        print(f"pruned {fp[:12]}")
    print(f"{len(dropped)} entries pruned, "
          f"{len(store.fingerprints())} kept")
    return 0


def cmd_export(store: ExperienceStore, args: argparse.Namespace) -> int:
    bundle = store.export_bundle()
    with open(args.out, "w", encoding="utf-8") as f:
        json.dump(bundle, f, indent=1, sort_keys=True)
    print(f"exported {len(bundle['entries'])} entries to {args.out}")
    return 0


def cmd_import(store: ExperienceStore, args: argparse.Namespace) -> int:
    with open(args.bundle, "r", encoding="utf-8") as f:
        bundle = json.load(f)
    n = store.import_bundle(bundle)
    if n == 0 and bundle.get("schema") != store.SCHEMA:
        print(f"schema mismatch: bundle v{bundle.get('schema')} vs "
              f"store v{store.SCHEMA}; nothing imported")
        return 1
    print(f"imported {n} entries into {store.dir}")
    return 0


def main(argv=None) -> int:
    common = argparse.ArgumentParser(add_help=False)
    common.add_argument("--dir", required=True,
                        help="store root (the directory holding v<N>/)")
    common.add_argument("--device", default="default",
                        help="device identity the store is keyed by")
    ap = argparse.ArgumentParser(
        description="inspect / maintain a TENSILE experience store")
    sub = ap.add_subparsers(dest="cmd", required=True)
    sub.add_parser("inspect", parents=[common],
                   help="per-fingerprint summary table")
    p_prune = sub.add_parser("prune", parents=[common],
                             help="drop stale/low-sample entries")
    p_prune.add_argument("--min-samples", type=int, default=1,
                         help="drop entries with fewer op samples")
    p_prune.add_argument("--max-age-days", type=float, default=None,
                         help="drop entries older than this many days")
    p_exp = sub.add_parser("export", parents=[common],
                           help="write the store as one bundle")
    p_exp.add_argument("--out", required=True)
    p_imp = sub.add_parser("import", parents=[common],
                           help="merge a bundle into the store")
    p_imp.add_argument("--bundle", required=True)
    args = ap.parse_args(argv)

    store = ExperienceStore(args.dir, device_id=args.device)
    return {"inspect": cmd_inspect, "prune": cmd_prune,
            "export": cmd_export, "import": cmd_import}[args.cmd](store,
                                                                  args)


if __name__ == "__main__":
    sys.exit(main())
