"""CI perf-trajectory gate: diff the scenario bench metrics against the
committed baseline and fail on regressions.

``python -m benchmarks.run --only scenarios --smoke`` distills its gate
metrics (global peak, time-to-within-budget, EOR, OOM count per
scenario/policy) into ``experiments/results/BENCH_scenarios.json``; this
tool compares that file against the committed baseline
``benchmarks/BENCH_scenarios.json`` and exits non-zero when

  * a global peak regresses by more than 10 %, or
  * an overhead metric (EOR, time-to-within-budget in burst-job
    iterations, or the telemetry plane's post-recalibration cost-model
    error ``calib_err``) regresses by more than 25 %, or
  * a scenario that was OOM-free gains OOM events, or
  * a scenario/policy row disappears from the current run, or
  * the cold-vs-warm dominance contract breaks on the CURRENT run (warm
    boot must hit the plan cache, stay within budget with zero OOMs from
    its first iteration, and start at or below the cold run's converged
    calibration error — see ``cold_warm_contract``).

Improvements and new rows never fail — they are reported and can be
pinned with ``--update``, which copies the current metrics over the
committed baseline.  Metrics are deterministic (the simulator runs in
virtual time from roofline-predicted latencies), so the thresholds guard
against real planning/engine regressions, not machine noise.

    PYTHONPATH=src python tools/check_bench_regression.py
    PYTHONPATH=src python tools/check_bench_regression.py --update
"""
from __future__ import annotations

import argparse
import json
import os
import shutil
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
BASELINE = os.path.join(ROOT, "benchmarks", "BENCH_scenarios.json")
CURRENT = os.path.join(ROOT, "experiments", "results",
                       "BENCH_scenarios.json")

PEAK_TOLERANCE = 0.10        # >10 % peak growth fails
OVERHEAD_TOLERANCE = 0.25    # >25 % EOR / time-to-within-budget growth fails
# overhead ratios near zero would make the relative test hair-trigger; a
# regression below this absolute floor is ignored
OVERHEAD_FLOOR = 0.05


def _rel_increase(base: float, cur: float, floor: float) -> float:
    if cur <= base:
        return 0.0
    return (cur - base) / max(abs(base), floor)


def compare(baseline: dict, current: dict) -> list:
    failures = []
    for key in sorted(baseline):
        if key == "_meta":
            continue
        base = baseline[key]
        cur = current.get(key)
        if cur is None:
            failures.append(f"{key}: missing from the current run "
                            "(scenario or policy removed?)")
            continue
        # ---- peak ----------------------------------------------------
        b_peak, c_peak = base.get("peak") or 0, cur.get("peak") or 0
        if b_peak and c_peak > b_peak * (1 + PEAK_TOLERANCE):
            failures.append(
                f"{key}: peak regressed {b_peak} -> {c_peak} "
                f"(+{(c_peak - b_peak) / b_peak:.1%}, limit "
                f"{PEAK_TOLERANCE:.0%})")
        # ---- overhead metrics ---------------------------------------
        # calib_err is the measured-telemetry plane's post-recalibration
        # cost-model error: a >25 % regression means the hub→calibration
        # feedback loop degraded
        for metric in ("EOR", "ttwb_burst_iters", "calib_err"):
            b, c = base.get(metric), cur.get(metric)
            if b is None or c is None:
                continue
            inc = _rel_increase(b, c, OVERHEAD_FLOOR)
            if inc > OVERHEAD_TOLERANCE and c - b > OVERHEAD_FLOOR:
                failures.append(
                    f"{key}: {metric} regressed {b:.4f} -> {c:.4f} "
                    f"(+{inc:.1%}, limit {OVERHEAD_TOLERANCE:.0%})")
        # ---- OOM-free scenarios must stay OOM-free -------------------
        b_oom, c_oom = base.get("oom_events"), cur.get("oom_events")
        if b_oom == 0 and (c_oom or 0) > 0:
            failures.append(f"{key}: was OOM-free, now {c_oom} OOM events")
        # ---- a recovering scenario must keep recovering --------------
        # (ttwb_recovered False == the run ENDED over budget; its ttwb is
        # null, so the relative test above cannot see the regression)
        if base.get("ttwb_recovered") is True \
                and cur.get("ttwb_recovered") is False:
            failures.append(f"{key}: used to return within budget, now "
                            "never recovers after the burst")
    return failures


def cold_warm_contract(current: dict) -> list:
    """The experience plane's warm-boot dominance contract, enforced on
    the CURRENT run (not just relative to the baseline): a warm boot
    must start at or below the cold run's CONVERGED calibration error,
    run its verified cached plan within budget from the first iteration
    with zero OOMs, and actually hit the plan cache.  Absent rows (a
    pre-experience baseline or a run without the scenario) check
    nothing."""
    cold = current.get("cold-vs-warm/cold")
    warm = current.get("cold-vs-warm/warm")
    if not cold or not warm:
        return []
    failures = []
    wf, cc = warm.get("calib_err_first"), cold.get("calib_err")
    if wf is not None and cc is not None and wf > cc + 1e-9:
        failures.append(
            f"cold-vs-warm: warm first-iteration calib_err {wf:.6f} "
            f"exceeds the cold run's converged {cc:.6f} — warm boot no "
            "longer dominates cold calibration")
    if warm.get("plan_cache_hit") is False:
        failures.append("cold-vs-warm: warm run missed the plan cache "
                        "(lookup or re-verification broke)")
    if warm.get("first_iter_within_budget") is False:
        failures.append("cold-vs-warm: warm run's cached-plan first "
                        "iteration exceeded the device budget")
    if (warm.get("oom_events") or 0) > 0:
        failures.append(f"cold-vs-warm: warm run produced "
                        f"{warm['oom_events']} ledger OOM events")
    return failures


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--update", action="store_true",
                    help="re-pin benchmarks/BENCH_scenarios.json from the "
                         "current run instead of diffing")
    ap.add_argument("--baseline", default=BASELINE)
    ap.add_argument("--current", default=CURRENT)
    args = ap.parse_args()

    if not os.path.exists(args.current):
        print(f"current metrics not found at {args.current}; run\n"
              "    python -m benchmarks.run --only scenarios --smoke\n"
              "first.")
        return 2

    with open(args.current) as f:
        current = json.load(f)
    baseline = None
    if os.path.exists(args.baseline):
        with open(args.baseline) as f:
            baseline = json.load(f)

    # smoke and full-size metrics are different universes; refuse to diff
    # or re-pin across the two (run the variant the baseline was pinned
    # from — CI uses --smoke)
    if baseline is not None:
        b_smoke = baseline.get("_meta", {}).get("smoke")
        c_smoke = current.get("_meta", {}).get("smoke")
        if b_smoke is not None and c_smoke is not None \
                and b_smoke != c_smoke:
            want = "--smoke" if b_smoke else "no --smoke"
            print(f"variant mismatch: baseline was pinned from a "
                  f"{'smoke' if b_smoke else 'full-size'} run, current is "
                  f"{'smoke' if c_smoke else 'full-size'}; rerun the "
                  f"scenarios bench with {want} (or re-pin deliberately "
                  "by deleting the baseline first).")
            return 2

    if args.update:
        shutil.copyfile(args.current, args.baseline)
        print(f"re-pinned {args.baseline}")
        return 0

    if baseline is None:
        print(f"no committed baseline at {args.baseline}; pin one with "
              "--update")
        return 2

    failures = compare(baseline, current) + cold_warm_contract(current)
    new_rows = sorted(set(current) - set(baseline) - {"_meta"})
    if new_rows:
        print(f"note: {len(new_rows)} new row(s) not in the baseline "
              f"(pin with --update): {', '.join(new_rows)}")
    if failures:
        print(f"\nBENCH REGRESSION: {len(failures)} failure(s)")
        for fmsg in failures:
            print("  " + fmsg)
        print("\nIf the change is intentional, re-pin with: "
              "PYTHONPATH=src python tools/check_bench_regression.py "
              "--update")
        return 1
    n_rows = len([k for k in baseline if k != "_meta"])
    print(f"bench OK: {n_rows} rows within tolerance "
          f"(peak +{PEAK_TOLERANCE:.0%}, overhead +{OVERHEAD_TOLERANCE:.0%})")
    return 0


if __name__ == "__main__":
    sys.exit(main())
