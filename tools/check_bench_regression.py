"""CI perf-trajectory gate: diff the scenario bench metrics against the
committed baseline and fail on regressions.

``python -m benchmarks.run --only scenarios --smoke`` distills its gate
metrics (global peak, time-to-within-budget, EOR, OOM count per
scenario/policy) into ``experiments/results/BENCH_scenarios.json``; this
tool compares that file against the committed baseline
``benchmarks/BENCH_scenarios.json`` and exits non-zero when

  * a global peak regresses by more than 10 %, or
  * an overhead metric (EOR, time-to-within-budget in burst-job
    iterations, or the telemetry plane's post-recalibration cost-model
    error ``calib_err``) regresses by more than 25 %, or
  * a scenario that was OOM-free gains OOM events, or
  * a scenario/policy row disappears from the current run, or
  * the cold-vs-warm dominance contract breaks on the CURRENT run (warm
    boot must hit the plan cache, stay within budget with zero OOMs from
    its first iteration, and start at or below the cold run's converged
    calibration error — see ``cold_warm_contract``), or
  * the service plane's admission contract breaks on the CURRENT run
    (under overload the admitted set's reservations never exceed
    capacity, the admission-gated run stays OOM-free and within budget,
    and warm-fingerprint peak predictions stay within +-15 % of the
    measured per-job peaks — see ``admission_contract``; queue-wait
    growth >25 % is gated like the other overhead metrics), or
  * the serving plane's pressure contract breaks on the CURRENT run
    (under a KV-cache budget the residency-scheduled decode stays
    OOM-free with outputs bit-identical to the unpressured golden run,
    finite p99 TTFT, and tokens/sec within a fixed band of the
    unpressured run, while the unscheduled baseline keeps OOMing — see
    ``serving_contract``).

The tool also gates the planner latency trajectory: ``python -m
benchmarks.run --only planner --smoke`` writes
``experiments/results/BENCH_planner.json`` (cold plan / incremental
replan / warm boot wall-time per op-count, see
``benchmarks/planner_bench.py``) and this tool diffs it against
``benchmarks/BENCH_planner.json``:

  * a per-(size, mode) row's ``ms`` regressing by more than 25 % fails
    (rows under a 1 ms absolute floor are exempt from the relative test
    — sub-millisecond timings cannot regress meaningfully by
    percentage, only past the floor), and
  * the hard latency contract on the CURRENT run: at the 10k-op row an
    incremental replan must be at least 10x faster than a cold plan,
    under 5 ms in the smoke environment, and warm boot must actually
    adopt the cached plan (see ``planner_contract``).

Unlike the scenario metrics, planner rows are wall-clock, so min-of-N
timing plus the 25 % + 1 ms slack absorbs scheduler noise.

The third gate is the runtime data path: ``python -m benchmarks.run
--only runtime --smoke`` writes
``experiments/results/BENCH_runtime.json`` (blocking vs double-buffered
executor swaps, per-block vs batched KV-block restore, the serving
pressure scenario with the batched transfer path — see
``benchmarks/runtime_bench.py``) and this tool diffs it against
``benchmarks/BENCH_runtime.json``:

  * a wall-clock row's ``ms`` regressing by more than 25 % past the 1 ms
    floor fails, a ``tokens_per_s`` row decaying by more than 25 %
    fails, and an OOM-free row gaining OOM events fails, and
  * the hard runtime contract on the CURRENT run: the batched KV restore
    must be at least 3x faster than the per-block path, and the batched
    pressure serving run must hold >=92 % of the unpressured tokens/sec
    with zero OOM events and decode outputs bit-identical to the golden
    run (see ``runtime_contract``).

Improvements and new rows never fail — they are reported and can be
pinned with ``--update``, which copies the current metrics over the
committed baselines.  Scenario metrics are deterministic (the simulator
runs in virtual time from roofline-predicted latencies), so their
thresholds guard against real planning/engine regressions, not machine
noise.

    PYTHONPATH=src python tools/check_bench_regression.py
    PYTHONPATH=src python tools/check_bench_regression.py --update
"""
from __future__ import annotations

import argparse
import json
import os
import shutil
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
BASELINE = os.path.join(ROOT, "benchmarks", "BENCH_scenarios.json")
CURRENT = os.path.join(ROOT, "experiments", "results",
                       "BENCH_scenarios.json")
PLANNER_BASELINE = os.path.join(ROOT, "benchmarks", "BENCH_planner.json")
PLANNER_CURRENT = os.path.join(ROOT, "experiments", "results",
                               "BENCH_planner.json")
RUNTIME_BASELINE = os.path.join(ROOT, "benchmarks", "BENCH_runtime.json")
RUNTIME_CURRENT = os.path.join(ROOT, "experiments", "results",
                               "BENCH_runtime.json")

PEAK_TOLERANCE = 0.10        # >10 % peak growth fails
OVERHEAD_TOLERANCE = 0.25    # >25 % EOR / time-to-within-budget growth fails
# overhead ratios near zero would make the relative test hair-trigger; a
# regression below this absolute floor is ignored
OVERHEAD_FLOOR = 0.05

LATENCY_TOLERANCE = 0.25     # >25 % planner wall-time growth fails
# wall-clock rows faster than this can't regress meaningfully by
# percentage; only crossing the floor counts
LATENCY_FLOOR_MS = 1.0
# the 10k-op latency contract (ISSUE 6): incremental replan >=10x
# faster than cold plan, and <5 ms in the smoke environment
CONTRACT_OPS = 10000
CONTRACT_SPEEDUP = 10.0
CONTRACT_SMOKE_MS = 5.0


def _rel_increase(base: float, cur: float, floor: float) -> float:
    if cur <= base:
        return 0.0
    return (cur - base) / max(abs(base), floor)


def compare(baseline: dict, current: dict) -> list:
    failures = []
    for key in sorted(baseline):
        if key == "_meta":
            continue
        base = baseline[key]
        cur = current.get(key)
        if cur is None:
            failures.append(f"{key}: missing from the current run "
                            "(scenario or policy removed?)")
            continue
        # ---- peak ----------------------------------------------------
        b_peak, c_peak = base.get("peak") or 0, cur.get("peak") or 0
        if b_peak and c_peak > b_peak * (1 + PEAK_TOLERANCE):
            failures.append(
                f"{key}: peak regressed {b_peak} -> {c_peak} "
                f"(+{(c_peak - b_peak) / b_peak:.1%}, limit "
                f"{PEAK_TOLERANCE:.0%})")
        # ---- overhead metrics ---------------------------------------
        # calib_err is the measured-telemetry plane's post-recalibration
        # cost-model error: a >25 % regression means the hub→calibration
        # feedback loop degraded
        # queue_wait_mean_iters is the overload scenario's admission-delay
        # trajectory: jobs waiting >25 % longer than the baseline means
        # the admission policy (or the predictions feeding it) regressed
        for metric in ("EOR", "ttwb_burst_iters", "calib_err",
                       "queue_wait_mean_iters"):
            b, c = base.get(metric), cur.get(metric)
            if b is None or c is None:
                continue
            inc = _rel_increase(b, c, OVERHEAD_FLOOR)
            if inc > OVERHEAD_TOLERANCE and c - b > OVERHEAD_FLOOR:
                failures.append(
                    f"{key}: {metric} regressed {b:.4f} -> {c:.4f} "
                    f"(+{inc:.1%}, limit {OVERHEAD_TOLERANCE:.0%})")
        # ---- OOM-free scenarios must stay OOM-free -------------------
        b_oom, c_oom = base.get("oom_events"), cur.get("oom_events")
        if b_oom == 0 and (c_oom or 0) > 0:
            failures.append(f"{key}: was OOM-free, now {c_oom} OOM events")
        # ---- a recovering scenario must keep recovering --------------
        # (ttwb_recovered False == the run ENDED over budget; its ttwb is
        # null, so the relative test above cannot see the regression)
        if base.get("ttwb_recovered") is True \
                and cur.get("ttwb_recovered") is False:
            failures.append(f"{key}: used to return within budget, now "
                            "never recovers after the burst")
    return failures


def cold_warm_contract(current: dict) -> list:
    """The experience plane's warm-boot dominance contract, enforced on
    the CURRENT run (not just relative to the baseline): a warm boot
    must start at or below the cold run's CONVERGED calibration error,
    run its verified cached plan within budget from the first iteration
    with zero OOMs, and actually hit the plan cache.  Absent rows (a
    pre-experience baseline or a run without the scenario) check
    nothing."""
    cold = current.get("cold-vs-warm/cold")
    warm = current.get("cold-vs-warm/warm")
    if not cold or not warm:
        return []
    failures = []
    wf, cc = warm.get("calib_err_first"), cold.get("calib_err")
    if wf is not None and cc is not None and wf > cc + 1e-9:
        failures.append(
            f"cold-vs-warm: warm first-iteration calib_err {wf:.6f} "
            f"exceeds the cold run's converged {cc:.6f} — warm boot no "
            "longer dominates cold calibration")
    if warm.get("plan_cache_hit") is False:
        failures.append("cold-vs-warm: warm run missed the plan cache "
                        "(lookup or re-verification broke)")
    if warm.get("first_iter_within_budget") is False:
        failures.append("cold-vs-warm: warm run's cached-plan first "
                        "iteration exceeded the device budget")
    if (warm.get("oom_events") or 0) > 0:
        failures.append(f"cold-vs-warm: warm run produced "
                        f"{warm['oom_events']} ledger OOM events")
    return failures


# warm-fingerprint admission predictions must stay within this relative
# error of the measured per-job peak (the ISSUE-7 precision contract)
ADMISSION_PRECISION = 0.15


def admission_contract(current: dict) -> list:
    """The service plane's admission contract, enforced on the CURRENT
    run: under the overload scenario the admitted set's reservations
    never exceed the device capacity, the admission-gated run is
    OOM-free and within budget (while demand exceeds capacity by
    construction), and warm-fingerprint predictions stay within
    +-15 % of the measured per-job peaks.  Absent rows check nothing
    (pre-service baselines)."""
    adm = current.get("overload/admission")
    if not adm:
        return []
    failures = []
    if (adm.get("oom_events") or 0) > 0:
        failures.append(f"overload/admission: {adm['oom_events']} ledger "
                        "OOM events — admission control no longer "
                        "protects the device")
    if adm.get("within_budget") is False:
        failures.append("overload/admission: global peak exceeded the "
                        "device capacity despite admission control")
    if (adm.get("admitted_over_capacity") or 0) > 0:
        failures.append("overload/admission: the admitted set's "
                        "reservations exceeded the admission capacity "
                        "(the reservation-ledger invariant broke)")
    err = adm.get("admission_max_abs_err")
    if err is not None and err > ADMISSION_PRECISION:
        failures.append(
            f"overload/admission: warm-fingerprint peak prediction off by "
            f"{err:.1%} (limit {ADMISSION_PRECISION:.0%}) — the "
            "experience-store prior degraded")
    return failures


# the pressured run's tokens/sec must stay within this relative band of
# the unpressured reference (residency scheduling pays with bounded,
# overlappable DMA stalls, not throughput collapse)
SERVING_TPS_BAND = 0.50


def serving_contract(current: dict) -> list:
    """The serving plane's pressure contract, enforced on the CURRENT
    run: under a KV-cache budget the residency-scheduled decode stays
    OOM-free and within budget, its outputs are bit-identical to the
    unpressured golden run, every admitted request gets a finite p99
    TTFT, and tokens/sec stays within a fixed band of the unpressured
    run — while the same budget without scheduling keeps OOMing (the
    pressure is real).  Absent rows check nothing (pre-serving
    baselines)."""
    sched = current.get("serving-pressure/kv-schedule")
    ref = current.get("serving-pressure/unpressured")
    base = current.get("serving-pressure/no-schedule")
    if not sched or not ref:
        return []
    failures = []
    if (sched.get("oom_events") or 0) > 0:
        failures.append(f"serving-pressure/kv-schedule: "
                        f"{sched['oom_events']} ledger OOM events — "
                        "residency scheduling no longer protects the "
                        "device under KV pressure")
    if sched.get("within_budget") is False:
        failures.append("serving-pressure/kv-schedule: KV peak exceeded "
                        "the device budget despite residency scheduling")
    if sched.get("decode_bit_identical") is False:
        failures.append("serving-pressure/kv-schedule: decode outputs "
                        "diverged from the unpressured run — KV "
                        "swap-out/prefetch corrupted the cache")
    if sched.get("ttft_p99") is None:
        failures.append("serving-pressure/kv-schedule: p99 TTFT is not "
                        "finite (requests starved in the prefill "
                        "admission queue)")
    tps_s, tps_r = sched.get("tokens_per_s"), ref.get("tokens_per_s")
    if tps_s is not None and tps_r \
            and tps_s < tps_r * (1.0 - SERVING_TPS_BAND):
        failures.append(
            f"serving-pressure/kv-schedule: tokens/sec {tps_s:.1f} fell "
            f"below {1.0 - SERVING_TPS_BAND:.0%} of the unpressured "
            f"{tps_r:.1f} — residency stalls dominate decode")
    if base is not None and (base.get("oom_events") or 0) == 0:
        failures.append("serving-pressure/no-schedule: the unscheduled "
                        "baseline no longer OOMs — the scenario's budget "
                        "stopped exerting pressure, so the kv-schedule "
                        "rows prove nothing")
    return failures


# the sim-vs-measured drift bounds (ISSUE 10): the engine parity
# guarantee makes predicted-vs-measured peak a hard near-equality, and
# modeled-vs-measured safe-point placement must stay substantially
# overlapping (1 - Jaccard over op indices)
DRIFT_PEAK_LIMIT = 0.10
DRIFT_SP_LIMIT = 0.50


def drift_contract(current: dict) -> list:
    """The observability plane's sim-vs-measured accuracy contract,
    enforced on the CURRENT run: the same captured job + plan run on the
    virtual-time simulator and on the real executor must agree on peak
    bytes to within ``DRIFT_PEAK_LIMIT`` (the engine parity guarantee,
    continuously gated), modeled safe-point placement must stay within
    ``DRIFT_SP_LIMIT`` of the telemetry-measured set, and the drift
    sample must actually persist into the ExperienceStore history
    (``history_len >= 1``).  EOR drift is recorded but not bounded — a
    virtual-time overhead ratio and a wall-clock one measure different
    machines.  Absent rows check nothing (pre-observability
    baselines)."""
    row = current.get("sim-vs-measured/drift")
    if not row:
        return []
    failures = []
    pd = row.get("peak_drift")
    if pd is not None and pd > DRIFT_PEAK_LIMIT:
        failures.append(
            f"drift contract: sim-predicted peak off the measured peak by "
            f"{pd:.1%} (limit {DRIFT_PEAK_LIMIT:.0%}) — the engine parity "
            "guarantee degraded "
            f"(predicted {row.get('predicted_peak')}, "
            f"measured {row.get('peak')})")
    sp = row.get("sp_drift")
    if sp is not None and sp > DRIFT_SP_LIMIT:
        failures.append(
            f"drift contract: modeled vs measured safe-point placement "
            f"disagrees by {sp:.1%} (1 - Jaccard, limit "
            f"{DRIFT_SP_LIMIT:.0%}) — preemptive splice points no longer "
            "land where the measured plane says they are")
    hl = row.get("history_len")
    if hl is not None and hl < 1:
        failures.append(
            "drift contract: the drift sample did not persist into the "
            "ExperienceStore history (record_drift/flush round-trip "
            "broke)")
    return failures


def scenario_contracts(current: dict) -> list:
    return (cold_warm_contract(current) + admission_contract(current)
            + serving_contract(current) + drift_contract(current))


def compare_planner(baseline: dict, current: dict) -> list:
    """Per-(size, mode) planner wall-time diff: fail when a row's ``ms``
    grows by more than 25 % AND crosses the 1 ms absolute floor.  A row
    disappearing from the current run fails too (an op-count tier or
    bench mode was dropped)."""
    failures = []
    for key in sorted(baseline):
        if key == "_meta":
            continue
        base = baseline[key]
        cur = current.get(key)
        if cur is None:
            failures.append(f"planner {key}: missing from the current run "
                            "(size or mode removed?)")
            continue
        # warm boot falling back to cold convergence is a functional
        # regression even if it happens to be fast
        if base.get("adopted") is True and cur.get("adopted") is False:
            failures.append(f"planner {key}: warm boot no longer adopts "
                            "the cached plan")
        b, c = base.get("ms"), cur.get("ms")
        if b is None or c is None:
            continue
        if c <= max(b, LATENCY_FLOOR_MS):
            continue
        inc = (c - b) / max(b, LATENCY_FLOOR_MS)
        if inc > LATENCY_TOLERANCE:
            failures.append(
                f"planner {key}: latency regressed {b:.3f} ms -> "
                f"{c:.3f} ms (+{inc:.1%}, limit {LATENCY_TOLERANCE:.0%}, "
                f"floor {LATENCY_FLOOR_MS:g} ms)")
    return failures


def planner_contract(current: dict) -> list:
    """The ISSUE-6 latency contract, enforced on the CURRENT run: at the
    10k-op row an incremental replan must be >=10x faster than a cold
    plan, under 5 ms when the run is a smoke variant, and the warm-boot
    row must actually adopt its cached plan."""
    failures = []
    cold = current.get(f"{CONTRACT_OPS}/cold_plan")
    inc = current.get(f"{CONTRACT_OPS}/incremental_replan")
    if cold is None or inc is None:
        failures.append(
            f"planner contract: the {CONTRACT_OPS}-op cold_plan/"
            "incremental_replan rows are missing — the contract size "
            "must stay in every bench variant")
        return failures
    c_ms, i_ms = cold.get("ms"), inc.get("ms")
    if c_ms and i_ms and i_ms * CONTRACT_SPEEDUP > c_ms:
        failures.append(
            f"planner contract: incremental replan at {CONTRACT_OPS} ops "
            f"is only {c_ms / i_ms:.1f}x faster than a cold plan "
            f"({i_ms:.3f} ms vs {c_ms:.3f} ms, need "
            f">={CONTRACT_SPEEDUP:g}x)")
    if current.get("_meta", {}).get("smoke") and i_ms is not None \
            and i_ms > CONTRACT_SMOKE_MS:
        failures.append(
            f"planner contract: incremental replan at {CONTRACT_OPS} ops "
            f"took {i_ms:.3f} ms (smoke limit {CONTRACT_SMOKE_MS:g} ms)")
    for key, row in sorted(current.items()):
        if key.endswith("/warm_boot") and row.get("adopted") is False:
            failures.append(f"planner contract: {key} did not adopt the "
                            "cached plan (warm boot fell back to cold "
                            "convergence)")
    return failures


# the ISSUE-9 runtime data-path contract: batched KV restore speedup
# floor, and the batched pressure run's tokens/sec band vs unpressured
# (tightened from the scenarios suite's coarse 50 % band — the batched
# transfer path is what makes the tighter band holdable)
RUNTIME_KV_SPEEDUP = 3.0
RUNTIME_TPS_BAND = 0.92


def compare_runtime(baseline: dict, current: dict) -> list:
    """Runtime data-path diff: wall-clock ``ms`` rows get the planner
    gate's 25 % + 1 ms floor treatment, ``tokens_per_s`` rows fail on a
    >25 % decay, and an OOM-free row gaining OOM events fails."""
    failures = []
    for key in sorted(baseline):
        if key == "_meta":
            continue
        base = baseline[key]
        cur = current.get(key)
        if cur is None:
            failures.append(f"runtime {key}: missing from the current run "
                            "(bench row removed?)")
            continue
        b, c = base.get("ms"), cur.get("ms")
        if b is not None and c is not None \
                and c > max(b, LATENCY_FLOOR_MS):
            inc = (c - b) / max(b, LATENCY_FLOOR_MS)
            if inc > LATENCY_TOLERANCE:
                failures.append(
                    f"runtime {key}: latency regressed {b:.3f} ms -> "
                    f"{c:.3f} ms (+{inc:.1%}, limit "
                    f"{LATENCY_TOLERANCE:.0%}, floor "
                    f"{LATENCY_FLOOR_MS:g} ms)")
        b_tps, c_tps = base.get("tokens_per_s"), cur.get("tokens_per_s")
        if b_tps and c_tps is not None \
                and c_tps < b_tps * (1 - LATENCY_TOLERANCE):
            failures.append(
                f"runtime {key}: tokens/sec decayed {b_tps:.1f} -> "
                f"{c_tps:.1f} (-{1 - c_tps / b_tps:.1%}, limit "
                f"{LATENCY_TOLERANCE:.0%})")
        b_oom, c_oom = base.get("oom_events"), cur.get("oom_events")
        if b_oom == 0 and (c_oom or 0) > 0:
            failures.append(f"runtime {key}: was OOM-free, now {c_oom} "
                            "OOM events")
    return failures


def runtime_contract(current: dict) -> list:
    """The runtime data-path contract, enforced on the CURRENT run: the
    batched KV-block restore must beat the per-block path by the speedup
    floor, and the batched pressure serving run must stay OOM-free,
    bit-identical, and inside the tokens/sec band of the unpressured
    run.  Absent rows check nothing (pre-runtime baselines)."""
    failures = []
    kv = current.get("kv_restore/batched")
    if kv is not None:
        sp = kv.get("speedup")
        if sp is not None and sp < RUNTIME_KV_SPEEDUP:
            failures.append(
                f"runtime contract: batched KV restore only {sp:.2f}x the "
                f"per-block path (need >={RUNTIME_KV_SPEEDUP:g}x) — the "
                "batched gather/scatter launch stopped paying")
    bat = current.get("serving/pressure_batched")
    ref = current.get("serving/unpressured")
    if not bat or not ref:
        return failures
    if (bat.get("oom_events") or 0) > 0:
        failures.append(f"runtime contract: serving/pressure_batched hit "
                        f"{bat['oom_events']} OOM events — the batched "
                        "transfer path broke residency protection")
    if bat.get("decode_bit_identical") is False:
        failures.append("runtime contract: serving/pressure_batched decode "
                        "outputs diverged from the unpressured golden run "
                        "— batched KV movement corrupted the cache")
    ratio = bat.get("ratio_vs_unpressured")
    if ratio is not None and ratio < RUNTIME_TPS_BAND:
        failures.append(
            f"runtime contract: batched pressure serving at "
            f"{ratio:.1%} of unpressured tokens/sec (need "
            f">={RUNTIME_TPS_BAND:.0%}) — transfer overhead is no longer "
            "hidden behind decode compute")
    return failures


def _smoke_mismatch(baseline: dict, current: dict, bench: str) -> bool:
    # smoke and full-size metrics are different universes; refuse to diff
    # or re-pin across the two (run the variant the baseline was pinned
    # from — CI uses --smoke)
    b_smoke = baseline.get("_meta", {}).get("smoke")
    c_smoke = current.get("_meta", {}).get("smoke")
    if b_smoke is None or c_smoke is None or b_smoke == c_smoke:
        return False
    want = "--smoke" if b_smoke else "no --smoke"
    print(f"variant mismatch: the {bench} baseline was pinned from a "
          f"{'smoke' if b_smoke else 'full-size'} run, current is "
          f"{'smoke' if c_smoke else 'full-size'}; rerun the "
          f"{bench} bench with {want} (or re-pin deliberately "
          "by deleting the baseline first).")
    return True


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--update", action="store_true",
                    help="re-pin the committed baselines from the current "
                         "run instead of diffing")
    ap.add_argument("--baseline", default=BASELINE)
    ap.add_argument("--current", default=CURRENT)
    ap.add_argument("--planner-baseline", default=PLANNER_BASELINE)
    ap.add_argument("--planner-current", default=PLANNER_CURRENT)
    ap.add_argument("--runtime-baseline", default=RUNTIME_BASELINE)
    ap.add_argument("--runtime-current", default=RUNTIME_CURRENT)
    args = ap.parse_args()

    # (baseline, current, bench name, compare fn, contract fn, run hint)
    gates = [
        (args.baseline, args.current, "scenarios", compare,
         scenario_contracts, "--only scenarios --smoke"),
        (args.planner_baseline, args.planner_current, "planner",
         compare_planner, planner_contract, "--only planner --smoke"),
        (args.runtime_baseline, args.runtime_current, "runtime",
         compare_runtime, runtime_contract, "--only runtime --smoke"),
    ]

    failures: list = []
    checked = 0
    for base_path, cur_path, bench, cmp_fn, contract_fn, hint in gates:
        have_baseline = os.path.exists(base_path)
        if not os.path.exists(cur_path):
            # a current file is only required where a baseline is
            # committed (lets the tool run before a bench's first pin)
            if have_baseline and not args.update:
                print(f"current {bench} metrics not found at {cur_path}; "
                      f"run\n    python -m benchmarks.run {hint}\nfirst.")
                return 2
            continue
        with open(cur_path) as f:
            current = json.load(f)
        baseline = None
        if have_baseline:
            with open(base_path) as f:
                baseline = json.load(f)
            if _smoke_mismatch(baseline, current, bench):
                return 2

        if args.update:
            shutil.copyfile(cur_path, base_path)
            print(f"re-pinned {base_path}")
            continue

        if baseline is None:
            print(f"no committed {bench} baseline at {base_path}; pin "
                  "one with --update")
            return 2

        failures += cmp_fn(baseline, current) + contract_fn(current)
        new_rows = sorted(set(current) - set(baseline) - {"_meta"})
        if new_rows:
            print(f"note: {len(new_rows)} new {bench} row(s) not in the "
                  f"baseline (pin with --update): {', '.join(new_rows)}")
        checked += len([k for k in baseline if k != "_meta"])

    if args.update:
        return 0
    if failures:
        print(f"\nBENCH REGRESSION: {len(failures)} failure(s)")
        for fmsg in failures:
            print("  " + fmsg)
        print("\nIf the change is intentional, re-pin with: "
              "PYTHONPATH=src python tools/check_bench_regression.py "
              "--update")
        return 1
    print(f"bench OK: {checked} rows within tolerance "
          f"(peak +{PEAK_TOLERANCE:.0%}, overhead +{OVERHEAD_TOLERANCE:.0%}, "
          f"latency +{LATENCY_TOLERANCE:.0%})")
    return 0


if __name__ == "__main__":
    sys.exit(main())
