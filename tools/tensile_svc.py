"""tensile-svc — operate the scheduler-as-a-service daemon.

    PYTHONPATH=src python tools/tensile_svc.py start  --root <dir> \
        [--capacity-bytes N] [--poll-interval S]
    PYTHONPATH=src python tools/tensile_svc.py submit --root <dir> \
        --job-id j1 --workload mlp [--params '{"size": "small"}'] \
        [--iterations N] [--priority P] [--budget-hint-bytes N] [--wait]
    PYTHONPATH=src python tools/tensile_svc.py submit --root <dir> \
        --job-id s1 --kind serve [--arch tinyllama-1.1b] [--requests N] \
        [--trace steady|burst|poisson] [--prompt-len N] [--gen N] [--wait]
    PYTHONPATH=src python tools/tensile_svc.py status --root <dir> [--json]
    PYTHONPATH=src python tools/tensile_svc.py metrics --root <dir> [--parsed]
    PYTHONPATH=src python tools/tensile_svc.py drain  --root <dir> [--wait]
    PYTHONPATH=src python tools/tensile_svc.py smoke  --root <dir>

`start` runs the ``SchedulerDaemon`` event loop in the foreground until
stopped or drained.  `submit`/`status`/`drain` are thin wrappers over
``ServiceClient`` — they share only the service root directory with the
daemon (filesystem inbox + durable job store), so they work from any
process.  `smoke` is the CI end-to-end self-check: it starts a daemon
subprocess, submits three jobs over the wire, drains, then simulates a
daemon crash mid-run and asserts the restarted daemon recovers the full
queue state (QUEUED/ADMITTED replayed, the RUNNING orphan re-queued
exactly once) and runs it to completion.
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "src"))

from repro.service import (JobRecord, JobSpec, JobState,  # noqa: E402
                           JobStore, SchedulerDaemon, ServeParams,
                           ServiceClient)


def _fmt_bytes(n: float) -> str:
    for unit in ("B", "KiB", "MiB", "GiB"):
        if n < 1024 or unit == "GiB":
            return f"{n:.1f}{unit}"
        n /= 1024
    return f"{n:.1f}GiB"


def cmd_start(args: argparse.Namespace) -> int:
    daemon = SchedulerDaemon(args.root,
                             capacity_bytes=args.capacity_bytes,
                             poll_interval=args.poll_interval)
    rec = daemon.recovered
    print(f"daemon up at {args.root} (pid {os.getpid()}, capacity "
          f"{_fmt_bytes(daemon.capacity_bytes)}); recovered "
          f"{len(rec['replayed'])} queued, "
          f"{len(rec['requeued_orphans'])} re-queued orphan(s), "
          f"{len(rec['failed_orphans'])} failed orphan(s)", flush=True)
    try:
        daemon.serve_forever()
    except KeyboardInterrupt:
        daemon.stop()
    print("daemon stopped", flush=True)
    return 0


def cmd_submit(args: argparse.Namespace) -> int:
    serve = None
    if args.kind == "serve":
        serve = ServeParams(arch=args.arch, max_sequences=args.max_sequences,
                            n_requests=args.requests,
                            prompt_len=args.prompt_len, gen_len=args.gen,
                            trace=args.trace, block_tokens=args.block_tokens)
    spec = JobSpec(args.job_id, kind=args.kind, serve=serve,
                   workload=args.workload,
                   workload_params=json.loads(args.params),
                   iterations=args.iterations, priority=args.priority,
                   budget_hint_bytes=args.budget_hint_bytes)
    client = ServiceClient(args.root)
    client.submit(spec)
    print(f"submitted {spec.job_id} -> {client.inbox}")
    if args.wait:
        records = client.wait([spec.job_id], timeout=args.timeout)
        rec = records[spec.job_id]
        print(f"{rec.job_id}: {rec.state.value}"
              + (f" ({rec.error})" if rec.error else ""))
        return 0 if rec.state is JobState.DONE else 1
    return 0


def cmd_status(args: argparse.Namespace) -> int:
    client = ServiceClient(args.root)
    hb = client.heartbeat()
    if args.json:
        records = client.status()
        print(json.dumps({
            "heartbeat": hb,
            "daemon_alive": client.daemon_alive(),
            "jobs": {jid: rec.to_dict()
                     for jid, rec in sorted(records.items())},
        }, indent=1, sort_keys=True))
        return 0
    if hb:
        alive = "alive" if client.daemon_alive() else "stale"
        print(f"daemon: {hb.get('state')} ({alive}, pid {hb.get('pid')}), "
              f"reserved {_fmt_bytes(hb.get('reserved_bytes', 0))} / "
              f"{_fmt_bytes(hb.get('capacity_bytes', 0))}, "
              f"{hb.get('waiting', 0)} waiting")
    else:
        print("daemon: no heartbeat")
    records = client.status()
    if not records:
        print("no jobs")
        return 0
    for jid, rec in sorted(records.items()):
        peak = (f" measured={_fmt_bytes(rec.measured_peak_bytes)}"
                if rec.measured_peak_bytes else "")
        pred = (f" predicted={_fmt_bytes(rec.predicted_peak_bytes)}"
                f"[{rec.predicted_source}]"
                if rec.predicted_peak_bytes else "")
        err = f" error={rec.error}" if rec.error else ""
        print(f"  {jid}: {rec.state.value}{pred}{peak}"
              f" requeues={rec.requeues}{err}")
    return 0


def cmd_metrics(args: argparse.Namespace) -> int:
    """Print the daemon's Prometheus text exposition (validated)."""
    from repro.obs import parse_metrics_text

    path = os.path.join(args.root, "metrics.prom")
    if not os.path.exists(path):
        print(f"no metrics file at {path} (daemon not started?)",
              file=sys.stderr)
        return 1
    with open(path, "r", encoding="utf-8") as f:
        text = f.read()
    try:
        parsed = parse_metrics_text(text)
    except ValueError as exc:
        print(f"metrics file does not parse: {exc}", file=sys.stderr)
        return 1
    if args.parsed:
        for (name, labels), value in sorted(parsed.items()):
            lbl = ("{" + ",".join(f'{k}="{v}"' for k, v in labels) + "}"
                   if labels else "")
            print(f"{name}{lbl} {value}")
    else:
        sys.stdout.write(text)
    return 0


def cmd_drain(args: argparse.Namespace) -> int:
    client = ServiceClient(args.root)
    client.drain()
    print("drain requested")
    if args.wait:
        deadline = time.time() + args.timeout
        while client.daemon_alive() and time.time() < deadline:
            time.sleep(0.1)
        if client.daemon_alive():
            print(f"daemon still running after {args.timeout}s")
            return 1
        print("daemon drained and stopped")
    return 0


# ---------------------------------------------------------------- smoke
def _check(ok: bool, what: str) -> None:
    print(("  ok  " if ok else "  FAIL") + f" {what}")
    if not ok:
        raise SystemExit(f"service smoke failed: {what}")


def cmd_smoke(args: argparse.Namespace) -> int:
    """CI end-to-end: wire submission + drain, then crash recovery."""
    root = args.root
    os.makedirs(root, exist_ok=True)

    # -- phase A: daemon subprocess, 3 wire submissions, drain ---------
    print("phase A: daemon subprocess, 3 wire jobs, drain")
    proc = subprocess.Popen(
        [sys.executable, os.path.abspath(__file__), "start", "--root", root,
         "--poll-interval", "0.02"],
        env={**os.environ, "JAX_PLATFORMS": "cpu"})
    client = ServiceClient(root)
    try:
        deadline = time.time() + 120
        while not client.daemon_alive() and time.time() < deadline:
            time.sleep(0.1)
        _check(client.daemon_alive(), "daemon heartbeat appears")
        jobs = []
        for i in range(3):
            spec = JobSpec(f"smoke-{i}", workload="mlp",
                           workload_params={"size": "small", "seed": i},
                           iterations=2)
            jobs.append(client.submit(spec))
        client.drain()
        records = client.wait(jobs, timeout=300)
        _check(all(r.state is JobState.DONE for r in records.values()),
               "all 3 wire jobs ran to DONE "
               f"({ {j: r.state.value for j, r in records.items()} })")
        proc.wait(timeout=60)
        _check(proc.returncode == 0, "daemon exited cleanly after drain")
    finally:
        if proc.poll() is None:
            proc.kill()

    # -- phase B: simulated crash mid-run, restart, recover ------------
    # seed the SAME durable store as a crashed daemon would leave it:
    # one QUEUED, one ADMITTED, one RUNNING orphan
    print("phase B: crash recovery on the same root")
    now = time.time()
    store = JobStore(root)
    seeded = {"crash-q": JobState.QUEUED, "crash-a": JobState.ADMITTED,
              "crash-r": JobState.RUNNING}
    for jid, state in seeded.items():
        spec = JobSpec(jid, workload="mlp",
                       workload_params={"size": "small"}, iterations=1)
        store.put(JobRecord(spec=spec, state=state, submitted_at=now), now)
    daemon = SchedulerDaemon(root, poll_interval=0.02)
    rec = daemon.recovered
    _check(set(rec["replayed"]) >= {"crash-q", "crash-a"},
           f"QUEUED/ADMITTED replayed ({sorted(rec['replayed'])})")
    _check(rec["requeued_orphans"] == ["crash-r"],
           "RUNNING orphan re-queued exactly once")
    _check(daemon.store.get("crash-r").requeues == 1,
           "orphan requeue recorded")
    ok = daemon.drain(timeout=300)
    _check(ok, "restarted daemon drained the recovered queue")
    states = {jid: daemon.store.get(jid).state for jid in seeded}
    _check(all(s is JobState.DONE for s in states.values()),
           f"recovered jobs ran to DONE ({ {j: s.value for j, s in states.items()} })")
    print("service smoke OK")
    return 0


def main() -> int:
    ap = argparse.ArgumentParser(prog="tensile-svc", description=__doc__)
    sub = ap.add_subparsers(dest="cmd", required=True)

    p = sub.add_parser("start", help="run the daemon event loop")
    p.add_argument("--root", required=True)
    p.add_argument("--capacity-bytes", type=int, default=None)
    p.add_argument("--poll-interval", type=float, default=0.05)
    p.set_defaults(fn=cmd_start)

    p = sub.add_parser("submit", help="submit a JobSpec over the inbox")
    p.add_argument("--root", required=True)
    p.add_argument("--job-id", required=True)
    p.add_argument("--kind", default="train", choices=("train", "serve"))
    p.add_argument("--workload", default=None,
                   help='registered name (e.g. "mlp", "lm") or '
                        '"module:attr"; required for train jobs')
    p.add_argument("--params", default="{}",
                   help="JSON dict of workload factory kwargs")
    p.add_argument("--arch", default="tinyllama-1.1b",
                   help="serve jobs: model config name")
    p.add_argument("--max-sequences", type=int, default=4,
                   help="serve jobs: batch slots in the decode cache")
    p.add_argument("--requests", type=int, default=8,
                   help="serve jobs: requests in the arrival trace")
    p.add_argument("--prompt-len", type=int, default=8)
    p.add_argument("--gen", type=int, default=8)
    p.add_argument("--trace", default="steady",
                   help="serve jobs: arrival trace (steady|burst|poisson)")
    p.add_argument("--block-tokens", type=int, default=4)
    p.add_argument("--iterations", type=int, default=1)
    p.add_argument("--priority", type=float, default=None)
    p.add_argument("--budget-hint-bytes", type=int, default=None)
    p.add_argument("--wait", action="store_true")
    p.add_argument("--timeout", type=float, default=300.0)
    p.set_defaults(fn=cmd_submit)

    p = sub.add_parser("status", help="daemon heartbeat + job table")
    p.add_argument("--root", required=True)
    p.add_argument("--json", action="store_true",
                   help="machine-readable heartbeat + full job records")
    p.set_defaults(fn=cmd_status)

    p = sub.add_parser("metrics",
                       help="print the daemon's Prometheus exposition")
    p.add_argument("--root", required=True)
    p.add_argument("--parsed", action="store_true",
                   help="print parsed samples instead of the raw text")
    p.set_defaults(fn=cmd_metrics)

    p = sub.add_parser("drain", help="finish queued work, then stop")
    p.add_argument("--root", required=True)
    p.add_argument("--wait", action="store_true")
    p.add_argument("--timeout", type=float, default=300.0)
    p.set_defaults(fn=cmd_drain)

    p = sub.add_parser("smoke", help="CI end-to-end self-check")
    p.add_argument("--root", required=True)
    p.set_defaults(fn=cmd_smoke)

    args = ap.parse_args()
    return args.fn(args)


if __name__ == "__main__":
    sys.exit(main())
