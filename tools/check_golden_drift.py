"""Regenerate the golden seed plans and diff them against
tests/golden/seed_plans.json, byte-for-byte.

The golden file pins the plans the four original policies produced in the
pre-refactor tree; tests/test_pipeline.py asserts equality per case, but a
bare assert gives no hint WHERE a plan drifted.  This tool re-derives every
golden case (defined once, in tests/golden_cases.py — shared with the
tests, so tool and tests can never enforce different definitions) and
prints a readable unified diff of the pretty-printed JSON (event level:
type, tensor, trigger, times, sizes) for each drifted case, then exits
non-zero.

    PYTHONPATH=src python tools/check_golden_drift.py
    PYTHONPATH=src python tools/check_golden_drift.py --update   # re-pin
"""
from __future__ import annotations

import argparse
import difflib
import json
import os
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(ROOT, "src"))
sys.path.insert(0, os.path.join(ROOT, "tests"))

GOLDEN = os.path.join(ROOT, "tests", "golden", "seed_plans.json")


def _pp(obj) -> list:
    return json.dumps(obj, indent=1, sort_keys=True).splitlines(keepends=True)


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--update", action="store_true",
                    help="re-pin tests/golden/seed_plans.json from the "
                         "current tree instead of diffing")
    args = ap.parse_args()

    from golden_cases import regenerate
    # normalize through JSON the way the tests do
    current = json.loads(json.dumps(regenerate()))

    if args.update:
        with open(GOLDEN, "w") as f:
            json.dump(current, f, indent=1, sort_keys=True)
        print(f"re-pinned {GOLDEN}")
        return 0

    with open(GOLDEN) as f:
        golden = json.load(f)

    drifted = []
    for key in sorted(set(golden) | set(current)):
        got = current.get(key)
        want = golden.get(key)
        if got == want:
            continue
        drifted.append(key)
        print(f"\n=== DRIFT in {key} " + "=" * max(1, 50 - len(key)))
        diff = difflib.unified_diff(
            _pp(want), _pp(got),
            fromfile=f"golden/{key}", tofile=f"current/{key}", n=2)
        shown = 0
        for line in diff:
            sys.stdout.write(line)
            shown += 1
            if shown > 200:
                print("... (diff truncated at 200 lines)")
                break
    if drifted:
        print(f"\nGOLDEN DRIFT: {len(drifted)} case(s) changed: "
              f"{', '.join(drifted)}")
        print("If the change is intentional, re-pin with: "
              "PYTHONPATH=src python tools/check_golden_drift.py --update")
        return 1
    print(f"golden OK: {len(golden)} cases byte-identical")
    return 0


if __name__ == "__main__":
    sys.exit(main())
