"""Planner raw-speed benchmark (the BENCH_planner latency gate).

Times the three planner entry points whose latency the hot-swap control
loop actually sits on, against synthetic chain graphs of growing op count:

    cold_plan          — Pipeline.plan from an empty plan (Alg. 3
                         convergence, iteration-capped)
    incremental_replan — Pipeline.replan_from at a mid-iteration safe
                         point with an unchanged slice, steady-state (the
                         per-job WindowSweep prefix is already frozen) —
                         the latency FLOOR of an arbitration tick
    shrinking_replan   — the same safe-point replan with the slice CUT to
                         0.9x, so eager events must be scheduled on top
                         of the frozen sweep — the cost of a real
                         flash-crowd tick
    warm_boot          — Pipeline.plan adopting a verified cached plan
                         from an ExperienceStore (rebase + re-verify)

The numbers feed the CI perf-trajectory gate: ``benchmarks/run.py
--only planner`` distills them into
``experiments/results/BENCH_planner.json`` and
``tools/check_bench_regression.py`` diffs that against the committed
baseline ``benchmarks/BENCH_planner.json`` (>25 % per-row latency
regression fails, plus the hard contract that at 10k ops an incremental
replan is >=10x faster than a cold plan and, in the smoke environment,
under 5 ms).

Graphs are ``tests/helpers.synthetic_chain``-shaped (fwd chain + mirror
bwd reuse) but built locally: benchmarks run under ``PYTHONPATH=src``
and must not import the test tree.
"""
from __future__ import annotations

import json
import tempfile
import time
from typing import Dict, List

import numpy as np

from repro.core import (ExperienceStore, MachineProfile, SchedulerConfig,
                        TelemetryHub, analyze, build_pipeline,
                        find_safe_points, vanilla_peak)
from repro.core.access import (AccessSequence, Operator, TensorKind,
                               TensorSpec)

PROFILE = MachineProfile()

# op counts are 2 * n_ops (fwd + mirrored bwd); the 5000 entry is the
# 10k-op row the latency contract is written against
SMOKE_N_OPS = [500, 2000, 5000]
FULL_N_OPS = [500, 2000, 5000, 20000, 50000]   # up to ~100k operators

# convergence cap: the bench measures per-iteration planner speed, not
# how many greedy steps a 0.7x budget needs at 100k ops
MAX_ITERATIONS = 32


def chain(n_ops: int, job_id: str = "chain", seed: int = 0,
          latency: float = 1.0) -> AccessSequence:
    """Linear producer-consumer chain with backward-like reuse: act_i is
    produced by op_i and consumed by op_{i+1} and op_{2n-1-i}."""
    rng = np.random.default_rng(seed)
    sizes = (rng.integers(1, 64, n_ops) * 1024).tolist()
    tensors = {"p0": TensorSpec("p0", 8 * 1024, kind=TensorKind.PARAM,
                                job_id=job_id)}
    for i in range(n_ops):
        tensors[f"a{i}"] = TensorSpec(f"a{i}", int(sizes[i]),
                                      kind=TensorKind.ACTIVATION,
                                      job_id=job_id)
    ops = []
    for i in range(n_ops):
        ins = ([f"a{i-1}"] if i > 0 else []) + ["p0"]
        ops.append(Operator(idx=i, name=f"fwd{i}", inputs=tuple(ins),
                            outputs=(f"a{i}",), latency=latency,
                            job_id=job_id))
    for j in range(n_ops):
        i = n_ops - 1 - j
        ops.append(Operator(idx=n_ops + j, name=f"bwd{i}",
                            inputs=(f"a{i}",), outputs=(), latency=latency,
                            job_id=job_id))
    return AccessSequence(job_id, ops, tensors, initial_resident=["p0"])


def _best_ms(fn, repeats: int) -> float:
    """min-of-N wall time in ms (min, not mean: scheduling noise only
    ever adds time)."""
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best * 1e3


def _config(budget: int) -> SchedulerConfig:
    return SchedulerConfig(memory_budget_bytes=budget,
                           max_iterations=MAX_ITERATIONS)


def bench_size(n_ops: int, smoke: bool) -> Dict[str, Dict[str, float]]:
    seq = chain(n_ops)
    jid = seq.job_id
    n = len(seq.operators)
    big = n_ops > 5000
    cold_reps = 1 if big else 3
    inc_reps = 10 if big else 30

    # the budget is the peak an iteration-capped plan toward 0.7x the
    # vanilla peak actually ACHIEVES: cold planning then converges inside
    # the cap, and the certified plan passes warm-boot re-verification
    probe = build_pipeline("tensile", profile=PROFILE,
                           config=_config(int(0.7 * vanilla_peak(seq)))
                           ).plan([seq])
    budget = int(probe.final_report.peak_bytes)

    # -- cold plan ----------------------------------------------------
    pipe = build_pipeline("tensile", profile=PROFILE,
                          config=_config(budget))
    res = pipe.plan([seq])

    def cold():
        build_pipeline("tensile", profile=PROFILE,
                       config=_config(budget)).plan([seq])

    ms_cold = _best_ms(cold, cold_reps)

    # -- incremental replan (steady state) ----------------------------
    # the arbitration-tick shape: the controller calls replan_from at a
    # safe point on every arbitration decision, and most ticks leave the
    # job's slice where it was — the replan re-verifies the remainder
    # window through the frozen incremental sweep and returns an
    # adoptable copy.  This row is the latency FLOOR of every preemptive
    # replan; ticks that do shrink the slice add work proportional to
    # the eager events scheduled on top of it.
    sps = find_safe_points(seq, res.plans[jid])
    step = sps[len(sps) // 4].op_idx if sps else n // 4
    budgets = {jid: budget}
    r0 = pipe.replan_from([seq], res.plans, step, budgets)  # freeze prefix
    added = r0.plans[jid].provenance[-1]["added_events"]

    def incremental():
        pipe.replan_from([seq], res.plans, step, budgets)

    ms_inc = _best_ms(incremental, inc_reps)

    # -- shrinking replan (the slice is cut at the tick) ---------------
    # the expensive half of a preemptive arbitration tick: the job's
    # slice shrinks at the safe point, so the replan schedules eager
    # evictions on top of the frozen prefix sweep; its latency bounds a
    # real flash-crowd tick end to end
    shrunk = {jid: int(budget * 0.9)}
    rs = pipe.replan_from([seq], res.plans, step, shrunk)
    added_shrink = rs.plans[jid].provenance[-1]["added_events"]

    def shrinking():
        pipe.replan_from([seq], res.plans, step, shrunk)

    ms_shrink = _best_ms(shrinking, inc_reps)

    # -- warm boot (plan-cache adoption) ------------------------------
    with tempfile.TemporaryDirectory() as td:
        store = ExperienceStore(td)
        store.record_job(store.fingerprint(seq), seq=seq,
                         hub=TelemetryHub(clock="virtual"), job_id=jid,
                         plan=res.plans[jid], pipeline="tensile",
                         peak_bytes=res.final_report.peak_bytes)
        store.flush()

        def warm():
            p = build_pipeline("tensile", profile=PROFILE,
                               config=_config(budget))
            p.experience = store
            return p.plan([seq])

        wres = warm()
        adopted = (wres.iterations == 0 and wres.plans[jid].provenance
                   and wres.plans[jid].provenance[-1]["action"]
                   == "warm-boot")
        ms_warm = _best_ms(warm, cold_reps)

    events = len(res.plans[jid].events)
    return {
        f"{n}/cold_plan": {"ms": round(ms_cold, 4), "ops": n,
                           "plan_events": events},
        f"{n}/incremental_replan": {"ms": round(ms_inc, 4), "ops": n,
                                    "safe_point": int(step),
                                    "added_events": int(added)},
        f"{n}/shrinking_replan": {"ms": round(ms_shrink, 4), "ops": n,
                                  "safe_point": int(step),
                                  "budget_frac": 0.9,
                                  "added_events": int(added_shrink)},
        f"{n}/warm_boot": {"ms": round(ms_warm, 4), "ops": n,
                           "adopted": bool(adopted)},
    }


def run(out_json: str, smoke: bool = False) -> Dict[str, Dict[str, float]]:
    rows: Dict[str, Dict[str, float]] = {}
    for n_ops in (SMOKE_N_OPS if smoke else FULL_N_OPS):
        rows.update(bench_size(n_ops, smoke))
    with open(out_json, "w") as f:
        json.dump({"_meta": {"smoke": bool(smoke),
                             "max_iterations": MAX_ITERATIONS},
                   **rows}, f, indent=1, sort_keys=True)
    return rows


if __name__ == "__main__":   # pragma: no cover - ad-hoc use
    import sys
    print(json.dumps(run("/dev/stdout" if len(sys.argv) < 2 else sys.argv[1],
                         smoke="--smoke" in sys.argv), indent=1))
