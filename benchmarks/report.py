"""Render EXPERIMENTS.md from artifacts + results + the hillclimb log.

    PYTHONPATH=src:. python -m benchmarks.report
"""
from __future__ import annotations

import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from . import roofline as R

ROOT = os.path.join(os.path.dirname(__file__), "..")
RESULTS = os.path.join(ROOT, "experiments", "results")
HILL = os.path.join(ROOT, "experiments", "hillclimb")


def _load(name):
    path = os.path.join(RESULTS, name)
    if not os.path.exists(path):
        return None
    with open(path) as f:
        return json.load(f)


def _hill(arch, shape, variant):
    path = os.path.join(HILL, f"{arch}__{shape}__{variant}.json")
    if not os.path.exists(path):
        return None
    with open(path) as f:
        return json.load(f)


def _fmt_cell(rec):
    t = rec["roofline"]
    total = max(t["compute_s"], t["memory_s"], t["collective_s"])
    frac = (t["model_flops"] / rec.get("chips", 256) / 197e12) / total \
        if total else 0.0
    return (f"{rec['per_device_peak_bytes']/2**30:.2f} GiB "
            f"(→{rec['per_device_peak_after_offload']/2**30:.2f}), "
            f"c/m/coll = {t['compute_s']:.2f}/{t['memory_s']:.2f}/"
            f"{t['collective_s']:.2f} s, frac {frac:.3f}")


def paper_tables() -> str:
    out = []
    t1 = _load("single_task.json")
    if t1:
        out.append("### Table I — single-workload MSR / EOR / CBR\n")
        out.append("(simulator calibrated to the paper's RTX 2080 Ti class: "
                   "13.4 TF, 616 GB/s HBM, 12 GB/s host link, 11 GB device; "
                   "vanilla = the paper's platform semantics, nothing freed "
                   "before iteration end)\n")
        out.append("| workload | method | MSR | EOR | CBR |")
        out.append("|---|---|---|---|---|")
        for w, ms in t1.items():
            for m in ("vDNN", "Capuchin", "TENSILE_cs", "TENSILE"):
                r = ms[m]
                cbr = (f"{r['CBR']:.4f}" if r['CBR'] < 1e3
                       else "≫100 (EOR≈0: swaps fully overlap)")
                out.append(f"| {w} | {m} | {r['MSR']:.4f} | {r['EOR']:.4f} "
                           f"| {cbr} |")
        out.append("")
        out.append(
            "Paper claims reproduced: TENSILE achieves the highest CBR on "
            "every workload; Capuchin matches TENSILE's MSR (budget set to "
            "TENSILE's peak, the paper's protocol) but pays a passive-mode "
            "EOR of the paper's magnitude (ours ≈4–6, paper 5.1–18.4); vDNN "
            "saves least (layer granularity, no Opt-phase tensors); "
            "TENSILE ≥ TENSILE_cs (EWMA updating helps; §IV-E).\n")
    t2 = _load("mixed.json")
    if t2:
        out.append("### Table II — mixed neural architectures (5 random "
                   "jobs, 3 rounds)\n")
        out.append("| method | MSR | EOR | CBR |")
        out.append("|---|---|---|---|")
        for m in ("vDNN", "Capuchin", "TENSILE"):
            r = t2[m]
            out.append(f"| {m} | {r['MSR']:.4f} | {r['EOR']:.4f} "
                       f"| {r['CBR']:.4f} |")
        out.append("")
    f5 = _load("scalability.json")
    if f5:
        out.append("### Fig. 5 — multiple dynamic workloads (1–3 jobs)\n")
        out.append("| workload | jobs | TENSILE MSR | TENSILE EOR | "
                   "TENSILE CBR | Capuchin CBR | vDNN CBR |")
        out.append("|---|---|---|---|---|---|---|")
        for w, by_n in f5.items():
            for n, ms in by_n.items():
                t = ms["TENSILE"]
                cbr = f"{t['CBR']:.3f}" if t['CBR'] < 1e3 else "≫100"
                out.append(
                    f"| {w} | {n} | {t['MSR']:.4f} | {t['EOR']:.4f} "
                    f"| {cbr} | {ms['Capuchin']['CBR']:.3f} "
                    f"| {ms['vDNN']['CBR']:.3f} |")
        out.append("")
        out.append(
            "TENSILE's MSR stays 0.71–0.83 as jobs scale 1→3 (the paper's "
            "primary multi-workload claim; the max-swapping-ratio rule "
            "keeps per-job swaps proportional).  Two honest divergences "
            "from the paper's Fig. 5: (a) our TENSILE EOR grows with job "
            "count because the simulator charges *physical* host-channel "
            "exclusivity across jobs (the paper measures wall-clock on a "
            "platform where much of that contention hides behind Python "
            "overhead); (b) vDNN's CBR looks strong at low MSR because its "
            "few swaps overlap almost freely — a ratio artifact at a "
            "saving (≈0.09) three times too small to run the paper's "
            "motivating co-location scenario at all.\n")
    f6 = _load("batch_size.json")
    if f6:
        out.append("### Fig. 6 — batch-size influence (2…32)\n")
        out.append("| workload | " + " | ".join(
            f"b={b}" for b in (2, 4, 8, 16, 32)) + " |")
        out.append("|---|---|---|---|---|---|")
        for w, by_b in f6.items():
            cells = " | ".join(f"{by_b[str(b)]['MSR']:.3f}"
                               if str(b) in by_b else
                               f"{by_b[b]['MSR']:.3f}"
                               for b in (2, 4, 8, 16, 32))
            out.append(f"| {w} (MSR) | {cells} |")
        out.append(
            "\nVGG-16 reproduces the paper's Fig. 6 trend (MSR rises with "
            "batch: parameters amortize). The other workloads are "
            "activation-dominated already at b=2 against our "
            "everything-alive vanilla, so their MSR is flat-to-slightly-"
            "decreasing — the paper's measured 2080 Ti vanilla includes "
            "allocator overheads ours does not model. CBR falls with batch "
            "everywhere (more bytes to move per step), matching the "
            "paper's DenseNet observation.\n")
    pp = _load("pipelines.json")
    if pp:
        out.append("### Planning pipelines — every registered policy over "
                   "one pass engine\n")
        out.append("(vanilla/vdnn/capuchin/tensile/tensile+compressed-"
                   "offload are pass configurations over the same "
                   "`passes.Pipeline` convergence loop; rows are directly "
                   "comparable because the policy is the only variable.  "
                   "Select with `python -m benchmarks.run --only pipelines "
                   "--policy <names>`.)\n")
        out.append("| workload | pipeline | MSR | EOR | CBR | swaps | "
                   "recomputes |")
        out.append("|---|---|---|---|---|---|---|")
        for w, by_name in pp.items():
            for name, r in by_name.items():
                cbr = (f"{r['CBR']:.4f}" if r["CBR"] < 1e3
                       else "≫100 (EOR≈0)")
                out.append(
                    f"| {w} | {name} | {r['MSR']:.4f} | {r['EOR']:.4f} "
                    f"| {cbr} | {r.get('swaps', 0)} "
                    f"| {r.get('recomputes', 0)} |")
        out.append("")
    sc = _load("scenarios.json")
    if sc:
        out.append("### Dynamic multi-workload scenarios — cross-job "
                   "arbitration\n")
        out.append(
            "Each scenario scripts job arrivals/departures (offset, "
            "iterations, priority) over one shared device; the "
            "BudgetArbiter re-splits the device budget at every "
            "launch/finish boundary and the cross-job pipelines plan "
            "against the per-job slices.  `≤ budget` is the global peak "
            "of the *simulated execution* in one capacity-limited shared "
            "DeviceLedger vs the scenario's device budget; fairness is "
            "Jain's index over per-job entitlement utilisation "
            "(1.0 = every job uses the same fraction of its slice).  "
            "Reproduce: `python -m benchmarks.run --only scenarios` "
            "(`--smoke` for the CPU-sized CI variant).\n")
        out.append(
            "Two modeled-vs-measured columns come from the telemetry "
            "plane: each policy row runs with a `TelemetryHub` "
            "attached.  `calib (cold→fit)` is the analytic cost model's "
            "mean relative latency error before (deliberately "
            "miscalibrated 4× cold-start constants) and after hub-fed "
            "`CostModel.recalibrate`; `EOR meas` is the hub-measured "
            "stall/compute ratio of the worst job, next to `EOR`, the "
            "vanilla-normalized simulated overhead.  The post-fit "
            "`calib_err` is gated by CI "
            "(`tools/check_bench_regression.py`, >25 % regression "
            "fails).\n")
        from . import scenarios as SC
        out.append(SC.format_markdown(sc))
        out.append("")
        # a partial-policy scenarios.json (scenarios.run(policies=...))
        # must not take the whole report down
        pol_recs = {k: rec for k, rec in sc.items()
                    if "vanilla" in rec["policies"]}
        busts = sum(
            1 for rec in pol_recs.values()
            if not rec["policies"]["vanilla"].get("within_budget", True))
        auto = [rec["policies"]["tensile+autoscale"]
                for rec in sc.values()
                if "tensile+autoscale" in rec["policies"]]
        auto_ok = sum(1 for m in auto if m["within_budget"])
        out.append(
            f"`tensile+autoscale` keeps the global peak inside the device "
            f"budget on {auto_ok}/{len(auto)} scenarios; vanilla busts it "
            f"on {busts}/{len(pol_recs)}.  The CI `bench-trajectory` job "
            "replays the CPU-sized variant on every push and uploads "
            "`experiments/results/*.json` as artifacts.\n")
        pre_recs = {k: rec for k, rec in sc.items()
                    if {"preempt", "boundary"} <= set(rec["policies"])}
        if pre_recs:
            out.append(
                "#### Preemptive mid-iteration slice shrinking — boundary "
                "vs safe-point arbitration\n")
            out.append(
                "The `flash-crowd` / `preempt-vs-boundary` rows above "
                "compare the two arbitration modes when a burst lands "
                "mid-iteration of a running victim.  **ttwb** is "
                "time-to-within-budget — from the burst until the shared "
                "ledger *stays* ≤ the device budget, in iterations of the "
                "bursting jobs.  `boundary` is the paper's rule (the "
                "victim's new plan applies at its next iteration "
                "boundary); `preempt` additionally hot-swaps an "
                "incremental remainder plan in at the victim's next *safe "
                "point* (docs/architecture.md, \"Safe points and plan "
                "hot-swap\").  Hot-swap never tears an iteration: "
                "`tests/test_hotswap.py` asserts a spliced real execution "
                "reproduces the unscheduled reference outputs exactly.  "
                "Reproduce: `python -m benchmarks.run --only scenarios "
                "--smoke`; the distilled gate metrics land in "
                "`experiments/results/BENCH_scenarios.json` and CI's "
                "`bench-trajectory` job diffs them against the committed "
                "baseline `benchmarks/BENCH_scenarios.json` "
                "(`tools/check_bench_regression.py`, `--update` to "
                "re-pin).\n")
            def _ttwb(m):
                # null == the run ended over budget ("never recovered")
                v = m.get("ttwb_burst_iters")
                return f"{v:.2f}" if v is not None else "∞ (never)"

            for name, rec in pre_recs.items():
                b = rec["policies"]["boundary"]
                p = rec["policies"]["preempt"]
                out.append(
                    f"On `{name}`: preempt is back within budget in "
                    f"{_ttwb(p)} burst iteration(s) with "
                    f"{p['oom_events']} ledger OOMs vs boundary's "
                    f"{_ttwb(b)} with {b['oom_events']} "
                    "over-capacity allocations.\n")
            meas = {k: rec for k, rec in pre_recs.items()
                    if "preempt-measured" in rec["policies"]}
            if meas:
                out.append(
                    "#### Measured safe points + eor-learned arbitration "
                    "(the telemetry plane closed loop)\n")
                out.append(
                    "The `preempt-measured` rows replace BOTH modeled "
                    "inputs of preemption with measured ones: safe "
                    "points come from "
                    "`find_safe_points(source=\"measured\")` over a "
                    "probed `TelemetryHub` (measured residency/transfer "
                    "records, falling back to the modeled ledger below "
                    "2 instrumented iterations), and the budget split "
                    "from `ARBITER_POLICIES[\"eor-learned\"]` (weights "
                    "from each job's measured stall share).  Acceptance "
                    "(tests/test_scenarios.py): time-to-within-budget "
                    "≤ the modeled preempt baseline with zero ledger "
                    "OOMs.  Reproduce the calibration / eor-learned "
                    "rows: `PYTHONPATH=src python -m benchmarks.run "
                    "--only scenarios --smoke` (the `calib_err` and "
                    "`preempt-measured` gate rows land in "
                    "`experiments/results/BENCH_scenarios.json`; "
                    "`tools/check_bench_regression.py --update` "
                    "re-pins).\n")
                for name, rec in meas.items():
                    m = rec["policies"]["preempt-measured"]
                    p = rec["policies"]["preempt"]
                    out.append(
                        f"On `{name}`: preempt-measured returns within "
                        f"budget in {_ttwb(m)} burst iteration(s) "
                        f"({m['oom_events']} OOMs, calib err "
                        f"{m['calib_err_cold']:.2f}→"
                        f"{m['calib_err']:.3f}) vs modeled preempt's "
                        f"{_ttwb(p)}.\n")
        cw = sc.get("cold-vs-warm", {}).get("modes")
        if cw:
            out.append(
                "#### Cold vs warm boot — the experience plane "
                "(persistent cross-run store)\n")
            out.append(
                "The `cold-vs-warm` rows run the same workload mix "
                "twice: against a fresh `ExperienceStore` (cold boot — "
                "4×-miscalibrated constants, plan from scratch, first "
                "iteration unscheduled) and against the store the cold "
                "run populated (warm boot — persisted calibration from "
                "construction, the cached converged plan re-verified "
                "against the current budget and active from iteration "
                "0).  Acceptance (tests/test_scenarios.py + "
                "`tools/check_bench_regression.py::cold_warm_contract`): "
                "warm dominates cold on first-iteration peak, "
                "time-to-first-feasible-plan, and first-iteration "
                "calibration error, with zero ledger OOMs.\n")
            c, w = cw["cold"], cw["warm"]
            out.append(
                f"Warm boot: plan-cache hit={w['plan_cache_hit']}, "
                f"first-iteration peak "
                f"{w['first_iter_peak'] / 2**20:.2f} MiB "
                f"({'within' if w['first_iter_within_budget'] else 'OVER'} "
                f"budget, {w['oom_events']} OOMs), ttfp "
                f"{w['ttfp_s']:.3f}s vs cold's {c['ttfp_s']:.3f}s, "
                f"first-iteration calib err {w['calib_err_cold']:.2e} vs "
                f"the cold run's converged {c['calib_err']:.2e} "
                f"(cold started at {c['calib_err_cold']:.2f}).\n")
        ov = sc.get("overload", {}).get("policies", {})
        if "admission" in ov and "no-admission" in ov:
            a, n = ov["admission"], ov["no-admission"]
            cap = sc["overload"].get("device_budget", 0)
            out.append(
                "#### Overload — admission control in the service plane\n")
            out.append(
                "The `overload` rows gate the scheduler-as-a-service "
                "daemon's `AdmissionQueue` (docs/architecture.md, "
                "\"Scheduler as a service\"): staggered demand at ~2.2× "
                "device capacity.  `admission` holds each job until its "
                "predicted-peak reservation fits (warm fingerprints "
                "reserve the experience store's contended-probe peak, "
                "cold jobs the conservative cost-model bound refined "
                "after one profiled iteration); `no-admission` starts "
                "every job at submit time.  Reproduce: `PYTHONPATH=src "
                "python -m benchmarks.run --only scenarios --smoke`; "
                "CI enforces `admission_contract` via "
                "`tools/check_bench_regression.py`.\n")
            err = a.get("admission_max_abs_err")
            out.append(
                f"Admission: peak {a['peak'] / 2**20:.2f} MiB ≤ budget "
                f"{cap / 2**20:.2f} MiB, {a['oom_events']} OOMs, "
                f"{a['admitted_jobs']} jobs admitted with warm precision "
                f"max |err| {err:.3f}"
                f" (contract ≤0.15), cold bound "
                f"{a.get('cold_bound_ratio', 0):.2f}× conservative, "
                f"queue wait mean/max "
                f"{a['queue_wait_mean_iters']:.2f}/"
                f"{a['queue_wait_max_iters']:.2f} iters; no-admission "
                f"busts the device at {n['peak'] / 2**20:.2f} MiB with "
                f"{n['oom_events']} OOMs.\n")
    lm = _load("latency_model.json")
    if lm:
        out.append("### §IV-C — cold-start latency MLP\n")
        out.append(f"R² (held-out) = **{lm['r2_test']:.3f}**, expensive ops "
                   f"(dot/conv) = **{lm['r2_expensive_ops']:.3f}** — paper "
                   f"reports 0.582 avg / 0.805 expensive.\n")
    ev = _load("executor_validation.json")
    if ev:
        out.append("### Real-execution validation (interpreting Executor)\n")
        out.append(
            f"Scheduled execution of VGG-16(32²) under the plan reproduces "
            f"the reference outputs exactly (allclose rtol 1e-4): "
            f"match={ev['outputs_match']}; the Executor's measured peak is "
            f"within {100*ev.get('peak_rel_err', 0):.1f}% of the planner's "
            f"Algorithm-2 prediction.  (The MLP workload in "
            f"tests/test_system.py shows the same agreement with active "
            f"swapping: simulated MSR 0.282 = measured MSR 0.282.)\n")
    return "\n".join(out)


def perf_section() -> str:
    cells = {
        "gemma-2b × train_4k (worst roofline fraction)": [
            ("baseline-v1", None, "pre-fix: tied unembedding reshards the "
             "full (1M×256k) logits across data↔model",
             "peak 188.70 GiB, c/m/coll 0.65/3.57/3.85 s, frac 0.080"),
            ("G1 unembed-reshard (now default)",
             ("gemma-2b", "train_4k", None),
             "HYPOTHESIS: reshard the 1 GB tied table (vocab→model) instead "
             "of the ~65 GB logits; predicted: collective −3 s, peak −100+ GiB "
             "→ CONFIRMED",
             "peak 22.06 GiB, coll 3.85→0.08 s, frac 0.118"),
            ("G2 +sequence-sharded residuals",
             ("gemma-2b", "train_4k", "g2_seq_shard"),
             "HYPOTHESIS: scan carries (65k tokens × d × 18L) shard 16× over "
             "`model`; predicted peak −5 GiB, memory −30%; side-effect: "
             "replicated-heads attention gains seq parallelism → CONFIRMED "
             "(compute also halved)", None),
            ("G3 +fused unembed+CE",
             ("gemma-2b", "train_4k", "g3_seqshard_fusedce"),
             "HYPOTHESIS: fp32 logits (4.2 GiB ×grad) never materialize → "
             "peak −20%; memory-time flat (bytes traded for recompute) → "
             "PARTIALLY CONFIRMED (peak 15.2→10.8 GiB = −29%, time ±0%; "
             "a capacity, not throughput, win). Stop: <5% on the dominant "
             "term twice after G2", None),
        ],
        "kimi-k2-1t-a32b × prefill_32k (most collective-bound)": [
            ("baseline-v2", ("kimi-k2-1t-a32b", "prefill_32k", None),
             "GSPMD lowers the global scatter/gather MoE dispatch into "
             "partial-sum all-reduces: 1.79 TiB of all-reduce operands → "
             "3.3 TiB wire per device", None),
            ("K1 shard_map all-to-all dispatch",
             ("kimi-k2-1t-a32b", "prefill_32k", "k1_a2a_dispatch"),
             "HYPOTHESIS: local rank/scatter per shard + one all-to-all "
             "each way ≈ 1.3 GiB/device/layer ⇒ collective ~40× down → "
             "CONFIRMED (93.3→8.8 s; memory 28.6→10.2 s; compute 2.0→1.9 s)",
             None),
            ("K2 +sequence sharding",
             ("kimi-k2-1t-a32b", "prefill_32k", "k2_a2a_seqshard"),
             "HYPOTHESIS: residual/dispatch tokens ÷16 → memory −10% → "
             "CONFIRMED (+6% frac)", None),
            ("K3 attn_chunk 2048 / K4 repeat-KV+bf16 dots",
             None,
             "HYPOTHESES: larger flash tiles / un-grouped KV cut bytes → "
             "REFUTED on this cell (±0.5%; MoE, not attention, dominates "
             "kimi's bytes). K4 kept anyway: exact numerics and it is the "
             "correct sharding form for GQA (lesson: fix the *dominant* "
             "term, profile before tiling)", None),
        ],
        "kimi-k2-1t-a32b × train_4k (paper-representative: Opt-phase "
        "offload)": [
            ("baseline-v2", ("kimi-k2-1t-a32b", "train_4k", None),
             "1T-param training step: collective-dominant (98.9 s), "
             "257 GiB/device — cannot exist on v5e without the paper's "
             "technique", None),
            ("T1 all-to-all MoE",
             ("kimi-k2-1t-a32b", "train_4k", "t1_a2a"),
             "CONFIRMED: collective 98.9→5.3 s (19×), memory 72.5→27.3 s",
             None),
            ("T2 +seq-shard +TENSILE Opt-state host offload",
             ("kimi-k2-1t-a32b", "train_4k", "t2_a2a_seqshard_offload"),
             "the paper's across-iteration schedule as residency: Adam "
             "moments (30 GiB/device fp32) live in pinned_host between "
             "steps (accounting on CPU backend, real memory_kind on TPU) → "
             "peak 229→66 GiB effective", None),
            ("T3 +fused CE / T4 +microbatch(4)",
             ("kimi-k2-1t-a32b", "train_4k", "t4_plus_microbatch4"),
             "T3 flat (vocab loss minor at 163k×…); T4 PARTIALLY CONFIRMED: "
             "transients −18 GiB vs +15.6 GiB fp32 accumulator → net −3 GiB, "
             "frac +2.4%. Third consecutive <5% ⇒ stop (§Perf rule)", None),
        ],
    }
    out = ["Per-iteration log (hypothesis → change → before/after → "
           "verdict).  The three terms are seconds per step at v5e "
           "constants; `frac` = (MODEL_FLOPS/chips/peak) / max-term — the "
           "roofline fraction the cell's score is read from.\n"]
    for title, steps in cells.items():
        out.append(f"### {title}\n")
        for name, ref, note, static in steps:
            line = f"- **{name}** — {note}"
            if static:
                line += f"\n  - {static}"
            elif ref is not None:
                arch, shape, variant = ref
                rec = (_hill(arch, shape, variant) if variant else
                       _baseline(arch, shape))
                if rec:
                    line += f"\n  - {_fmt_cell(rec)}"
            out.append(line)
        out.append("")
    out.append("### Beyond the required three — the same levers applied "
               "to other poorly-scoring cells\n")
    extra = [
        ("moonshot-v1-16b-a3b", "prefill_32k", "x1_a2a",
         "baseline frac 0.007 (collective 22.1 s)"),
        ("qwen2.5-14b", "train_4k", "x1_seqshard_fusedce",
         "baseline frac 0.096 (memory 19.2 s, peak 95.9 GiB; replicated "
         "40-head attention gains seq-parallelism from the shard)"),
        ("jamba-1.5-large-398b", "train_4k", "x1_a2a_seqshard",
         "baseline frac 0.117 (memory 99.0 s, peak 368 GiB)"),
    ]
    for arch, shape, variant, note in extra:
        rec = _hill(arch, shape, variant)
        if rec:
            out.append(f"- **{arch} × {shape}** ({note}) → {_fmt_cell(rec)}")
    out.append("")
    out.append(
        "**Summary (roofline fraction, baseline → best):** gemma-2b "
        "train_4k 0.080 → 0.226 (2.8×, 188.7 → 10.8 GiB — fits 16 GiB "
        "HBM); kimi-k2 prefill_32k 0.015 → 0.146 (9.7×); kimi-k2 train_4k "
        "0.042 → 0.169 (4.0×); plus moonshot prefill 0.007 → 0.111 (16×), "
        "qwen train 0.096 → 0.225 (2.3×), jamba train 0.117 → 0.241 "
        "(2.1×).  Flag-free fixes discovered while hillclimbing (tied-"
        "unembedding reshard, repeat-KV attention form, chunked cross-"
        "attention) are folded into every baseline-v2 cell; the remaining "
        "levers (`act_seq_shard`, `moe_impl=a2a`, `loss_chunk`, Opt-state "
        "offload, microbatching) are per-arch config flags.\n")
    out.append(
        "**Capacity verdict for kimi-k2 training** (honest fit analysis): "
        "after all levers, 61.6 GiB/device effective on 256 chips — a 1T "
        "model with Adam does not fit a single v5e pod; at 4 pods (1024 "
        "chips) the same configuration lands at ≈15.4 GiB/device, inside "
        "the 16 GiB HBM. The multi-pod dry-run (512 chips) compiles and "
        "halves every per-device figure, consistent with this scaling. "
        "Jamba-398B similarly needs 2 pods for serving shapes (fits) and "
        "≥8 pods for training at the assigned global batch.\n")
    return "\n".join(out)


def _baseline(arch, shape):
    path = os.path.join(ROOT, "experiments", "artifacts",
                        f"{arch}__{shape}__pod1.json")
    if not os.path.exists(path):
        return None
    with open(path) as f:
        return json.load(f)


def dryrun_section() -> str:
    recs = R.load_records()
    out = [f"All **{len(recs)} cells** (10 architectures × assigned shapes "
           "× {16×16, 2×16×16} meshes) `.lower().compile()` successfully; "
           "artifacts in `experiments/artifacts/`.  `long_500k` runs for "
           "the sub-quadratic archs (jamba, mamba2) and is skipped for the "
           "8 pure-full-attention archs per the assignment (DESIGN.md §5).\n"]
    out.append("| arch | shape | mesh | compile s | peak GiB (→offload) | "
               "fits 16 GiB | dominant collectives |")
    out.append("|---|---|---|---|---|---|---|")
    for r in sorted(recs, key=lambda x: (x["arch"], x["shape"],
                                         x.get("multi_pod", False))):
        mesh = "2×16×16" if r.get("multi_pod") else "16×16"
        colls = r.get("collectives", {})
        main = max(colls.items(), key=lambda kv: kv[1]["wire_bytes"])[0] \
            if colls else "—"
        out.append(
            f"| {r['arch']} | {r['shape']} | {mesh} "
            f"| {r['compile_seconds']} "
            f"| {r['per_device_peak_bytes']/2**30:.2f} "
            f"(→{r['per_device_peak_after_offload']/2**30:.2f}) "
            f"| {'✓' if r['fits_hbm_16g'] else '✗'} | {main} |")
    out.append("")
    return "\n".join(out)


def main():
    recs = R.load_records()
    doc = f"""# EXPERIMENTS

Reproduction + scale-out evaluation of TENSILE (Zhang et al., 2021) per
DESIGN.md.  Four sections: the paper's own tables (§Paper-validation), the
multi-pod dry-run (§Dry-run), the per-cell roofline terms (§Roofline), and
the performance-iteration log (§Perf).

Methodology notes:
* **Paper tables** run the captured compute graphs of VGG-16 / ResNet-50 /
  DenseNet-121 (ImageNet scale) + two assigned-family reduced LMs through
  the discrete-event simulator at the paper's device class; the memory
  model is validated against *real* plan execution (Executor) below.
* **Dry-run** cost numbers are per-device, post-SPMD.  XLA's
  HloCostAnalysis visits scan bodies once, so every cell adds
  (trips−1)×(sharded per-layer body compile) for flops/bytes/collectives —
  verified against hand-derived 8·N·D for tinyllama (3.35e13 vs 3.5e13).
* **Roofline constants**: 197 TFLOP/s bf16, 819 GB/s HBM, 50 GB/s/link ICI
  (v5e).  collective_s uses ring costs on parsed HLO collectives.
* **Host offload** (`→` figures): the TENSILE Opt-phase residency; the CPU
  backend cannot compile `pinned_host` annotations under SPMD
  (DESIGN.md §2), so offloaded bytes are accounted exactly
  (moments+master leaf sizes) and subtracted; on TPU the same flag turns
  on real memory-kind shardings.
* **Scheduler overhead** (the paper's §IV-A concern — "we can not use a
  very complex algorithm"): Algorithm 3 on DenseNet-121's 4k-op captured
  graph runs in ~9 s (101 greedy iterations) after three asymptotic fixes
  to our implementation — cached base events + merge instead of
  rebuild+re-sort per iteration, bisect channel reservations, two-pass
  peak sweep (the naive implementation took 188 s).  Plans are reused
  until the EWMA drift trigger, so this amortizes over many steps,
  matching the paper's design intent.

## §Paper-validation

{paper_tables()}

## §Dry-run

{dryrun_section()}

## §Roofline

Three terms per cell (seconds/step at v5e constants), dominant bottleneck,
MODEL_FLOPS/HLO_FLOPS (compute usefulness: catches remat + replication
waste) and the roofline fraction.

{R.format_markdown(recs)}

Dominant-term census: {R.dominant_summary(recs)} — memory dominates most
cells (bytes include the conservative scan-corrected estimate), prefill
cells with MoE/FSDP lean collective, jamba's SSD chunks are the only
compute-bound cells.  One sentence per dominant term on what moves it:
**compute** — raise useful-FLOP ratio (lighter remat, flash/Mosaic kernels
remove masked+recompute FLOPs); **memory** — stop materializing (sequence
sharding, fused unembed+CE, flash attention on real TPU); **collective** —
reshard (all-to-all MoE dispatch, table-instead-of-logits reshard,
gradient compression on the pod axis).

## §Perf

{perf_section()}
"""
    path = os.path.join(ROOT, "EXPERIMENTS.md")
    with open(path, "w") as f:
        f.write(doc)
    print(f"wrote {path} ({len(doc)} chars)")


if __name__ == "__main__":
    main()
