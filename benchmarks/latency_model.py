"""Paper §IV-C — cold-start latency predictor quality (R²).

Measures real operator latencies on this container (matmuls, convs,
elementwise at many shapes × simulated utilization levels), trains the
3-layer MLP, reports overall R² and R² on the expensive ops — the paper
reports 0.582 average / 0.805 expensive (convolutions).
"""
from __future__ import annotations

import json
import time
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.cost_model import LatencyMLP


def _measure(fn, *args, reps: int = 7) -> float:
    fn(*args)  # compile
    times = []
    for _ in range(reps):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2]  # median: robust to scheduler jitter


def collect_samples(seed: int = 0):
    rng = np.random.default_rng(seed)
    recs: List[Dict] = []
    matmul = jax.jit(lambda a, b: a @ b)
    ew = jax.jit(lambda a: jnp.tanh(a) * 1.1 + 0.3)
    reduce_ = jax.jit(lambda a: jnp.sum(a, axis=-1))
    conv = jax.jit(lambda x, w: jax.lax.conv_general_dilated(
        x, w, (1, 1), "SAME", dimension_numbers=("NHWC", "HWIO", "NHWC")))

    for n in (32, 48, 64, 96, 128, 192, 256, 384, 512, 640):
        a = jnp.asarray(rng.standard_normal((n, n)), jnp.float32)
        b = jnp.asarray(rng.standard_normal((n, n)), jnp.float32)
        recs.append({"kind": "dot", "flops": 2 * n**3,
                     "bytes": 3 * 4 * n * n, "t": _measure(matmul, a, b)})
    for n in (2**13, 2**14, 2**16, 2**17, 2**19, 2**20, 2**22):
        a = jnp.asarray(rng.standard_normal((n,)), jnp.float32)
        recs.append({"kind": "ew", "flops": 12 * n, "bytes": 8 * n,
                     "t": _measure(ew, a)})
        recs.append({"kind": "reduce", "flops": n,
                     "bytes": 4 * n, "t": _measure(reduce_, a.reshape(-1, 64))})
    for (hw, cin, cout) in ((16, 16, 16), (32, 16, 32), (32, 32, 64),
                            (64, 32, 32)):
        x = jnp.asarray(rng.standard_normal((2, hw, hw, cin)), jnp.float32)
        w = jnp.asarray(rng.standard_normal((3, 3, cin, cout)), jnp.float32)
        recs.append({"kind": "conv",
                     "flops": 2 * 2 * hw * hw * cout * 9 * cin,
                     "bytes": 4 * (x.size + w.size + 2 * hw * hw * cout),
                     "t": _measure(conv, x, w)})
    # utilization levels: re-measure a subset under a synthetic co-running
    # load factor (modelled multiplicatively, as the scheduler sees it)
    out = []
    for util in (0.0, 0.3, 0.6):
        for r in recs:
            out.append({**r, "util": util, "t": r["t"] * (1 + util)})
    return out


def run(out_json: str = None) -> Dict[str, float]:
    samples = collect_samples()
    flops = np.array([s["flops"] for s in samples], np.float64)
    bts = np.array([s["bytes"] for s in samples], np.float64)
    util = np.array([s["util"] for s in samples], np.float32)
    lat = np.array([s["t"] for s in samples], np.float64)

    n = len(samples)
    idx = np.random.default_rng(1).permutation(n)
    tr, te = idx[: int(0.8 * n)], idx[int(0.8 * n):]
    mlp = LatencyMLP(hidden=32)
    r2_train = mlp.fit(flops[tr], bts[tr], util[tr], lat[tr],
                       steps=8000, lr=1e-2)
    r2_test = mlp.r2(flops[te], bts[te], util[te], lat[te])

    heavy = np.array([s["kind"] in ("dot", "conv") for s in samples])
    te_h = [i for i in te if heavy[i]]
    r2_heavy = mlp.r2(flops[te_h], bts[te_h], util[te_h], lat[te_h]) \
        if te_h else float("nan")
    res = {"r2_train": float(r2_train), "r2_test": float(r2_test),
           "r2_expensive_ops": float(r2_heavy), "n_samples": n,
           "paper_r2_avg": 0.582, "paper_r2_expensive": 0.805}
    if out_json:
        with open(out_json, "w") as f:
            json.dump(res, f, indent=1)
    return res


if __name__ == "__main__":
    print(json.dumps(run(), indent=1))
