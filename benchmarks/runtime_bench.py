"""Runtime data-path benchmark (the BENCH_runtime gate).

Times the three layers of the batched, overlapped transfer path on real
arrays — the runtime counterpart of ``planner_bench.py``'s planner-speed
trajectory:

    executor/swap_*    — a captured CNN training step run through the
                         JaxprExecutor under a swap-heavy plan, with the
                         DMA transfers blocking (sync) vs double-buffered
                         on the async Swap Executor stream
    kv_restore/*       — restoring K KV-cache blocks one kernel launch
                         per block vs ONE batched gather/scatter launch
                         (kernels/kv_block_copy); the headline speedup of
                         tensor-granularity batching at the kernel layer
    serving/*          — the serving plane's pressure scenario end to end
                         on the real ServingEngine: decode under a KV
                         budget that forces block churn, with the batched
                         data path (``batch_transfers=True``) vs the
                         per-rid legacy path vs the unpressured golden run

The serving scenario is sized so the budget forces real evict/prefetch
churn but not cohort splits (splits cost whole extra decode turns — a
compute effect batching cannot and should not hide), which is exactly the
regime the batched path targets.

The numbers feed the CI perf-trajectory gate: ``benchmarks/run.py --only
runtime`` distills them into ``experiments/results/BENCH_runtime.json``
and ``tools/check_bench_regression.py`` diffs that against the committed
baseline ``benchmarks/BENCH_runtime.json`` (>25 % per-row latency or
tokens/sec regression fails), plus the hard runtime contract: the batched
KV restore is >=3x the per-block path at the smoke size, and the batched
pressure run stays >=92 % of the unpressured tokens/sec with 0 OOM events
and decode outputs bit-identical to the golden run.
"""
from __future__ import annotations

import json
import time
from typing import Dict

import numpy as np

from repro.core import (JaxprExecutor, MemoryEngine, schedule_single,
                        vanilla_peak)
from repro.core.plan import MachineProfile

PROFILE = MachineProfile()

# (prompt_len, gen_len, n_requests, max_sequences, resident_slots);
# shape-invariant across smoke/full: the serving rows are already
# CPU-sized, and keeping them identical makes the gate file comparable
SERVE_SHAPE = {True: (8, 16, 12, 6, 4), False: (8, 16, 12, 6, 4)}
SERVE_MEAN_GAP = 0.002
# (pool rows, row width, blocks restored) for the kernel micro-bench
KV_SHAPE = {True: (64, 2048, 32), False: (256, 4096, 64)}


def _best_ms(fn, repeats: int) -> float:
    """min-of-N wall time in ms (min, not mean: scheduling noise only
    ever adds time)."""
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best * 1e3


# ----------------------------------------------------------------------
# Executor: blocking vs double-buffered swap stream
# ----------------------------------------------------------------------
def bench_executor(smoke: bool) -> Dict[str, Dict]:
    import jax

    from .workloads import capture_cnn

    seq, closed, (params, opt, batch) = capture_cnn(
        "vgg16", batch=2, img=32, job_id="rt")
    # a budget at 0.7x the vanilla peak forces a swap-heavy plan
    res = schedule_single(seq, profile=PROFILE,
                          budget_bytes=int(0.7 * vanilla_peak(seq)))
    plan = res.plans[seq.job_id]
    key = jax.random.PRNGKey(0)
    cparams = jax.tree.map(
        lambda s: jax.random.normal(key, s.shape, s.dtype) * 0.02, params)
    copt = jax.tree.map(lambda s: jax.numpy.zeros(s.shape, s.dtype), opt)
    cbatch = jax.tree.map(lambda s: jax.numpy.ones(s.shape, s.dtype), batch)
    reps = 2 if smoke else 4

    def run(async_swap):
        ex = JaxprExecutor(closed, seq, plan,
                           engine=MemoryEngine(PROFILE),
                           async_swap=async_swap)
        ex.run(cparams, copt, cbatch)
        ex.close()
        return ex

    ex_sync = run(False)          # warm the jit caches before timing
    ms_sync = _best_ms(lambda: run(False), reps)
    ex_async = run(True)
    ms_async = _best_ms(lambda: run(True), reps)
    launches = ex_async.async_exec.batches
    return {
        "executor/swap_sync": {
            "ms": round(ms_sync, 4),
            "swap_outs": ex_sync.stats.swap_out_count,
            "swap_ins": (ex_sync.stats.swap_in_count
                         + ex_sync.stats.passive_swap_ins),
        },
        "executor/swap_async": {
            "ms": round(ms_async, 4),
            "swap_outs": ex_async.stats.swap_out_count,
            "launches": len(launches),
            "batched_launches": sum(1 for b in launches if len(b) > 1),
        },
    }


# ----------------------------------------------------------------------
# Kernel layer: per-block vs batched KV restore
# ----------------------------------------------------------------------
def bench_kv_restore(smoke: bool) -> Dict[str, Dict]:
    import jax

    from repro.kernels.kv_block_copy import kv_block_gather, kv_block_scatter

    n, w, k = KV_SHAPE[bool(smoke)]
    rng = np.random.default_rng(0)
    pool = jax.numpy.asarray(rng.standard_normal((n, w)).astype(np.float32))
    idx = np.asarray(rng.permutation(n)[:k], np.int32)
    blocks = jax.numpy.asarray(
        rng.standard_normal((k, w)).astype(np.float32))
    reps = 2 if smoke else 4

    def per_block():
        out = pool
        for j in range(k):
            row = kv_block_gather(out, idx[j:j + 1])
            out = kv_block_scatter(out, idx[j:j + 1],
                                   blocks[j:j + 1] + row)
        return out.block_until_ready()

    def batched():
        rows = kv_block_gather(pool, idx)
        return kv_block_scatter(pool, idx,
                                blocks + rows).block_until_ready()

    ref = per_block()
    got = batched()
    assert np.allclose(np.asarray(ref), np.asarray(got)), \
        "batched KV restore diverged from the per-block path"
    ms_per_block = _best_ms(per_block, reps)
    ms_batched = _best_ms(batched, reps)
    return {
        "kv_restore/per_block": {"ms": round(ms_per_block, 4),
                                 "blocks": k, "row_bytes": 4 * w},
        "kv_restore/batched": {"ms": round(ms_batched, 4), "blocks": k,
                               "row_bytes": 4 * w,
                               "speedup": round(ms_per_block
                                                / max(ms_batched, 1e-9), 4)},
    }


# ----------------------------------------------------------------------
# Serving plane: batched data path end to end on the real engine
# ----------------------------------------------------------------------
def bench_serving(smoke: bool) -> Dict[str, Dict]:
    from repro.serving import ServingEngine, make_trace

    prompt_len, gen_len, n_requests, max_seq, resident = \
        SERVE_SHAPE[bool(smoke)]
    max_len = prompt_len + gen_len
    eng = ServingEngine("tinyllama-1.1b", max_sequences=max_seq,
                        max_len=max_len, seed=0)
    requests = make_trace("poisson", n_requests, seed=0,
                          prompt_len=prompt_len, gen_len=gen_len,
                          mean_gap=SERVE_MEAN_GAP)
    bpt = eng.bytes_per_token
    budget = bpt * (max_len * resident + 2)
    assert budget < bpt * max_len * max_seq

    def serve(capacity, serve_budget, schedule, batch):
        mem = MemoryEngine(PROFILE, capacity_bytes=capacity, trace=True)
        report, outputs = eng.serve(
            requests, budget_bytes=serve_budget, schedule=schedule,
            block_tokens=4, engine=mem, job_id="serve",
            batch_transfers=batch)
        return report, outputs

    ref, golden = serve(None, None, False, False)
    legacy, out_l = serve(budget, budget, True, False)
    batched, out_b = serve(budget, budget, True, True)

    def row(report, outputs, batch=False):
        r = {
            "tokens_per_s": round(report.tokens_per_s, 6),
            "ratio_vs_unpressured": round(
                report.tokens_per_s / max(ref.tokens_per_s, 1e-12), 6),
            "oom_events": report.oom_events,
            "decode_bit_identical": bool(outputs == golden),
            "evictions": report.evictions,
            "prefetches": report.prefetches,
            "stall_ms": round(report.stall_time * 1e3, 4),
        }
        if batch:
            r["batched_transfers"] = report.batched_transfers
            r["saved_fixup_ms"] = round(report.saved_fixup_s * 1e3, 6)
        return r

    return {
        "serving/unpressured": row(ref, golden),
        "serving/pressure_legacy": row(legacy, out_l),
        "serving/pressure_batched": row(batched, out_b, batch=True),
    }


def run(out_json: str, smoke: bool = False) -> Dict[str, Dict]:
    rows: Dict[str, Dict] = {}
    rows.update(bench_executor(smoke))
    rows.update(bench_kv_restore(smoke))
    rows.update(bench_serving(smoke))
    with open(out_json, "w") as f:
        json.dump({"_meta": {"smoke": bool(smoke)}, **rows}, f, indent=1,
                  sort_keys=True)
    return rows


if __name__ == "__main__":   # pragma: no cover - ad-hoc use
    import sys
    print(json.dumps(run("/dev/stdout" if len(sys.argv) < 2 else sys.argv[1],
                         smoke="--smoke" in sys.argv), indent=1))
