"""Benchmark workload pool.

Each workload = a captured training step (compute graph + tensor-access
sequence).  Capture works on abstract inputs, so ImageNet-scale CNNs trace
instantly; the simulator then runs on analytic latencies calibrated to the
paper's device class (RTX 2080 Ti: ~13 TFLOP/s, 616 GB/s, PCIe3 ×16 ≈
12 GB/s effective) so MSR/EOR/CBR are comparable with the paper's tables.
"""
from __future__ import annotations

import functools
import sys
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

sys.path.insert(0, __file__.rsplit("/", 2)[0] + "/src")

from repro.core import (AccessSequence, CostModel, DeviceCalibration,
                        capture_train_step)
from repro.core.plan import MachineProfile
from repro.optim.adam import adamw_init, adamw_update
from .models_cnn import BUILDERS

# RTX 2080 Ti-class calibration (the paper's platform)
GPU_CALIB = DeviceCalibration(flops=13.4e12, mem_bw=616e9, overhead_s=8e-6)
GPU_PROFILE = MachineProfile(
    device_memory_bytes=11 * 2 ** 30,       # 2080 Ti HBM
    host_link_bw=12e9, host_link_latency=20e-6,
    compute_flops=13.4e12, mem_bw=616e9)


def _sgd_train_step(forward, params, batch, lr=1e-3):
    x, y = batch

    def loss_fn(p):
        logits = forward(p, x)
        return jnp.mean((logits - y) ** 2)

    loss, grads = jax.value_and_grad(loss_fn)(params)
    new_params = [p - lr * g for p, g in zip(params, grads)]
    return new_params, loss


def _adam_train_step(forward, params, opt_state, batch, lr=1e-3):
    x, y = batch

    def loss_fn(p):
        logits = forward(p, x)
        return jnp.mean((logits - y) ** 2)

    loss, grads = jax.value_and_grad(loss_fn)(params)
    params, opt_state = adamw_update(params, grads, opt_state, lr=lr)
    return params, opt_state, loss


def capture_cnn(name: str, batch: int = 16, img: int = 224,
                job_id: Optional[str] = None,
                cost_model: Optional[CostModel] = None):
    """Capture a CNN training step (Adam, matching the paper's setup)."""
    params, forward = BUILDERS[name](jax.random.PRNGKey(0), img=img)
    params = jax.tree.map(lambda p: jax.ShapeDtypeStruct(p.shape, p.dtype),
                          params)
    opt = jax.eval_shape(adamw_init, params)
    x = jax.ShapeDtypeStruct((batch, img, img, 3), jnp.float32)
    y = jax.ShapeDtypeStruct((batch, 1000), jnp.float32)
    cm = cost_model or CostModel(GPU_CALIB)
    step = functools.partial(_adam_train_step, forward)
    seq, closed = capture_train_step(step, params, opt, (x, y),
                                     job_id=job_id or name, cost_model=cm)
    return seq, closed, (params, opt, (x, y))


def capture_lm(arch: str, batch: int = 8, seq_len: int = 256,
               job_id: Optional[str] = None,
               cost_model: Optional[CostModel] = None):
    from repro.configs import get_config
    from repro.models.registry import get_model
    cfg = get_config(arch).reduced()
    cfg.d_model = 256
    cfg.attn_chunk = 4096  # full attention at bench seqs
    if cfg.n_experts:
        cfg.moe_impl = "dense"
    api = get_model(cfg)
    params = jax.eval_shape(lambda: api.init(jax.random.PRNGKey(0))[0])
    opt = jax.eval_shape(adamw_init, params)
    batch_spec = {"tokens": jax.ShapeDtypeStruct((batch, seq_len), jnp.int32),
                  "labels": jax.ShapeDtypeStruct((batch, seq_len), jnp.int32)}
    if cfg.enc_dec:
        batch_spec["audio_feats"] = jax.ShapeDtypeStruct(
            (batch, seq_len, cfg.d_model), jnp.float32)
        batch_spec["tokens"] = jax.ShapeDtypeStruct(
            (batch, max(seq_len // cfg.enc_seq_ratio, 8)), jnp.int32)
        batch_spec["labels"] = batch_spec["tokens"]

    def step(params, opt_state, b):
        loss, grads = jax.value_and_grad(
            lambda p: api.loss(p, b))(params)
        params, opt_state = adamw_update(params, grads, opt_state, lr=1e-3)
        return params, opt_state, loss

    cm = cost_model or CostModel(GPU_CALIB)
    seqc, closed = capture_train_step(step, params, opt, batch_spec,
                                      job_id=job_id or arch, cost_model=cm)
    return seqc, closed, (params, opt, batch_spec)


# The five-workload pool for the paper's tables (DESIGN.md §7.4)
POOL: Dict[str, Callable[..., Tuple]] = {
    "vgg16": functools.partial(capture_cnn, "vgg16"),
    "resnet50": functools.partial(capture_cnn, "resnet50"),
    "densenet121": functools.partial(capture_cnn, "densenet121"),
    "tinyllama-r": functools.partial(capture_lm, "tinyllama-1.1b"),
    "gemma-r": functools.partial(capture_lm, "gemma-2b"),
}


_CACHE: Dict[Tuple[str, Optional[int]], AccessSequence] = {}


def get_workload(name: str, batch: Optional[int] = None,
                 job_id: Optional[str] = None,
                 cost_model: Optional[CostModel] = None) -> AccessSequence:
    """Traced workloads are cached by (name, batch): tracing ImageNet-scale
    CNNs costs ~20 s each; benchmark sweeps reuse clones."""
    key = (name, batch)
    if key not in _CACHE:
        kw: Dict[str, Any] = {"cost_model": cost_model}
        if batch is not None:
            kw["batch"] = batch
        seq, closed, args = POOL[name](**kw)
        _CACHE[key] = seq
    seq = _CACHE[key]
    return seq.clone(job_id) if job_id else seq.clone(seq.job_id)
