"""Paper Fig. 5 — multiple dynamic workloads (1–3 concurrent copies).

N copies of a workload launch together (staggered offsets); the Memory
Scheduler plans over the MERGED timeline with the per-job max-swapping
ratio = 1/N (the paper's conflict-mitigation rule); metrics against the
same N-job vanilla run.
"""
from __future__ import annotations

import json
from typing import Dict


from repro.core import MemoryScheduler, SchedulerConfig, evaluate
from repro.core.baselines import capuchin_plan, vdnn_conv_plan

from .workloads import GPU_PROFILE, get_workload

WORKLOADS = ["vgg16", "resnet50", "densenet121", "tinyllama-r", "gemma-r"]


def bench(name: str, n_jobs: int) -> Dict[str, Dict[str, float]]:
    seqs = [get_workload(name, job_id=f"{name}#{i}") for i in range(n_jobs)]
    offsets = {s.job_id: i * s.iteration_time / max(n_jobs, 1) * 0.5
               for i, s in enumerate(seqs)}
    out: Dict[str, Dict[str, float]] = {}

    # TENSILE: one global schedule, MSR limit split across jobs
    sched = MemoryScheduler(GPU_PROFILE, SchedulerConfig(
        max_swap_ratio=1.0 / n_jobs))
    for s in seqs:
        sched.register_job(s, offset=offsets[s.job_id])
    res = sched.schedule()
    out["TENSILE"] = evaluate(seqs, res.plans, GPU_PROFILE, offsets=offsets)

    # baselines schedule each job independently (their design)
    out["vDNN"] = evaluate(
        seqs, {s.job_id: vdnn_conv_plan(s, GPU_PROFILE) for s in seqs},
        GPU_PROFILE, offsets=offsets, free_at_last_use=False)
    budget = res.final_report.peak_bytes // max(n_jobs, 1)
    cap_plans = {s.job_id: capuchin_plan(s, budget, GPU_PROFILE).plan
                 for s in seqs}
    m = evaluate(seqs, cap_plans, GPU_PROFILE, offsets=offsets)
    m["EOR"] += seqs[0].iteration_time / max(m["vanilla_time"], 1e-12)
    m["CBR"] = m["MSR"] / m["EOR"] if m["EOR"] > 0 else 0.0
    out["Capuchin"] = m
    return out


def run(out_json: str = None) -> Dict:
    table = {}
    for w in WORKLOADS:
        table[w] = {n: bench(w, n) for n in (1, 2, 3)}
    if out_json:
        with open(out_json, "w") as f:
            json.dump(table, f, indent=1, default=float)
    return table


def format_markdown(table: Dict) -> str:
    lines = ["| workload | jobs | method | MSR | EOR | CBR |",
             "|---|---|---|---|---|---|"]
    for w, by_n in table.items():
        for n, methods in by_n.items():
            for m, r in methods.items():
                lines.append(f"| {w} | {n} | {m} | {r['MSR']:.4f} | "
                             f"{r['EOR']:.4f} | {r['CBR']:.4f} |")
    return "\n".join(lines)


if __name__ == "__main__":
    print(format_markdown(run()))
