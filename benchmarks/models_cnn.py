"""CNN workloads for the paper's benchmark tables (pure jnp).

The paper evaluates on VGG-16, InceptionV3/V4, ResNet-50 and DenseNet;
we implement VGG-16, ResNet-50 and DenseNet-121 faithfully and fill the
pool with assigned-family reduced LMs (DESIGN.md §7.4).  For the simulator
path only *tracing* matters (shapes + analytic latencies), so the full
224×224 ImageNet-scale graphs are usable on this container.
"""
from __future__ import annotations

from typing import Any, List

import jax
import jax.numpy as jnp
import numpy as np


def _conv(x, w, stride=1, padding="SAME"):
    return jax.lax.conv_general_dilated(
        x, w, (stride, stride), padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"))


def _maxpool(x, k=2, s=2):
    return jax.lax.reduce_window(x, -jnp.inf, jax.lax.max,
                                 (1, k, k, 1), (1, s, s, 1), "VALID")


def _avgpool_global(x):
    return jnp.mean(x, axis=(1, 2))


def _bn(x, scale, bias, eps=1e-5):
    mu = jnp.mean(x, axis=(0, 1, 2), keepdims=True)
    var = jnp.var(x, axis=(0, 1, 2), keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + eps) * scale + bias


class _Init:
    def __init__(self, key):
        self.key = key
        self.params: List[Any] = []

    def conv(self, kh, kw, cin, cout):
        self.key, k = jax.random.split(self.key)
        w = jax.random.normal(k, (kh, kw, cin, cout)) * np.sqrt(
            2.0 / (kh * kw * cin))
        self.params.append(w)
        return len(self.params) - 1

    def bn(self, c):
        self.params.append(jnp.ones((c,)))
        self.params.append(jnp.zeros((c,)))
        return len(self.params) - 2

    def fc(self, cin, cout):
        self.key, k = jax.random.split(self.key)
        self.params.append(jax.random.normal(k, (cin, cout))
                           * np.sqrt(1.0 / cin))
        self.params.append(jnp.zeros((cout,)))
        return len(self.params) - 2


# ----------------------------------------------------------------------
VGG16_LAYERS = [64, 64, "M", 128, 128, "M", 256, 256, 256, "M",
                512, 512, 512, "M", 512, 512, 512, "M"]


def build_vgg16(key, img=224, n_classes=1000):
    ini = _Init(key)
    cin = 3
    plan = []
    for item in VGG16_LAYERS:
        if item == "M":
            plan.append(("pool", None))
        else:
            idx = ini.conv(3, 3, cin, item)
            plan.append(("conv", idx))
            cin = item
    feat = 512 * (img // 32) ** 2
    f1 = ini.fc(feat, 4096)
    f2 = ini.fc(4096, 4096)
    f3 = ini.fc(4096, n_classes)

    def forward(params, x):
        for kind, idx in plan:
            if kind == "pool":
                x = _maxpool(x)
            else:
                x = jax.nn.relu(_conv(x, params[idx]))
        x = x.reshape(x.shape[0], -1)
        x = jax.nn.relu(x @ params[f1] + params[f1 + 1])
        x = jax.nn.relu(x @ params[f2] + params[f2 + 1])
        return x @ params[f3] + params[f3 + 1]

    return ini.params, forward


# ----------------------------------------------------------------------
RESNET50_BLOCKS = [(3, 64), (4, 128), (6, 256), (3, 512)]


def build_resnet50(key, img=224, n_classes=1000):
    ini = _Init(key)
    stem = ini.conv(7, 7, 3, 64)
    stem_bn = ini.bn(64)
    plan = []
    cin = 64
    for stage, (n_blocks, width) in enumerate(RESNET50_BLOCKS):
        for b in range(n_blocks):
            stride = 2 if (b == 0 and stage > 0) else 1
            proj = None
            cout = width * 4
            if cin != cout or stride != 1:
                proj = (ini.conv(1, 1, cin, cout), ini.bn(cout), stride)
            c1 = (ini.conv(1, 1, cin, width), ini.bn(width))
            c2 = (ini.conv(3, 3, width, width), ini.bn(width), stride)
            c3 = (ini.conv(1, 1, width, cout), ini.bn(cout))
            plan.append((proj, c1, c2, c3))
            cin = cout
    fc = ini.fc(cin, n_classes)

    def forward(params, x):
        x = jax.nn.relu(_bn(_conv(x, params[stem], 2),
                            params[stem_bn], params[stem_bn + 1]))
        x = _maxpool(x, 3, 2)
        for proj, c1, c2, c3 in plan:
            sc = x
            if proj is not None:
                pi, pb, ps = proj
                sc = _bn(_conv(x, params[pi], ps), params[pb], params[pb + 1])
            h = jax.nn.relu(_bn(_conv(x, params[c1[0]]),
                                params[c1[1]], params[c1[1] + 1]))
            h = jax.nn.relu(_bn(_conv(h, params[c2[0]], c2[2]),
                                params[c2[1]], params[c2[1] + 1]))
            h = _bn(_conv(h, params[c3[0]]), params[c3[1]], params[c3[1] + 1])
            x = jax.nn.relu(h + sc)
        x = _avgpool_global(x)
        return x @ params[fc] + params[fc + 1]

    return ini.params, forward


# ----------------------------------------------------------------------
def build_densenet121(key, img=224, n_classes=1000, growth=32):
    ini = _Init(key)
    stem = ini.conv(7, 7, 3, 64)
    stem_bn = ini.bn(64)
    cin = 64
    plan = []
    for stage, n_layers in enumerate([6, 12, 24, 16]):
        block = []
        for _ in range(n_layers):
            b1 = ini.bn(cin)
            c1 = ini.conv(1, 1, cin, 4 * growth)
            b2 = ini.bn(4 * growth)
            c2 = ini.conv(3, 3, 4 * growth, growth)
            block.append((b1, c1, b2, c2))
            cin += growth
        trans = None
        if stage < 3:
            tb = ini.bn(cin)
            tc = ini.conv(1, 1, cin, cin // 2)
            trans = (tb, tc)
            cin //= 2
        plan.append((block, trans))
    final_bn = ini.bn(cin)
    fc = ini.fc(cin, n_classes)

    def forward(params, x):
        x = jax.nn.relu(_bn(_conv(x, params[stem], 2),
                            params[stem_bn], params[stem_bn + 1]))
        x = _maxpool(x, 3, 2)
        for block, trans in plan:
            for b1, c1, b2, c2 in block:
                h = jax.nn.relu(_bn(x, params[b1], params[b1 + 1]))
                h = _conv(h, params[c1])
                h = jax.nn.relu(_bn(h, params[b2], params[b2 + 1]))
                h = _conv(h, params[c2])
                x = jnp.concatenate([x, h], axis=-1)
            if trans is not None:
                tb, tc = trans
                x = jax.nn.relu(_bn(x, params[tb], params[tb + 1]))
                x = _conv(x, params[tc])
                x = jax.lax.reduce_window(x, 0.0, jax.lax.add,
                                          (1, 2, 2, 1), (1, 2, 2, 1),
                                          "VALID") / 4.0
        x = jax.nn.relu(_bn(x, params[final_bn], params[final_bn + 1]))
        x = _avgpool_global(x)
        return x @ params[fc] + params[fc + 1]

    return ini.params, forward


BUILDERS = {
    "vgg16": build_vgg16,
    "resnet50": build_resnet50,
    "densenet121": build_densenet121,
}
