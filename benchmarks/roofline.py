"""§Roofline — assemble the per-cell roofline table from dry-run artifacts.

Reads experiments/artifacts/*.json (written by repro.launch.dryrun) and
emits the markdown table for EXPERIMENTS.md: the three roofline terms, the
dominant bottleneck, MODEL_FLOPS/HLO_FLOPS, and a one-line lever per cell.
"""
from __future__ import annotations

import glob
import json
import os
from typing import Dict, List

ART = os.path.join(os.path.dirname(__file__), "..", "experiments",
                   "artifacts")

LEVER_BY_BOTTLENECK = {
    "compute": "raise useful-FLOP ratio: lighter remat policy / flash "
               "kernel removes recompute+mask FLOPs",
    "memory": "cut bytes/step: sequence-shard activations over `model`, "
              "fuse norm+proj, bf16 logits",
    "collective": "reshard to cut all-gathers: move FSDP gather into the "
                  "scan (overlap), or trade FSDP for replicated params",
}


def load_records() -> List[Dict]:
    recs = []
    for path in sorted(glob.glob(os.path.join(ART, "*.json"))):
        with open(path) as f:
            recs.append(json.load(f))
    return recs


def roofline_row(r: Dict) -> Dict:
    roof = r["roofline"]
    total = max(roof["compute_s"], roof["memory_s"], roof["collective_s"])
    chips = r["chips"]
    useful = roof["model_flops"] / chips / 197e12
    return {
        "arch": r["arch"], "shape": r["shape"],
        "mesh": "2×16×16" if r.get("multi_pod") else "16×16",
        "compute_s": roof["compute_s"], "memory_s": roof["memory_s"],
        "collective_s": roof["collective_s"],
        "dominant": roof["dominant"],
        "step_lower_bound_s": total,
        "useful_ratio": roof["useful_flops_ratio"],
        "roofline_fraction": useful / total if total else 0.0,
        "peak_gib": r["per_device_peak_bytes"] / 2**30,
        "peak_after_offload_gib": r["per_device_peak_after_offload"] / 2**30,
        "fits": r["fits_hbm_16g"],
    }


def format_markdown(recs: List[Dict]) -> str:
    rows = [roofline_row(r) for r in recs]
    rows.sort(key=lambda x: (x["arch"], x["shape"], x["mesh"]))
    lines = [
        "| arch | shape | mesh | compute(s) | memory(s) | collective(s) | "
        "dominant | MODEL/HLO | roofline frac | peak GiB (→offload) |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for x in rows:
        lines.append(
            f"| {x['arch']} | {x['shape']} | {x['mesh']} "
            f"| {x['compute_s']:.3e} | {x['memory_s']:.3e} "
            f"| {x['collective_s']:.3e} | **{x['dominant']}** "
            f"| {x['useful_ratio']:.2f} | {x['roofline_fraction']:.2f} "
            f"| {x['peak_gib']:.2f} (→{x['peak_after_offload_gib']:.2f}) |")
    return "\n".join(lines)


def dominant_summary(recs: List[Dict]) -> Dict[str, int]:
    out: Dict[str, int] = {}
    for r in recs:
        d = r["roofline"]["dominant"]
        out[d] = out.get(d, 0) + 1
    return out


if __name__ == "__main__":
    recs = load_records()
    print(f"{len(recs)} artifacts")
    print(format_markdown(recs))
    print("\ndominant terms:", dominant_summary(recs))
