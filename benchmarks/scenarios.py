"""Multi-workload dynamic scenario suite (paper §III-D / §V-C territory).

TENSILE's headline claim is scheduling under *multiple dynamic workloads*:
jobs launch at different times, finish at different times, differ in size
and priority, and the Global Controller's BudgetArbiter re-splits the
device-wide byte budget at every launch/finish/drift replan.  Each scenario
here is a small script of job arrivals (offset, iterations, priority) over
a shared device; every registered cross-job policy plans the merged
timeline and is then run through the discrete-event simulator against a
capacity-limited shared DeviceLedger, reporting:

    peak            global peak bytes in the shared ledger
    within_budget   peak <= the scenario's device budget
    oom_events      ledger allocations that crossed capacity
    MSR/EOR/CBR     the paper's metrics vs the vanilla run
    fairness        Jain's index over per-job entitlement utilisation
                    (peak_j / budget_j): 1.0 = every job uses the same
                    fraction of its arbiter-assigned slice

Scenarios (all ≥ 2 concurrent jobs, all dynamic):
    staggered          three equal jobs arriving half-an-iteration apart
    churn              short jobs joining and leaving around a long job;
                       a finishing job's bytes must be reclaimed
    priority-inversion memory-hog low-priority jobs start first, a
                       high-priority job arrives late and must still get
                       its weighted share
    bursty             a burst of small jobs interferes with one big job
    overload           sustained demand beyond device capacity; the service
                       plane's AdmissionQueue holds jobs until their
                       predicted peak (ExperienceStore fingerprint, else a
                       conservative cost-model bound) fits the unreserved
                       capacity — measuring queue wait, admission precision
                       (predicted vs measured peak), fairness over
                       slowdowns, and zero OOMs
    serving-pressure   continuous-batching LM decode (the real
                       ServingEngine) whose aggregate KV cache exceeds the
                       device budget: the KvResidencyPass swaps cold
                       sequences' blocks to host and prefetches them ahead
                       of their decode turn — zero OOMs and bit-identical
                       decode outputs where the unscheduled baseline OOMs

Preemption scenarios (arbiter mode "boundary" vs "preempt", measuring
**time-to-within-budget** — how long after a burst the device budget is
actually respected):
    flash-crowd          a crowd of small fast jobs lands mid-iteration of
                         a large unscheduled job; boundary arbitration
                         leaves the victim over-share until its next
                         iteration boundary, preemptive arbitration
                         hot-swaps an incremental shrink plan in at the
                         victim's next safe point
    preempt-vs-boundary  one joiner, head-to-head splice-latency numbers

Both preemption scenarios additionally run a **preempt-measured** mode:
safe points detected from the MEASURED residency telemetry
(`find_safe_points(source="measured")` over a probed TelemetryHub) and
the budget split by the `eor-learned` arbiter policy (weights from each
job's measured stall share) — the fully measured-plane variant of the
modeled `preempt` baseline.  Every policy row also reports
`calib_err_cold` / `calib_err` (analytic cost-model latency error before
and after hub-fed recalibration) and `measured_eor`.

Run:  python -m benchmarks.run --only scenarios [--smoke]
"""
from __future__ import annotations

import dataclasses
import functools
import json
import math
import sys
import tempfile
from typing import Dict, List, Optional, Tuple

sys.path.insert(0, __file__.rsplit("/", 2)[0] + "/src")

from repro.core import (BudgetArbiter, CostModel, DeviceCalibration,
                        ExperienceStore, MachineProfile, MemoryEngine,
                        PlanUpdate, SchedulerConfig, SchedulingPlan,
                        TelemetryHub, analyze, build_pipeline,
                        find_safe_points, simulate)
from repro.service import AdmissionQueue, JobSpec

# the CPU-sized MLP device class used by the system tests: fast to capture,
# slow enough per-op that swaps have real windows
PROFILE = MachineProfile(host_link_bw=16e9, compute_flops=5e10, mem_bw=1e10)

POLICIES = ("vanilla", "tensile", "tensile+priority", "tensile+autoscale")


# ----------------------------------------------------------------------
# Workloads: captured MLP training steps, cached per shape
# ----------------------------------------------------------------------
@functools.lru_cache(maxsize=None)
def _mlp_seq(sizes: Tuple[int, ...], batch: int):
    import jax
    import jax.numpy as jnp

    from repro.core import capture_train_step
    from repro.optim.adam import adamw_init, adamw_update

    def forward(params, x):
        h = x
        for i, p in enumerate(params):
            h = h @ p["w"] + p["b"]
            if i < len(params) - 1:
                h = jnp.tanh(h)
        return h

    def step(params, opt_state, b):
        x, y = b

        def loss_fn(p):
            return jnp.mean((forward(p, x) - y) ** 2)

        loss, grads = jax.value_and_grad(loss_fn)(params)
        params, opt_state = adamw_update(params, grads, opt_state, lr=1e-3)
        return params, opt_state, loss

    key = jax.random.PRNGKey(0)
    params = []
    for i in range(len(sizes) - 1):
        key, k = jax.random.split(key)
        params.append(
            {"w": jax.random.normal(k, (sizes[i], sizes[i + 1])) * 0.02,
             "b": jnp.zeros(sizes[i + 1])})
    opt = adamw_init(params)
    x = jax.random.normal(jax.random.PRNGKey(1), (batch, sizes[0]))
    y = jax.random.normal(jax.random.PRNGKey(2), (batch, sizes[-1]))
    seq, _closed = capture_train_step(step, params, opt, (x, y),
                                     job_id="mlp")
    return seq


# job size classes; smoke keeps shapes small so the whole suite stays
# CPU-sized (<5 min) for the CI bench-trajectory job
SHAPES = {
    "small": {True: ((32, 64, 64, 8), 8), False: ((64, 128, 128, 8), 16)},
    "medium": {True: ((64, 128, 128, 8), 16),
               False: ((64, 256, 256, 8), 32)},
    "large": {True: ((64, 256, 256, 8), 16),
              False: ((128, 512, 512, 8), 32)},
}


def _job(job_id: str, size: str, offset_frac: float, iterations: int,
         priority: Optional[float] = None) -> JobSpec:
    """Scenario shorthand over the service-plane ``JobSpec`` wire format:
    the job is the registered ``"mlp"`` workload at a size class, arriving
    at ``offset_frac`` mean-iterations.  The scenario runners map the size
    class through ``SHAPES`` (smoke-aware) themselves."""
    return JobSpec(job_id, workload="mlp", workload_params={"size": size},
                   offset_frac=offset_frac, iterations=iterations,
                   priority=priority)


def _size_of(js: JobSpec) -> str:
    return js.workload_params["size"]


def _priority_of(js: JobSpec) -> float:
    return js.priority if js.priority is not None else 1.0


@dataclasses.dataclass
class Scenario:
    name: str
    description: str
    jobs: List[JobSpec]
    arbiter_policy: str = "equal"
    budget_frac: float = 0.4    # device budget as a fraction of vanilla peak


SCENARIOS: List[Scenario] = [
    Scenario(
        name="staggered",
        description="three equal jobs launched half-an-iteration apart",
        jobs=[_job("s0", "medium", 0.0, 3),
              _job("s1", "medium", 0.5, 3),
              _job("s2", "medium", 1.0, 3)],
        arbiter_policy="equal"),
    Scenario(
        name="churn",
        description="short jobs join and leave around a long-running job; "
                    "finished jobs' budgets are reclaimed and redistributed",
        jobs=[_job("long", "medium", 0.0, 4),
              _job("short0", "small", 0.2, 1),
              _job("short1", "small", 0.8, 1),
              _job("late", "medium", 1.6, 2)],
        arbiter_policy="peak"),
    Scenario(
        name="priority-inversion",
        description="low-priority memory hogs start first; a high-priority "
                    "job arrives late and must still get its weighted share",
        jobs=[_job("hog0", "large", 0.0, 3, priority=1.0),
              _job("hog1", "large", 0.15, 3, priority=1.0),
              _job("vip", "medium", 0.6, 2, priority=4.0)],
        arbiter_policy="priority"),
    Scenario(
        name="bursty",
        description="a burst of small jobs interferes with one big job",
        jobs=[_job("big", "large", 0.0, 4)] + [
            _job(f"burst{i}", "small", 0.5 + 0.08 * i, 1)
            for i in range(4)],
        arbiter_policy="equal"),
]


# ----------------------------------------------------------------------
# Preemption scenarios: boundary vs safe-point arbitration
# ----------------------------------------------------------------------
@dataclasses.dataclass
class PreemptScenario:
    """A burst landing mid-iteration of a running victim.  The victim runs
    unscheduled pre-burst (a lone job under a big budget has no reason to
    swap); at ``burst_frac`` of its iteration a crowd of jobs arrives, the
    arbiter re-splits, and the two arbitration modes race to get the
    device back within budget."""

    name: str
    description: str
    victim_size: str                 # key into SHAPES
    victim_iterations: int
    burst_sizes: List[str]           # one job per entry
    burst_frac: float                # arrival, in victim-iteration units
    burst_stagger: float             # spacing between crowd members (same)
    burst_iterations: int
    victim_slice_frac: float         # victim's post-burst slice, as a
    #                                  fraction of its solo scheduled peak


PREEMPT_SCENARIOS: List[PreemptScenario] = [
    PreemptScenario(
        name="flash-crowd",
        description="a flash crowd of small fast jobs lands mid-iteration "
                    "of a large unscheduled job; preemptive arbitration "
                    "shrinks the victim at its next safe point, boundary "
                    "mode leaves it over-share until the next iteration",
        victim_size="large", victim_iterations=3,
        burst_sizes=["small", "small", "small"],
        burst_frac=0.12, burst_stagger=0.03, burst_iterations=3,
        victim_slice_frac=0.75),
    PreemptScenario(
        name="preempt-vs-boundary",
        description="one joiner arrives mid-iteration; head-to-head "
                    "time-to-within-budget for the two arbitration modes",
        victim_size="medium", victim_iterations=3,
        burst_sizes=["small"],
        burst_frac=0.12, burst_stagger=0.0, burst_iterations=3,
        victim_slice_frac=0.8),
]


def _calibration_metrics(hub: TelemetryHub) -> Dict[str, float]:
    """Modeled-vs-measured calibration quality for one simulated run.

    A CostModel is started from deliberately WRONG cold-start constants
    (4x off both throughput axes — the miscalibrated-device case) and
    recalibrated online from the run's telemetry; ``calib_err_cold`` is
    the analytic model's mean relative latency error before any feedback,
    ``calib_err`` after hub-fed recalibration.  The gap is exactly what
    the measured-telemetry plane buys; `calib_err` is gated by
    tools/check_bench_regression.py (>25 % regression fails CI)."""
    truth = DeviceCalibration()
    cm = CostModel(DeviceCalibration(flops=truth.flops / 4.0,
                                     mem_bw=truth.mem_bw / 4.0))
    cold = cm.calibration_report(hub)
    fit = cm.recalibrate(hub)
    return {"calib_err_cold": cold.overall, "calib_err": fit.overall,
            "calib_samples": fit.samples}


def _time_to_within(timeline, level: int, t_from: float) -> float:
    """Seconds from `t_from` until usage is back at or under `level` FOR
    GOOD: the first at-or-under sample after the LAST over-`level` state
    (0.0 if never over; ``inf`` if the run ENDS over the level — "never
    recovered" must not read as a plausible finite recovery time in the
    CI gate).  The state entering the window counts: usage left over
    `level` just before `t_from` is over at `t_from`."""
    last_over = None
    recover = None
    prev_used = 0
    for t, used in timeline:
        if t < t_from - EPS_T:
            prev_used = used
            continue
        if last_over is None and prev_used > level:
            last_over = t_from          # entered the window already over
        if used > level:
            last_over = t
            recover = None
        elif last_over is not None and recover is None:
            recover = t
        prev_used = used
    if last_over is None:
        return 0.0
    if recover is None:
        return float("inf")             # run ended over the level
    return max(0.0, recover - t_from)


EPS_T = 1e-12


def run_preempt_scenario(scn: PreemptScenario, smoke: bool = False) -> Dict:
    victim = "victim"
    vshape, vbatch = SHAPES[scn.victim_size][smoke]
    vseq = _mlp_seq(tuple(vshape), vbatch).clone(victim)
    T_v = vseq.iteration_time
    burst_ids = [f"crowd{i}" for i in range(len(scn.burst_sizes))]
    bseqs = []
    for jid, size in zip(burst_ids, scn.burst_sizes):
        shape, batch = SHAPES[size][smoke]
        bseqs.append(_mlp_seq(tuple(shape), batch).clone(jid))
    t_burst = scn.burst_frac * T_v
    offsets = {victim: 0.0}
    for i, jid in enumerate(burst_ids):
        offsets[jid] = t_burst + i * scn.burst_stagger * T_v
    iters = {victim: scn.victim_iterations}
    iters.update({j: scn.burst_iterations for j in burst_ids})
    T_burst = sum(s.iteration_time for s in bseqs) / len(bseqs)

    # pass 1 — plan the crowd against generous slices (their own solo
    # peaks): what each crowd member will actually hold is its PLANNED
    # peak, which is what the device must reserve for it
    vsolo = analyze([vseq]).peak_bytes             # scheduled-run semantics
    slice_target = int(vsolo * scn.victim_slice_frac)
    solo_peaks = {s.job_id: analyze([s]).peak_bytes for s in bseqs}
    cfg0 = SchedulerConfig(per_job_budget_bytes=dict(solo_peaks))
    pipe0 = build_pipeline("tensile+autoscale", profile=PROFILE, config=cfg0)
    crowd = pipe0.plan(bseqs, offsets={j: offsets[j] for j in burst_ids})
    demands = {j: crowd.plans[j].planned_peak_bytes for j in burst_ids}

    # pass 2 — the device budget is the victim's post-burst slice target
    # plus exactly those reservations; the arbiter's demand-capped
    # water-fill then reproduces the intended split (crowd capped at its
    # demand, the hungry victim takes the remainder)
    budget = slice_target + sum(demands.values())
    arbiter = BudgetArbiter(budget, policy="equal", mode="preempt")
    arbiter.register(victim, demand_bytes=0)       # hungry: uncapped
    for j, d in demands.items():
        arbiter.register(j, demand_bytes=d)
    budgets = arbiter.split([victim] + burst_ids)
    v_slice = budgets[victim]
    cfg = SchedulerConfig(memory_budget_bytes=budget,
                          per_job_budget_bytes=dict(budgets))
    pipe = build_pipeline("tensile+autoscale", profile=PROFILE, config=cfg)

    # victim plans: pre-burst none (unscheduled), boundary-mode full plan
    # against the new slice, preempt-mode incremental remainder plan from
    # the first safe point after the burst
    pre_plan = SchedulingPlan(job_id=victim)
    full = pipe.plan([vseq]).plans[victim]
    sps = find_safe_points(vseq, pre_plan)
    future = [sp for sp in sps if sp.time > t_burst]
    step = future[0].op_idx if future else len(vseq.operators) - 2
    inc = pipe.replan_from([vseq], {victim: pre_plan}, {victim: step},
                           budgets={victim: v_slice}).plans[victim]
    safe_ops = frozenset(sp.op_idx for sp in future)

    # measured plane ("preempt-measured" mode): probe the victim and the
    # crowd through the simulator with a TelemetryHub attached, detect the
    # victim's safe points from MEASURED residency (not the modeled
    # ledger), and split the budget with the eor-learned policy (weights
    # from each job's measured stall share; demand caps keep the split
    # sound when stalls are uniform)
    probe_hub = TelemetryHub(clock="virtual")
    simulate([vseq], {victim: pre_plan.copy()}, PROFILE, iterations=2,
             telemetry=probe_hub)
    simulate(bseqs, {j: p.copy() for j, p in crowd.plans.items()}, PROFILE,
             iterations=1, offsets={j: 0.0 for j in burst_ids},
             telemetry=probe_hub)
    sps_m = find_safe_points(vseq, pre_plan, source="measured",
                             telemetry=probe_hub)
    future_m = [sp for sp in sps_m if sp.time > t_burst]
    step_m = future_m[0].op_idx if future_m else step
    arb_m = BudgetArbiter(budget, policy="eor-learned", mode="preempt",
                          telemetry=probe_hub)
    arb_m.register(victim, demand_bytes=0)        # hungry: uncapped
    for j, d in demands.items():
        arb_m.register(j, demand_bytes=d)
    budgets_m = arb_m.split([victim] + burst_ids)
    inc_m = pipe.replan_from(
        [vseq], {victim: pre_plan}, {victim: step_m},
        budgets={victim: budgets_m[victim]}).plans[victim]
    safe_ops_m = frozenset(sp.op_idx for sp in future_m)

    # vanilla normalizer for EOR (paper §V-A)
    vanilla = simulate([vseq] + bseqs, None, PROFILE, iterations=iters,
                       offsets=offsets, free_at_last_use=False)

    rec = {
        "description": scn.description,
        "device_budget": budget,
        "vanilla_peak": vanilla.peak_bytes,
        "arbiter_policy": "equal",
        "t_burst": t_burst,
        "victim_iteration_time": T_v,
        "burst_iteration_time": T_burst,
        "victim_slice": v_slice,
        "jobs": {j: {"offset": offsets[j], "iterations": iters[j],
                     "priority": 1.0, "budget": budgets.get(j, 0)}
                 for j in [victim] + burst_ids},
        "policies": {},
    }

    for mode in ("boundary", "preempt", "preempt-measured"):
        updates = [PlanUpdate(at_time=t_burst, plan=full, mode="boundary")]
        mode_budgets, mode_slice = budgets, v_slice
        if mode == "preempt":
            updates.insert(0, PlanUpdate(
                at_time=t_burst, plan=inc, mode="safe-point",
                safe_ops=safe_ops))
        elif mode == "preempt-measured":
            updates.insert(0, PlanUpdate(
                at_time=t_burst, plan=inc_m, mode="safe-point",
                safe_ops=safe_ops_m))
            mode_budgets = budgets_m
            mode_slice = budgets_m[victim]
        plans = {victim: pre_plan.copy(), **crowd.plans}
        hub = TelemetryHub(clock="virtual")
        eng = MemoryEngine(PROFILE, capacity_bytes=budget)
        sim = simulate([vseq] + bseqs, plans, PROFILE, iterations=iters,
                       offsets=offsets, engine=eng,
                       plan_updates={victim: updates}, telemetry=hub)
        ttwb = _time_to_within(eng.ledger.timeline, budget, t_burst)
        ttws = _time_to_within(eng.ledger.job_timeline.get(victim, []),
                               mode_slice, t_burst)
        util = {j: sim.per_job_peak.get(j, 0)
                / max(mode_budgets.get(j, 1), 1)
                for j in mode_budgets}
        rec["policies"][mode] = {
            "peak": sim.peak_bytes,
            "within_budget": bool(sim.peak_bytes <= budget),
            "oom_events": eng.ledger.oom_events,
            "MSR": sim.msr(vanilla), "EOR": sim.eor(vanilla),
            "CBR": sim.cbr(vanilla),
            "time": sim.total_time,
            "fairness": jain_fairness(util),
            "per_job_peak": dict(sim.per_job_peak),
            "swap_conflicts": sim.swap_conflicts,
            "passive_swap_ins": sim.passive_swap_ins,
            # device-level: seconds/iterations after the burst until the
            # ledger stays ≤ the device budget
            "ttwb_s": ttwb,
            "ttwb_victim_iters": ttwb / T_v,
            "ttwb_burst_iters": ttwb / T_burst,
            # victim-level: until the victim stays ≤ its shrunken slice
            "victim_ttws_s": ttws,
            "victim_ttws_victim_iters": ttws / T_v,
            "victim_ttws_burst_iters": ttws / T_burst,
            "plan_swaps": {j: list(map(list, v))
                           for j, v in sim.plan_swaps.items()},
            "canceled_swap_ins": sim.canceled_swap_ins,
            "measured_eor": max((hub.measured_eor(j)
                                 for j in [victim] + burst_ids),
                                default=0.0),
            **_calibration_metrics(hub),
        }
    return rec


# ----------------------------------------------------------------------
# Cold vs warm boot: the experience plane's headline scenario
# ----------------------------------------------------------------------
@dataclasses.dataclass
class ColdWarmScenario:
    """The same workload mix run twice: once against a FRESH experience
    store (cold boot — deliberately miscalibrated cold-start constants,
    plan from scratch, the first iteration runs before any plan exists)
    and once against the store the cold run populated (warm boot —
    persisted calibration from construction, verified cached plan active
    from iteration 0).  This is the paper's cold-start problem made
    measurable: recurring workloads should not pay the cold price twice."""

    name: str
    description: str
    jobs: List[JobSpec]


COLD_WARM = ColdWarmScenario(
    name="cold-vs-warm",
    description="a workload mix run twice — against a fresh experience "
                "store (cold: 4x-miscalibrated constants, plan from "
                "scratch, first iteration unscheduled) and against the "
                "store the cold run populated (warm: persisted "
                "calibration, verified cached plan from iteration 0)",
    jobs=[_job("mix0", "medium", 0.0, 3),
          _job("mix1", "small", 0.4, 3)])


def _relatency(seq, cm: CostModel) -> None:
    """Re-estimate the sequence's operator latencies through a cost
    model — the capture-time path (graph_capture feeds analytic
    latencies from the model's calibration), applied to a clone."""
    seq.set_latencies([cm.latency(op.flops, op.bytes_accessed, op.name)
                       for op in seq.operators])


def run_cold_warm_scenario(scn: ColdWarmScenario, smoke: bool = False,
                           experience_dir: Optional[str] = None) -> Dict:
    """Cold run then warm run; the warm run's store is ``experience_dir``
    when given (CI persists it across runs via actions/cache — a
    populated dir proves warm boot works across whole CI runs, not just
    within one process), else a scratch dir populated by the cold run.
    The cold run always plans against a fresh empty store."""
    truth = DeviceCalibration()
    cold_calib = DeviceCalibration(flops=truth.flops / 4.0,
                                   mem_bw=truth.mem_bw / 4.0)

    base: Dict[str, object] = {}
    for js in scn.jobs:
        shape, batch = SHAPES[_size_of(js)][smoke]
        base[js.job_id] = _mlp_seq(tuple(shape), batch).clone(js.job_id)
    seqs = list(base.values())
    mean_T = sum(s.iteration_time for s in seqs) / len(seqs)
    offsets = {js.job_id: js.offset_frac * mean_T for js in scn.jobs}
    iters = {js.job_id: js.iterations for js in scn.jobs}

    # the PLANNING budget: the simulated peak of the tensile plan
    # converged against that same budget (fixed point, 3 % headroom for
    # plan-vs-run drift).  The scenario's DEVICE budget is set below from
    # the cold run's own converged plan — "what the cold boot only
    # achieves after converging is what the warm boot must achieve at
    # iteration 0"
    plan_budget = None
    for _ in range(3):
        cfg = SchedulerConfig(memory_budget_bytes=plan_budget)
        probe = build_pipeline("tensile", profile=PROFILE,
                               config=cfg).plan(seqs, offsets=offsets)
        probe_sim = simulate(seqs, {j: p.copy()
                                    for j, p in probe.plans.items()},
                             PROFILE, iterations=iters, offsets=offsets)
        nxt = int(probe_sim.peak_bytes * 1.03)
        if plan_budget is not None and nxt <= plan_budget:
            break
        plan_budget = nxt
    unsched = simulate(seqs, None, PROFILE, iterations=iters,
                       offsets=offsets)
    vanilla = simulate(seqs, None, PROFILE, iterations=iters,
                       offsets=offsets, free_at_last_use=False)
    first_window = max(offsets[j] + base[j].iteration_time for j in base)

    warm_root = experience_dir or tempfile.mkdtemp(prefix="tensile-exp-")
    warm_store = ExperienceStore(warm_root, device_id="scenario-device")
    warm_preexisting = all(
        warm_store.get(warm_store.fingerprint(base[j])) is not None
        for j in base)

    def _clones(cm: CostModel) -> List:
        out = []
        for j in base:
            s = base[j].clone(j)
            _relatency(s, cm)
            out.append(s)
        return out

    def _first_peak(eng: MemoryEngine) -> int:
        return max((used for t, used in eng.ledger.timeline
                    if t <= first_window + EPS_T), default=0)

    def _count_oom(eng: MemoryEngine, cap: int) -> int:
        """Allocations that landed above `cap`, replayed from the ledger
        timeline (the sims run capacity-free so the device budget can be
        fixed AFTER the cold run's converged plan is known — the ledger's
        own counter uses the identical alloc-above-capacity rule)."""
        count, prev = 0, 0
        for _t, used in eng.ledger.timeline:
            if used > prev and used > cap:
                count += 1
            prev = used
        return count

    # ---- COLD: fresh store, miscalibrated constants ------------------
    cold_store = ExperienceStore(tempfile.mkdtemp(prefix="tensile-cold-"),
                                 device_id="scenario-device")
    cold_cm = CostModel(DeviceCalibration(flops=cold_calib.flops,
                                          mem_bw=cold_calib.mem_bw))
    cold_seqs = _clones(cold_cm)
    pipe = build_pipeline("tensile", profile=PROFILE,
                          config=SchedulerConfig(
                              memory_budget_bytes=plan_budget))
    pipe.experience = cold_store          # empty: every lookup misses
    res_cold = pipe.plan(cold_seqs, offsets=offsets)
    # the cold system has NO plan at launch: iteration 0 runs unscheduled
    # and the freshly planned version lands at each job's first boundary
    # (the paper's "right before computing the next batch")
    updates = {j: [PlanUpdate(at_time=offsets[j], plan=res_cold.plans[j],
                              mode="boundary")] for j in base}
    hub_c = TelemetryHub(clock="virtual")
    eng_c = MemoryEngine(PROFILE)
    sim_c = simulate(seqs, {j: SchedulingPlan(job_id=j) for j in base},
                     PROFILE, iterations=iters, offsets=offsets,
                     engine=eng_c, plan_updates=updates, telemetry=hub_c)
    calib_first_c = cold_cm.calibration_report(hub_c).overall
    fit_c = cold_cm.recalibrate(hub_c)
    # the experience the store keeps: the plan REPLANNED on recalibrated
    # latencies (the §IV-E loop closing before persistence)
    conv_seqs = _clones(cold_cm)
    res_conv = build_pipeline(
        "tensile", profile=PROFILE,
        config=SchedulerConfig(memory_budget_bytes=plan_budget)).plan(
            conv_seqs, offsets=offsets)
    conv_sim = simulate(seqs, {j: p.copy()
                               for j, p in res_conv.plans.items()},
                        PROFILE, iterations=iters, offsets=offsets)
    # the DEVICE budget the two boots are judged against: what the cold
    # boot only achieves after converging (its replanned plan's simulated
    # peak + 3 % headroom; floored at the planning target) — the warm
    # boot must deliver it from iteration 0
    budget = max(plan_budget, int(conv_sim.peak_bytes * 1.03))
    for s in conv_seqs:
        warm_store.record_job(
            warm_store.fingerprint(s), seq=s, hub=hub_c, job_id=s.job_id,
            plan=res_conv.plans[s.job_id], pipeline="tensile",
            peak_bytes=eng_c.ledger.job_peak(s.job_id),
            calib=cold_cm.calib,
            calib_samples=fit_c.samples)
    warm_store.flush()

    rec = {
        "description": scn.description,
        "device_budget": budget,
        "plan_budget": plan_budget,
        "vanilla_peak": vanilla.peak_bytes,
        "unscheduled_peak": unsched.peak_bytes,
        "arbiter_policy": "none",
        "jobs": {j: {"offset": offsets[j], "iterations": iters[j],
                     "priority": 1.0, "budget": budget}
                 for j in base},
        "policies": {},
        "modes": {},
        "store_root": warm_root,
        "warm_store_preexisting": warm_preexisting,
    }
    rec["modes"]["cold"] = {
        "peak": sim_c.peak_bytes,
        "within_budget": bool(sim_c.peak_bytes <= budget),
        "first_iter_peak": _first_peak(eng_c),
        "first_iter_within_budget": bool(_first_peak(eng_c) <= budget),
        "oom_events": _count_oom(eng_c, budget),
        "MSR": sim_c.msr(vanilla), "EOR": sim_c.eor(vanilla),
        "CBR": sim_c.cbr(vanilla), "time": sim_c.total_time,
        "ttfp_s": res_cold.plan_wallclock_s,
        "plan_iterations": res_cold.iterations,
        "plan_cache_hit": False,
        "calib_err_cold": calib_first_c,
        "calib_err": fit_c.overall,
        "calib_samples": fit_c.samples,
    }

    # ---- WARM: the populated store -----------------------------------
    warm_cm = CostModel(calib=warm_store.device_calibration()
                        or DeviceCalibration(flops=cold_calib.flops,
                                             mem_bw=cold_calib.mem_bw))
    warm_seqs = _clones(warm_cm)
    pipe_w = build_pipeline("tensile", profile=PROFILE,
                            config=SchedulerConfig(
                                memory_budget_bytes=budget))
    pipe_w.experience = warm_store
    res_warm = pipe_w.plan(warm_seqs, offsets=offsets)
    cache_hit = all(
        any(r.get("action") == "warm-boot"
            for r in res_warm.plans[j].provenance)
        for j in base)
    hub_w = TelemetryHub(clock="virtual")
    eng_w = MemoryEngine(PROFILE)
    # warm boot: the verified cached plan is ACTIVE from iteration 0
    sim_w = simulate(seqs, {j: res_warm.plans[j].copy() for j in base},
                     PROFILE, iterations=iters, offsets=offsets,
                     engine=eng_w, telemetry=hub_w)
    calib_first_w = warm_cm.calibration_report(hub_w).overall
    fit_w = warm_cm.recalibrate(hub_w)
    for s in warm_seqs:
        warm_store.record_job(
            warm_store.fingerprint(s), seq=s, hub=hub_w, job_id=s.job_id,
            plan=res_warm.plans[s.job_id], pipeline="tensile",
            peak_bytes=eng_w.ledger.job_peak(s.job_id),
            calib=warm_cm.calib, calib_samples=fit_w.samples)
    warm_store.flush()
    rec["modes"]["warm"] = {
        "peak": sim_w.peak_bytes,
        "within_budget": bool(sim_w.peak_bytes <= budget),
        "first_iter_peak": _first_peak(eng_w),
        "first_iter_within_budget": bool(_first_peak(eng_w) <= budget),
        "oom_events": _count_oom(eng_w, budget),
        "MSR": sim_w.msr(vanilla), "EOR": sim_w.eor(vanilla),
        "CBR": sim_w.cbr(vanilla), "time": sim_w.total_time,
        "ttfp_s": res_warm.plan_wallclock_s,
        "plan_iterations": res_warm.iterations,
        "plan_cache_hit": cache_hit,
        "calib_err_cold": calib_first_w,
        "calib_err": fit_w.overall,
        "calib_samples": fit_w.samples,
    }
    return rec


# ----------------------------------------------------------------------
# Arbiter replay: min assignment over the scenario's launch/finish phases
# ----------------------------------------------------------------------
def replay_arbiter(arbiter: BudgetArbiter,
                   windows: Dict[str, Tuple[float, float]]
                   ) -> Dict[str, int]:
    """Walk the scenario's launch/finish events; at each boundary the
    arbiter re-splits the device budget over the live set (exactly what the
    Global Controller does at every launch/finish replan).  A job plans
    once against its *minimum* assignment over its lifetime, so the split
    stays sound in the most-crowded phase it lives through."""
    boundaries = sorted({t for w in windows.values() for t in w})
    assigned: Dict[str, int] = {}
    for lo, hi in zip(boundaries, boundaries[1:]):
        mid = 0.5 * (lo + hi)
        live = [j for j, (s, e) in windows.items() if s <= mid < e]
        if not live:
            continue
        split = arbiter.split(live)
        for j, b in split.items():
            assigned[j] = min(assigned.get(j, b), b)
    return assigned


def jain_fairness(utilisation: Dict[str, float]) -> float:
    xs = [max(x, 0.0) for x in utilisation.values()]
    if not xs or not any(xs):
        return 1.0
    return min(1.0, (sum(xs) ** 2) / (len(xs) * sum(x * x for x in xs)))


# ----------------------------------------------------------------------
# One scenario under one policy
# ----------------------------------------------------------------------
def _build_jobs(scn: Scenario, smoke: bool):
    seqs, offsets, iters, prios = [], {}, {}, {}
    mean_T = 0.0
    for js in scn.jobs:
        shape, batch = SHAPES[_size_of(js)][smoke]
        seq = _mlp_seq(tuple(shape), batch).clone(js.job_id)
        seqs.append(seq)
        mean_T += seq.iteration_time
    mean_T /= len(seqs)
    for js, seq in zip(scn.jobs, seqs):
        offsets[js.job_id] = js.offset_frac * mean_T
        iters[js.job_id] = js.iterations
        prios[js.job_id] = _priority_of(js)
    return seqs, offsets, iters, prios


def run_scenario(scn: Scenario, smoke: bool = False,
                 policies=POLICIES) -> Dict:
    seqs, offsets, iters, prios = _build_jobs(scn, smoke)
    jobs = {s.job_id: s for s in seqs}

    # vanilla reference: nothing freed before iteration end (paper §V-A)
    vanilla = simulate(seqs, None, PROFILE, iterations=iters,
                       offsets=offsets, free_at_last_use=False)
    budget = int(vanilla.peak_bytes * scn.budget_frac)

    # the arbiter split each job plans against (launch/finish replay)
    arbiter = BudgetArbiter(budget, policy=scn.arbiter_policy)
    windows = {}
    for s in seqs:
        arbiter.register(
            s.job_id, priority=prios[s.job_id],
            demand_bytes=analyze([s], free_at_last_use=False).peak_bytes)
        start = offsets[s.job_id]
        windows[s.job_id] = (start,
                            start + iters[s.job_id] * s.iteration_time)
    budgets = replay_arbiter(arbiter, windows)

    rec = {
        "description": scn.description,
        "device_budget": budget,
        "vanilla_peak": vanilla.peak_bytes,
        "arbiter_policy": scn.arbiter_policy,
        "jobs": {j: {"offset": offsets[j], "iterations": iters[j],
                     "priority": prios[j], "budget": budgets.get(j, 0)}
                 for j in jobs},
        "policies": {},
    }

    equal_split = {j: budget // len(jobs) for j in jobs}
    for policy in policies:
        cfg = SchedulerConfig(memory_budget_bytes=budget,
                              job_priorities=dict(prios))
        entitlement = equal_split
        if policy in ("tensile+priority", "tensile+autoscale"):
            cfg.per_job_budget_bytes = dict(budgets)
            entitlement = budgets
        plans = None
        plan_wall = 0.0
        if policy != "vanilla":
            res = build_pipeline(policy, profile=PROFILE, config=cfg) \
                .plan(seqs, offsets=offsets)
            plans = res.plans
            plan_wall = res.plan_wallclock_s
        hub = TelemetryHub(clock="virtual")
        eng = MemoryEngine(PROFILE, capacity_bytes=budget)
        sim = simulate(seqs, plans, PROFILE, iterations=iters,
                       offsets=offsets,
                       free_at_last_use=(policy != "vanilla"),
                       engine=eng, telemetry=hub)
        msr = sim.msr(vanilla)
        eor = sim.eor(vanilla)
        util = {j: sim.per_job_peak.get(j, 0) / max(entitlement.get(j, 1), 1)
                for j in jobs}
        rec["policies"][policy] = {
            "peak": sim.peak_bytes,
            "within_budget": bool(sim.peak_bytes <= budget),
            "oom_events": eng.ledger.oom_events,
            "MSR": msr, "EOR": eor,
            "CBR": sim.cbr(vanilla),
            "time": sim.total_time,
            "fairness": jain_fairness(util),
            "per_job_peak": dict(sim.per_job_peak),
            "swap_conflicts": sim.swap_conflicts,
            "passive_swap_ins": sim.passive_swap_ins,
            "plan_wallclock_s": plan_wall,
            "measured_eor": max((hub.measured_eor(j) for j in jobs),
                                default=0.0),
            **_calibration_metrics(hub),
        }
    return rec


# ----------------------------------------------------------------------
# Overload: admission control under sustained demand beyond capacity
# ----------------------------------------------------------------------
@dataclasses.dataclass
class OverloadScenario:
    """More demand than the device can ever hold at once.  Jobs are held in
    the service plane's ``AdmissionQueue`` and admitted only when their
    predicted peak fits the unreserved capacity: warm size classes predict
    from an ``ExperienceStore`` fingerprint a probe run populated, cold
    classes get the conservative no-free cost-model bound, refined to the
    measured peak after the job's first iteration (freeing headroom that
    admits waiting jobs).  The replay is virtual-time and deterministic —
    the exact admission policy the live daemon runs, minus wall clocks."""

    name: str
    description: str
    jobs: List[JobSpec]            # offset_frac = submission time
    warm_sizes: Tuple[str, ...]    # size classes probed into the store
    capacity_frac: float           # capacity / sum of predicted peaks
    arbiter_policy: str = "priority"


OVERLOAD = OverloadScenario(
    name="overload",
    description="sustained demand beyond device capacity: eight jobs submit "
                "within one iteration; the admission queue holds them until "
                "their predicted peak (experience fingerprint, else a "
                "conservative cost-model bound refined after one profiled "
                "iteration) fits the unreserved capacity",
    jobs=[_job("o0", "large", 0.0, 2),
          _job("o1", "medium", 0.1, 2),
          _job("o2", "medium", 0.2, 2),
          _job("o3", "small", 0.3, 2),           # cold: conservative bound
          _job("o4", "large", 0.4, 2, priority=2.0),
          _job("o5", "medium", 0.5, 2),
          _job("o6", "medium", 0.6, 2),
          _job("o7", "large", 0.7, 2)],
    warm_sizes=("medium", "large"),
    capacity_frac=0.45)

# admission keeps this fraction of device capacity unreserved, absorbing
# plan-vs-run drift so certified per-job peaks never sum past the device
ADMISSION_HEADROOM = 0.03
# reservations are taken at predicted * (1 + margin): the prediction is the
# experience-measured peak; the margin absorbs residual DMA-contention
# drift between the probed mix and the live one
RESERVE_MARGIN = 0.10


def _admission_replay(capacity: int, order: List[str],
                      submit: Dict[str, float], predicted: Dict[str, int],
                      sources: Dict[str, str], prios: Dict[str, float],
                      durations: Dict[str, float],
                      first_iter: Dict[str, float],
                      measured: Optional[Dict[str, int]] = None):
    """Deterministic virtual-time replay of the admission queue.

    Events: job submissions, reservation refinements (one iteration after
    admission, when ``measured`` peaks are known from a prior pass), and
    job finishes.  After every event the queue admits whatever fits.
    Returns (admit_times, queue) — the queue carries the reservation
    high-water mark and admission log for the CI contract."""
    q = AdmissionQueue(capacity)
    events: List[Tuple[float, int, str, str]] = [
        (submit[j], i, "submit", j) for i, j in enumerate(order)]
    admit: Dict[str, float] = {}
    n = len(order)
    while events:
        events.sort()
        t, _, kind, jid = events.pop(0)
        if kind == "submit":
            q.push(jid, predicted[jid], priority=prios[jid],
                   source=sources[jid], enqueued_at=t)
        elif kind == "refine" and measured is not None \
                and measured.get(jid, 0) > 0:
            q.refine(jid, measured[jid])
        elif kind == "finish":
            q.release(jid)
        for job in q.pop_admissible(t):
            admit[job.job_id] = t
            n += 1
            events.append((t + durations[job.job_id], n, "finish",
                           job.job_id))
            if measured is not None:
                n += 1
                events.append((t + first_iter[job.job_id], n, "refine",
                               job.job_id))
    return admit, q


def run_overload_scenario(scn: OverloadScenario, smoke: bool = False) -> Dict:
    base: Dict[str, object] = {}
    for js in scn.jobs:
        shape, batch = SHAPES[_size_of(js)][smoke]
        base[js.job_id] = _mlp_seq(tuple(shape), batch).clone(js.job_id)
    order = [js.job_id for js in scn.jobs]
    seqs = [base[j] for j in order]
    mean_T = sum(s.iteration_time for s in seqs) / len(seqs)
    submit = {js.job_id: js.offset_frac * mean_T for js in scn.jobs}
    iters = {js.job_id: js.iterations for js in scn.jobs}
    prios = {js.job_id: _priority_of(js) for js in scn.jobs}
    T = {j: base[j].iteration_time for j in order}

    # ---- warm phase: probe each warm size class solo, distill into a
    # scratch experience store (fingerprints are structural, so one probe
    # covers every job instance of that class)
    store = ExperienceStore(tempfile.mkdtemp(prefix="tensile-overload-"),
                            device_id="scenario-device")
    for size in scn.warm_sizes:
        shape, batch = SHAPES[size][smoke]
        probe = _mlp_seq(tuple(shape), batch).clone(f"warm-{size}")
        plan_budget = None
        for _ in range(3):      # converge budget -> simulated peak
            cfg = SchedulerConfig(memory_budget_bytes=plan_budget)
            res_p = build_pipeline("tensile+autoscale", profile=PROFILE,
                                   config=cfg).plan([probe])
            sim_p = simulate([probe],
                             {probe.job_id: res_p.plans[probe.job_id].copy()},
                             PROFILE, iterations=1)
            nxt = int(sim_p.peak_bytes * 1.03)
            if plan_budget is not None and nxt <= plan_budget:
                break
            plan_budget = nxt
        # the peak the store remembers is measured CONTENDED: two clones of
        # the class share the device half-an-iteration apart, both planned
        # against the converged solo budget — a multi-tenant daemon's prior
        # runs are contended, and contention-delayed swap-outs are what
        # make a solo-probed peak underpredict the live mix
        mate = _mlp_seq(tuple(shape), batch).clone(f"warm2-{size}")
        duo_offsets = {probe.job_id: 0.0,
                       mate.job_id: 0.5 * probe.iteration_time}
        cfg_d = SchedulerConfig(
            memory_budget_bytes=2 * plan_budget,
            per_job_budget_bytes={probe.job_id: plan_budget,
                                  mate.job_id: plan_budget})
        res_d = build_pipeline("tensile+autoscale", profile=PROFILE,
                               config=cfg_d).plan([probe, mate],
                                                  offsets=duo_offsets)
        hub_p = TelemetryHub(clock="virtual")
        eng_p = MemoryEngine(PROFILE)
        sim_d = simulate([probe, mate],
                         {j: p.copy() for j, p in res_d.plans.items()},
                         PROFILE, iterations={probe.job_id: 2,
                                              mate.job_id: 2},
                         offsets=duo_offsets, engine=eng_p, telemetry=hub_p)
        store.record_job(store.fingerprint(probe), seq=probe, hub=hub_p,
                         job_id=probe.job_id,
                         plan=res_p.plans[probe.job_id],
                         pipeline="tensile+autoscale",
                         peak_bytes=max(sim_d.per_job_peak.values()))
    store.flush()

    # ---- admission predictions: experience for warm fingerprints, the
    # conservative no-free bound for cold ones (the daemon's predict_peak)
    predicted: Dict[str, int] = {}
    sources: Dict[str, str] = {}
    for j in order:
        prior = store.predicted_peak(base[j])
        if prior is not None:
            predicted[j], sources[j] = prior
        else:
            predicted[j] = int(analyze([base[j]],
                                       free_at_last_use=False).peak_bytes)
            sources[j] = "cost-model"
    # reservations carry the drift margin; the planning budget stays at the
    # raw prediction (planning to the margin would waste device memory)
    reserve = {j: int(predicted[j] * (1.0 + RESERVE_MARGIN)) for j in order}
    # floored so the largest reservation still fits the admission
    # capacity after headroom — overload means waiting, not rejection
    capacity = max(int(sum(predicted.values()) * scn.capacity_frac),
                   int(max(reserve.values())
                       / (1.0 - ADMISSION_HEADROOM)) + 1)
    adm_capacity = int(capacity * (1.0 - ADMISSION_HEADROOM))

    # vanilla normalizer: every job starts at its SUBMIT time, nothing
    # freed before iteration end — what an unmanaged device would attempt
    vanilla = simulate(seqs, None, PROFILE, iterations=iters,
                       offsets=submit, free_at_last_use=False,
                       job_lifecycle=True)

    # ---- admission replay + planned sim, iterated to a fixed point: the
    # replay needs per-job durations, which depend on admission times.
    # Pass 1 estimates durations from solo iteration times; later passes
    # use the previous sim's measured finishes and refine cold
    # reservations from measured peaks.  Deterministic throughout.
    durations = {j: iters[j] * T[j] for j in order}
    first_iter = dict(T)
    measured: Optional[Dict[str, int]] = None
    admit: Dict[str, float] = {}
    for _pass in range(6):
        prev_admit = dict(admit)
        admit, q = _admission_replay(adm_capacity, order, submit, reserve,
                                     sources, prios, durations, first_iter,
                                     measured)
        budgets = {j: min(predicted[j], adm_capacity) for j in order}
        cfg = SchedulerConfig(memory_budget_bytes=capacity,
                              per_job_budget_bytes=budgets,
                              job_priorities=dict(prios))
        res = build_pipeline("tensile+autoscale", profile=PROFILE,
                             config=cfg).plan(seqs, offsets=admit)
        hub = TelemetryHub(clock="virtual")
        eng = MemoryEngine(PROFILE, capacity_bytes=capacity)
        sim = simulate(seqs, {j: res.plans[j].copy() for j in order},
                       PROFILE, iterations=iters, offsets=admit,
                       job_lifecycle=True, engine=eng, telemetry=hub)
        measured = {j: sim.per_job_peak.get(j, 0) for j in order}
        durations = {}
        for j in order:
            tl = eng.ledger.job_timeline.get(j, [])
            end = tl[-1][0] if tl else admit[j] + iters[j] * T[j]
            durations[j] = max(end - admit[j], T[j])
        if prev_admit == admit:
            break

    # ---- no-admission baseline: same plans, but every job starts the
    # moment it is submitted — reservations ignored, capacity busted
    hub0 = TelemetryHub(clock="virtual")
    eng0 = MemoryEngine(PROFILE, capacity_bytes=capacity)
    sim0 = simulate(seqs, {j: res.plans[j].copy() for j in order},
                    PROFILE, iterations=iters, offsets=submit,
                    job_lifecycle=True, engine=eng0, telemetry=hub0)

    waits = {j: admit[j] - submit[j] for j in order}
    wait_iters = {j: waits[j] / T[j] for j in order}
    # fairness over per-job slowdowns (wait+run)/run — 1.0 = every job
    # delayed in equal proportion
    slowdown = {j: (waits[j] + durations[j]) / max(durations[j], 1e-12)
                for j in order}
    warm = [j for j in order if sources[j].startswith("experience")]
    cold = [j for j in order if not sources[j].startswith("experience")]
    prec = {j: abs(predicted[j] - measured[j]) / max(measured[j], 1)
            for j in warm}
    bound_ratio = {j: predicted[j] / max(measured[j], 1) for j in cold}

    def _row(s, e, h, queue_stats):
        return {
            "peak": s.peak_bytes,
            "within_budget": bool(s.peak_bytes <= capacity),
            "oom_events": e.ledger.oom_events,
            "MSR": s.msr(vanilla), "EOR": s.eor(vanilla),
            "CBR": s.cbr(vanilla),
            "time": s.total_time,
            "per_job_peak": dict(s.per_job_peak),
            "swap_conflicts": s.swap_conflicts,
            "passive_swap_ins": s.passive_swap_ins,
            "measured_eor": max((h.measured_eor(j) for j in order),
                                default=0.0),
            **queue_stats,
            **_calibration_metrics(h),
        }

    rec = {
        "description": scn.description,
        "device_budget": capacity,
        "admission_capacity": adm_capacity,
        "vanilla_peak": vanilla.peak_bytes,
        "arbiter_policy": scn.arbiter_policy,
        "jobs": {j: {"offset": submit[j], "iterations": iters[j],
                     "priority": prios[j], "budget": budgets[j],
                     "predicted_peak": predicted[j],
                     "predicted_source": sources[j],
                     "admitted_at": admit[j],
                     "queue_wait_iters": wait_iters[j]}
                 for j in order},
        "policies": {},
    }
    rec["policies"]["admission"] = _row(sim, eng, hub, {
        "fairness": jain_fairness(slowdown),
        "queue_wait_mean_iters": sum(wait_iters.values()) / len(order),
        "queue_wait_max_iters": max(wait_iters.values()),
        "admission_max_abs_err": max(prec.values()) if prec else 0.0,
        "admission_mean_abs_err": (sum(prec.values()) / len(prec))
        if prec else 0.0,
        "cold_bound_ratio": max(bound_ratio.values()) if bound_ratio else 0.0,
        "max_reserved_bytes": q.max_reserved_bytes,
        "max_reserved_frac": q.max_reserved_bytes / capacity,
        "admitted_over_capacity": int(q.max_reserved_bytes > adm_capacity),
        "admitted_jobs": len(admit),
    })
    rec["policies"]["no-admission"] = _row(sim0, eng0, hub0, {
        "fairness": jain_fairness({j: 1.0 for j in order}),
        "queue_wait_mean_iters": 0.0,
        "queue_wait_max_iters": 0.0,
        "admission_max_abs_err": None,
        "admission_mean_abs_err": None,
        "cold_bound_ratio": None,
        "max_reserved_bytes": 0,
        "max_reserved_frac": 0.0,
        "admitted_over_capacity": 0,
        "admitted_jobs": len(order),
    })
    return rec


# ----------------------------------------------------------------------
# Serving pressure: continuous-batching decode under a KV-cache budget
# ----------------------------------------------------------------------
@dataclasses.dataclass
class ServingScenario:
    """An LM decode mix whose full KV footprint exceeds the device budget.

    The real :class:`~repro.serving.engine.ServingEngine` serves the same
    arrival trace three ways: ``unpressured`` (no budget — the reference
    run whose outputs are golden), ``kv-schedule`` (the KvResidencyPass
    swaps cold sequences' cache blocks to host and prefetches them ahead
    of their decode turn; prefills admitted through the AdmissionQueue),
    and ``no-schedule`` (same capacity, residency scheduling off — the
    ledger counts every capacity crossing as an OOM event).  The contract
    row: under pressure the scheduled run stays OOM-free with decode
    outputs bit-identical to the unpressured run, at tokens/sec within a
    fixed band of it; the unscheduled baseline OOMs by construction."""

    name: str
    description: str
    arch: str = "tinyllama-1.1b"
    max_sequences: int = 4
    trace: str = "poisson"         # staggered arrivals, bursty in bulk
    mean_gap: float = 0.002
    block_tokens: int = 4
    resident_slots: int = 2        # budget ~= this many full sequences
    # (prompt_len, gen_len, n_requests) per variant
    shape: Dict[bool, Tuple[int, int, int]] = dataclasses.field(
        default_factory=lambda: {True: (4, 8, 6), False: (8, 16, 10)})


SERVING = ServingScenario(
    name="serving-pressure",
    description="continuous-batching LM decode whose aggregate KV cache "
                "exceeds the device budget: cold sequences' cache blocks "
                "swap to host between decode turns and are prefetched "
                "ahead of their next turn; the same trace without "
                "residency scheduling busts the capacity",
)


def run_serving_scenario(scn: ServingScenario, smoke: bool = False) -> Dict:
    from repro.serving import ServingEngine, make_trace

    prompt_len, gen_len, n_requests = scn.shape[bool(smoke)]
    max_len = prompt_len + gen_len
    eng = ServingEngine(scn.arch, max_sequences=scn.max_sequences,
                        max_len=max_len, seed=0)
    requests = make_trace(scn.trace, n_requests, seed=0,
                          prompt_len=prompt_len, gen_len=gen_len,
                          mean_gap=scn.mean_gap)
    # the budget holds `resident_slots` full sequences plus a little slack
    # — strictly less than the mix's full footprint, so the unscheduled
    # baseline cannot fit
    bpt = eng.bytes_per_token
    budget = bpt * (max_len * scn.resident_slots + 2)
    full_footprint = bpt * max_len * scn.max_sequences
    assert budget < full_footprint

    def _serve(capacity, serve_budget, schedule):
        mem = MemoryEngine(PROFILE, capacity_bytes=capacity, trace=True)
        report, outputs = eng.serve(
            requests, budget_bytes=serve_budget, schedule=schedule,
            block_tokens=scn.block_tokens, engine=mem, job_id="serve")
        return report, outputs

    # golden reference: no budget, no scheduling
    ref, golden = _serve(None, None, False)
    sched, out_s = _serve(budget, budget, True)
    base, out_b = _serve(budget, budget, False)

    def _srow(report, outputs):
        eor = max(report.total_time - ref.total_time, 0.0) \
            / max(ref.total_time, 1e-12)
        msr = 1.0 - report.peak_bytes / max(ref.peak_bytes, 1)
        p99 = report.ttft_p99
        return {
            "time": report.total_time,
            "peak": report.peak_bytes,
            "within_budget": bool(report.peak_bytes <= budget),
            "oom_events": report.oom_events,
            "MSR": msr, "EOR": eor,
            "CBR": msr / eor if eor > 0 else 0.0,
            "fairness": jain_fairness(report.ttft),
            "tokens_per_s": report.tokens_per_s,
            "ttft_mean": report.ttft_mean,
            "ttft_p99": p99 if math.isfinite(p99) else None,
            "decode_bit_identical": bool(outputs == golden),
            "served": report.served,
            "rejected": len(report.rejected),
            "evictions": report.evictions,
            "prefetches": report.prefetches,
            "stall_time": report.stall_time,
            "swapped_out_bytes": report.swapped_out_bytes,
            "swapped_in_bytes": report.swapped_in_bytes,
        }

    rec = {
        "description": scn.description,
        "device_budget": budget,
        "full_footprint_bytes": full_footprint,
        "bytes_per_token": bpt,
        "arch": scn.arch,
        "trace": scn.trace,
        "jobs": {r.rid: {"offset": r.arrival,
                         "iterations": r.gen_len,
                         "priority": r.priority,
                         "budget": bpt * r.total_tokens,
                         "prompt_len": r.prompt_len}
                 for r in requests},
        "policies": {
            "kv-schedule": _srow(sched, out_s),
            "no-schedule": _srow(base, out_b),
            "unpressured": _srow(ref, golden),
        },
    }
    return rec


# ----------------------------------------------------------------------
# Sim-vs-measured drift: the observability plane's accuracy contract
# ----------------------------------------------------------------------
@dataclasses.dataclass
class DriftScenario:
    """The same captured job + plan run through the virtual-time
    simulator (predicted) and the real ``JaxprExecutor`` (measured),
    compared by the :class:`~repro.obs.drift.DriftMonitor`.  The engine
    parity guarantee says the two runtimes book identical residency
    decisions, so predicted-vs-measured peak drift must sit at ~0 — the
    distilled ``drift`` bench row turns that from a point assertion in
    the test suite into a continuously gated product metric
    (``tools/check_bench_regression.py::drift_contract``).  Safe-point
    placement is compared modeled (planned-ledger) vs measured
    (telemetry-replayed) on the same plan."""

    name: str
    description: str
    size: str = "small"


DRIFT = DriftScenario(
    name="sim-vs-measured",
    description="one captured MLP job + tensile plan run on the "
                "virtual-time simulator and on the real JaxprExecutor; "
                "the DriftMonitor compares predicted vs measured peak, "
                "EOR, and safe-point placement, and persists the sample "
                "into an ExperienceStore drift history")


def run_drift_scenario(scn: DriftScenario = DRIFT,
                       smoke: bool = False) -> Dict:
    from repro.core import (JaxprExecutor, capture_train_step,
                            schedule_single)
    from repro.obs import DriftMonitor, EventLog, MetricsRegistry
    from repro.service.workloads import make_mlp

    shape, batch = SHAPES[scn.size][smoke]
    step, params, opt, batch_data = make_mlp(sizes=shape, batch=batch)
    seq, closed = capture_train_step(step, params, opt, batch_data,
                                     job_id="drift")
    plan = schedule_single(seq, profile=PROFILE).plans["drift"]

    # predicted: the engine-backed sim in sync transfer mode (the parity
    # configuration — identical residency decisions to the executor)
    hub_s = TelemetryHub(clock="virtual")
    sim = simulate([seq], {"drift": plan.copy()}, PROFILE, iterations=1,
                   transfer_mode="sync", engine=MemoryEngine(PROFILE),
                   telemetry=hub_s)
    sps_pred = find_safe_points(seq, plan)

    # measured: the real executor running the same plan on real arrays
    hub_m = TelemetryHub(clock="real")
    ex = JaxprExecutor(closed, seq, plan,
                       engine=MemoryEngine(PROFILE, telemetry=hub_m))
    ex.run(params, opt, batch_data)
    ex.close()
    sps_meas = find_safe_points(seq, plan, source="measured",
                                telemetry=hub_m)

    events = EventLog()
    metrics = MetricsRegistry()
    exp = ExperienceStore(tempfile.mkdtemp(prefix="tensile-drift-"),
                          device_id="scenario-device")
    monitor = DriftMonitor(events=events, metrics=metrics, experience=exp)
    fp = exp.fingerprint(seq)
    s = monitor.observe(
        fp,
        predicted_peak=sim.peak_bytes,
        measured_peak=ex.stats.peak_bytes,
        job_id="drift",
        predicted_eor=hub_s.measured_eor("drift"),
        measured_eor=hub_m.measured_eor("drift"),
        predicted_safe_points=[sp.op_idx for sp in sps_pred],
        measured_safe_points=[sp.op_idx for sp in sps_meas])
    exp.flush()
    # round-trip: the persisted history must survive a fresh store open
    history_len = len(ExperienceStore(
        exp.root, device_id="scenario-device").drift_history(fp))

    return {
        "description": scn.description,
        "jobs": {"drift": {"offset": 0.0, "iterations": 1,
                           "priority": 1.0,
                           "budget": plan.planned_peak_bytes}},
        "policies": {},
        "drift": {
            "time": sim.total_time,
            "predicted_peak": sim.peak_bytes,
            "measured_peak": ex.stats.peak_bytes,
            "peak_drift": s.peak_drift,
            "predicted_eor": s.predicted_eor,
            "measured_eor": s.measured_eor,
            "eor_drift": s.eor_drift,
            "modeled_safe_points": sorted(sp.op_idx for sp in sps_pred),
            "measured_safe_points": sorted(sp.op_idx for sp in sps_meas),
            "sp_drift": s.sp_drift,
            "worst": s.worst,
            "over_threshold": bool(s.worst > monitor.threshold),
            "warn_events": len(events.warnings()),
            "history_len": history_len,
        },
    }


def _json_safe(obj):
    """Replace non-finite floats (ttwb=inf == "never recovered") with
    None: `Infinity` is not valid RFC-8259 JSON and would break strict
    consumers of the uploaded artifacts."""
    if isinstance(obj, dict):
        return {k: _json_safe(v) for k, v in obj.items()}
    if isinstance(obj, list):
        return [_json_safe(v) for v in obj]
    if isinstance(obj, float) and not math.isfinite(obj):
        return None
    return obj


def run(out_json: Optional[str] = None, smoke: bool = False,
        policies=POLICIES, preemption: bool = True,
        cold_warm: bool = True, overload: bool = True,
        serving: bool = True, drift: bool = False,
        experience_dir: Optional[str] = None) -> Dict[str, Dict]:
    table = {scn.name: run_scenario(scn, smoke=smoke, policies=policies)
             for scn in SCENARIOS}
    if preemption:
        for scn in PREEMPT_SCENARIOS:
            table[scn.name] = run_preempt_scenario(scn, smoke=smoke)
    if cold_warm:
        table[COLD_WARM.name] = run_cold_warm_scenario(
            COLD_WARM, smoke=smoke, experience_dir=experience_dir)
    if overload:
        table[OVERLOAD.name] = run_overload_scenario(OVERLOAD, smoke=smoke)
    if serving:
        table[SERVING.name] = run_serving_scenario(SERVING, smoke=smoke)
    if drift:
        # opt-in (the bench runner sets it): the drift record carries a
        # single job and no per-policy rows, so it does not fit the
        # suite-wide jobs/policies shape tests/test_scenarios.py asserts
        # over every row of the default table
        table[DRIFT.name] = run_drift_scenario(DRIFT, smoke=smoke)
    if out_json:
        with open(out_json, "w") as f:
            json.dump(_json_safe(table), f, indent=1)
    return table


def format_markdown(table: Dict[str, Dict]) -> str:
    """The scenario table; two modeled-vs-measured columns come from the
    telemetry plane — `calib (cold→fit)` is the analytic cost model's
    latency error before (deliberately miscalibrated cold-start
    constants) and after hub-fed recalibration, and `EOR meas` is the
    hub-measured stall/compute ratio (vs `EOR`, the vanilla-normalized
    simulated overhead)."""
    lines = ["| scenario | policy | peak (MiB) | ≤ budget | MSR | EOR | "
             "EOR meas | CBR | fairness | ttwb (burst iters) | "
             "calib (cold→fit) |",
             "|---|---|---|---|---|---|---|---|---|---|---|"]
    for scn, rec in table.items():
        rows = {**rec["policies"], **rec.get("modes", {})}
        for pol, m in rows.items():
            cbr = (f"{m['CBR']:.3f}" if m["CBR"] < 1e3 else "≫100")
            ttwb = m.get("ttwb_burst_iters")
            calib = (f"{m['calib_err_cold']:.2f}→{m['calib_err']:.3f}"
                     if "calib_err" in m else "—")
            meor = m.get("measured_eor")
            fair = m.get("fairness")
            lines.append(
                f"| {scn} | {pol} | {m['peak'] / 2**20:.2f} "
                f"| {'✓' if m['within_budget'] else '✗'} "
                f"| {m['MSR']:.4f} | {m['EOR']:.4f} "
                f"| {f'{meor:.4f}' if meor is not None else '—'} | {cbr} "
                f"| {f'{fair:.3f}' if fair is not None else '—'} "
                f"| {f'{ttwb:.3f}' if ttwb is not None else '—'} "
                f"| {calib} |")
    return "\n".join(lines)


if __name__ == "__main__":
    print(format_markdown(run(smoke="--smoke" in sys.argv)))
