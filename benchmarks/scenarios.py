"""Multi-workload dynamic scenario suite (paper §III-D / §V-C territory).

TENSILE's headline claim is scheduling under *multiple dynamic workloads*:
jobs launch at different times, finish at different times, differ in size
and priority, and the Global Controller's BudgetArbiter re-splits the
device-wide byte budget at every launch/finish/drift replan.  Each scenario
here is a small script of job arrivals (offset, iterations, priority) over
a shared device; every registered cross-job policy plans the merged
timeline and is then run through the discrete-event simulator against a
capacity-limited shared DeviceLedger, reporting:

    peak            global peak bytes in the shared ledger
    within_budget   peak <= the scenario's device budget
    oom_events      ledger allocations that crossed capacity
    MSR/EOR/CBR     the paper's metrics vs the vanilla run
    fairness        Jain's index over per-job entitlement utilisation
                    (peak_j / budget_j): 1.0 = every job uses the same
                    fraction of its arbiter-assigned slice

Scenarios (all ≥ 2 concurrent jobs, all dynamic):
    staggered          three equal jobs arriving half-an-iteration apart
    churn              short jobs joining and leaving around a long job;
                       a finishing job's bytes must be reclaimed
    priority-inversion memory-hog low-priority jobs start first, a
                       high-priority job arrives late and must still get
                       its weighted share
    bursty             a burst of small jobs interferes with one big job

Run:  python -m benchmarks.run --only scenarios [--smoke]
"""
from __future__ import annotations

import dataclasses
import functools
import json
import sys
from typing import Callable, Dict, List, Optional, Tuple

sys.path.insert(0, __file__.rsplit("/", 2)[0] + "/src")

from repro.core import (BudgetArbiter, MachineProfile, MemoryEngine,
                        SchedulerConfig, analyze, build_pipeline, simulate)

# the CPU-sized MLP device class used by the system tests: fast to capture,
# slow enough per-op that swaps have real windows
PROFILE = MachineProfile(host_link_bw=16e9, compute_flops=5e10, mem_bw=1e10)

POLICIES = ("vanilla", "tensile", "tensile+priority", "tensile+autoscale")


# ----------------------------------------------------------------------
# Workloads: captured MLP training steps, cached per shape
# ----------------------------------------------------------------------
@functools.lru_cache(maxsize=None)
def _mlp_seq(sizes: Tuple[int, ...], batch: int):
    import jax
    import jax.numpy as jnp

    from repro.core import capture_train_step
    from repro.optim.adam import adamw_init, adamw_update

    def forward(params, x):
        h = x
        for i, p in enumerate(params):
            h = h @ p["w"] + p["b"]
            if i < len(params) - 1:
                h = jnp.tanh(h)
        return h

    def step(params, opt_state, b):
        x, y = b

        def loss_fn(p):
            return jnp.mean((forward(p, x) - y) ** 2)

        loss, grads = jax.value_and_grad(loss_fn)(params)
        params, opt_state = adamw_update(params, grads, opt_state, lr=1e-3)
        return params, opt_state, loss

    key = jax.random.PRNGKey(0)
    params = []
    for i in range(len(sizes) - 1):
        key, k = jax.random.split(key)
        params.append(
            {"w": jax.random.normal(k, (sizes[i], sizes[i + 1])) * 0.02,
             "b": jnp.zeros(sizes[i + 1])})
    opt = adamw_init(params)
    x = jax.random.normal(jax.random.PRNGKey(1), (batch, sizes[0]))
    y = jax.random.normal(jax.random.PRNGKey(2), (batch, sizes[-1]))
    seq, _closed = capture_train_step(step, params, opt, (x, y),
                                     job_id="mlp")
    return seq


# job size classes; smoke keeps shapes small so the whole suite stays
# CPU-sized (<5 min) for the CI scenarios-smoke job
SHAPES = {
    "small": {True: ((32, 64, 64, 8), 8), False: ((64, 128, 128, 8), 16)},
    "medium": {True: ((64, 128, 128, 8), 16),
               False: ((64, 256, 256, 8), 32)},
    "large": {True: ((64, 256, 256, 8), 16),
              False: ((128, 512, 512, 8), 32)},
}


@dataclasses.dataclass
class JobSpec:
    job_id: str
    size: str                 # key into SHAPES
    offset_frac: float        # launch time, in mean-iteration units
    iterations: int
    priority: float = 1.0


@dataclasses.dataclass
class Scenario:
    name: str
    description: str
    jobs: List[JobSpec]
    arbiter_policy: str = "equal"
    budget_frac: float = 0.4    # device budget as a fraction of vanilla peak


SCENARIOS: List[Scenario] = [
    Scenario(
        name="staggered",
        description="three equal jobs launched half-an-iteration apart",
        jobs=[JobSpec("s0", "medium", 0.0, 3),
              JobSpec("s1", "medium", 0.5, 3),
              JobSpec("s2", "medium", 1.0, 3)],
        arbiter_policy="equal"),
    Scenario(
        name="churn",
        description="short jobs join and leave around a long-running job; "
                    "finished jobs' budgets are reclaimed and redistributed",
        jobs=[JobSpec("long", "medium", 0.0, 4),
              JobSpec("short0", "small", 0.2, 1),
              JobSpec("short1", "small", 0.8, 1),
              JobSpec("late", "medium", 1.6, 2)],
        arbiter_policy="peak"),
    Scenario(
        name="priority-inversion",
        description="low-priority memory hogs start first; a high-priority "
                    "job arrives late and must still get its weighted share",
        jobs=[JobSpec("hog0", "large", 0.0, 3, priority=1.0),
              JobSpec("hog1", "large", 0.15, 3, priority=1.0),
              JobSpec("vip", "medium", 0.6, 2, priority=4.0)],
        arbiter_policy="priority"),
    Scenario(
        name="bursty",
        description="a burst of small jobs interferes with one big job",
        jobs=[JobSpec("big", "large", 0.0, 4)] + [
            JobSpec(f"burst{i}", "small", 0.5 + 0.08 * i, 1)
            for i in range(4)],
        arbiter_policy="equal"),
]


# ----------------------------------------------------------------------
# Arbiter replay: min assignment over the scenario's launch/finish phases
# ----------------------------------------------------------------------
def replay_arbiter(arbiter: BudgetArbiter,
                   windows: Dict[str, Tuple[float, float]]
                   ) -> Dict[str, int]:
    """Walk the scenario's launch/finish events; at each boundary the
    arbiter re-splits the device budget over the live set (exactly what the
    Global Controller does at every launch/finish replan).  A job plans
    once against its *minimum* assignment over its lifetime, so the split
    stays sound in the most-crowded phase it lives through."""
    boundaries = sorted({t for w in windows.values() for t in w})
    assigned: Dict[str, int] = {}
    for lo, hi in zip(boundaries, boundaries[1:]):
        mid = 0.5 * (lo + hi)
        live = [j for j, (s, e) in windows.items() if s <= mid < e]
        if not live:
            continue
        split = arbiter.split(live)
        for j, b in split.items():
            assigned[j] = min(assigned.get(j, b), b)
    return assigned


def jain_fairness(utilisation: Dict[str, float]) -> float:
    xs = [max(x, 0.0) for x in utilisation.values()]
    if not xs or not any(xs):
        return 1.0
    return min(1.0, (sum(xs) ** 2) / (len(xs) * sum(x * x for x in xs)))


# ----------------------------------------------------------------------
# One scenario under one policy
# ----------------------------------------------------------------------
def _build_jobs(scn: Scenario, smoke: bool):
    seqs, offsets, iters, prios = [], {}, {}, {}
    mean_T = 0.0
    for js in scn.jobs:
        shape, batch = SHAPES[js.size][smoke]
        seq = _mlp_seq(tuple(shape), batch).clone(js.job_id)
        seqs.append(seq)
        mean_T += seq.iteration_time
    mean_T /= len(seqs)
    for js, seq in zip(scn.jobs, seqs):
        offsets[js.job_id] = js.offset_frac * mean_T
        iters[js.job_id] = js.iterations
        prios[js.job_id] = js.priority
    return seqs, offsets, iters, prios


def run_scenario(scn: Scenario, smoke: bool = False,
                 policies=POLICIES) -> Dict:
    seqs, offsets, iters, prios = _build_jobs(scn, smoke)
    jobs = {s.job_id: s for s in seqs}

    # vanilla reference: nothing freed before iteration end (paper §V-A)
    vanilla = simulate(seqs, None, PROFILE, iterations=iters,
                       offsets=offsets, free_at_last_use=False)
    budget = int(vanilla.peak_bytes * scn.budget_frac)

    # the arbiter split each job plans against (launch/finish replay)
    arbiter = BudgetArbiter(budget, policy=scn.arbiter_policy)
    windows = {}
    for s in seqs:
        arbiter.register(
            s.job_id, priority=prios[s.job_id],
            demand_bytes=analyze([s], free_at_last_use=False).peak_bytes)
        start = offsets[s.job_id]
        windows[s.job_id] = (start,
                            start + iters[s.job_id] * s.iteration_time)
    budgets = replay_arbiter(arbiter, windows)

    rec = {
        "description": scn.description,
        "device_budget": budget,
        "vanilla_peak": vanilla.peak_bytes,
        "arbiter_policy": scn.arbiter_policy,
        "jobs": {j: {"offset": offsets[j], "iterations": iters[j],
                     "priority": prios[j], "budget": budgets.get(j, 0)}
                 for j in jobs},
        "policies": {},
    }

    equal_split = {j: budget // len(jobs) for j in jobs}
    for policy in policies:
        cfg = SchedulerConfig(memory_budget_bytes=budget,
                              job_priorities=dict(prios))
        entitlement = equal_split
        if policy in ("tensile+priority", "tensile+autoscale"):
            cfg.per_job_budget_bytes = dict(budgets)
            entitlement = budgets
        plans = None
        plan_wall = 0.0
        if policy != "vanilla":
            res = build_pipeline(policy, profile=PROFILE, config=cfg) \
                .plan(seqs, offsets=offsets)
            plans = res.plans
            plan_wall = res.plan_wallclock_s
        eng = MemoryEngine(PROFILE, capacity_bytes=budget)
        sim = simulate(seqs, plans, PROFILE, iterations=iters,
                       offsets=offsets,
                       free_at_last_use=(policy != "vanilla"),
                       engine=eng)
        msr = sim.msr(vanilla)
        eor = sim.eor(vanilla)
        util = {j: sim.per_job_peak.get(j, 0) / max(entitlement.get(j, 1), 1)
                for j in jobs}
        rec["policies"][policy] = {
            "peak": sim.peak_bytes,
            "within_budget": bool(sim.peak_bytes <= budget),
            "oom_events": eng.ledger.oom_events,
            "MSR": msr, "EOR": eor,
            "CBR": sim.cbr(vanilla),
            "time": sim.total_time,
            "fairness": jain_fairness(util),
            "per_job_peak": dict(sim.per_job_peak),
            "swap_conflicts": sim.swap_conflicts,
            "passive_swap_ins": sim.passive_swap_ins,
            "plan_wallclock_s": plan_wall,
        }
    return rec


def run(out_json: Optional[str] = None, smoke: bool = False,
        policies=POLICIES) -> Dict[str, Dict]:
    table = {scn.name: run_scenario(scn, smoke=smoke, policies=policies)
             for scn in SCENARIOS}
    if out_json:
        with open(out_json, "w") as f:
            json.dump(table, f, indent=1)
    return table


def format_markdown(table: Dict[str, Dict]) -> str:
    lines = ["| scenario | policy | peak (MiB) | ≤ budget | MSR | EOR | "
             "CBR | fairness |",
             "|---|---|---|---|---|---|---|---|"]
    for scn, rec in table.items():
        for pol, m in rec["policies"].items():
            cbr = (f"{m['CBR']:.3f}" if m["CBR"] < 1e3 else "≫100")
            lines.append(
                f"| {scn} | {pol} | {m['peak'] / 2**20:.2f} "
                f"| {'✓' if m['within_budget'] else '✗'} "
                f"| {m['MSR']:.4f} | {m['EOR']:.4f} | {cbr} "
                f"| {m['fairness']:.3f} |")
    return "\n".join(lines)


if __name__ == "__main__":
    print(format_markdown(run(smoke="--smoke" in sys.argv)))
