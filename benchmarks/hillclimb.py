"""§Perf hillclimbing harness.

Each experiment = (cell, variant name, config mutations) → re-lower,
re-analyse, record the three roofline terms + peak next to the recorded
baseline.  Variants never overwrite baseline artifacts; results land in
experiments/hillclimb/<arch>__<shape>__<variant>.json and the iteration
log is assembled into EXPERIMENTS.md §Perf.

Levers exposed (see repro.launch.sharding / configs.base):
    act_seq_shard     — Megatron sequence sharding of residuals
    offload_opt       — TENSILE Opt-phase host residency (accounting on CPU)
    microbatch        — gradient accumulation (activation peak / n)
    attn_chunk        — q/kv chunk for the online-softmax attention
    ssm_chunk         — SSD chunk length (quadratic intra-chunk term)
    capacity_factor   — MoE dispatch capacity
    untie_unembed     — resharded tied-unembedding path
    remat             — none|block
"""
from __future__ import annotations

import json
import os
import sys
import time
from typing import Any, Dict, Optional

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

HILL = os.path.join(os.path.dirname(__file__), "..", "experiments",
                    "hillclimb")


def run_variant(arch: str, shape_name: str, variant: str,
                cfg_mut: Optional[Dict[str, Any]] = None,
                rules_mut: Optional[Dict[str, Any]] = None,
                tstep_mut: Optional[Dict[str, Any]] = None,
                multi_pod: bool = False) -> Dict:
    """Compile one modified cell and record its roofline."""
    import jax
    import numpy as np
    from repro.configs import get_config, ALL_SHAPES
    from repro.launch import dryrun as D
    from repro.launch.mesh import make_production_mesh
    from repro.launch.sharding import MeshRules
    from repro.launch.steps import (TrainStepConfig, build_prefill_step,
                                    build_serve_step, build_train_step,
                                    offloaded_bytes, opt_state_for,
                                    opt_state_shardings)
    from repro.models.registry import get_model

    cfg = get_config(arch)
    for k, v in (cfg_mut or {}).items():
        setattr(cfg, k, v)
    shape = {s.name: s for s in ALL_SHAPES}[shape_name]
    api = get_model(cfg)
    mesh = make_production_mesh(multi_pod=multi_pod)
    rules = MeshRules(mesh, cfg=cfg, **(rules_mut or {}))
    chips = int(np.prod(list(mesh.shape.values())))

    t0 = time.time()
    params, axes = api.abstract_params()
    p_shard = rules.param_shardings(axes)
    tcfg = TrainStepConfig(**(tstep_mut or {}))

    if shape.kind == "train":
        opt = opt_state_for(params, abstract=True)
        o_shard = opt_state_shardings(rules, p_shard)
        batch = api.input_specs(shape, abstract=True)
        if tcfg.microbatches > 1:
            # keep per-microbatch rows divisible across the batch shards
            assert shape.global_batch % tcfg.microbatches == 0
        b_shard = rules.batch_sharding(batch)
        step = build_train_step(api, rules, tcfg)
        jitted = jax.jit(step, in_shardings=(p_shard, o_shard, b_shard),
                         donate_argnums=(0, 1))
        lowered = jitted.lower(params, opt, batch)
        host_bytes = offloaded_bytes(opt) if rules.offload_opt_state else 0
    elif shape.kind == "prefill":
        batch = api.input_specs(shape, abstract=True)
        b_shard = rules.batch_sharding(batch)
        step = build_prefill_step(api, rules)
        jitted = jax.jit(step, in_shardings=(p_shard, b_shard))
        lowered = jitted.lower(params, batch)
        host_bytes = 0
    else:
        cache, cache_axes = api.abstract_cache(shape.global_batch,
                                               shape.seq_len)
        c_shard = rules.shardings_for(cache_axes, cache)
        batch = api.decode_input_specs(shape, abstract=True)
        b_shard = rules.batch_sharding(batch)
        step = build_serve_step(api, rules)
        jitted = jax.jit(step, in_shardings=(p_shard, c_shard, b_shard, None),
                         donate_argnums=(1,))
        lowered = jitted.lower(params, cache, batch,
                               jax.ShapeDtypeStruct((), jax.numpy.int32))
        host_bytes = 0

    compiled = lowered.compile()
    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    colls = D.parse_collectives(compiled.as_text())
    corr = D._body_cost(cfg, shape, rules, api, params, batch, axes)
    flops = float(cost.get("flops", 0.0)) + corr["flops"]
    bts = float(cost.get("bytes accessed", 0.0)) + corr["bytes"]
    for kind, slot in corr["collectives"].items():
        agg = colls.setdefault(kind, {"count": 0, "bytes": 0.0,
                                      "wire_bytes": 0.0})
        agg["count"] += slot["count"]
        agg["bytes"] += slot["bytes"]
        agg["wire_bytes"] += slot["wire_bytes"]
    wire = sum(c["wire_bytes"] for c in colls.values())
    peak = (mem.argument_size_in_bytes + mem.temp_size_in_bytes
            + mem.output_size_in_bytes - mem.alias_size_in_bytes)
    m_flops = D.model_flops_for(cfg, shape)
    rec = {
        "arch": arch, "shape": shape_name, "variant": variant,
        "cfg_mut": {k: str(v) for k, v in (cfg_mut or {}).items()},
        "rules_mut": rules_mut or {}, "tstep_mut": {
            k: str(v) for k, v in (tstep_mut or {}).items()},
        "compile_seconds": round(time.time() - t0, 1),
        "per_device_peak_bytes": int(peak),
        "host_offload_bytes_per_device": int(host_bytes // chips),
        "per_device_peak_after_offload": int(peak - host_bytes // chips),
        "cost": {"flops": flops, "bytes_accessed": bts},
        "collectives_wire_bytes": wire,
        "roofline": {
            "compute_s": flops / D.PEAK_FLOPS,
            "memory_s": bts / D.HBM_BW,
            "collective_s": wire / D.ICI_BW,
            "model_flops": m_flops,
            "useful_flops_ratio": (m_flops / chips) / flops if flops else 0,
        },
    }
    terms = rec["roofline"]
    total = max(terms["compute_s"], terms["memory_s"], terms["collective_s"])
    terms["dominant"] = max(
        [("compute", terms["compute_s"]), ("memory", terms["memory_s"]),
         ("collective", terms["collective_s"])], key=lambda kv: kv[1])[0]
    terms["step_lower_bound_s"] = total
    terms["roofline_fraction"] = (
        (m_flops / chips / D.PEAK_FLOPS) / total if total else 0.0)
    os.makedirs(HILL, exist_ok=True)
    out = os.path.join(HILL, f"{arch}__{shape_name}__{variant}.json")
    with open(out, "w") as f:
        json.dump(rec, f, indent=1)
    print(f"[{variant}] peak={peak/2**30:.2f}GiB "
          f"(offload→{rec['per_device_peak_after_offload']/2**30:.2f}) "
          f"compute={terms['compute_s']:.2f}s memory={terms['memory_s']:.2f}s "
          f"collective={terms['collective_s']:.2f}s "
          f"dominant={terms['dominant']} "
          f"roofline_frac={terms['roofline_fraction']:.3f}")
    return rec


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--variant", required=True)
    ap.add_argument("--seq-shard", action="store_true")
    ap.add_argument("--offload-opt", action="store_true")
    ap.add_argument("--remat", default=None)
    args = ap.parse_args()
    rules_mut = {}
    if args.seq_shard:
        rules_mut["act_seq_shard"] = True
    if args.offload_opt:
        rules_mut["offload_opt_state"] = True
    cfg_mut = {"remat": args.remat} if args.remat else None
    run_variant(args.arch, args.shape, args.variant, cfg_mut, rules_mut)
