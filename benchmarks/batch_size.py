"""Paper Fig. 6 — batch size influence (2…32).

Interim results scale with batch; parameters don't — so MSR should rise
with batch size (more swappable activation bytes per parameter byte).
"""
from __future__ import annotations

import json
from typing import Dict

from repro.core import evaluate, schedule_single

from .workloads import GPU_PROFILE, get_workload

WORKLOADS = ["vgg16", "resnet50", "densenet121", "tinyllama-r", "gemma-r"]
BATCHES = [2, 4, 8, 16, 32]


def run(out_json: str = None) -> Dict:
    table: Dict[str, Dict[int, Dict[str, float]]] = {}
    for w in WORKLOADS:
        table[w] = {}
        for b in BATCHES:
            seq = get_workload(w, batch=b)
            res = schedule_single(seq, profile=GPU_PROFILE,
                                  budget_bytes=GPU_PROFILE.device_memory_bytes)
            table[w][b] = evaluate([seq], res.plans, GPU_PROFILE)
    if out_json:
        with open(out_json, "w") as f:
            json.dump(table, f, indent=1)
    return table


def format_markdown(table: Dict) -> str:
    lines = ["| workload | batch | MSR | EOR | CBR |",
             "|---|---|---|---|---|"]
    for w, by_b in table.items():
        for b, r in by_b.items():
            lines.append(f"| {w} | {b} | {r['MSR']:.4f} | {r['EOR']:.4f} "
                         f"| {r['CBR']:.4f} |")
    return "\n".join(lines)


if __name__ == "__main__":
    print(format_markdown(run()))
