"""Paper Table I — single-workload MSR / EOR / CBR.

Five workloads × {vDNN, Capuchin, TENSILE_cs, TENSILE}, all normalized
against the vanilla (no-scheduling) run of the same simulator:

  * TENSILE_cs — plan from *cold-start* latencies (the analytic/MLP
    predictor; no passive observation), measured at job launch.
  * TENSILE    — plan after EWMA latency correction (§IV-E): latencies are
    perturbed as a co-located load would (the dynamic-workload mechanism),
    EWMA folds in the measurements, the scheduler replans.
  * Capuchin's budget is set to TENSILE's achieved peak (the paper's
    "Extra Setting": Capuchin only schedules down to what is needed).
"""
from __future__ import annotations

import json
from typing import Dict, List

import numpy as np

from repro.core import (MemoryScheduler, SchedulerConfig, capuchin_plan,
                        evaluate, schedule_single, vdnn_conv_plan)

from .workloads import GPU_PROFILE, get_workload

WORKLOADS = ["vgg16", "resnet50", "densenet121", "tinyllama-r", "gemma-r"]


def perturb_latencies(seq, scale: float = 1.35, seed: int = 0) -> List[float]:
    """Co-located-load latency drift: heavier ops slow down more (they
    contend for the device), light ops mostly wait."""
    rng = np.random.default_rng(seed)
    out = []
    for op in seq.operators:
        jitter = rng.uniform(0.9, 1.1)
        out.append(op.latency * scale * jitter)
    return out


def bench_one(name: str) -> Dict[str, Dict[str, float]]:
    results: Dict[str, Dict[str, float]] = {}
    seq = get_workload(name)
    profile = GPU_PROFILE

    # --- TENSILE cold start -------------------------------------------
    res_cs = schedule_single(seq, profile=profile,
                             budget_bytes=profile.device_memory_bytes)
    results["TENSILE_cs"] = evaluate([seq], res_cs.plans, profile)
    tensile_peak = res_cs.final_report.peak_bytes

    # --- TENSILE after EWMA update (dynamic workload) ------------------
    sched = MemoryScheduler(profile, SchedulerConfig())
    sched.register_job(seq)
    sched.schedule()
    drift = sched.update_latencies(seq.job_id, perturb_latencies(seq))
    res_up = sched.schedule()
    results["TENSILE"] = evaluate([seq], res_up.plans, profile)
    results["TENSILE"]["replanned"] = float(drift)

    # --- vDNN (layer granularity, swap-only: its framework has no
    # activity-analysis releases) ----------------------------------------
    results["vDNN"] = evaluate(
        [seq], {seq.job_id: vdnn_conv_plan(seq, profile)}, profile,
        free_at_last_use=False)

    # --- Capuchin (budget = TENSILE's achieved peak) --------------------
    cap = capuchin_plan(seq, budget_bytes=tensile_peak, profile=profile)
    m = evaluate([seq], {seq.job_id: cap.plan}, profile)
    # passive observation epoch under budget pressure: every byte over
    # budget round-trips the host link, serialized with compute
    over = max(0, results["TENSILE_cs"]["vanilla_peak"] - tensile_peak)
    passive_epoch = seq.iteration_time + 2 * over / profile.host_link_bw
    m["EOR"] = m["EOR"] + passive_epoch / max(m["vanilla_time"], 1e-12)
    m["CBR"] = m["MSR"] / m["EOR"] if m["EOR"] > 0 else 0.0
    results["Capuchin"] = m
    return results


def run(out_json: str = None) -> Dict:
    table = {}
    for w in WORKLOADS:
        table[w] = bench_one(w)
    if out_json:
        with open(out_json, "w") as f:
            json.dump(table, f, indent=1)
    return table


def format_markdown(table: Dict) -> str:
    lines = ["| workload | method | MSR | EOR | CBR |",
             "|---|---|---|---|---|"]
    for w, methods in table.items():
        for m in ("vDNN", "Capuchin", "TENSILE_cs", "TENSILE"):
            r = methods[m]
            lines.append(f"| {w} | {m} | {r['MSR']:.4f} | {r['EOR']:.4f} "
                         f"| {r['CBR']:.4f} |")
    return "\n".join(lines)


if __name__ == "__main__":
    t = run()
    print(format_markdown(t))
