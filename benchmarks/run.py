"""Benchmark driver — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (us_per_call = simulated step
time for the scheduled run; derived = the table's headline metric) and
writes JSON results under experiments/results/ for EXPERIMENTS.md.

    PYTHONPATH=src python -m benchmarks.run             # everything
    PYTHONPATH=src python -m benchmarks.run --only single_task,latency_model
"""
from __future__ import annotations

import argparse
import json
import math
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

RESULTS = os.path.join(os.path.dirname(__file__), "..", "experiments",
                       "results")


def _emit(name: str, us: float, derived: str) -> None:
    print(f"{name},{us:.1f},{derived}")


def bench_single_task() -> None:
    from . import single_task
    t = single_task.run(os.path.join(RESULTS, "single_task.json"))
    for w, methods in t.items():
        for m in ("vDNN", "Capuchin", "TENSILE_cs", "TENSILE"):
            r = methods[m]
            _emit(f"tab1/{w}/{m}", r["time"] * 1e6,
                  f"MSR={r['MSR']:.4f};EOR={r['EOR']:.4f};CBR={r['CBR']:.4f}")


def bench_scalability() -> None:
    from . import scalability
    t = scalability.run(os.path.join(RESULTS, "scalability.json"))
    for w, by_n in t.items():
        for n, methods in by_n.items():
            r = methods["TENSILE"]
            _emit(f"fig5/{w}/x{n}/TENSILE", r["time"] * 1e6,
                  f"MSR={r['MSR']:.4f};CBR={r['CBR']:.4f}")


def bench_mixed() -> None:
    from . import mixed
    t = mixed.run(out_json=os.path.join(RESULTS, "mixed.json"))
    for m, r in t.items():
        _emit(f"tab2/{m}", r["time"] * 1e6,
              f"MSR={r['MSR']:.4f};EOR={r['EOR']:.4f};CBR={r['CBR']:.4f}")


def bench_batch_size() -> None:
    from . import batch_size
    t = batch_size.run(os.path.join(RESULTS, "batch_size.json"))
    for w, by_b in t.items():
        for b, r in by_b.items():
            _emit(f"fig6/{w}/b{b}", r["time"] * 1e6,
                  f"MSR={r['MSR']:.4f};CBR={r['CBR']:.4f}")


def bench_latency_model() -> None:
    from . import latency_model
    r = latency_model.run(os.path.join(RESULTS, "latency_model.json"))
    _emit("sec4c/latency_mlp", 0.0,
          f"r2_test={r['r2_test']:.3f};r2_expensive={r['r2_expensive_ops']:.3f}")


def bench_pipelines(policies=None, workloads=("vgg16", "tinyllama-r")) -> None:
    """Policy comparison by *pipeline name*: every registered planning
    pipeline (vanilla / vdnn / capuchin / tensile / tensile+compressed-
    offload / ...) over the same workloads, MSR/EOR/CBR per row.

    Protocol follows the paper's Table I: Capuchin's budget is set to
    TENSILE's achieved peak and charged its passive observation epoch;
    vDNN/vanilla run without activity-analysis releases (their frameworks
    lack them)."""
    from repro.core import SchedulerConfig, build_pipeline, evaluate
    from repro.core.passes import PIPELINES

    from .workloads import GPU_PROFILE, get_workload

    names = list(policies) if policies else list(PIPELINES)
    # tensile first: its achieved peak is the budget baselines plan toward
    names.sort(key=lambda n: (n != "tensile", n))
    table = {}
    for w in workloads:
        seq = get_workload(w)
        table[w] = {}
        budget = None
        if "tensile" not in names:
            # keep the Table-I protocol even for partial selections: the
            # budget is always TENSILE's achieved peak
            budget = build_pipeline("tensile", profile=GPU_PROFILE) \
                .plan([seq]).final_report.peak_bytes
        for name in names:
            cfg = SchedulerConfig(memory_budget_bytes=budget)
            pipe = build_pipeline(name, profile=GPU_PROFILE, config=cfg)
            res = pipe.plan([seq])
            if name == "tensile":
                budget = res.final_report.peak_bytes
            m = evaluate([seq], res.plans, GPU_PROFILE,
                         free_at_last_use=pipe.free_at_last_use)
            if pipe.passive_iterations:
                # observation epoch surcharge (Capuchin passive mode)
                m["EOR"] += (pipe.passive_iterations * seq.iteration_time
                             / max(m["vanilla_time"], 1e-12))
                m["CBR"] = m["MSR"] / m["EOR"] if m["EOR"] > 0 else 0.0
            m["swaps"] = res.swaps_scheduled
            m["recomputes"] = res.recomputes_scheduled
            m["pass_steps"] = res.pass_steps
            table[w][name] = m
            _emit(f"pipelines/{w}/{name}", m["time"] * 1e6,
                  f"MSR={m['MSR']:.4f};EOR={m['EOR']:.4f};CBR={m['CBR']:.4f}")
    with open(os.path.join(RESULTS, "pipelines.json"), "w") as f:
        json.dump(table, f, indent=1)


def bench_scenarios(smoke: bool = False,
                    experience_dir: str = None) -> None:
    """Multi-workload dynamic scenario suite: staggered launches, job
    churn, priority inversion, bursty interference, the two preemption
    scenarios (flash-crowd, preempt-vs-boundary), the experience
    plane's cold-vs-warm boot scenario, and the serving plane's
    serving-pressure scenario (real continuous-batching decode under a
    KV-cache budget) — every cross-job policy vs the arbiter-assigned
    device budget (see benchmarks/scenarios.py).

    ``experience_dir`` persists the cold-vs-warm scenario's experience
    store across invocations (CI keys it on the store schema version via
    actions/cache, proving warm boot works across whole CI runs); without
    it the warm run boots from a scratch store the cold run populated.

    Also distills the CI perf-trajectory gate metrics (global peak,
    time-to-within-budget, EOR per scenario/policy, and the cold-vs-warm
    dominance fields) into ``experiments/results/BENCH_scenarios.json``;
    ``tools/check_bench_regression.py`` diffs that file against the
    committed baseline ``benchmarks/BENCH_scenarios.json``."""
    from . import scenarios
    t = scenarios.run(os.path.join(RESULTS, "scenarios.json"), smoke=smoke,
                      drift=True, experience_dir=experience_dir)
    # the gate file records which variant produced it: smoke and full-size
    # metrics are NOT comparable, and check_bench_regression refuses to
    # diff (or --update) across the two
    gate = {"_meta": {"smoke": bool(smoke)}}
    for scn, rec in t.items():
        for pol, m in rec["policies"].items():
            ttwb = m.get("ttwb_burst_iters")
            finite = ttwb is not None and math.isfinite(ttwb)
            _emit(f"scenarios/{scn}/{pol}", m["time"] * 1e6,
                  f"peak={m['peak']};within_budget={m['within_budget']};"
                  f"MSR={m['MSR']:.4f};EOR={m['EOR']:.4f};"
                  f"fairness={m['fairness']:.3f}"
                  + (f";ttwb_burst_iters={ttwb:.3f}"
                     if ttwb is not None else ""))
            gate[f"{scn}/{pol}"] = {
                "peak": m["peak"],
                "EOR": round(m["EOR"], 6),
                "oom_events": m.get("oom_events"),
                # inf ("never recovered") is not valid JSON: recorded as
                # null + an explicit recovered flag the gate checks
                "ttwb_burst_iters": round(ttwb, 6) if finite else None,
                "ttwb_recovered": (finite if ttwb is not None else None),
                # measured-telemetry plane: post-recalibration cost-model
                # error (gated at >25 % regression like the other
                # overhead metrics)
                "calib_err": (round(m["calib_err"], 6)
                              if "calib_err" in m else None),
            }
            # serving-plane rows: throughput/TTFT trajectory plus the
            # serving contract fields (0 OOMs under pressure, decode
            # bit-identity vs the unpressured golden run, finite p99
            # TTFT) tools/check_bench_regression.py enforces
            if "tokens_per_s" in m:
                p99 = m.get("ttft_p99")
                gate[f"{scn}/{pol}"].update({
                    "within_budget": m["within_budget"],
                    "tokens_per_s": round(m["tokens_per_s"], 6),
                    "ttft_p99": (round(p99, 6) if p99 is not None
                                 else None),
                    "decode_bit_identical": m["decode_bit_identical"],
                    "served": m["served"],
                    "rejected": m["rejected"],
                    "evictions": m["evictions"],
                    "prefetches": m["prefetches"],
                })
            # service-plane overload rows: queue-wait trajectory plus the
            # admission contract fields (reservations never over capacity,
            # warm-fingerprint prediction precision) the gate enforces
            if "queue_wait_mean_iters" in m:
                err = m.get("admission_max_abs_err")
                gate[f"{scn}/{pol}"].update({
                    "within_budget": m["within_budget"],
                    "queue_wait_mean_iters":
                        round(m["queue_wait_mean_iters"], 6),
                    "queue_wait_max_iters":
                        round(m["queue_wait_max_iters"], 6),
                    "admission_max_abs_err":
                        (round(err, 6) if err is not None else None),
                    "admitted_over_capacity": m["admitted_over_capacity"],
                    "admitted_jobs": m["admitted_jobs"],
                })
        # cold-vs-warm rows: the experience plane's warm-boot dominance
        # fields (calib_err_first, within-budget/OOM-free first iteration,
        # plan-cache hit) — tools/check_bench_regression.py enforces the
        # warm-dominates-cold contract on these
        for mode, m in rec.get("modes", {}).items():
            _emit(f"scenarios/{scn}/{mode}", m["time"] * 1e6,
                  f"peak={m['peak']};within_budget={m['within_budget']};"
                  f"first_iter_peak={m['first_iter_peak']};"
                  f"oom={m['oom_events']};"
                  f"cache_hit={m['plan_cache_hit']};"
                  f"calib_err={m['calib_err_cold']:.4f}"
                  f"->{m['calib_err']:.4f}")
            gate[f"{scn}/{mode}"] = {
                "peak": m["peak"],
                "EOR": round(m["EOR"], 6),
                "oom_events": m["oom_events"],
                "within_budget": m["within_budget"],
                "first_iter_within_budget": m["first_iter_within_budget"],
                "plan_cache_hit": m["plan_cache_hit"],
                "calib_err": round(m["calib_err"], 6),
                "calib_err_first": round(m["calib_err_cold"], 6),
            }
        # sim-vs-measured drift row: the observability plane's accuracy
        # contract — the engine parity guarantee (identical residency
        # decisions on both runtimes) as a continuously gated metric;
        # tools/check_bench_regression.py::drift_contract enforces the
        # absolute drift bounds and that the sample persisted into the
        # ExperienceStore drift history
        d = rec.get("drift")
        if d:
            def _fmt(v):
                return f"{v:.4f}" if v is not None else "n/a"
            _emit(f"scenarios/{scn}/drift", d["time"] * 1e6,
                  f"peak_drift={_fmt(d['peak_drift'])};"
                  f"sp_drift={_fmt(d['sp_drift'])};"
                  f"eor_drift={_fmt(d['eor_drift'])};"
                  f"history_len={d['history_len']}")
            gate[f"{scn}/drift"] = {
                "peak": d["measured_peak"],
                "predicted_peak": d["predicted_peak"],
                "peak_drift": round(d["peak_drift"], 6),
                "sp_drift": (round(d["sp_drift"], 6)
                             if d["sp_drift"] is not None else None),
                "eor_drift": (round(d["eor_drift"], 6)
                              if d["eor_drift"] is not None else None),
                "history_len": d["history_len"],
                "over_threshold": d["over_threshold"],
            }
    with open(os.path.join(RESULTS, "BENCH_scenarios.json"), "w") as f:
        json.dump(gate, f, indent=1, sort_keys=True)


def bench_planner(smoke: bool = False) -> None:
    """Planner raw-speed trajectory: cold plan, incremental replan
    (arbitration-tick floor at a safe point), and warm-boot adoption
    latency vs op count on synthetic chain graphs (see
    benchmarks/planner_bench.py).  Writes the gate file
    ``experiments/results/BENCH_planner.json``;
    ``tools/check_bench_regression.py`` diffs it against the committed
    baseline ``benchmarks/BENCH_planner.json`` (>25 % per-row latency
    regression, plus the 10k-op contract: incremental replan >=10x
    faster than cold and, in smoke, under 5 ms)."""
    from . import planner_bench
    t = planner_bench.run(os.path.join(RESULTS, "BENCH_planner.json"),
                          smoke=smoke)
    for key, m in sorted(t.items()):
        extra = ";".join(f"{k}={v}" for k, v in sorted(m.items())
                         if k not in ("ms",))
        _emit(f"planner/{key}", m["ms"] * 1e3, extra)


def bench_runtime(smoke: bool = False) -> None:
    """Runtime data-path trajectory: blocking vs double-buffered executor
    swaps, per-block vs batched KV-block restore kernels, and the serving
    plane's pressure scenario with the batched transfer path (see
    benchmarks/runtime_bench.py).  Writes the gate file
    ``experiments/results/BENCH_runtime.json``;
    ``tools/check_bench_regression.py`` diffs it against the committed
    baseline ``benchmarks/BENCH_runtime.json`` (>25 % per-row latency or
    tokens/sec regression, plus the hard runtime contract: batched KV
    restore >=3x per-block at the smoke size, batched pressure serving
    >=92 % of unpressured tokens/sec with 0 OOMs and decode outputs
    bit-identical)."""
    from . import runtime_bench
    t = runtime_bench.run(os.path.join(RESULTS, "BENCH_runtime.json"),
                          smoke=smoke)
    for key, m in sorted(t.items()):
        extra = ";".join(f"{k}={v}" for k, v in sorted(m.items())
                         if k != "ms")
        _emit(f"runtime/{key}", m.get("ms", 0.0) * 1e3, extra)


def bench_executor_validation() -> None:
    """Real-execution check: interpreter peak/MSR vs simulator prediction
    and bit-exactness of outputs under the plan (CPU-sized workload)."""
    import jax
    import numpy as np
    from repro.core import (JaxprExecutor, MachineProfile, evaluate,
                            reference_outputs, schedule_single)
    from .workloads import capture_cnn
    seq, closed, (params, opt, batch) = capture_cnn("vgg16", batch=2, img=32)
    prof = MachineProfile(host_link_bw=12e9, compute_flops=5e10, mem_bw=1e10)
    res = schedule_single(seq, profile=prof, budget_bytes=2**62)
    # concrete inputs
    key = jax.random.PRNGKey(0)
    cparams = jax.tree.map(
        lambda s: jax.random.normal(key, s.shape, s.dtype) * 0.02, params)
    copt = jax.tree.map(lambda s: jax.numpy.zeros(s.shape, s.dtype), opt)
    cbatch = jax.tree.map(
        lambda s: jax.numpy.ones(s.shape, s.dtype), batch)
    ref = reference_outputs(closed, cparams, copt, cbatch)
    ex = JaxprExecutor(closed, seq, res.plans[seq.job_id])
    t0 = time.perf_counter()
    out = ex.run(cparams, copt, cbatch)
    dt = time.perf_counter() - t0
    ok = all(np.allclose(np.asarray(a), np.asarray(b), rtol=1e-4, atol=1e-5)
             for a, b in zip(ref, out))
    ex0 = JaxprExecutor(closed, seq, None)
    ex0.run(cparams, copt, cbatch)
    # like-for-like: both the real runs and the planner predictions use
    # free-at-last-use semantics here (the executor always frees at last
    # use; the paper-vanilla no-free baseline is a simulator-only notion)
    from repro.core import analyze
    pred_sched = analyze([seq], res.plans).peak_bytes
    pred_vanilla = analyze([seq]).peak_bytes
    msr_real = 1 - ex.stats.peak_bytes / ex0.stats.peak_bytes
    msr_pred = 1 - pred_sched / pred_vanilla
    peak_err = abs(ex.stats.peak_bytes - pred_sched) / max(pred_sched, 1)
    _emit("exec/vgg16_32", dt * 1e6,
          f"outputs_match={ok};MSR_real={msr_real:.4f};"
          f"MSR_pred={msr_pred:.4f};peak_rel_err={peak_err:.4f}")
    with open(os.path.join(RESULTS, "executor_validation.json"), "w") as f:
        json.dump({"outputs_match": bool(ok), "msr_real": float(msr_real),
                   "msr_pred": float(msr_pred),
                   "peak_real_bytes": int(ex.stats.peak_bytes),
                   "peak_pred_bytes": int(pred_sched),
                   "peak_rel_err": float(peak_err)}, f)


ALL = {
    "single_task": bench_single_task,
    "scalability": bench_scalability,
    "mixed": bench_mixed,
    "batch_size": bench_batch_size,
    "latency_model": bench_latency_model,
    "pipelines": bench_pipelines,
    "scenarios": bench_scenarios,
    "planner": bench_planner,
    "runtime": bench_runtime,
    "executor_validation": bench_executor_validation,
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated subset of " + ",".join(ALL))
    ap.add_argument("--policy", default=None,
                    help="comma-separated planning-pipeline names for the "
                         "`pipelines` benchmark (default: all registered; "
                         "see repro.core.passes.PIPELINES)")
    ap.add_argument("--smoke", action="store_true",
                    help="CPU-sized variants of the heavy suites "
                         "(`scenarios`, `planner`): small workloads, "
                         "<5 min, for CI")
    ap.add_argument("--experience-dir", default=None,
                    help="persistent ExperienceStore root wired through to "
                         "the controller/scenarios: the cold-vs-warm "
                         "scenario warm-boots from it and flushes back "
                         "into it (CI persists it across runs)")
    args = ap.parse_args()
    os.makedirs(RESULTS, exist_ok=True)
    names = args.only.split(",") if args.only else list(ALL)
    print("name,us_per_call,derived")
    for n in names:
        if n == "pipelines":
            bench_pipelines(policies=args.policy.split(",")
                            if args.policy else None)
        elif n == "scenarios":
            bench_scenarios(smoke=args.smoke,
                            experience_dir=args.experience_dir)
        elif n == "planner":
            bench_planner(smoke=args.smoke)
        elif n == "runtime":
            bench_runtime(smoke=args.smoke)
        else:
            ALL[n]()


if __name__ == "__main__":
    main()
