"""Paper Table II — mixed neural architecture workloads.

Five random launches from the pool (repeats allowed), launched one-by-one
in random order; TENSILE schedules the merged set, baselines schedule each
job independently; repeated 3× and averaged (as the paper does).
"""
from __future__ import annotations

import json
import random
from typing import Dict

import numpy as np

from repro.core import MemoryScheduler, SchedulerConfig, evaluate
from repro.core.baselines import capuchin_plan, vdnn_conv_plan

from .workloads import GPU_PROFILE, POOL, get_workload


def bench_round(seed: int) -> Dict[str, Dict[str, float]]:
    rng = random.Random(seed)
    names = [rng.choice(list(POOL)) for _ in range(5)]
    seqs = [get_workload(n, job_id=f"{n}#{i}") for i, n in enumerate(names)]
    offsets = {}
    t = 0.0
    for s in seqs:
        offsets[s.job_id] = t
        t += 0.25 * s.iteration_time

    sched = MemoryScheduler(GPU_PROFILE, SchedulerConfig(
        max_swap_ratio=1.0 / len(seqs)))
    for s in seqs:
        sched.register_job(s, offset=offsets[s.job_id])
    res = sched.schedule()
    out = {"TENSILE": evaluate(seqs, res.plans, GPU_PROFILE,
                               offsets=offsets)}
    out["vDNN"] = evaluate(
        seqs, {s.job_id: vdnn_conv_plan(s, GPU_PROFILE) for s in seqs},
        GPU_PROFILE, offsets=offsets, free_at_last_use=False)
    budget = res.final_report.peak_bytes // len(seqs)
    cap = {s.job_id: capuchin_plan(s, budget, GPU_PROFILE).plan
           for s in seqs}
    m = evaluate(seqs, cap, GPU_PROFILE, offsets=offsets)
    m["EOR"] += seqs[0].iteration_time / max(m["vanilla_time"], 1e-12)
    m["CBR"] = m["MSR"] / m["EOR"] if m["EOR"] > 0 else 0.0
    out["Capuchin"] = m
    return out


def run(rounds: int = 3, out_json: str = None) -> Dict:
    acc: Dict[str, Dict[str, list]] = {}
    for r in range(rounds):
        res = bench_round(seed=100 + r)
        for method, metrics in res.items():
            slot = acc.setdefault(method, {})
            for k, v in metrics.items():
                slot.setdefault(k, []).append(v)
    table = {m: {k: float(np.mean(v)) for k, v in ks.items()}
             for m, ks in acc.items()}
    if out_json:
        with open(out_json, "w") as f:
            json.dump(table, f, indent=1)
    return table


def format_markdown(table: Dict) -> str:
    lines = ["| method | MSR | EOR | CBR |", "|---|---|---|---|"]
    for m in ("vDNN", "Capuchin", "TENSILE"):
        r = table[m]
        lines.append(f"| {m} | {r['MSR']:.4f} | {r['EOR']:.4f} "
                     f"| {r['CBR']:.4f} |")
    return "\n".join(lines)


if __name__ == "__main__":
    print(format_markdown(run()))
