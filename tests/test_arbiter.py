"""Cross-job arbitration tests: BudgetArbiter splits (equal / priority /
peak-proportional, demand-capped water-filling), planning against
arbiter-assigned per-job budgets, device-budget certification in the
shared DeviceLedger, budget reclaim on job finish, and loud surfacing of
job-thread failures."""
import pytest

from repro.core import (ARBITER_POLICIES, BudgetArbiter, GlobalController,
                        JaxprExecutor, JobFailedError, MachineProfile,
                        MemoryEngine, SchedulerConfig, analyze,
                        build_pipeline, simulate)

from repro.service import JobSpec

from helpers import capture_mlp, mlp_train_step, synthetic_chain

PROFILE = MachineProfile(host_link_bw=16e9, compute_flops=5e10, mem_bw=1e10)


@pytest.fixture(scope="module")
def two_mlps():
    a, _, _ = capture_mlp(sizes=(64, 128, 128, 8), batch=16, job_id="a")
    b, _, _ = capture_mlp(sizes=(64, 128, 128, 8), batch=16, job_id="b")
    return a, b


# ---------------------------------------------------------------- arbiter
def test_split_policies_and_registry():
    assert {"equal", "priority", "peak"} <= set(ARBITER_POLICIES)
    with pytest.raises(KeyError):
        BudgetArbiter(100, policy="no-such-policy")

    arb = BudgetArbiter(1000, policy="equal")
    arb.register("a")
    arb.register("b")
    assert arb.split(["a", "b"]) == {"a": 500, "b": 500}

    arb = BudgetArbiter(1000, policy="priority")
    arb.register("hi", priority=3.0)
    arb.register("lo", priority=1.0)
    split = arb.split(["hi", "lo"])
    assert split["hi"] == 750 and split["lo"] == 250

    arb = BudgetArbiter(1000, policy="peak")
    arb.register("big", demand_bytes=600)
    arb.register("small", demand_bytes=200)
    split = arb.split(["big", "small"])
    assert split["big"] == 3 * split["small"]
    assert sum(split.values()) <= 1000


def test_split_caps_at_demand_and_redistributes():
    """Water-filling: a job that cannot use its share is capped at its
    demand; the surplus re-flows to the uncapped jobs."""
    arb = BudgetArbiter(1000, policy="equal")
    arb.register("tiny", demand_bytes=100)
    arb.register("hungry", demand_bytes=0)    # unknown demand: uncapped
    split = arb.split(["tiny", "hungry"])
    assert split["tiny"] == 100
    assert split["hungry"] == 900
    assert sum(split.values()) <= 1000


def test_finishing_job_bytes_reclaimed_and_redistributed():
    """On every finish the controller re-splits; the survivor's next plan
    gets the departed job's bytes back."""
    arb = BudgetArbiter(1 << 20, policy="equal")
    arb.register("long")
    arb.register("short")
    first = arb.split(["long", "short"])
    arb.unregister("short")
    second = arb.split(["long"])
    assert second["long"] > first["long"]
    assert second["long"] == 1 << 20
    assert arb.history == [first, second]


# ------------------------------------------------- budget-aware planning
def test_two_staggered_jobs_respect_device_budget_in_shared_ledger(two_mlps):
    """The arbiter splits the device budget, each job plans against its
    slice, and the *simulated execution* on one capacity-limited shared
    DeviceLedger never exceeds the device budget (zero OOM events) —
    while the vanilla run of the same two jobs busts it."""
    a, b = two_mlps
    offsets = {"a": 0.0, "b": 0.5 * a.iteration_time}
    vanilla = simulate([a, b], None, PROFILE, iterations=2, offsets=offsets,
                       free_at_last_use=False)
    budget = int(vanilla.peak_bytes * 0.5)
    assert vanilla.peak_bytes > budget     # vanilla exceeds the budget

    arb = BudgetArbiter(budget, policy="equal")
    for s in (a, b):
        arb.register(s.job_id,
                     demand_bytes=analyze(
                         [s], free_at_last_use=False).peak_bytes)
    budgets = arb.split(["a", "b"])
    assert sum(budgets.values()) <= budget

    cfg = SchedulerConfig(memory_budget_bytes=budget,
                          per_job_budget_bytes=budgets)
    res = build_pipeline("tensile+autoscale", profile=PROFILE,
                         config=cfg).plan([a, b], offsets=offsets)
    assert res.plans["a"].budget_bytes == budgets["a"]

    eng = MemoryEngine(PROFILE, capacity_bytes=budget)
    sim = simulate([a, b], res.plans, PROFILE, iterations=2,
                   offsets=offsets, engine=eng)
    assert eng.ledger.peak <= budget
    assert eng.ledger.oom_events == 0
    assert sim.peak_bytes == eng.ledger.peak   # one shared ledger


def test_high_priority_job_keeps_weighted_share(two_mlps):
    """Under tensile+priority the high-priority job's plan retains at
    least its weighted share: swap victims come from the low-priority job
    first, so hi's planned residency dominates lo's."""
    a, b = two_mlps          # identical shapes -> differences are policy
    prios = {"a": 3.0, "b": 1.0}
    offsets = {"a": 0.0, "b": 0.25 * a.iteration_time}
    van = analyze([a, b], offsets=offsets, free_at_last_use=False).peak_bytes
    budget = int(van * 0.5)
    arb = BudgetArbiter(budget, policy="priority")
    arb.register("a", priority=3.0)
    arb.register("b", priority=1.0)
    budgets = arb.split(["a", "b"])
    # weighted 3:1 shares (independent floor-division: tolerance of a few
    # bytes, not exact equality)
    assert abs(budgets["a"] - 3 * budgets["b"]) <= 3

    cfg = SchedulerConfig(memory_budget_bytes=budget,
                          per_job_budget_bytes=budgets,
                          job_priorities=prios)
    res = build_pipeline("tensile+priority", profile=PROFILE,
                         config=cfg).plan([a, b], offsets=offsets)
    peaks = res.final_report.per_job_peak
    assert peaks["a"] >= peaks["b"]
    # hi keeps >= its weight share of what planning left resident
    assert peaks["a"] / max(peaks["a"] + peaks["b"], 1) >= 0.5


def test_autoscale_pass_enforces_tight_per_job_budget():
    """BudgetAutoscalePass acts when plain greedy swapping leaves a job
    over its arbiter slice: per-job budgets tighter than what global
    largest-first reaches force job-targeted steps."""
    a = synthetic_chain(n_ops=10, latency=2.0, job_id="a", seed=1)
    b = synthetic_chain(n_ops=10, latency=2.0, job_id="b", seed=2)
    prof = MachineProfile(host_link_bw=1e6, host_link_latency=1e-3,
                          compute_flops=1e9, mem_bw=1e9)
    solo = {j: analyze([s]).peak_bytes for j, s in (("a", a), ("b", b))}
    budgets = {j: int(p * 0.55) for j, p in solo.items()}
    cfg = SchedulerConfig(per_job_budget_bytes=budgets)
    res = build_pipeline("tensile+autoscale", profile=prof,
                         config=cfg).plan([a, b], offsets={"b": 3.0})
    after = res.final_report.per_job_peak
    # every job moved toward its slice vs its unscheduled solo peak
    for j in ("a", "b"):
        assert after[j] < solo[j]
    assert res.pass_steps["swap"] > 0


# ------------------------------------------------ controller integration
def _make_job(j):
    import jax

    from repro.optim.adam import adamw_init

    from helpers import mlp_params
    p = mlp_params(jax.random.PRNGKey(j), [32, 64, 64, 4])
    o = adamw_init(p)
    b = (jax.random.normal(jax.random.PRNGKey(10 + j), (8, 32)),
         jax.random.normal(jax.random.PRNGKey(20 + j), (8, 4)))
    return p, o, b


def test_controller_arbitrated_staggered_jobs():
    """End-to-end: two staggered jobs under the arbitrated controller —
    budgets re-split at launch AND at finish, per-job ledger views carry
    the slices, plans swap at iteration boundaries, nothing fails."""
    gc = GlobalController(profile=PROFILE, async_swap=False,
                          pipeline_name="tensile+autoscale",
                          arbiter_policy="equal")
    p, o, b = _make_job(0)
    gc.submit(JobSpec("j0", iterations=2,
                      payload=(mlp_train_step, p, o, b)))
    p, o, b = _make_job(1)
    gc.submit(JobSpec("j1", iterations=2, priority=2.0,
                      payload=(mlp_train_step, p, o, b)))
    gc.wait(timeout=300)
    assert all(h.done and h.error is None for h in gc.jobs.values())
    # the launch of j1 re-split over {j0, j1}; each finish re-split again
    assert gc.arbiter is not None and len(gc.arbiter.history) >= 2
    assert any(set(s) == {"j0", "j1"} for s in gc.arbiter.history)
    for split in gc.arbiter.history:
        assert sum(split.values()) <= gc.arbiter.capacity
    for h in gc.jobs.values():
        assert h.ledger_view is not None
        assert h.ledger_view.budget_bytes is not None
        assert h.ledger_view.peak == gc.accountant.job_peak(h.job_id)
    assert gc.global_peak_bytes > 0
    assert gc.replan_count >= 3     # 2 launches + >=1 finish re-split


def test_departure_with_zero_reclaimed_bytes_skips_replan(two_mlps):
    """Regression: a finished job that held ZERO bytes of the arbiter
    split (an under-demand job) reclaims nothing — its departure must NOT
    trigger a survivors' replan (it would rebuild identical plans), while
    a departure that does reclaim bytes still re-splits."""
    from repro.core import JobHandle

    a, b = two_mlps
    c = a.clone("c")
    gc = GlobalController(profile=PROFILE, async_swap=False,
                          pipeline_name="tensile+autoscale",
                          arbiter_policy="equal")
    for s in (a, b, c):
        gc.scheduler.register_job(s)
        gc.jobs[s.job_id] = JobHandle(job_id=s.job_id, seq=s,
                                      closed_jaxpr=None, args=(),
                                      iterations=1)
        gc.arbiter.register(s.job_id)
    gc.arbiter.split(["a", "b", "c"])
    # job "a" finished holding none of the split (demand-capped to zero)
    gc.arbiter.last_assignment["a"] = 0

    before = gc.replan_count
    gc._on_job_exit(gc.jobs["a"])
    assert gc.replan_count == before          # no-op replan skipped
    assert "a" not in gc.arbiter.priorities   # still deregistered
    assert "a" not in gc.scheduler.jobs

    # a departure that DOES reclaim bytes replans the survivors
    assert gc.arbiter.last_assignment["b"] > 0
    gc._on_job_exit(gc.jobs["b"])
    assert gc.replan_count == before + 1
    assert gc.jobs["c"].plan is not None      # survivor got a fresh plan


def test_job_thread_failure_surfaces_loudly(monkeypatch):
    """A job thread dying must not be silent: wait() raises JobFailedError
    naming the job, chaining the original exception, and carrying the
    thread's traceback."""
    def boom(self, *args, **kwargs):
        raise RuntimeError("executor exploded")

    monkeypatch.setattr(JaxprExecutor, "run", boom)
    gc = GlobalController(profile=PROFILE, async_swap=False)
    p, o, b = _make_job(0)
    gc.submit(JobSpec("doomed", iterations=1,
                      payload=(mlp_train_step, p, o, b)))
    with pytest.raises(JobFailedError) as ei:
        gc.wait(timeout=120)
    err = ei.value
    assert "doomed" in str(err)
    assert "executor exploded" in str(err)
    assert isinstance(err.failures["doomed"], RuntimeError)
    assert isinstance(err.__cause__, RuntimeError)
    assert "RuntimeError" in err.tracebacks["doomed"]
    assert gc.failures() and gc.jobs["doomed"].error_tb
    # non-raising inspection path still reports
    gc.wait(timeout=1, raise_errors=False)
