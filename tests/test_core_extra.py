"""Simulator, recompute, baselines, cost model, graph capture, TENSILE
compiled-path decisions."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (CostModel, EWMATracker, LatencyMLP, MachineProfile,
                        evaluate, schedule_single, simulate)
from repro.core.access import (TensorKind)
from repro.core.baselines import capuchin_plan, vdnn_conv_plan
from repro.core.peak_analysis import analyze
from repro.core.recompute_planner import RecomputePlanner
from repro.core.scheduler import MemoryScheduler, SchedulerConfig

from helpers import capture_mlp, synthetic_chain

PROFILE = MachineProfile(host_link_bw=1e6, host_link_latency=1e-3,
                         compute_flops=1e9, mem_bw=1e9)


# ---------------------------------------------------------------- simulator
def test_simulator_vanilla_peak_matches_analysis():
    seq = synthetic_chain(n_ops=10, latency=2.0, seed=4)
    sim = simulate([seq], None, PROFILE, iterations=1)
    rep = analyze([seq])
    assert sim.peak_bytes == rep.peak_bytes


def test_simulator_multi_iteration_steady():
    seq = synthetic_chain(n_ops=6, latency=1.0, seed=2)
    s1 = simulate([seq], None, PROFILE, iterations=1)
    s3 = simulate([seq], None, PROFILE, iterations=3)
    assert s3.peak_bytes == s1.peak_bytes  # steady state, no leak
    assert s3.total_time > 2.5 * s1.total_time


def test_simulator_async_jobs_interleave():
    a = synthetic_chain(n_ops=6, latency=1.0, job_id="a", seed=1)
    b = synthetic_chain(n_ops=6, latency=1.0, job_id="b", seed=2)
    both = simulate([a, b], None, PROFILE, iterations=1)
    apart = simulate([a, b], None, PROFILE, iterations=1,
                     offsets={"b": 100.0})
    assert apart.peak_bytes <= both.peak_bytes


# ---------------------------------------------------------------- recompute
def _tight_channel_profile():
    # swaps effectively impossible: 1 B/s link
    return MachineProfile(host_link_bw=1.0, host_link_latency=100.0,
                          compute_flops=1e9, mem_bw=1e9)


def test_recompute_when_swap_impossible():
    seq = synthetic_chain(n_ops=10, latency=1.0, seed=9)
    prof = _tight_channel_profile()
    sched = MemoryScheduler(prof, SchedulerConfig(memory_budget_bytes=1))
    sched.register_job(seq)
    res = sched.schedule()
    assert res.swaps_scheduled == 0
    assert res.recomputes_scheduled > 0
    assert res.final_report.peak_bytes < res.initial_report.peak_bytes


def test_recompute_msps_ordering():
    seq = synthetic_chain(n_ops=8, latency=1.0, seed=5)
    from repro.core.plan import SchedulingPlan
    plan = SchedulingPlan(job_id=seq.job_id)
    rp = RecomputePlanner(seq, plan)
    cands = rp.candidates(analyze([seq]))
    msps = [c.msps for c in cands]
    assert msps == sorted(msps, reverse=True)


def test_recompute_skipped_when_budget_fits():
    seq = synthetic_chain(n_ops=10, latency=1.0, seed=9)
    prof = _tight_channel_profile()
    sched = MemoryScheduler(prof, SchedulerConfig(
        memory_budget_bytes=2 ** 62))
    sched.register_job(seq)
    res = sched.schedule()
    assert res.recomputes_scheduled == 0  # paper Alg 3 line 13 gate


# ---------------------------------------------------------------- baselines
def test_vdnn_swaps_only_heavy_feature_maps():
    seq, _, _ = capture_mlp()
    plan = vdnn_conv_plan(seq, PROFILE)
    heavy_io = set()
    for op in seq.operators:
        if op.name in ("dot_general", "conv_general_dilated"):
            heavy_io |= set(op.inputs) | set(op.outputs)
    for ev in plan.events:
        assert ev.tensor_id in heavy_io
        assert seq.tensors[ev.tensor_id].kind is TensorKind.ACTIVATION


def test_capuchin_within_iteration_only():
    seq, _, _ = capture_mlp()
    res = capuchin_plan(seq, budget_bytes=10_000, profile=PROFILE)
    assert all(not e.crosses_iteration for e in res.plan.events)
    kinds = {seq.tensors[e.tensor_id].kind for e in res.plan.events}
    assert TensorKind.OPT_STATE not in kinds  # cannot schedule Opt phase


def test_comparative_ordering_tensile_wins_cbr():
    seq, _, _ = capture_mlp(sizes=(64, 512, 512, 512, 8), batch=64)
    prof = MachineProfile(host_link_bw=12e9, compute_flops=13e12,
                          mem_bw=600e9)
    res = schedule_single(seq, profile=prof)
    t = evaluate([seq], res.plans, prof)
    v = evaluate([seq], {seq.job_id: vdnn_conv_plan(seq, prof)}, prof,
                 free_at_last_use=False)
    assert t["MSR"] >= v["MSR"]
    # CBR dominance holds when vDNN saves non-trivially (a near-zero EOR
    # denominator on a tiny saving can inflate vDNN's ratio)
    if v["MSR"] >= 0.5 * t["MSR"]:
        assert t["CBR"] >= v["CBR"]


# --------------------------------------------------------------- cost model
def test_cost_model_dot_flops():
    import jax.numpy as jnp
    cm = CostModel()
    closed = jax.make_jaxpr(lambda a, b: a @ b)(
        jnp.zeros((32, 64)), jnp.zeros((64, 16)))
    flops, bts = cm.eqn_cost(closed.jaxpr.eqns[0])
    assert flops == 2 * 32 * 64 * 16
    assert bts == 4 * (32 * 64 + 64 * 16 + 32 * 16)


def test_cost_model_scan_multiplies_trip_count():
    def f(x, ws):
        return jax.lax.scan(lambda h, w: (jnp.tanh(h @ w), None), x, ws)[0]
    closed = jax.make_jaxpr(f)(jnp.zeros((8, 16)), jnp.zeros((5, 16, 16)))
    cm = CostModel()
    scan_eqn = [e for e in closed.jaxpr.eqns if e.primitive.name == "scan"][0]
    flops, _ = cm.eqn_cost(scan_eqn)
    assert flops >= 5 * 2 * 8 * 16 * 16  # trip count included


def test_ewma_tracker():
    t = EWMATracker(alpha=0.5)
    t.update(0, 1.0)
    assert t.update(0, 3.0) == 2.0
    assert t.drift_ratio(1.0) == 1.0


def test_latency_mlp_learns_monotonicity():
    rng = np.random.default_rng(0)
    flops = 10 ** rng.uniform(6, 12, 200)
    bts = flops / 10
    util = rng.uniform(0, 1, 200).astype(np.float32)
    lat = flops / 1e12 * (1 + util) + 1e-6
    mlp = LatencyMLP(hidden=16)
    r2 = mlp.fit(flops, bts, util, lat, steps=800)
    assert r2 > 0.9
    assert mlp.predict_one(1e12, 1e11, 0.0) > mlp.predict_one(1e8, 1e7, 0.0)


# ------------------------------------------------------- compiled-path glue
def test_schedule_for_budget_decisions():
    from repro.core import schedule_for_budget
    seq, _, _ = capture_mlp(sizes=(64, 512, 512, 8), batch=64)
    dec = schedule_for_budget(seq, budget_bytes=1, profile=PROFILE)
    # a 1-byte budget forces both offloads and remat decisions
    assert dec.offload_opt_state or dec.offload_names or dec.remat_names


def test_make_remat_policy_cpu_fallback():
    from repro.core import TensileDecisions, make_remat_policy
    dec = TensileDecisions(remat_names=frozenset({"x"}),
                           save_names=frozenset({"keep"}))
    pol = make_remat_policy(dec, offload=True)  # CPU: falls back
    assert callable(pol)
