"""Per-architecture smoke tests (deliverable (f)): every assigned arch,
reduced config, one forward/train step on CPU, output shapes + no NaNs +
decode step; plus MoE path equivalence and SSD-vs-recurrence checks."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, list_configs, ShapeSpec
from repro.models.registry import get_model

SMOKE_TRAIN = ShapeSpec("smoke", 64, 2, "train")
SMOKE_DECODE = ShapeSpec("smoke_dec", 64, 2, "decode")


@pytest.mark.parametrize("arch", list_configs())
def test_arch_smoke(arch):
    cfg = get_config(arch).reduced()
    if cfg.n_experts:
        cfg.moe_impl = "dense"
    api = get_model(cfg)
    params, axes = api.init(jax.random.PRNGKey(0))
    # axes tree mirrors params tree
    assert (jax.tree.structure(params)
            == jax.tree.structure(axes, is_leaf=lambda x: isinstance(x, tuple)))
    batch = api.input_specs(SMOKE_TRAIN, abstract=False)
    loss = api.loss(params, batch)
    assert np.isfinite(float(loss)), f"{arch}: non-finite loss"
    logits, aux = api.forward(params, batch)
    assert logits.shape[0] == 2 and logits.shape[-1] == cfg.padded_vocab
    assert np.isfinite(np.asarray(logits, np.float32)).all()

    cache, caxes = api.init_cache(2, 64)
    dbatch = api.decode_input_specs(SMOKE_DECODE, abstract=False)
    dec_logits, cache2 = api.decode(params, dbatch, cache, jnp.int32(3))
    assert dec_logits.shape == (2, 1, cfg.padded_vocab)
    assert np.isfinite(np.asarray(dec_logits, np.float32)).all()
    assert jax.tree.structure(cache) == jax.tree.structure(cache2)


@pytest.mark.parametrize("arch", ["tinyllama-1.1b", "mamba2-780m",
                                  "moonshot-v1-16b-a3b"])
def test_arch_grad_step_decreases_loss(arch):
    from repro.optim.adam import adamw_init, adamw_update
    cfg = get_config(arch).reduced()
    if cfg.n_experts:
        cfg.moe_impl = "dense"
    api = get_model(cfg)
    params, _ = api.init(jax.random.PRNGKey(0))
    opt = adamw_init(params)
    batch = api.input_specs(SMOKE_TRAIN, abstract=False)

    @jax.jit
    def step(p, o):
        loss, grads = jax.value_and_grad(lambda pp: api.loss(pp, batch))(p)
        p, o = adamw_update(p, grads, o, lr=3e-3)
        return p, o, loss

    losses = []
    for _ in range(8):
        params, opt, loss = step(params, opt)
        losses.append(float(loss))
    assert losses[-1] < losses[0], f"{arch}: loss did not decrease {losses}"


def test_moe_dense_vs_scatter_equivalence():
    """With capacity high enough to drop nothing, the EP scatter path must
    match the dense reference numerically."""
    from repro.models.layers import ParamBuilder
    from repro.models.moe import init_moe, moe_apply_dense, moe_apply_scatter
    d, e, f, k = 32, 8, 64, 2
    b = ParamBuilder(jax.random.PRNGKey(0), jnp.float32)
    init_moe(b, d, e, f, "swiglu")
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 24, d))
    y_dense, aux1 = moe_apply_dense(b.params, x, top_k=k, n_experts=e,
                                    act="swiglu")
    y_scatter, aux2 = moe_apply_scatter(b.params, x, top_k=k, n_experts=e,
                                        capacity_factor=8.0, act="swiglu")
    np.testing.assert_allclose(np.asarray(y_dense), np.asarray(y_scatter),
                               rtol=2e-4, atol=2e-5)
    np.testing.assert_allclose(float(aux1), float(aux2), rtol=1e-5)


def test_ssd_matches_sequential_recurrence():
    from repro.models.ssm import ssd_chunked
    b, s, h, p, n, chunk = 2, 160, 4, 16, 32, 64
    ks = jax.random.split(jax.random.PRNGKey(0), 5)
    xh = jax.random.normal(ks[0], (b, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    a = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.5)
    bb = jax.random.normal(ks[3], (b, s, n))
    cc = jax.random.normal(ks[4], (b, s, n))
    y, fin = ssd_chunked(xh, dt, a, bb, cc, chunk)

    st = np.zeros((b, h, p, n))
    ys = np.zeros((b, s, h, p))
    dtn, an, bbn, ccn, xn = map(np.asarray, (dt, a, bb, cc, xh))
    for t in range(s):
        dec = np.exp(dtn[:, t] * an[None])
        st = st * dec[..., None, None] + np.einsum(
            "bh,bn,bhp->bhpn", dtn[:, t], bbn[:, t], xn[:, t])
        ys[:, t] = np.einsum("bn,bhpn->bhp", ccn[:, t], st)
    np.testing.assert_allclose(np.asarray(y), ys, rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(np.asarray(fin), st, rtol=2e-3, atol=2e-3)


def test_decode_matches_forward_prefix():
    """Greedy decode over a prompt must produce the same logits as the
    parallel forward (KV-cache correctness)."""
    cfg = get_config("tinyllama-1.1b").reduced()
    api = get_model(cfg)
    params, _ = api.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 12), 0, 100)
    logits_par, _ = api.forward(params, {"tokens": toks})
    cache, _ = api.init_cache(2, 16)
    outs = []
    for i in range(12):
        lg, cache = api.decode(params, {"tokens": toks[:, i:i + 1]},
                               cache, jnp.int32(i))
        outs.append(lg[:, 0])
    logits_dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(logits_par, np.float32),
                               np.asarray(logits_dec, np.float32),
                               rtol=2e-3, atol=2e-3)


def test_param_counts_match_public_specs():
    expect = {
        "jamba-1.5-large-398b": 398e9,
        "kimi-k2-1t-a32b": 1.03e12,
        "gemma-2b": 2.5e9,
        "qwen2.5-14b": 14.8e9,
        "minitron-4b": 4.2e9,
        "tinyllama-1.1b": 1.1e9,
        "pixtral-12b": 12.2e9,
        "mamba2-780m": 0.86e9,
        "whisper-base": 0.1e9,
    }
    for arch, target in expect.items():
        n = get_config(arch).param_count()
        assert abs(n - target) / target < 0.08, \
            f"{arch}: {n/1e9:.2f}B vs expected {target/1e9:.2f}B"


def test_active_params_moe():
    kimi = get_config("kimi-k2-1t-a32b")
    active = kimi.active_param_count()
    assert 25e9 < active < 45e9  # "a32b"
    jamba = get_config("jamba-1.5-large-398b")
    assert 80e9 < jamba.active_param_count() < 110e9  # 94B active


def test_vocab_padding():
    w = get_config("whisper-base")
    assert w.padded_vocab % 256 == 0 and w.padded_vocab >= w.vocab_size
