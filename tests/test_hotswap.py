"""Safe-point plan hot-swap: safe-point detection invariants, plan
splicing (property-tested under hypothesis), the simulator's and the
executor's mid-iteration splice, and the preemptive controller path.

The acceptance contract of the preemption feature: a plan spliced at a
safe point never exceeds the pre-splice plan's peak before the splice,
respects the new (shrunken) slice after it whenever the incremental
replan certified the slice, and hot-swap execution produces outputs
identical to boundary-mode execution — the splice never tears an
iteration."""
import numpy as np
import pytest

from conftest import hypothesis_or_stub
from repro.core import (GlobalController, JaxprExecutor, MachineProfile,
                        MemoryEngine, PlanUpdate, SchedulerConfig,
                        SchedulingPlan, analyze, build_pipeline,
                        find_safe_points, reference_outputs, simulate)

from repro.service import JobSpec

from helpers import capture_mlp, mlp_train_step, synthetic_chain

given, settings, st = hypothesis_or_stub()

PROFILE = MachineProfile(host_link_bw=16e9, compute_flops=5e10, mem_bw=1e10)
EPS = 1e-12


@pytest.fixture(scope="module")
def mlp():
    return capture_mlp(sizes=(64, 128, 128, 8), batch=16, job_id="vic")


# ---------------------------------------------------------------- safe points
def test_safe_points_are_quiescent_local_minima(mlp):
    """Every reported safe point has no planned transfer in flight across
    its boundary and carries a residency that is a local minimum of the
    boundary-residency sequence."""
    seq, _, _ = mlp
    cfg = SchedulerConfig(per_job_budget_bytes={"vic": 1 << 60})
    plan = build_pipeline("tensile", profile=PROFILE,
                          config=cfg).plan([seq]).plans["vic"]
    sps = find_safe_points(seq, plan)
    assert sps, "a trained MLP step must expose safe points"
    T = seq.iteration_time
    spans = []
    for ev in plan.events:
        if ev.end - ev.start > EPS:
            s = ev.start % T
            d = ev.end - ev.start
            while d > EPS:
                c = min(d, T - s)
                spans.append((s, s + c))
                d -= c
                s = 0.0
    n = len(seq.operators)
    for sp in sps:
        assert 0 <= sp.op_idx < n - 1        # never the iteration boundary
        assert sp.time == seq.op_end[sp.op_idx]
        assert not any(s < sp.time - 1e-9 and sp.time < e - 1e-9
                       for s, e in spans), \
            f"transfer in flight across safe point op {sp.op_idx}"
        assert sp.resident_bytes >= 0


def test_empty_plan_safe_points_track_activity_minima(mlp):
    seq, _, _ = mlp
    sps = find_safe_points(seq, None)
    assert sps
    # residency at safe points is bounded by the job's own scheduled peak
    peak = analyze([seq]).peak_bytes
    assert all(sp.resident_bytes <= peak for sp in sps)


# ---------------------------------------------------------------- splicing
def _windowed_peak(seq, plan, lo, hi):
    return analyze([seq], plans={seq.job_id: plan},
                   window=(lo, hi)).peak_bytes


def _splice_invariants(seq, prior, slice_frac, sp_choice):
    """Shared body of the deterministic and property tests."""
    sps = find_safe_points(seq, prior)
    if not sps:
        return
    sp = sps[sp_choice % len(sps)]
    solo = analyze([seq], plans={seq.job_id: prior}).peak_bytes
    new_slice = max(1, int(solo * slice_frac))
    pipe = build_pipeline("tensile+autoscale", profile=PROFILE,
                          config=SchedulerConfig())
    res = pipe.replan_from([seq], {seq.job_id: prior},
                           {seq.job_id: sp.op_idx},
                           budgets={seq.job_id: new_slice})
    newp = res.plans[seq.job_id]
    spliced = prior.splice(newp, sp.op_idx)
    T = seq.iteration_time

    # prefix invariance: before the splice the spliced plan IS the prior
    # plan — its peak there can never exceed the pre-splice plan's
    before_prior = _windowed_peak(seq, prior, 0.0, sp.time + EPS)
    before_spliced = _windowed_peak(seq, spliced, 0.0, sp.time + EPS)
    assert before_spliced <= before_prior

    # remainder: never worse than the prior plan, and when the replan
    # certified the slice (its whole-timeline peak fits), the spliced
    # remainder respects the shrunken slice too
    after_prior = _windowed_peak(seq, prior, sp.time + EPS, T + EPS)
    after_spliced = _windowed_peak(seq, spliced, sp.time + EPS, T + EPS)
    assert after_spliced <= max(after_prior, new_slice)
    if newp.planned_peak_bytes <= new_slice:
        assert after_spliced <= new_slice

    # provenance: the splice is auditable
    assert spliced.provenance
    rec = spliced.provenance[-1]
    assert rec["action"] == "splice" and rec["at_op"] == sp.op_idx
    assert any(r.get("action") == "replan_from"
               for r in spliced.provenance)


def test_splice_invariants_deterministic(mlp):
    seq, _, _ = mlp
    for frac in (0.9, 0.7, 0.5):
        for choice in (0, 1, 5):
            _splice_invariants(seq, SchedulingPlan(job_id=seq.job_id),
                               frac, choice)


@settings(max_examples=25, deadline=None)
@given(n_ops=st.integers(min_value=4, max_value=16),
       seed=st.integers(min_value=0, max_value=1000),
       frac=st.floats(min_value=0.3, max_value=0.95),
       choice=st.integers(min_value=0, max_value=40))
def test_splice_safe_point_property(n_ops, seed, frac, choice):
    """Property (hypothesis): for ANY synthetic chain, ANY safe point and
    ANY shrunken slice, the spliced plan never exceeds the pre-splice
    plan's peak before the splice and respects the new slice after it
    whenever the incremental replan certified the slice."""
    seq = synthetic_chain(n_ops=n_ops, latency=2.0, seed=seed,
                          job_id="chain")
    _splice_invariants(seq, SchedulingPlan(job_id="chain"), frac, choice)


# ------------------------------------------------------------- simulator
def test_simulator_hot_swap_at_safe_point(mlp):
    """A safe-point PlanUpdate lands at the first eligible safe point at
    or after its at_time, is recorded in plan_swaps, and can only lower
    the global peak vs never swapping."""
    seq, _, _ = mlp
    prior = SchedulingPlan(job_id="vic")
    sps = find_safe_points(seq, prior)
    T = seq.iteration_time
    t_req = 0.2 * T
    future = [sp for sp in sps if sp.time > t_req]
    assert future
    new_slice = int(analyze([seq]).peak_bytes * 0.7)
    pipe = build_pipeline("tensile+autoscale", profile=PROFILE,
                          config=SchedulerConfig())
    newp = pipe.replan_from([seq], {"vic": prior}, {"vic": future[0].op_idx},
                            budgets={"vic": new_slice}).plans["vic"]
    upd = PlanUpdate(at_time=t_req, plan=newp, mode="safe-point",
                     safe_ops=frozenset(sp.op_idx for sp in future))
    base = simulate([seq], {"vic": prior.copy()}, PROFILE, iterations=3)
    eng = MemoryEngine(PROFILE)
    sim = simulate([seq], {"vic": prior.copy()}, PROFILE, iterations=3,
                   engine=eng, plan_updates={"vic": [upd]})
    assert upd.applied_time is not None
    assert upd.applied_op in upd.safe_ops
    assert upd.applied_time >= t_req
    assert sim.plan_swaps["vic"] == [(upd.applied_time, upd.applied_op)]
    assert sim.peak_bytes <= base.peak_bytes


def test_simulator_safe_point_update_not_blocked_by_earlier_boundary(mlp):
    """A due safe-point update queued BEHIND a boundary update still
    splices mid-iteration (the queue is scanned, not just its head) —
    and the boundary update SURVIVES the splice: the remainder plan is
    only certified for the splice iteration, so the full boundary plan
    must still land at the next boundary."""
    seq, _, _ = mlp
    T = seq.iteration_time
    prior = SchedulingPlan(job_id="vic")
    sps = find_safe_points(seq, prior)
    future = [sp for sp in sps if sp.time > 0.1 * T]
    new_slice = int(analyze([seq]).peak_bytes * 0.7)
    pipe = build_pipeline("tensile+autoscale", profile=PROFILE,
                          config=SchedulerConfig())
    newp = pipe.replan_from([seq], {"vic": prior}, {"vic": future[0].op_idx},
                            budgets={"vic": new_slice}).plans["vic"]
    stale = PlanUpdate(at_time=0.05 * T, plan=prior.copy(), mode="boundary")
    fresh = PlanUpdate(at_time=0.1 * T, plan=newp, mode="safe-point",
                       safe_ops=frozenset(sp.op_idx for sp in future))
    simulate([seq], {"vic": prior.copy()}, PROFILE, iterations=2,
             plan_updates={"vic": [stale, fresh]})
    assert fresh.applied_time is not None
    assert fresh.applied_op in fresh.safe_ops
    assert fresh.applied_time < T            # mid-iteration, not blocked
    # the boundary update was NOT swallowed by the splice: it lands at
    # the iteration boundary as the iteration-scope plan
    assert stale.applied_op == -1
    assert stale.applied_time >= T - 1e-9


def test_simulator_boundary_update_waits_for_the_boundary(mlp):
    seq, _, _ = mlp
    T = seq.iteration_time
    newp = SchedulingPlan(job_id="vic")
    upd = PlanUpdate(at_time=0.1 * T, plan=newp, mode="boundary")
    simulate([seq], {"vic": SchedulingPlan(job_id="vic")}, PROFILE,
             iterations=2, plan_updates={"vic": [upd]})
    assert upd.applied_op == -1
    assert upd.applied_time >= T - 1e-9      # not before the boundary


# -------------------------------------------------------------- executor
def test_executor_hot_swap_preserves_outputs(mlp):
    """The real interpreting executor splices a pending plan in at a safe
    point mid-iteration and still produces outputs identical to the
    unscheduled reference — the hot-swap never tears the iteration."""
    seq, closed, (params, opt, batch) = mlp
    prior = SchedulingPlan(job_id="vic")
    sps = find_safe_points(seq, prior)
    assert sps
    new_slice = int(analyze([seq]).peak_bytes * 0.7)
    pipe = build_pipeline("tensile+autoscale", profile=PROFILE,
                          config=SchedulerConfig())
    newp = pipe.replan_from([seq], {"vic": prior}, {"vic": sps[0].op_idx},
                            budgets={"vic": new_slice}).plans["vic"]
    ref = reference_outputs(closed, params, opt, batch)

    ex = JaxprExecutor(closed, seq, prior)
    ex.request_plan(newp, {sp.op_idx for sp in sps})
    out = ex.run(params, opt, batch)
    assert ex.stats.hot_swaps == 1
    assert ex.plan is newp and ex.ctx.plan is newp
    for a, b in zip(ref, out):
        assert np.allclose(np.asarray(a), np.asarray(b),
                           rtol=1e-5, atol=1e-6)
    # the spliced plan's swap-outs actually ran on the data path
    assert ex.stats.swap_out_count > 0


def test_executor_ignores_request_without_reachable_safe_point(mlp):
    seq, closed, (params, opt, batch) = mlp
    prior = SchedulingPlan(job_id="vic")
    newp = SchedulingPlan(job_id="vic")
    ex = JaxprExecutor(closed, seq, prior)
    ex.request_plan(newp, set())             # no eligible op: never fires
    ex.run(params, opt, batch)
    assert ex.stats.hot_swaps == 0
    assert ex.ctx.plan is prior


# ---------------------------------------------------- controller preemption
def test_controller_preempts_running_victim(mlp):
    """The controller-side path: a shrunken slice routes through
    MemoryScheduler.replan_from into the victim's live executor, and the
    executor applies it at a safe point with outputs intact."""
    seq, closed, (params, opt, batch) = mlp
    gc = GlobalController(profile=PROFILE, async_swap=False,
                          pipeline_name="tensile+autoscale",
                          arbiter_policy="equal", arbiter_mode="preempt")
    assert gc.arbiter is not None and gc.arbiter.mode == "preempt"
    gc.scheduler.register_job(seq)
    gc.arbiter.register("vic", demand_bytes=0)
    prev = {"vic": analyze([seq]).peak_bytes}
    gc.arbiter.last_assignment = dict(prev)

    from repro.core import JobHandle
    handle = JobHandle(job_id="vic", seq=seq, closed_jaxpr=closed,
                       args=(params, opt, batch), iterations=1)
    ex = JaxprExecutor(closed, seq, None, accountant=gc.accountant,
                       channel=gc.channel)
    handle.executor = ex
    gc.jobs["vic"] = handle
    # the victim currently holds more than its shrunken slice
    gc.accountant.alloc("vic", "resident-blob", prev["vic"])
    new_slice = int(prev["vic"] * 0.7)

    gc._preempt_victims({"vic": new_slice}, prev)
    assert gc.preempt_count == 1
    assert handle.preemptions
    assert not gc.preempt_failures
    assert ex._pending_plan is not None
    plan, safe_ops = ex._pending_plan
    assert plan.budget_bytes == new_slice
    assert plan.provenance and \
        plan.provenance[-1]["action"] == "replan_from"

    # the requested plan lands at a safe point and execution is exact
    ref = reference_outputs(closed, params, opt, batch)
    out = ex.run(params, opt, batch)
    assert ex.stats.hot_swaps == 1
    for a, b in zip(ref, out):
        assert np.allclose(np.asarray(a), np.asarray(b),
                           rtol=1e-5, atol=1e-6)


def test_boundary_and_preempt_controllers_agree_on_results():
    """End-to-end: the same two-job launch script under boundary and
    preempt arbitration completes cleanly in both modes with every
    iteration accounted for — preemption changes WHEN memory moves, never
    WHAT is computed (value-identity of a spliced run is asserted by
    test_executor_hot_swap_preserves_outputs)."""
    import jax

    from repro.optim.adam import adamw_init

    from helpers import mlp_params

    def job_args(j):
        p = mlp_params(jax.random.PRNGKey(j), [32, 64, 64, 4])
        o = adamw_init(p)
        b = (jax.random.normal(jax.random.PRNGKey(10 + j), (8, 32)),
             jax.random.normal(jax.random.PRNGKey(20 + j), (8, 4)))
        return p, o, b

    for mode in ("boundary", "preempt"):
        gc = GlobalController(profile=PROFILE, async_swap=False,
                              pipeline_name="tensile+autoscale",
                              arbiter_policy="equal", arbiter_mode=mode)
        p, o, b = job_args(0)
        h0 = gc.submit(JobSpec("j0", iterations=3,
                               payload=(mlp_train_step, p, o, b)))
        p, o, b = job_args(1)
        h1 = gc.submit(JobSpec("j1", iterations=2,
                               payload=(mlp_train_step, p, o, b)))
        gc.wait(timeout=300)
        assert all(h.done and h.error is None for h in gc.jobs.values()), mode
        assert not gc.preempt_failures, mode
        # every iteration ran to completion in both modes: nothing torn
        assert len(h0.step_times) == 3, mode
        assert len(h1.step_times) == 2, mode
