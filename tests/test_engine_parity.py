"""The shared memory-event engine, and the headline guarantee it buys:
the engine-backed simulator and the sync executor report IDENTICAL peak
bytes and residency event ordering for the same job + plan."""
import numpy as np
import pytest

from repro.core import (JaxprExecutor, MachineProfile, MemoryEngine,
                        reference_outputs, schedule_single, simulate)
from repro.core.engine import DeviceLedger, DmaChannel, EngineTrace

from helpers import capture_mlp, synthetic_chain

PROFILE = MachineProfile(host_link_bw=16e9, compute_flops=5e10, mem_bw=1e10)


# ---------------------------------------------------------------- ledger
def test_ledger_idempotent_and_keyed():
    led = DeviceLedger()
    assert led.alloc("j", "a", 100, 0.0)
    assert not led.alloc("j", "a", 100, 1.0)   # already resident: no-op
    assert led.alloc("k", "a", 50, 1.0)        # other job, same storage id
    assert led.used == 150 and led.peak == 150
    assert led.job_bytes("j") == 100 and led.job_bytes("k") == 50
    assert led.free("j", "a", 2.0) == 100
    assert led.free("j", "a", 2.0) == 0        # already freed: no-op
    assert led.used == 50
    assert led.peak == 150                     # peak is sticky
    assert led.is_resident("k", "a") and not led.is_resident("j", "a")


def test_ledger_capacity_oom_counting():
    led = DeviceLedger(capacity_bytes=100)
    led.alloc("j", "a", 80, 0.0)
    assert led.oom_events == 0
    led.alloc("j", "b", 80, 1.0)
    assert led.oom_events == 1


def test_dma_channel_virtual_fifo():
    ch = DmaChannel()
    s0, e0 = ch.acquire(0.0, 1.0)
    assert (s0, e0) == (0.0, 1.0)
    s1, e1 = ch.acquire(0.5, 1.0)              # conflicts: queues FIFO
    assert (s1, e1) == (1.0, 2.0)
    assert ch.conflicts == 1


def test_dma_channel_real_transfer_serializes():
    ch = DmaChannel()
    out = ch.transfer(lambda: 42)
    assert out == 42
    assert ch.busy_s >= 0


# ------------------------------------------------------- sim-vs-real parity
@pytest.fixture(scope="module")
def mlp_with_plan():
    seq, closed, args = capture_mlp(sizes=(64, 128, 128, 8), batch=16)
    res = schedule_single(seq, profile=PROFILE)
    return seq, closed, args, res.plans[seq.job_id]


def test_sim_and_executor_identical_peak_and_event_order(mlp_with_plan):
    """THE parity guarantee of the engine refactor: same residency
    decisions, byte-for-byte and in the same order, whether the plan runs
    on the virtual clock or on real arrays."""
    seq, closed, args, plan = mlp_with_plan
    assert plan.events, "plan must actually schedule something"

    sim_eng = MemoryEngine(PROFILE, trace=True)
    sim = simulate([seq], {seq.job_id: plan}, PROFILE, iterations=1,
                   transfer_mode="sync", engine=sim_eng)

    ex_eng = MemoryEngine(PROFILE, trace=True)
    ex = JaxprExecutor(closed, seq, plan, engine=ex_eng)
    out = ex.run(*args)
    ex.close()

    assert ex.stats.peak_bytes == sim.peak_bytes
    assert sim_eng.trace.keys() == ex_eng.trace.keys()
    # and the real run still computes the right numbers
    for a, b in zip(reference_outputs(closed, *args), out):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-6)


def test_sim_and_executor_identical_telemetry_records(mlp_with_plan):
    """Measured-telemetry parity: both runtimes emit records of EXACTLY
    the same schema (field-for-field), and their residency-event
    ordering — (action, storage) through the shared DeviceLedger hook —
    is identical for the same job + plan."""
    import dataclasses as _dc

    from repro.core import TelemetryHub, record_schemas

    seq, closed, args, plan = mlp_with_plan
    schemas = record_schemas()

    hub_sim = TelemetryHub(clock="virtual")
    simulate([seq], {seq.job_id: plan}, PROFILE, iterations=1,
             transfer_mode="sync", engine=MemoryEngine(PROFILE),
             telemetry=hub_sim)

    hub_ex = TelemetryHub(clock="real")
    ex = JaxprExecutor(closed, seq, plan,
                       engine=MemoryEngine(PROFILE, telemetry=hub_ex))
    ex.run(*args)
    ex.close()

    # identical record schemas, produced (not just declared) by BOTH
    for hub in (hub_sim, hub_ex):
        j = seq.job_id
        assert hub.ops[j] and hub.transfers[j] and hub.residency[j]
        for kind, recs in (("op", hub.ops[j]), ("transfer",
                                                hub.transfers[j]),
                           ("residency", hub.residency[j])):
            names = tuple(f.name for f in _dc.fields(recs[0]))
            assert names == schemas[kind]
    # identical residency-event ordering (one executor iteration vs the
    # simulator's first)
    sim_keys = [(r.action, r.storage) for r in hub_sim.residency[seq.job_id]
                if r.iteration == 0]
    assert hub_ex.residency_keys(seq.job_id) == sim_keys
    # both runtimes agree on how many iterations completed
    assert hub_sim.iterations(seq.job_id) == 1
    assert hub_ex.iterations(seq.job_id) == 1
    # ...and the executor extends its stats with the measured timeline
    assert ex.stats.residency_timeline
    assert ex.stats.residency_timeline[-1][1] >= 0


def test_sim_and_executor_identical_without_plan(mlp_with_plan):
    seq, closed, args, _ = mlp_with_plan
    sim_eng = MemoryEngine(PROFILE, trace=True)
    sim = simulate([seq], None, PROFILE, iterations=1,
                   transfer_mode="sync", engine=sim_eng)
    ex_eng = MemoryEngine(PROFILE, trace=True)
    ex = JaxprExecutor(closed, seq, None, engine=ex_eng)
    ex.run(*args)
    ex.close()
    assert ex.stats.peak_bytes == sim.peak_bytes
    assert sim_eng.trace.keys() == ex_eng.trace.keys()


def test_sync_and_async_sim_agree_on_peak_shape():
    """The sync transfer mode exists for parity; it must stay a faithful
    sibling of the async mode (same residency set, timing differences
    only)."""
    seq = synthetic_chain(n_ops=12, latency=2.0, seed=0)
    prof = MachineProfile(host_link_bw=1e6, host_link_latency=1e-3,
                          compute_flops=1e9, mem_bw=1e9)
    from repro.core import schedule_single as ss
    plan = ss(seq, profile=prof).plans[seq.job_id]
    a = simulate([seq], {seq.job_id: plan}, prof, iterations=1)
    s = simulate([seq], {seq.job_id: plan}, prof, iterations=1,
                 transfer_mode="sync")
    assert a.peak_bytes > 0 and s.peak_bytes > 0
    # sync serializes transfers with compute: never faster than async
    assert s.total_time >= a.total_time - 1e-9


def test_coalesced_sim_conserves_events_and_bytes():
    """A coalescing DmaChannel changes channel *timing* only: the sync
    simulation of the same plan books the same residency decisions in the
    same order, moves the same bytes in the same direction sequence, and
    never gets slower — it just pays fewer fixup latencies."""
    from repro.core import TelemetryHub

    seq = synthetic_chain(n_ops=12, latency=2.0, seed=0)
    prof = MachineProfile(host_link_bw=1e6, host_link_latency=1e-3,
                          compute_flops=1e9, mem_bw=1e9)
    plan = schedule_single(seq, profile=prof).plans[seq.job_id]

    def run(channel):
        eng = MemoryEngine(prof, channel=channel, trace=True)
        hub = TelemetryHub(clock="virtual")
        sim = simulate([seq], {seq.job_id: plan}, prof, iterations=1,
                       transfer_mode="sync", engine=eng, telemetry=hub)
        moved = [(r.storage, r.direction, r.size_bytes)
                 for r in hub.transfers[seq.job_id]]
        return sim, eng, moved

    base, base_eng, base_moved = run(DmaChannel())
    # plan triggers fire roughly one op latency (2.0 virtual s) apart, so
    # the window must cover that gap for adjacent bookings to merge
    co_ch = DmaChannel(coalesce=True, coalesce_window=2.5,
                       batch_overhead_s=2e-6)
    co, co_eng, co_moved = run(co_ch)

    # identical residency decisions and byte movement, event for event
    assert co_eng.trace.keys() == base_eng.trace.keys()
    assert co_moved == base_moved
    assert co.peak_bytes == base.peak_bytes
    # coalescing actually fired and only ever saves time
    assert co_ch.batched_transfers > 0
    assert co_ch.saved_fixup_s > 0
    assert co.total_time <= base.total_time + 1e-9


def test_engine_shared_ledger_across_jobs():
    """Two jobs on one engine share the device ledger (global peak covers
    both) — the multiplexer's accounting model."""
    a = synthetic_chain(n_ops=6, latency=1.0, job_id="a", seed=1)
    b = synthetic_chain(n_ops=6, latency=1.0, job_id="b", seed=2)
    eng = MemoryEngine(MachineProfile())
    sim = simulate([a, b], None, iterations=1, engine=eng)
    assert eng.ledger.peak == sim.peak_bytes
    assert sim.per_job_peak["a"] <= sim.peak_bytes
    assert eng.ledger.job_peak("a") == sim.per_job_peak["a"]


def test_trace_pauses():
    tr = EngineTrace()
    tr.record("alloc", "j", "x")
    tr.paused = True
    tr.record("alloc", "j", "y")
    assert tr.keys() == [("alloc", "j", "x")]
