"""Experience plane (src/repro/core/experience.py): fingerprints, the
persistent store's tolerance guarantees, concurrency safety, plan-cache
re-verification, and the no-store byte-reproducibility contract."""
import json
import os
import subprocess
import sys
import threading

import pytest

from repro.core import (CostModel, DeviceCalibration, ExperienceStore,
                        MachineProfile, SchedulerConfig, TelemetryHub,
                        build_pipeline, fingerprint, simulate)

from repro.service import JobSpec

from helpers import capture_mlp, synthetic_chain

PROFILE = MachineProfile(host_link_bw=16e9, compute_flops=5e10, mem_bw=1e10)


@pytest.fixture(scope="module")
def mlp_seq():
    seq, _closed, _args = capture_mlp(sizes=(16, 32, 8), batch=4)
    return seq


@pytest.fixture()
def store(tmp_path):
    return ExperienceStore(str(tmp_path / "exp"), device_id="test-device")


def _populate(store, seq, budget=None, iterations=2):
    """Cold-plan the sequence, simulate it, and flush the distilled
    experience; returns (budget, plan)."""
    if budget is None:
        budget = build_pipeline("tensile", profile=PROFILE).plan(
            [seq]).final_report.peak_bytes
    res = build_pipeline(
        "tensile", profile=PROFILE,
        config=SchedulerConfig(memory_budget_bytes=budget)).plan([seq])
    hub = TelemetryHub(clock="virtual")
    simulate([seq], {k: p.copy() for k, p in res.plans.items()}, PROFILE,
             iterations=iterations, telemetry=hub)
    cm = CostModel(DeviceCalibration(flops=5e10 / 4, mem_bw=1e10 / 4))
    cm.recalibrate(hub, report=False)
    store.record_job(store.fingerprint(seq), seq=seq, hub=hub,
                     job_id=seq.job_id, plan=res.plans[seq.job_id],
                     pipeline="tensile", calib=cm.calib, calib_samples=17)
    store.flush()
    return budget, res.plans[seq.job_id]


# ---------------------------------------------------------------- fingerprints
def test_fingerprint_stable_across_processes(mlp_seq):
    """The same capture in a FRESH interpreter produces the same
    fingerprint — the property that makes cross-run warm boot possible."""
    fp_here = fingerprint(mlp_seq, device_id="x")
    code = (
        "import sys; sys.path.insert(0, 'src'); sys.path.insert(0, '.')\n"
        "from tests.helpers import capture_mlp\n"
        "from repro.core import fingerprint\n"
        "seq, _c, _a = capture_mlp(sizes=(16, 32, 8), batch=4)\n"
        "print(fingerprint(seq, device_id='x'))\n")
    out = subprocess.run([sys.executable, "-c", code],
                         capture_output=True, text=True,
                         cwd=os.path.dirname(os.path.dirname(
                             os.path.abspath(__file__))))
    assert out.returncode == 0, out.stderr
    assert out.stdout.strip().splitlines()[-1] == fp_here


def test_fingerprint_invariant_to_parameter_values():
    """Different weights/inputs, same structure -> same fingerprint."""
    import jax
    import jax.numpy as jnp
    from repro.core import capture_train_step
    from repro.optim.adam import adamw_init
    from helpers import mlp_train_step

    def cap(seed, scale):
        key = jax.random.PRNGKey(seed)
        params = []
        sizes = (16, 32, 8)
        for i in range(len(sizes) - 1):
            key, k = jax.random.split(key)
            params.append(
                {"w": jax.random.normal(k, (sizes[i], sizes[i + 1])) * scale,
                 "b": jnp.zeros(sizes[i + 1])})
        opt = adamw_init(params)
        x = jax.random.normal(jax.random.PRNGKey(seed + 1), (4, sizes[0]))
        y = jax.random.normal(jax.random.PRNGKey(seed + 2), (4, sizes[-1]))
        seq, _ = capture_train_step(mlp_train_step, params, opt, (x, y),
                                    job_id="j")
        return seq

    assert fingerprint(cap(0, 0.02)) == fingerprint(cap(9, 1.7))


def test_fingerprint_differs_across_shape_and_topology(mlp_seq):
    wider, _c, _a = capture_mlp(sizes=(16, 64, 8), batch=4)       # shape
    deeper, _c, _a = capture_mlp(sizes=(16, 32, 32, 8), batch=4)  # topology
    fps = {fingerprint(s) for s in (mlp_seq, wider, deeper)}
    assert len(fps) == 3


def test_fingerprint_salted_by_device_identity(mlp_seq):
    assert fingerprint(mlp_seq, device_id="tpu-v5e") \
        != fingerprint(mlp_seq, device_id="cpu-container")


def test_fingerprint_ignores_latencies(mlp_seq):
    clone = mlp_seq.clone(mlp_seq.job_id)
    clone.set_latencies([lat * 7.5 + 1e-6
                         for lat in (op.latency
                                     for op in clone.operators)])
    assert fingerprint(clone) == fingerprint(mlp_seq)


# ---------------------------------------------------------------- tolerance
def test_corrupt_store_degrades_to_cold(store, mlp_seq):
    budget, _plan = _populate(store, mlp_seq)
    fp = store.fingerprint(mlp_seq)
    assert store.get(fp) is not None
    # trash the entry file AND the device record
    for name in os.listdir(store.dir):
        with open(os.path.join(store.dir, name), "w") as f:
            f.write("{not json\x00garbage\n\xff")
    assert store.get(fp) is None
    assert store.device_calibration() is None
    assert store.lookup_plan(mlp_seq, "tensile", budget,
                             profile=PROFILE) is None
    # a pipeline over the corrupt store plans cold without crashing, and
    # produces the same plan a store-less pipeline does
    cfg = SchedulerConfig(memory_budget_bytes=budget)
    pipe = build_pipeline("tensile", profile=PROFILE, config=cfg)
    pipe.experience = store
    warm = pipe.plan([mlp_seq])
    cold = build_pipeline("tensile", profile=PROFILE,
                          config=SchedulerConfig(
                              memory_budget_bytes=budget)).plan([mlp_seq])
    assert warm.plans[mlp_seq.job_id].to_dict() \
        == cold.plans[mlp_seq.job_id].to_dict()


def test_version_mismatch_reads_as_absent(store, mlp_seq):
    _populate(store, mlp_seq)
    fp = store.fingerprint(mlp_seq)
    path = store._path(fp)
    with open(path) as f:
        lines = f.read().splitlines()
    header = json.loads(lines[0])
    header["schema"] = 999
    with open(path, "w") as f:
        f.write("\n".join([json.dumps(header)] + lines[1:]) + "\n")
    assert store.get(fp) is None


def test_corrupt_lines_are_skipped_not_fatal(store, mlp_seq):
    _populate(store, mlp_seq)
    fp = store.fingerprint(mlp_seq)
    path = store._path(fp)
    with open(path) as f:
        lines = f.read().splitlines()
    # corrupt one record line in the middle; the rest must survive
    lines.insert(1, "}}}garbage{{{")
    with open(path, "w") as f:
        f.write("\n".join(lines) + "\n")
    entry = store.get(fp)
    assert entry is not None
    assert entry.telemetry is not None and entry.telemetry.samples > 0


# ---------------------------------------------------------------- concurrency
def test_atomic_concurrent_writers(tmp_path, mlp_seq):
    """Two writers (separate store handles, same root — the two-process
    model) flushing the same fingerprint interleaved: the final file
    parses, and the surviving telemetry carries the monotone-max sample
    count."""
    root = str(tmp_path / "shared")
    fp = ExperienceStore(root, device_id="d").fingerprint(mlp_seq)
    hub = TelemetryHub(clock="virtual")
    simulate([mlp_seq], None, PROFILE, iterations=1, telemetry=hub)
    errors = []

    def writer(n_flushes):
        try:
            st = ExperienceStore(root, device_id="d")
            for _ in range(n_flushes):
                st.record_job(fp, seq=mlp_seq, hub=hub,
                              job_id=mlp_seq.job_id,
                              calib=DeviceCalibration(), calib_samples=5)
                st.flush()
        except Exception as e:  # noqa: BLE001
            errors.append(e)

    threads = [threading.Thread(target=writer, args=(12,))
               for _ in range(2)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errors
    reader = ExperienceStore(root, device_id="d")
    entry = reader.get(fp)
    assert entry is not None
    n_ops = sum(len(v) for v in hub.ops.values())
    assert entry.telemetry.samples == n_ops
    # no orphaned tmp files survived the atomic replaces
    assert not [n for n in os.listdir(reader.dir) if ".tmp." in n]


# ---------------------------------------------------------------- plan cache
def test_plan_cache_rejects_shrunken_budget(store, mlp_seq):
    budget, plan = _populate(store, mlp_seq)
    hit = store.lookup_plan(mlp_seq, "tensile", budget, profile=PROFILE)
    assert hit is not None
    assert hit.provenance[-1]["action"] == "warm-boot"
    # the budget shrank below what the cached plan certifies: reject
    assert store.lookup_plan(mlp_seq, "tensile", budget // 4,
                             profile=PROFILE) is None
    # unknown pipeline: no candidates
    assert store.lookup_plan(mlp_seq, "vdnn", budget,
                             profile=PROFILE) is None


def test_warm_boot_skips_convergence_and_matches_cold_plan(store, mlp_seq):
    budget, cold_plan = _populate(store, mlp_seq)
    pipe = build_pipeline("tensile", profile=PROFILE,
                          config=SchedulerConfig(
                              memory_budget_bytes=budget))
    pipe.experience = store
    res = pipe.plan([mlp_seq])
    plan = res.plans[mlp_seq.job_id]
    assert res.iterations == 0                      # adopted, not re-run
    assert plan.provenance[-1]["action"] == "warm-boot"
    # the adopted plan is the stored plan, rebased losslessly (same
    # timeline -> identical events)
    a = [e.to_dict() for e in cold_plan.events]
    b = [e.to_dict() for e in plan.events]
    assert len(a) == len(b)
    for ea, eb in zip(a, b):
        for k, v in ea.items():
            if isinstance(v, float):
                assert abs(v - eb[k]) < 1e-9, (k, v, eb[k])
            else:
                assert v == eb[k], (k, v, eb[k])


def test_no_store_and_empty_store_plans_byte_identical(store, mlp_seq):
    """The golden contract: with no experience dir configured — or an
    EMPTY store (every lookup misses) — plans are byte-identical to the
    store-less pipeline's."""
    budget = build_pipeline("tensile", profile=PROFILE).plan(
        [mlp_seq]).final_report.peak_bytes
    cold = build_pipeline("tensile", profile=PROFILE,
                          config=SchedulerConfig(
                              memory_budget_bytes=budget)).plan([mlp_seq])
    pipe = build_pipeline("tensile", profile=PROFILE,
                          config=SchedulerConfig(
                              memory_budget_bytes=budget))
    pipe.experience = store                          # exists but empty
    warm = pipe.plan([mlp_seq])
    assert json.dumps(cold.plans[mlp_seq.job_id].to_dict(), sort_keys=True) \
        == json.dumps(warm.plans[mlp_seq.job_id].to_dict(), sort_keys=True)


def test_rebase_rejects_structurally_stale_plans(store, mlp_seq):
    budget, _plan = _populate(store, mlp_seq)
    # a different topology under the SAME fingerprint cannot happen via
    # the public API; simulate staleness by looking up with a sequence
    # whose tensors changed size (clone with grown specs)
    other = synthetic_chain(n_ops=6, job_id=mlp_seq.job_id)
    assert store.lookup_plan(other, "tensile", budget,
                             profile=PROFILE) is None


# ---------------------------------------------------------------- warm boots
def test_cost_model_warm_boots_from_store(store, mlp_seq):
    _populate(store, mlp_seq)
    stored = store.device_calibration()
    assert stored is not None
    cm = CostModel(experience=store)
    assert cm.calib.flops == stored.flops
    assert cm.calib.mem_bw == stored.mem_bw
    # an explicit calibration always wins
    explicit = DeviceCalibration(flops=1.0, mem_bw=1.0)
    assert CostModel(explicit, experience=store).calib is explicit
    # no store / empty store: probe defaults
    empty = ExperienceStore(str(store.root) + "-empty")
    assert CostModel(experience=empty).calib.flops \
        == DeviceCalibration().flops


def test_swap_planner_seeds_bandwidth_from_store(store, mlp_seq):
    from repro.core import SchedulingPlan, SwapPlanner
    _populate(store, mlp_seq)
    assert store.bandwidth() is not None
    pl = SwapPlanner(mlp_seq, SchedulingPlan(job_id=mlp_seq.job_id),
                     PROFILE, experience=store)
    seeded = pl._swap_time(1 << 20)
    modeled = PROFILE.transfer_time(1 << 20)
    assert seeded != modeled
    assert seeded == PROFILE.host_link_latency + (1 << 20) / store.bandwidth()


# ---------------------------------------------------------------- controller
def test_controller_flushes_and_warm_boots(tmp_path):
    """End-to-end cross-process cycle through the GlobalController: run 1
    (fresh store) flushes distilled experience on job finish; run 2 (new
    controller over the same dir) warm-boots its cost model from the
    persisted calibration and finds the fingerprint's entry with an
    arbiter prior attached."""
    import jax
    from repro.core import GlobalController
    from helpers import mlp_params, mlp_train_step
    from repro.optim.adam import adamw_init

    root = str(tmp_path / "ctl-exp")
    params = mlp_params(jax.random.PRNGKey(0), [12, 24, 6])
    opt = adamw_init(params)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 12))
    y = jax.random.normal(jax.random.PRNGKey(2), (4, 6))

    ctl1 = GlobalController(profile=PROFILE, experience_dir=root,
                            async_swap=False)
    ctl1.submit(JobSpec("run1", iterations=2,
                        payload=(mlp_train_step, params, opt, (x, y))))
    ctl1.wait(timeout=120)
    assert not ctl1.experience_failures
    fps = ctl1.experience.fingerprints()
    assert len(fps) == 1
    entry = ctl1.experience.get(fps[0])
    assert entry is not None and entry.telemetry.samples > 0
    assert ctl1.experience.device_calibration() is not None

    ctl2 = GlobalController(profile=PROFILE, experience_dir=root,
                            arbiter_policy="eor-learned", async_swap=False)
    stored = ctl2.experience.device_calibration()
    assert ctl2.cost_model.calib.flops == stored.flops
    h = ctl2.submit(JobSpec("run2", iterations=1,
                            payload=(mlp_train_step, params, opt, (x, y))))
    assert h.fingerprint == fps[0]          # same structure, same entry
    assert "run2" in ctl2.arbiter.priors    # prior attached at launch
    ctl2.wait(timeout=120)
    assert not ctl2.experience_failures
    # run 2's flush merged into the same entry with monotone samples
    merged = ctl2.experience.get(fps[0])
    assert merged.telemetry.samples >= entry.telemetry.samples


# ---------------------------------------------------------------- maintenance
def test_prune_export_import_roundtrip(store, tmp_path, mlp_seq):
    _populate(store, mlp_seq)
    fp = store.fingerprint(mlp_seq)
    bundle = store.export_bundle()
    assert fp in bundle["entries"]
    dest = ExperienceStore(str(tmp_path / "dest"), device_id="test-device")
    assert dest.import_bundle(bundle) == 1
    entry = dest.get(fp)
    assert entry is not None
    assert entry.telemetry.samples == store.get(fp).telemetry.samples
    assert dest.device_calibration() is not None
    # schema-mismatched bundles import nothing
    bad = dict(bundle, schema=999)
    assert dest.import_bundle(bad) == 0
    # prune by sample floor removes the entry
    assert dest.prune(min_samples=10 ** 9) == [fp]
    assert dest.get(fp) is None
