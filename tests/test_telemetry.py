"""The measured-telemetry plane: hub-fed calibration convergence,
measured-mode safe-point detection (subset-of-quiescent-instants
property, cold-start fallback), measured swap-window sizing, the
eor-learned arbiter policy, hub-reported drift, and the two PR-3
follow-ups that ride on it — revising swap-INs already booked on the
DmaChannel at a splice, and recompute actions in incremental remainder
plans."""
import numpy as np
import pytest

from conftest import hypothesis_or_stub
from repro.core import (ARBITER_POLICIES, BudgetArbiter, CostModel,
                        DeviceCalibration, JaxprExecutor, MachineProfile,
                        MemoryEngine, PlanUpdate, SchedulerConfig,
                        SchedulingPlan, SwapPlanner, TelemetryHub, analyze,
                        build_pipeline, find_safe_points, simulate)
from repro.core.plan import EventType, ScheduleEvent
from repro.core.scheduler import MemoryScheduler

from helpers import capture_mlp, synthetic_chain

given, settings, st = hypothesis_or_stub()

PROFILE = MachineProfile(host_link_bw=16e9, compute_flops=5e10, mem_bw=1e10)
EPS = 1e-12


@pytest.fixture(scope="module")
def mlp():
    return capture_mlp(sizes=(64, 128, 128, 8), batch=16, job_id="vic")


# ------------------------------------------------------- calibration
def test_calibration_error_decreases_monotonically(mlp):
    """Hub-fed DeviceCalibration recalibration: starting from
    deliberately wrong throughput constants, the analytic model's error
    against the measured latencies of a captured job decreases
    monotonically as iterations of samples are folded in."""
    seq, _, _ = mlp
    truth = DeviceCalibration()
    cm = CostModel(DeviceCalibration(flops=truth.flops / 4.0,
                                     mem_bw=truth.mem_bw / 4.0))
    hub = TelemetryHub(clock="virtual")
    errs = []
    for _ in range(4):
        simulate([seq], None, PROFILE, iterations=1, telemetry=hub)
        errs.append(cm.recalibrate(hub).overall)
    cold = CostModel(DeviceCalibration(flops=truth.flops / 4.0,
                                       mem_bw=truth.mem_bw / 4.0))
    err_cold = cold.calibration_report(hub).overall
    assert errs[0] < err_cold                 # feedback helps immediately
    for a, b in zip(errs, errs[1:]):
        assert b <= a + 1e-9                  # and never regresses
    assert errs[-1] < 0.05                    # converged on this job


def test_calibration_per_primitive_error_exposed(mlp):
    seq, _, _ = mlp
    hub = TelemetryHub(clock="virtual")
    simulate([seq], None, PROFILE, iterations=1, telemetry=hub)
    cm = CostModel(DeviceCalibration())
    rep = cm.calibration_report(hub)
    assert rep.samples == len(hub.ops[seq.job_id])
    assert "dot_general" in rep.per_primitive
    assert all(e >= 0 for e in rep.per_primitive.values())


def test_ewma_tracker_ingests_hub_samples(mlp):
    seq, _, _ = mlp
    from repro.core import EWMATracker
    hub = TelemetryHub(clock="virtual")
    simulate([seq], None, PROFILE, iterations=2, telemetry=hub)
    tr = EWMATracker()
    n = tr.ingest(hub, seq.job_id)
    assert n == len(hub.ops[seq.job_id])
    assert len(tr.values) == len(seq.operators)
    assert tr.ingest(hub, seq.job_id) == 0    # cursor: nothing new


# ------------------------------------------------- measured safe points
def test_measured_safe_points_cold_start_falls_back_to_modeled(mlp):
    seq, _, _ = mlp
    hub = TelemetryHub()                       # no samples at all
    modeled = find_safe_points(seq, None)
    measured = find_safe_points(seq, None, source="measured",
                                telemetry=hub)
    assert [s.op_idx for s in measured] == [s.op_idx for s in modeled]
    # one iteration is still below the blending threshold
    simulate([seq], None, PROFILE, iterations=1, telemetry=hub)
    measured = find_safe_points(seq, None, source="measured",
                                telemetry=hub, min_iterations=2)
    assert [s.op_idx for s in measured] == [s.op_idx for s in modeled]


def test_measured_safe_points_subset_of_executor_quiescence(mlp):
    """Measured-mode safe points are a subset of the quiescent instants
    of the EXECUTOR's real event log: for every reported safe point, in
    every iteration it was detected from, no recorded transfer interval
    spans the op's measured completion instant, and the measured
    residency is a local minimum."""
    seq, closed, args = mlp
    cfg = SchedulerConfig(per_job_budget_bytes={"vic": 1 << 60})
    plan = build_pipeline("tensile", profile=PROFILE,
                          config=cfg).plan([seq]).plans["vic"]
    hub = TelemetryHub(clock="real")
    eng = MemoryEngine(PROFILE, telemetry=hub)
    for _ in range(2):
        ex = JaxprExecutor(closed, seq, plan, engine=eng)
        ex.run(*args)
        ex.close()
    assert hub.iterations("vic") == 2
    sps = find_safe_points(seq, plan, source="measured", telemetry=hub)
    n = len(seq.operators)
    for it in range(2):
        view = hub.iteration_view("vic", it)
        resident = hub.measured_boundary_residency("vic", it, n)
        assert view is not None and resident is not None
        for sp in sps:
            assert 0 <= sp.op_idx < n - 1
            t_k = view.op_end[sp.op_idx]
            # quiescent in the raw event log
            assert not any(s < t_k - 1e-9 and t_k < e - 1e-9
                           for s, e in view.transfers), \
                f"transfer in flight across measured safe point {sp.op_idx}"
            # local minimum of the measured residency profile
            k = sp.op_idx
            left = resident[k - 1] if k > 0 else resident[k]
            assert resident[k] <= left and resident[k] <= resident[k + 1]


@settings(max_examples=30, deadline=None)
@given(n_ops=st.integers(min_value=4, max_value=12),
       seed=st.integers(min_value=0, max_value=10**6))
def test_measured_safe_points_subset_property(n_ops, seed):
    """Property (hypothesis): for ANY synthetic record stream — random
    residency walk, random transfer intervals — every measured-mode safe
    point is a quiescent local minimum of that stream, in every observed
    iteration."""
    seq = synthetic_chain(n_ops=n_ops, latency=1.0, seed=seed,
                          job_id="chain")
    rng = np.random.default_rng(seed)
    total = len(seq.operators)
    T = seq.iteration_time
    res = rng.integers(0, 1000, total).tolist()
    transfers = [(float(rng.uniform(0, T)), float(rng.uniform(0, T / 3)))
                 for _ in range(int(rng.integers(0, 4)))]
    hub = TelemetryHub(clock="virtual")
    for it in range(2):
        off = it * T
        for k, op in enumerate(seq.operators):
            hub.record_op("chain", k, op.latency, prim=op.name,
                          t=off + seq.op_end[k])
            hub.record_residency("chain", f"s{k}", "alloc", int(res[k]),
                                 t=off + seq.op_end[k])
        for s, d in transfers:
            hub.record_transfer("chain", "x", "out", 1024, d, t=off + s)
        hub.end_iteration("chain")
    sps = find_safe_points(seq, None, source="measured", telemetry=hub)
    for sp in sps:
        k = sp.op_idx
        assert 0 <= k < total - 1
        t_k = seq.op_end[k]
        for it in range(2):
            off = it * T
            assert not any(off + s < t_k + off - EPS
                           and t_k + off < off + s + d - EPS
                           for s, d in transfers)
        left = res[k - 1] if k > 0 else res[k]
        assert res[k] <= left and res[k] <= res[k + 1]


def test_measured_boundary_residency_tie_break_is_emission_order():
    """An op's allocs and frees share one timestamp (the op's end
    instant): the boundary must settle at the LAST-EMITTED value, not
    the largest one."""
    seq = synthetic_chain(n_ops=2, latency=1.0, seed=0, job_id="chain")
    total = len(seq.operators)
    hub = TelemetryHub(clock="virtual")
    for k in range(total):
        hub.record_op("chain", k, 1.0, t=seq.op_end[k])
    hub.record_residency("chain", "x", "alloc", 150, t=seq.op_end[0])
    hub.record_residency("chain", "x", "free", 30, t=seq.op_end[0])
    hub.end_iteration("chain")
    res = hub.measured_boundary_residency("chain", 0, total)
    assert res is not None
    assert res[0] == 30          # post-release value, not the high-water


# ------------------------------------------------ measured swap windows
def test_swap_planner_sizes_windows_from_measured_bandwidth(mlp):
    """With enough transfer samples, the planner's swap time comes from
    the measured DMA bandwidth; without a hub it is byte-identical to the
    profile constant (golden plans stay pinned)."""
    seq, _, _ = mlp
    hub = TelemetryHub(clock="virtual")
    # measured channel is 100x slower than the profile claims
    for i in range(5):
        hub.record_transfer("vic", f"s{i}", "out", 1 << 20,
                            (1 << 20) / (PROFILE.host_link_bw / 100.0))
    pl_modeled = SwapPlanner(seq, SchedulingPlan(job_id="vic"), PROFILE)
    pl_measured = SwapPlanner(seq, SchedulingPlan(job_id="vic"), PROFILE,
                              telemetry=hub)
    size = 8 << 20
    assert pl_modeled._swap_time(size) == PROFILE.transfer_time(size)
    assert pl_measured._swap_time(size) > 50 * pl_modeled._swap_time(size)
    # below the sample floor the planner stays on the modeled constant
    cold = SwapPlanner(seq, SchedulingPlan(job_id="vic"), PROFILE,
                       telemetry=TelemetryHub())
    assert cold._swap_time(size) == PROFILE.transfer_time(size)


# ------------------------------------------------- eor-learned arbiter
def test_eor_learned_policy_weights_stalled_jobs():
    assert "eor-learned" in ARBITER_POLICIES
    hub = TelemetryHub()
    # job a: 40% of its time lost to stalls; job b: stall-free
    hub.record_op("a", 0, 0.6)
    hub.record_stall("a", 0, 0.4, "passive_in")
    hub.record_op("b", 0, 1.0)
    arb = BudgetArbiter(1000, policy="eor-learned", telemetry=hub)
    arb.register("a")
    arb.register("b")
    split = arb.split(["a", "b"])
    assert split["a"] > split["b"]
    assert split["a"] + split["b"] <= 1000


def test_eor_learned_policy_degrades_to_equal_without_telemetry():
    arb = BudgetArbiter(1000, policy="eor-learned")
    arb.register("a")
    arb.register("b")
    split = arb.split(["a", "b"])
    assert split["a"] == split["b"]


def test_hub_drift_ratio_and_scheduler_fold(mlp):
    seq, _, _ = mlp
    hub = TelemetryHub(clock="virtual")
    sched = MemoryScheduler(PROFILE)
    sched.register_job(seq)
    baseline = sum(op.latency for op in seq.operators)
    assert hub.drift_ratio("vic", baseline) == 0.0      # no samples yet
    assert not sched.update_latencies_from_hub("vic", hub)
    # measured latencies 3x the modeled ones -> drift past the threshold
    for i, op in enumerate(seq.operators):
        hub.record_op("vic", i, 3.0 * op.latency, prim=op.name)
    assert hub.drift_ratio("vic", baseline) > 1.0
    old = [op.latency for op in seq.operators]
    assert sched.update_latencies_from_hub("vic", hub)
    new = [op.latency for op in seq.operators]
    assert sum(new) > sum(old)                          # folded in


# --------------------------------- revising booked swap-INs at a splice
def _chain_with_late_swap_in(n_ops=6):
    seq = synthetic_chain(n_ops=n_ops, latency=1.0, seed=3, job_id="c")
    spec = seq.tensors["a0"]
    plan = SchedulingPlan(job_id="c")
    plan.add(ScheduleEvent(
        event_type=EventType.SWAP_OUT, tensor_id="a0", job_id="c",
        trigger_op=1, delta=0.0, start=0.0, end=0.0,
        size_bytes=spec.size_bytes))
    # prefetch booked at op 3 but scheduled to START much later
    # (delta 5): between those instants it is booked-but-unstarted
    plan.add(ScheduleEvent(
        event_type=EventType.SWAP_IN, tensor_id="a0", job_id="c",
        trigger_op=3, delta=5.0, start=0.0, end=0.0,
        size_bytes=spec.size_bytes, target_op=2 * n_ops - 1))
    return seq, plan


def test_simulator_splice_cancels_unstarted_booked_swap_in():
    """A safe-point splice no longer waits for a swap-IN that is booked
    on the channel but has not started: the booking is cancelled (and
    the channel tail refunded), the splice lands, and the value is still
    correct via the passive path at its next use."""
    seq, plan = _chain_with_late_swap_in()
    upd = PlanUpdate(at_time=3.5, plan=SchedulingPlan(job_id="c"),
                     mode="safe-point", safe_ops=frozenset({3}))
    sim = simulate([seq], {"c": plan}, PROFILE, iterations=1,
                   plan_updates={"c": [upd]})
    assert upd.applied_op == 3                 # splice landed mid-iteration
    assert sim.canceled_swap_ins == 1          # the booked prefetch revised
    assert sim.passive_swap_ins >= 1           # value refetched passively


def test_simulator_splice_still_waits_for_started_swap_in():
    """A transfer already on the wire pins the splice to a later safe
    point — cancellation only covers unstarted bookings."""
    seq, plan = _chain_with_late_swap_in()
    # allow every op boundary: the first eligible one AFTER the transfer
    # starts (t=8) must be used, never one inside the transfer
    upd = PlanUpdate(at_time=8.2, plan=SchedulingPlan(job_id="c"),
                     mode="safe-point", safe_ops=None)
    sim = simulate([seq], {"c": plan}, PROFILE, iterations=1,
                   plan_updates={"c": [upd]})
    assert upd.applied_op is not None
    assert sim.canceled_swap_ins == 0          # it landed, nothing revised


def test_executor_splice_cancels_queued_prefetches(mlp):
    """The real executor path: cancel_unstarted drains queued (not yet
    running) swap-ins so a hot-swap is not blocked by them, and the run
    still reproduces the reference outputs."""
    from repro.core import reference_outputs
    seq, closed, args = mlp
    cfg = SchedulerConfig(per_job_budget_bytes={"vic": 1 << 60})
    plan = build_pipeline("tensile", profile=PROFILE,
                          config=cfg).plan([seq]).plans["vic"]
    sps = find_safe_points(seq, plan)
    assert sps
    ex = JaxprExecutor(closed, seq, plan, async_swap=True)
    ex.request_plan(SchedulingPlan(job_id="vic"),
                    {sp.op_idx for sp in sps})
    out = ex.run(*args)
    ex.close()
    assert ex.stats.hot_swaps == 1
    ref = reference_outputs(closed, *args)
    for a, b in zip(ref, out):
        assert np.allclose(np.asarray(a), np.asarray(b),
                           rtol=1e-5, atol=1e-6)


# ------------------------------- recompute in incremental remainder plans
def test_preemptive_replan_emits_recompute_when_swaps_infeasible():
    """When the windowed swap budget is infeasible (the DMA channel is
    too slow for any eager swap-out pair to fit the remainder), the
    incremental replan may emit RECOMPUTE actions — triggered strictly
    after the safe point and only when they verifiably lower the
    windowed peak."""
    seq = synthetic_chain(n_ops=8, latency=1.0, seed=7, job_id="c")
    # per-transfer setup alone exceeds any window: swaps can never fit
    slow = MachineProfile(host_link_bw=1e3, host_link_latency=1e6)
    pipe = build_pipeline("tensile+autoscale", profile=slow,
                          config=SchedulerConfig())
    prior = SchedulingPlan(job_id="c")
    sps = find_safe_points(seq, prior)
    assert sps
    step = sps[0].op_idx
    solo = analyze([seq]).peak_bytes
    res = pipe.replan_from([seq], {"c": prior}, {"c": step},
                           budgets={"c": max(1, int(solo * 0.5))})
    plan = res.plans["c"]
    recs = plan.recomputes()
    assert recs, "infeasible swap window must fall back to recomputation"
    assert not plan.swap_outs()                 # swaps truly infeasible
    for ev in plan.events:
        assert ev.trigger_op > step             # strictly after the splice
    # per-step peak verification held: the windowed peak improved
    w0 = analyze([seq], plans={"c": prior},
                 window=(seq.op_end[step], seq.iteration_time)).peak_bytes
    w1 = analyze([seq], plans={"c": plan},
                 window=(seq.op_end[step], seq.iteration_time)).peak_bytes
    assert w1 < w0
