"""Dynamic multi-workload scenario suite acceptance (smoke-sized).

The contract the CI scenarios-smoke job also enforces: >= 4 multi-job
dynamic scenarios; under `tensile+autoscale` every scenario's global peak
stays within the scenario's device budget (zero OOM events in the shared
capacity-limited ledger) while `vanilla` exceeds it on at least two."""
import pytest


@pytest.fixture(scope="module")
def table():
    from benchmarks import scenarios
    return scenarios.run(smoke=True)


def test_suite_has_dynamic_multi_job_scenarios(table):
    assert len(table) >= 4
    names = set(table)
    assert {"staggered", "churn", "priority-inversion", "bursty"} <= names
    for rec in table.values():
        assert len(rec["jobs"]) >= 2
        offsets = [j["offset"] for j in rec["jobs"].values()]
        assert len(set(offsets)) > 1           # dynamic: staggered arrivals
    churn_iters = {j["iterations"]
                   for j in table["churn"]["jobs"].values()}
    assert len(churn_iters) > 1                # jobs finish at different times
    prios = {j["priority"]
             for j in table["priority-inversion"]["jobs"].values()}
    assert len(prios) > 1


def test_autoscale_fits_budget_vanilla_does_not(table):
    vanilla_over = 0
    for name, rec in table.items():
        auto = rec["policies"]["tensile+autoscale"]
        assert auto["within_budget"], \
            f"{name}: autoscale peak {auto['peak']} > {rec['device_budget']}"
        assert auto["oom_events"] == 0
        assert auto["MSR"] > 0
        if not rec["policies"]["vanilla"]["within_budget"]:
            vanilla_over += 1
    assert vanilla_over >= 2


def test_arbiter_budgets_are_sound_and_fairness_reported(table):
    for rec in table.values():
        budgets = {j: v["budget"] for j, v in rec["jobs"].items()}
        assert sum(b for b in budgets.values()) <= rec["device_budget"] * \
            len(budgets)     # per-job min-assignments, each <= capacity
        assert all(0 <= b <= rec["device_budget"] for b in budgets.values())
        for m in rec["policies"].values():
            assert 0.0 < m["fairness"] <= 1.0


def test_priority_policy_improves_fairness_under_churn(table):
    """Arbitrated policies entitle jobs to their slices; utilisation of
    those entitlements is more uniform than vanilla's equal-split view."""
    rec = table["churn"]
    assert rec["policies"]["tensile+priority"]["fairness"] >= \
        rec["policies"]["vanilla"]["fairness"]
