"""Dynamic multi-workload scenario suite acceptance (smoke-sized).

The contract the CI bench-trajectory job also exercises: >= 4 multi-job
dynamic scenarios; under `tensile+autoscale` every scenario's global peak
stays within the scenario's device budget (zero OOM events in the shared
capacity-limited ledger) while `vanilla` exceeds it on at least two.

Preemption scenarios (flash-crowd, preempt-vs-boundary) add the
time-to-within-budget contract: preemptive arbitration gets the device
back inside the budget in < 1 burst-job iteration with zero ledger OOMs,
while boundary arbitration takes >= 1.

The cold-vs-warm scenario adds the experience plane's acceptance
contract: a warm boot's first-iteration calibration error is at or below
the cold run's CONVERGED error, its verified cached plan runs within
budget from iteration 0 with zero ledger OOMs, and it dominates the cold
boot on first-iteration peak and time-to-first-feasible-plan."""
import pytest


@pytest.fixture(scope="module")
def table():
    from benchmarks import scenarios
    return scenarios.run(smoke=True)


@pytest.fixture(scope="module")
def policy_table(table):
    """The 4 cross-job-policy scenarios (staggered/churn/...)."""
    return {k: v for k, v in table.items()
            if "tensile+autoscale" in v["policies"]}


@pytest.fixture(scope="module")
def preempt_table(table):
    """The boundary-vs-preempt arbitration scenarios."""
    return {k: v for k, v in table.items()
            if "preempt" in v["policies"]}


def test_suite_has_dynamic_multi_job_scenarios(table):
    assert len(table) >= 7
    names = set(table)
    assert {"staggered", "churn", "priority-inversion", "bursty",
            "flash-crowd", "preempt-vs-boundary", "cold-vs-warm"} <= names
    for rec in table.values():
        assert len(rec["jobs"]) >= 2
        offsets = [j["offset"] for j in rec["jobs"].values()]
        assert len(set(offsets)) > 1           # dynamic: staggered arrivals
    churn_iters = {j["iterations"]
                   for j in table["churn"]["jobs"].values()}
    assert len(churn_iters) > 1                # jobs finish at different times
    prios = {j["priority"]
             for j in table["priority-inversion"]["jobs"].values()}
    assert len(prios) > 1


def test_autoscale_fits_budget_vanilla_does_not(policy_table):
    vanilla_over = 0
    for name, rec in policy_table.items():
        auto = rec["policies"]["tensile+autoscale"]
        assert auto["within_budget"], \
            f"{name}: autoscale peak {auto['peak']} > {rec['device_budget']}"
        assert auto["oom_events"] == 0
        assert auto["MSR"] > 0
        if not rec["policies"]["vanilla"]["within_budget"]:
            vanilla_over += 1
    assert vanilla_over >= 2


def test_arbiter_budgets_are_sound_and_fairness_reported(table):
    for rec in table.values():
        budgets = {j: v["budget"] for j, v in rec["jobs"].items()}
        assert sum(b for b in budgets.values()) <= rec["device_budget"] * \
            len(budgets)     # per-job min-assignments, each <= capacity
        assert all(0 <= b <= rec["device_budget"] for b in budgets.values())
        for m in rec["policies"].values():
            assert 0.0 < m["fairness"] <= 1.0


def test_priority_policy_improves_fairness_under_churn(policy_table):
    """Arbitrated policies entitle jobs to their slices; utilisation of
    those entitlements is more uniform than vanilla's equal-split view."""
    rec = policy_table["churn"]
    assert rec["policies"]["tensile+priority"]["fairness"] >= \
        rec["policies"]["vanilla"]["fairness"]


# ---------------------------------------------------------------- preemption
def test_flash_crowd_preempt_beats_boundary(preempt_table):
    """The acceptance contract: on flash-crowd, preemptive arbitration is
    back within the device budget in < 1 burst-job iteration with ZERO
    ledger OOMs, while boundary arbitration stays over for >= 1 (the
    across-iteration lag the paper's Algorithm 3 is meant to avoid)."""
    rec = preempt_table["flash-crowd"]
    pre = rec["policies"]["preempt"]
    bnd = rec["policies"]["boundary"]
    assert pre["ttwb_burst_iters"] < 1.0
    assert pre["oom_events"] == 0
    assert pre["within_budget"]
    assert bnd["ttwb_burst_iters"] >= 1.0
    # preemption also strictly reduces the global peak excursion
    assert pre["peak"] <= bnd["peak"]


def test_preempt_never_worse_than_boundary(preempt_table):
    """Head-to-head on every preemption scenario: the safe-point hot-swap
    can only shrink the over-budget window and the OOM count."""
    for name, rec in preempt_table.items():
        pre = rec["policies"]["preempt"]
        bnd = rec["policies"]["boundary"]
        assert pre["ttwb_burst_iters"] <= bnd["ttwb_burst_iters"], name
        assert pre["oom_events"] <= bnd["oom_events"], name


def test_measured_preempt_matches_modeled_baseline(preempt_table):
    """Measured-telemetry acceptance: tensile with MEASURED safe points
    (find_safe_points(source="measured") over a probed hub) plus
    eor-learned arbitration achieves time-to-within-budget <= the
    modeled preempt baseline, with zero ledger OOMs."""
    for name, rec in preempt_table.items():
        m = rec["policies"]["preempt-measured"]
        base = rec["policies"]["preempt"]
        assert m["ttwb_burst_iters"] <= base["ttwb_burst_iters"] + 1e-9, name
        assert m["oom_events"] == 0, name
        assert m["within_budget"], name
        # the splice actually landed at a measured safe point
        assert any(op >= 0 for _t, op in m["plan_swaps"]["victim"]), name


def test_calibration_metrics_reported_and_converged(table):
    """Every scenario/policy row carries the modeled-vs-measured
    calibration pair, and hub-fed recalibration always improves on the
    deliberately miscalibrated cold-start constants."""
    for name, rec in table.items():
        for pol, m in rec["policies"].items():
            if "tokens_per_s" in m:
                # serving rows: decode turns, not training iterations —
                # there is no cold-start cost model being recalibrated
                continue
            assert "calib_err" in m and "calib_err_cold" in m, (name, pol)
            assert m["calib_samples"] > 0, (name, pol)
            assert m["calib_err"] <= m["calib_err_cold"] + 1e-9, (name, pol)
            assert m["calib_err"] < 0.25, (name, pol)


@pytest.fixture(scope="module")
def coldwarm(table):
    """The experience plane's cold-vs-warm boot scenario."""
    return table["cold-vs-warm"]


# ---------------------------------------------------------- cold vs warm
def test_warm_calibration_dominates_cold_converged(coldwarm):
    """THE acceptance criterion: the warm boot's calibration error at its
    FIRST iteration is at or below the cold run's CONVERGED error — the
    persisted calibration makes recalibration's end state the warm run's
    starting state."""
    cold = coldwarm["modes"]["cold"]
    warm = coldwarm["modes"]["warm"]
    assert warm["calib_err_cold"] <= cold["calib_err"] + 1e-9
    # and far below the cold run's own first-iteration error
    assert warm["calib_err_cold"] < cold["calib_err_cold"]


def test_warm_cached_plan_first_iteration_within_budget(coldwarm):
    """The warm boot runs its re-verified cached plan from iteration 0:
    within the device budget, zero ledger OOMs — while the cold boot's
    unplanned first iteration busts the budget."""
    warm = coldwarm["modes"]["warm"]
    cold = coldwarm["modes"]["cold"]
    assert warm["plan_cache_hit"]
    assert warm["first_iter_peak"] <= coldwarm["device_budget"]
    assert warm["first_iter_within_budget"]
    assert warm["oom_events"] == 0
    assert warm["within_budget"]
    assert not cold["first_iter_within_budget"]
    assert cold["oom_events"] > 0


def test_warm_dominates_cold_on_all_three(coldwarm):
    """Warm must dominate cold on first-iteration peak,
    time-to-first-feasible-plan, and first-iteration calibration error."""
    cold = coldwarm["modes"]["cold"]
    warm = coldwarm["modes"]["warm"]
    assert warm["first_iter_peak"] <= cold["first_iter_peak"]
    assert warm["ttfp_s"] <= cold["ttfp_s"]
    assert warm["calib_err_cold"] <= cold["calib_err_cold"]
    # the cache hit is what makes ttfp collapse: the verified cached
    # plan is adopted without re-running the convergence loop
    assert warm["plan_iterations"] == 0
    assert cold["plan_iterations"] > 0


# ------------------------------------------------------------- overload
@pytest.fixture(scope="module")
def overload(table):
    """The service plane's admission-control scenario."""
    return table["overload"]


def test_overload_admission_protects_the_device(overload):
    """THE service-plane acceptance criterion: with the AdmissionQueue
    gating starts, demand beyond capacity produces queue wait — never
    OOMs — while the same job mix started at submit time busts the
    device."""
    adm = overload["policies"]["admission"]
    none = overload["policies"]["no-admission"]
    assert adm["oom_events"] == 0
    assert adm["within_budget"]
    assert adm["peak"] <= overload["device_budget"]
    # the scenario is genuinely overloaded: the ungated run cannot fit
    assert none["oom_events"] > 0
    assert not none["within_budget"]


def test_overload_admission_precision(overload):
    """Warm-fingerprint predictions (experience-store priors measured
    under contention) stay within +-15 % of the measured per-job peaks;
    the cold class's cost-model bound is conservative (>= 1x)."""
    adm = overload["policies"]["admission"]
    assert adm["admission_max_abs_err"] <= 0.15
    assert adm["cold_bound_ratio"] >= 1.0
    srcs = {j["predicted_source"] for j in overload["jobs"].values()}
    assert "experience" in srcs and "cost-model" in srcs


def test_overload_reservations_never_exceed_capacity(overload):
    """The reservation-ledger invariant: at no instant does the admitted
    set's reserved total exceed the admission capacity, yet every job is
    eventually admitted and some genuinely wait."""
    adm = overload["policies"]["admission"]
    assert adm["admitted_over_capacity"] == 0
    assert adm["max_reserved_bytes"] <= overload["admission_capacity"]
    assert adm["admitted_jobs"] == len(overload["jobs"])
    waits = [j["queue_wait_iters"] for j in overload["jobs"].values()]
    assert any(w > 0.5 for w in waits)      # sustained overload queues
    assert any(w == 0.0 for w in waits)     # early arrivals run at once
    assert adm["queue_wait_mean_iters"] > 0
    assert 0.0 < adm["fairness"] <= 1.0


def test_preempt_scenarios_record_the_splice(preempt_table):
    """The hot-swap must actually land: the victim's plan_swaps records a
    safe-point splice (op >= 0) in preempt mode, and only the boundary
    pickup (op == -1) in boundary mode."""
    for name, rec in preempt_table.items():
        pre_swaps = rec["policies"]["preempt"]["plan_swaps"]["victim"]
        assert any(op >= 0 for _t, op in pre_swaps), name
        bnd_swaps = rec["policies"]["boundary"]["plan_swaps"]["victim"]
        assert all(op == -1 for _t, op in bnd_swaps), name
        # the splice lands after the burst instant
        t_burst = rec["t_burst"]
        assert all(t >= t_burst for t, _op in pre_swaps), name
