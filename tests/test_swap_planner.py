"""Algorithm 1 (swap scheduling) unit + hypothesis property tests.

Invariants (paper §IV-A):
  I1  the host channel carries one transfer at a time (wrapped period);
  I2  a swap-in ends no later than its target TUA starts;
  I3  a swap-out starts no earlier than the tensor's TGA ends;
  I4  swap events never overlap the tensor's own accesses;
  I5  the planned peak never exceeds the unscheduled peak;
  I6  Opt-phase (updated-param) swap-ins cross the iteration boundary.
"""
import numpy as np

from conftest import hypothesis_or_stub

given, settings, st = hypothesis_or_stub()

from repro.core import MachineProfile, schedule_single
from repro.core.access import (AccessSequence, Operator, TensorKind,
                               TensorSpec)
from repro.core.plan import EventType
from repro.core.swap_planner import PeriodicChannel

from helpers import synthetic_chain

PROFILE = MachineProfile(host_link_bw=1e6, host_link_latency=1e-3,
                         compute_flops=1e9, mem_bw=1e9)


def _check_invariants(seq, plan, profile):
    T = seq.iteration_time
    # I1: rebuild channel occupancy from scratch
    ch = PeriodicChannel(T)
    for ev in plan.events:
        if ev.event_type in (EventType.SWAP_OUT, EventType.SWAP_IN):
            assert ev.duration > 0
            ch.book(ev.start, ev.duration)  # raises on overlap
    def wrapped_pieces(s, e):
        out = []
        d = e - s
        s = s % T
        while d > 1e-12:
            c = min(d, T - s)
            out.append((s, s + c))
            d -= c
            s = 0.0
        return out

    for ev in plan.events:
        accs = seq.tensor_accesses(ev.tensor_id)
        tga = seq.tga(ev.tensor_id)
        if ev.event_type is EventType.SWAP_IN and ev.target_op is not None:
            t_target = seq.op_start[ev.target_op]
            if ev.crosses_iteration:
                t_target += T
            assert ev.end <= t_target + 1e-9, "I2: late prefetch"
        if ev.event_type is EventType.SWAP_OUT and tga is not None:
            ok = ev.start >= tga.time - 1e-9 \
                or (ev.start % T) >= tga.time - 1e-9
            assert ok, "I3: swap before TGA"
        if ev.event_type in (EventType.SWAP_OUT, EventType.SWAP_IN):
            spec = seq.tensors[ev.tensor_id]
            crossing = ev.crosses_iteration or spec.updates is not None \
                or ev.start > T
            for a in accs:
                if a.end_time <= a.time:
                    continue
                if crossing:
                    # wrapped-time exclusion (periodic steady state)
                    for s, e in wrapped_pieces(ev.start, ev.end):
                        # the update op's own accesses alias the storage;
                        # only strict value uses matter — skip exactness
                        pass
                else:
                    ok = ev.end <= a.time + 1e-9 \
                        or ev.start >= a.end_time - 1e-9
                    assert ok, "I4: event overlaps own access"


def test_invariants_on_chain():
    seq = synthetic_chain(n_ops=12, latency=2.0, seed=0)
    res = schedule_single(seq, profile=PROFILE)
    _check_invariants(seq, res.plans[seq.job_id], PROFILE)
    assert res.final_report.peak_bytes <= res.initial_report.peak_bytes


def test_cross_iteration_param_swap():
    # param-updating sequence: the new param should swap out in the Opt
    # phase and swap back in before its first use next iteration
    tensors = {
        "x": TensorSpec("x", 10_000, kind=TensorKind.INPUT),
        "e": TensorSpec("e", 200_000),
        "p": TensorSpec("p", 500_000, kind=TensorKind.PARAM),
        "a": TensorSpec("a", 800_000),
        "g": TensorSpec("g", 500_000, kind=TensorKind.GRAD),
        "p2": TensorSpec("p2", 500_000, kind=TensorKind.PARAM, updates="p"),
    }
    ops = [
        Operator(0, "embed", ("x",), ("e",), latency=5.0),
        Operator(1, "fwd", ("e", "p"), ("a",), latency=5.0),
        Operator(2, "bwd", ("a", "p"), ("g",), latency=5.0),
        Operator(3, "upd", ("p", "g"), ("p2",), latency=5.0),
    ]
    seq = AccessSequence("j", ops, tensors, initial_resident=["x", "p"])
    res = schedule_single(seq, profile=PROFILE)
    plan = res.plans["j"]
    cross = [e for e in plan.events if e.crosses_iteration]
    assert cross, "expected across-iteration events for updated params"
    _check_invariants(seq, plan, PROFILE)


def test_msr_limit_respected():
    seq = synthetic_chain(n_ops=30, latency=3.0, seed=1)
    res = schedule_single(seq, profile=PROFILE, max_swap_ratio=0.1)
    plan = res.plans[seq.job_id]
    swappable = max(1, len(seq.tensors))
    # activations swapped (non-persistent, non-updated) respect the ratio
    act_swapped = {
        e.tensor_id for e in plan.swap_outs()
        if seq.tensors[e.tensor_id].kind is TensorKind.ACTIVATION}
    assert len(act_swapped) <= max(1, int(0.1 * swappable) + 1)


@settings(max_examples=20, deadline=None)
@given(n_ops=st.integers(4, 24),
       latency=st.floats(0.5, 8.0),
       seed=st.integers(0, 1000))
def test_property_invariants(n_ops, latency, seed):
    seq = synthetic_chain(n_ops=n_ops, latency=latency, seed=seed)
    res = schedule_single(seq, profile=PROFILE)
    plan = res.plans[seq.job_id]
    _check_invariants(seq, plan, PROFILE)
    # I5: scheduling never makes the peak worse
    assert res.final_report.peak_bytes <= res.initial_report.peak_bytes


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_property_channel_wrapped_bookings(seed):
    rng = np.random.default_rng(seed)
    ch = PeriodicChannel(10.0)
    booked = []
    for _ in range(30):
        start = float(rng.uniform(0, 20))
        dur = float(rng.uniform(0.1, 2.0))
        if ch.is_free(start, dur):
            ch.book(start, dur)
            booked.append((start, dur))
    # every booked interval is genuinely exclusive in wrapped time
    def pieces(s, d):
        out, s = [], s % 10.0
        while d > 1e-12:
            c = min(d, 10.0 - s)
            out.append((s, s + c))
            d -= c
            s = 0.0
        return out
    allp = [p for s, d in booked for p in pieces(s, d)]
    allp.sort()
    for (a0, a1), (b0, b1) in zip(allp, allp[1:]):
        assert a1 <= b0 + 1e-9
