"""The observability plane: Chrome-trace export parity between the two
runtimes, Prometheus round-trip, the structured event log, the drift
monitor, and the no-recorder zero-overhead contract."""
import json

import pytest

from repro.core import (JaxprExecutor, MachineProfile, MemoryEngine,
                        TelemetryHub, schedule_single, simulate)
from repro.core.experience import ExperienceStore
from repro.obs import (DriftMonitor, EventLog, MetricsRegistry,
                       TraceRecorder, parse_metrics_text, summarize_trace,
                       validate_chrome_trace)
from repro.obs.trace import DMA_TID, EVENTS_TID

from helpers import capture_mlp, synthetic_chain

PROFILE = MachineProfile(host_link_bw=16e9, compute_flops=5e10, mem_bw=1e10)


@pytest.fixture(scope="module")
def mlp_with_plan():
    seq, closed, args = capture_mlp(sizes=(64, 128, 128, 8), batch=16)
    res = schedule_single(seq, profile=PROFILE)
    return seq, closed, args, res.plans[seq.job_id]


def _sim_trace(seq, plan, budget=None):
    hub = TelemetryHub(clock="virtual")
    eng = MemoryEngine(PROFILE, telemetry=hub)
    rec = TraceRecorder(clock="virtual", budget_bytes=budget)
    eng.attach_recorder(rec)
    simulate([seq], {seq.job_id: plan}, PROFILE, iterations=1,
             transfer_mode="sync", engine=eng, telemetry=hub)
    return rec.to_chrome()


def _real_trace(mlp_with_plan, budget=None):
    seq, closed, args, plan = mlp_with_plan
    hub = TelemetryHub(clock="real")
    eng = MemoryEngine(PROFILE, telemetry=hub)
    rec = TraceRecorder(clock="real", budget_bytes=budget)
    eng.attach_recorder(rec)
    ex = JaxprExecutor(closed, seq, plan, engine=eng)
    ex.run(*args)
    ex.close()
    return rec.to_chrome()


# ---------------------------------------------------------------- traces
def test_sim_trace_is_valid_chrome_trace(mlp_with_plan):
    seq, _, _, plan = mlp_with_plan
    trace = _sim_trace(seq, plan, budget=plan.planned_peak_bytes)
    assert validate_chrome_trace(trace) == []
    evs = [e for e in trace["traceEvents"] if e.get("ph") != "M"]
    # the three tracks: job ops, DMA transfers, residency counters
    assert any(e["ph"] == "X" and e.get("cat") == "op" for e in evs)
    assert any(e.get("tid") == DMA_TID and e.get("cat") == "transfer"
               for e in evs)
    counters = {e["name"] for e in evs if e["ph"] == "C"}
    assert {"resident:job0", "device_used_bytes",
            "device_budget_bytes"} <= counters
    # timestamps are normalized: earliest event sits at ts=0
    assert min(e["ts"] for e in evs) == 0.0
    assert trace["otherData"]["clock"] == "virtual"
    # thread-name metadata names every track in use
    names = {e["args"]["name"] for e in trace["traceEvents"]
             if e["ph"] == "M" and e["name"] == "thread_name"}
    assert {"job:job0", "dma"} <= names


def test_budget_violation_instants(mlp_with_plan):
    seq, _, _, plan = mlp_with_plan
    trace = _sim_trace(seq, plan, budget=1)  # everything is over budget
    assert validate_chrome_trace(trace) == []
    summary = summarize_trace(trace)
    assert summary["budget_violations"]
    # a roomy budget produces none (the PLANNED peak is a model, not a
    # bound — the simulated run may transiently exceed it)
    roomy = _sim_trace(seq, plan, budget=1 << 30)
    assert summarize_trace(roomy)["budget_violations"] == []


def test_sim_and_real_traces_share_schema(mlp_with_plan):
    """The headline trace guarantee: a virtual-time and a wall-clock run
    of the same job + plan produce the same event names, categories, and
    args schema — only the clock differs."""
    seq, _, _, plan = mlp_with_plan
    sim = _sim_trace(seq, plan)
    real = _real_trace(mlp_with_plan)
    assert validate_chrome_trace(sim) == []
    assert validate_chrome_trace(real) == []
    assert sim["otherData"]["clock"] == "virtual"
    assert real["otherData"]["clock"] == "real"

    def shape(trace):
        out = {}
        for e in trace["traceEvents"]:
            if e.get("ph") == "M":
                continue
            key = (e.get("cat"), e["ph"])
            out.setdefault(key, set()).add(
                (e["name"], tuple(sorted(e.get("args", {})))))
        return out

    s, r = shape(sim), shape(real)
    assert s.keys() == r.keys()
    # op spans: identical names AND identical args schema
    assert s[("op", "X")] == r[("op", "X")]
    # same residency counter tracks
    assert s[("residency", "C")] == r[("residency", "C")]
    # transfers move the same storages in the same directions
    assert s[("transfer", "X")] == r[("transfer", "X")]


def test_trace_json_serializable_and_summary(mlp_with_plan):
    seq, _, _, plan = mlp_with_plan
    trace = json.loads(json.dumps(_sim_trace(seq, plan)))
    assert validate_chrome_trace(trace) == []
    summary = summarize_trace(trace)
    assert summary["jobs"] == ["job0"]
    assert summary["transfer_count"] > 0
    assert 0.0 <= summary["stall_share"]["job0"] <= 1.0


def test_no_recorder_is_identity(mlp_with_plan):
    """The zero-overhead contract: without a recorder every tap is a
    single ``is not None`` check and the simulation result is
    unchanged."""
    seq, _, _, plan = mlp_with_plan
    assert TelemetryHub(clock="virtual")._recorder is None
    assert MemoryEngine(PROFILE).recorder is None

    def run(with_recorder):
        eng = MemoryEngine(PROFILE, telemetry=TelemetryHub(clock="virtual"))
        if with_recorder:
            eng.attach_recorder(TraceRecorder())
        return simulate([seq], {seq.job_id: plan}, PROFILE, iterations=1,
                        transfer_mode="sync", engine=eng)

    bare, taped = run(False), run(True)
    assert bare.peak_bytes == taped.peak_bytes
    assert bare.total_time == taped.total_time


def test_validate_rejects_malformed():
    assert validate_chrome_trace([]) != []
    assert validate_chrome_trace({"traceEvents": "nope"}) != []
    assert validate_chrome_trace({"traceEvents": []}) != []
    bad = {"traceEvents": [
        {"ph": "Z", "name": "x", "ts": 0, "pid": 1},           # unknown ph
        {"ph": "X", "name": "x", "ts": -1, "pid": 1, "tid": 1,
         "dur": 1},                                            # negative ts
        {"ph": "X", "name": "x", "ts": 0, "pid": 1, "tid": 1}, # missing dur
        {"ph": "C", "name": "c", "ts": 0, "pid": 1,
         "args": {"v": "high"}},                               # non-number
    ]}
    errs = validate_chrome_trace(bad)
    assert len(errs) == 4


# ---------------------------------------------------------------- metrics
def test_metrics_render_parse_round_trip():
    reg = MetricsRegistry()
    reg.counter("tensile_test_total", "a counter").inc(job="a")
    reg.counter("tensile_test_total").inc(job="a")
    reg.gauge("tensile_test_bytes", "a gauge").set(1.5e6, job="a")
    reg.histogram("tensile_test_seconds", "a histogram",
                  buckets=(0.1, 1.0)).observe(0.5)
    text = reg.render_text()
    parsed = parse_metrics_text(text)
    assert parsed[("tensile_test_total", (("job", "a"),))] == 2
    assert parsed[("tensile_test_bytes", (("job", "a"),))] == 1.5e6
    assert parsed[("tensile_test_seconds_bucket", (("le", "0.1"),))] == 0
    assert parsed[("tensile_test_seconds_bucket", (("le", "1"),))] == 1
    assert parsed[("tensile_test_seconds_bucket", (("le", "+Inf"),))] == 1
    assert parsed[("tensile_test_seconds_count", ())] == 1
    assert parsed[("tensile_test_seconds_sum", ())] == 0.5


def test_metrics_registry_idempotent_and_typed():
    reg = MetricsRegistry()
    c = reg.counter("tensile_x_total")
    assert reg.counter("tensile_x_total") is c
    with pytest.raises(TypeError):
        reg.gauge("tensile_x_total")


def test_parse_rejects_malformed_text():
    with pytest.raises(ValueError):
        parse_metrics_text("tensile_x{job=a} 1\n")   # unquoted label
    with pytest.raises(ValueError):
        parse_metrics_text("tensile_x not_a_number\n")
    with pytest.raises(ValueError):
        parse_metrics_text("# only comments\n")


# ---------------------------------------------------------------- events
def test_event_log_bounded_and_forwarded():
    rec = TraceRecorder()
    log = EventLog(maxlen=2, clock=lambda: 42.0)
    log.attach_recorder(rec)
    log.info("boot", "starting")
    log.warn("experience", "flush failed", job_id="j", error="IOError()")
    log.error("replan", "replan failed")
    assert len(log) == 2 and log.dropped == 1
    assert [e.source for e in log.warnings()] == ["experience", "replan"]
    assert log.events(level="ERROR")[0].source == "replan"
    # every emit landed on the trace as an instant on the events track
    names = [e["name"] for e in log.recorder.extras]
    assert names == ["INFO:boot", "WARN:experience", "ERROR:replan"]
    trace = rec.to_chrome()
    assert validate_chrome_trace(trace) == []
    assert any(e.get("tid") == EVENTS_TID for e in trace["traceEvents"]
               if e.get("ph") == "i")


# ----------------------------------------------------------------- drift
def test_drift_monitor_threshold_and_metrics():
    log, reg = EventLog(), MetricsRegistry()
    mon = DriftMonitor(threshold=0.15, events=log, metrics=reg,
                       clock=lambda: 0.0)
    ok = mon.observe("fp-quiet", predicted_peak=100, measured_peak=100,
                     predicted_safe_points=[1, 2], measured_safe_points=[1, 2])
    assert ok.worst == 0.0 and not log.warnings()
    bad = mon.observe("fp-loud", predicted_peak=200, measured_peak=100,
                      job_id="j")
    assert bad.peak_drift == 1.0
    warns = log.events(level="WARN", source="drift")
    assert len(warns) == 1 and warns[0].args["fingerprint"] == "fp-loud"
    assert reg.gauge("tensile_drift_peak_ratio").value(
        fingerprint="fp-loud") == 1.0
    assert [s.fingerprint for s in mon.over_threshold()] == ["fp-loud"]
    assert mon.worst_drift() == 1.0
    assert len(mon.history("fp-quiet")) == 1


def test_drift_safe_point_axis():
    mon = DriftMonitor(threshold=0.15)
    s = mon.observe("fp", predicted_peak=100, measured_peak=100,
                    predicted_safe_points=[1, 2, 3],
                    measured_safe_points=[4, 5, 6])
    assert s.sp_drift == 1.0 and s.worst == 1.0
    s2 = mon.observe("fp", predicted_peak=100, measured_peak=100,
                     predicted_safe_points=None, measured_safe_points=None)
    assert s2.sp_drift is None and s2.worst == 0.0


def test_drift_history_persists_across_store_reopen(tmp_path):
    exp = ExperienceStore(str(tmp_path), device_id="test-device")
    fp = ExperienceStore.fingerprint(exp, synthetic_chain(n_ops=4))
    mon = DriftMonitor(experience=exp, clock=lambda: 7.0)
    mon.observe(fp, predicted_peak=120, measured_peak=100, job_id="j",
                predicted_eor=0.1, measured_eor=0.2,
                predicted_safe_points=[3], measured_safe_points=[3])
    exp.flush()
    hist = ExperienceStore(str(tmp_path),
                           device_id="test-device").drift_history(fp)
    assert len(hist) == 1
    rec = hist[0]
    assert rec.predicted_peak == 120 and rec.measured_peak == 100
    assert rec.peak_drift == pytest.approx(0.2)
    assert rec.sp_drift == 0.0
    assert rec.t == 7.0


# ------------------------------------------------- controller visibility
def test_experience_flush_failure_is_visible_event():
    """The bugfix regression: a failing ExperienceStore flush on job exit
    must surface as a WARN event, not just a silent list append."""
    from repro.core.multiplexer import GlobalController, JobHandle

    class ExplodingStore:
        def fingerprint(self, seq):
            return "fp"

        def record_job(self, *a, **kw):
            raise IOError("disk full")

        def flush(self):
            raise AssertionError("flush unreachable: record_job raised")

    seq = synthetic_chain(n_ops=4, job_id="doomed")
    ctl = GlobalController(profile=PROFILE)
    ctl.experience = ExplodingStore()
    handle = JobHandle(job_id="doomed", seq=seq, closed_jaxpr=None,
                       args=(), iterations=1, fingerprint="fp")
    ctl._on_job_exit(handle)
    assert [j for j, _ in ctl.experience_failures] == ["doomed"]
    warns = ctl.events.events(level="WARN", source="experience")
    assert len(warns) == 1
    assert warns[0].args["job_id"] == "doomed"
    assert "disk full" in warns[0].args["error"]


def test_drift_scenario_row_holds_parity():
    """The bench row drift_contract gates: on the same engine, sim and
    executor book identical peaks and safe-point placements (drift
    exactly 0), and the history round-trips through the store."""
    from benchmarks.scenarios import run_drift_scenario

    d = run_drift_scenario(smoke=True)["drift"]
    assert d["peak_drift"] == 0.0
    assert d["sp_drift"] == 0.0
    assert d["history_len"] >= 1
    assert not d["over_threshold"] or d["eor_drift"] is not None


def test_daemon_writes_parseable_metrics_file(tmp_path):
    from repro.service.daemon import SchedulerDaemon

    d = SchedulerDaemon(str(tmp_path))
    d.step()
    prom = tmp_path / "metrics.prom"
    assert prom.exists()
    parsed = parse_metrics_text(prom.read_text())
    for name in ("tensile_queue_depth", "tensile_capacity_bytes",
                 "tensile_reserved_bytes"):
        assert (name, ()) in parsed
    assert parsed[("tensile_queue_depth", ())] == 0
