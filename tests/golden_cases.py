"""The golden plan-parity cases, defined ONCE.

`tests/test_pipeline.py` asserts these cases (and uses `fp_plan` to
fingerprint plans), `tools/check_golden_drift.py` regenerates and diffs
them in CI, and `test_golden_cases_cover_golden_file` cross-checks that
`regenerate()` reproduces `tests/golden/seed_plans.json` in full — so the
tool and the tests can never quietly enforce different cases.
"""
from __future__ import annotations

from repro.core import (MachineProfile, MemoryScheduler, SchedulerConfig,
                        schedule_single)
from repro.core.baselines import capuchin_plan, vdnn_conv_plan

from helpers import capture_mlp, synthetic_chain

PROFILE = MachineProfile(host_link_bw=1e6, host_link_latency=1e-3,
                         compute_flops=1e9, mem_bw=1e9)
MLP_PROFILE = MachineProfile(host_link_bw=16e9, compute_flops=5e10,
                             mem_bw=1e10)


def fp_plan(plan):
    """Canonical plan fingerprint every golden comparison uses."""
    evs = sorted(
        (e.event_type.value, e.tensor_id, e.trigger_op,
         round(e.delta, 9), round(e.start, 9), round(e.end, 9),
         e.size_bytes, e.target_op,
         list(e.recompute_ops or []), bool(e.crosses_iteration))
        for e in plan.events)
    return {"events": [[list(x) if isinstance(x, tuple) else x for x in ev]
                       for ev in evs],
            "release_after_op": dict(sorted(plan.release_after_op.items()))}


def regenerate() -> dict:
    """Re-derive every golden case through the current pass pipeline."""
    out: dict = {}

    seq = synthetic_chain(n_ops=12, latency=2.0, seed=0)
    res = schedule_single(seq, profile=PROFILE)
    out["tensile_chain"] = {
        "plan": fp_plan(res.plans[seq.job_id]),
        "initial_peak": res.initial_report.peak_bytes,
        "final_peak": res.final_report.peak_bytes,
        "iterations": res.iterations,
        "swaps": res.swaps_scheduled,
        "recomputes": res.recomputes_scheduled,
    }
    out["vdnn_chain"] = {"plan": fp_plan(vdnn_conv_plan(seq, PROFILE))}
    out["capuchin_chain"] = {
        "plan": fp_plan(capuchin_plan(seq, budget_bytes=50_000,
                                      profile=PROFILE).plan)}

    tight = MachineProfile(host_link_bw=1.0, host_link_latency=100.0,
                           compute_flops=1e9, mem_bw=1e9)
    seq9 = synthetic_chain(n_ops=10, latency=1.0, seed=9)
    sched = MemoryScheduler(tight, SchedulerConfig(memory_budget_bytes=1))
    sched.register_job(seq9)
    res9 = sched.schedule()
    out["tensile_recompute_chain"] = {
        "plan": fp_plan(res9.plans[seq9.job_id]),
        "final_peak": res9.final_report.peak_bytes,
        "swaps": res9.swaps_scheduled,
        "recomputes": res9.recomputes_scheduled,
    }

    a = synthetic_chain(n_ops=8, latency=2.0, job_id="a", seed=1)
    b = synthetic_chain(n_ops=8, latency=2.0, job_id="b", seed=2)
    ms = MemoryScheduler(PROFILE, SchedulerConfig(max_swap_ratio=0.5))
    ms.register_job(a)
    ms.register_job(b, offset=3.0)
    resm = ms.schedule()
    out["tensile_multi"] = {
        "plans": {j: fp_plan(resm.plans[j]) for j in ("a", "b")},
        "final_peak": resm.final_report.peak_bytes,
        "swaps": resm.swaps_scheduled,
        "recomputes": resm.recomputes_scheduled,
    }

    mseq, _, _ = capture_mlp(sizes=(64, 128, 128, 8), batch=16)
    mres = schedule_single(mseq, profile=MLP_PROFILE)
    out["tensile_mlp"] = {
        "plan": fp_plan(mres.plans[mseq.job_id]),
        "final_peak": mres.final_report.peak_bytes,
        "swaps": mres.swaps_scheduled,
        "recomputes": mres.recomputes_scheduled,
    }
    out["vdnn_mlp"] = {"plan": fp_plan(vdnn_conv_plan(mseq, MLP_PROFILE))}
    cap = capuchin_plan(mseq, budget_bytes=10_000, profile=MLP_PROFILE)
    out["capuchin_mlp"] = {"plan": fp_plan(cap.plan),
                           "passive_iterations": cap.passive_iterations}
    cap2 = capuchin_plan(mseq, budget_bytes=mres.final_report.peak_bytes,
                         profile=MLP_PROFILE)
    out["capuchin_mlp_tensile_budget"] = {"plan": fp_plan(cap2.plan)}
    return out
