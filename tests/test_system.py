"""End-to-end behaviour tests for the TENSILE system (paper pipeline:
capture → schedule → execute → update)."""
import numpy as np
import pytest

import jax

from repro.core import (GlobalController, JaxprExecutor, MachineProfile,
                        MemoryScheduler, SchedulerConfig, evaluate,
                        reference_outputs, schedule_single)

from repro.service import JobSpec

from helpers import capture_mlp, mlp_train_step

PROFILE = MachineProfile(host_link_bw=16e9, compute_flops=5e10, mem_bw=1e10)


@pytest.fixture(scope="module")
def mlp():
    return capture_mlp(sizes=(64, 256, 256, 256, 8), batch=32)


def test_capture_classifies_tensors(mlp):
    seq, closed, _ = mlp
    kinds = {t.kind.value for t in seq.tensors.values()}
    assert {"param", "opt_state", "activation", "input"} <= kinds
    aliased = [t for t in seq.tensors.values() if t.updates]
    # 3 layers × (w, b) × (param + 2 moments) aliases minimum
    assert len(aliased) >= 8


def test_schedule_reduces_peak(mlp):
    seq, _, _ = mlp
    res = schedule_single(seq, profile=PROFILE)
    assert res.swaps_scheduled > 0
    assert res.memory_saving_ratio > 0.2
    assert any(e.crosses_iteration for e in res.plans[seq.job_id].events)


def test_executor_matches_reference_under_plan(mlp):
    seq, closed, args = mlp
    res = schedule_single(seq, profile=PROFILE)
    ref = reference_outputs(closed, *args)
    ex = JaxprExecutor(closed, seq, res.plans[seq.job_id])
    out = ex.run(*args)
    for a, b in zip(ref, out):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-6)
    assert ex.stats.swap_out_count > 0
    ex.close()


def test_executor_peak_below_vanilla(mlp):
    seq, closed, args = mlp
    res = schedule_single(seq, profile=PROFILE)
    ex0 = JaxprExecutor(closed, seq, None)
    ex0.run(*args)
    ex1 = JaxprExecutor(closed, seq, res.plans[seq.job_id])
    ex1.run(*args)
    assert ex1.stats.peak_bytes < ex0.stats.peak_bytes
    ex0.close(), ex1.close()


def test_simulated_metrics(mlp):
    seq, _, _ = mlp
    res = schedule_single(seq, profile=PROFILE)
    m = evaluate([seq], res.plans, PROFILE)
    assert 0.0 < m["MSR"] <= 1.0
    assert m["EOR"] < 1.0  # swaps mostly overlap compute
    assert m["CBR"] > 1.0


def test_plan_update_on_drift(mlp):
    seq, _, _ = mlp
    sched = MemoryScheduler(PROFILE, SchedulerConfig(update_threshold=0.2))
    sched.register_job(seq)
    sched.schedule()
    small = [op.latency * 1.01 for op in seq.operators]
    assert not sched.update_latencies(seq.job_id, small)
    big = [op.latency * 5.0 for op in seq.operators]
    assert sched.update_latencies(seq.job_id, big)
    res2 = sched.schedule()
    assert res2.plans[seq.job_id].events  # replanning still yields a plan


def test_global_controller_multi_job():
    import jax

    from repro.optim.adam import adamw_init

    def make_job(j):
        from helpers import mlp_params
        p = mlp_params(jax.random.PRNGKey(j), [32, 64, 64, 4])
        o = adamw_init(p)
        b = (jax.random.normal(jax.random.PRNGKey(10 + j), (8, 32)),
             jax.random.normal(jax.random.PRNGKey(20 + j), (8, 4)))
        return p, o, b

    gc = GlobalController(profile=PROFILE, async_swap=True)
    for j in range(2):
        p, o, b = make_job(j)
        gc.submit(JobSpec(f"j{j}", iterations=2,
                          payload=(mlp_train_step, p, o, b)))
    gc.wait(timeout=180)
    assert all(h.done and h.error is None for h in gc.jobs.values())
    assert gc.global_peak_bytes > 0
    assert gc.replan_count >= 1
