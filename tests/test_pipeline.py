"""Pass-pipeline tests: the four policy configurations reproduce the plans
the seed's dedicated code paths produced (goldens captured from the
pre-refactor tree), the registry resolves every policy by name, and the
CompressedOffloadPass schedules quantized transfers where plain swapping
cannot fit."""
import json
import os

import pytest

from repro.core import (MachineProfile, MemoryScheduler, SchedulerConfig,
                        build_pipeline, evaluate, schedule_single)
from repro.core.access import AccessSequence, Operator, TensorKind, TensorSpec
from repro.core.baselines import capuchin_plan, vdnn_conv_plan
from repro.core.passes import PIPELINES, PlanningPass, SwapPass
from repro.core.peak_analysis import analyze

from golden_cases import fp_plan as _canonical_fp_plan
from helpers import capture_mlp, synthetic_chain

GOLDEN = os.path.join(os.path.dirname(__file__), "golden", "seed_plans.json")

PROFILE = MachineProfile(host_link_bw=1e6, host_link_latency=1e-3,
                         compute_flops=1e9, mem_bw=1e9)
MLP_PROFILE = MachineProfile(host_link_bw=16e9, compute_flops=5e10,
                             mem_bw=1e10)


@pytest.fixture(scope="module")
def gold():
    with open(GOLDEN) as f:
        return json.load(f)


# the one canonical fingerprint (tests + tools/check_golden_drift.py)
fp_plan = _canonical_fp_plan


def assert_matches(got, want):
    assert json.loads(json.dumps(got)) == want


def test_golden_cases_cover_golden_file(gold):
    """tools/check_golden_drift.py regenerates the SAME cases these tests
    assert: golden_cases.regenerate() must reproduce the pinned file in
    full, so tool and tests can never enforce different definitions."""
    from golden_cases import regenerate
    assert_matches(regenerate(), gold)


# ---------------------------------------------------------------- goldens
def test_tensile_pipeline_reproduces_seed_plan(gold):
    seq = synthetic_chain(n_ops=12, latency=2.0, seed=0)
    res = schedule_single(seq, profile=PROFILE)
    g = gold["tensile_chain"]
    assert_matches(fp_plan(res.plans[seq.job_id]), g["plan"])
    assert res.initial_report.peak_bytes == g["initial_peak"]
    assert res.final_report.peak_bytes == g["final_peak"]
    assert res.iterations == g["iterations"]
    assert (res.swaps_scheduled, res.recomputes_scheduled) == \
        (g["swaps"], g["recomputes"])


def test_tensile_recompute_path_reproduces_seed_plan(gold):
    tight = MachineProfile(host_link_bw=1.0, host_link_latency=100.0,
                           compute_flops=1e9, mem_bw=1e9)
    seq = synthetic_chain(n_ops=10, latency=1.0, seed=9)
    sched = MemoryScheduler(tight, SchedulerConfig(memory_budget_bytes=1))
    sched.register_job(seq)
    res = sched.schedule()
    g = gold["tensile_recompute_chain"]
    assert_matches(fp_plan(res.plans[seq.job_id]), g["plan"])
    assert res.final_report.peak_bytes == g["final_peak"]
    assert (res.swaps_scheduled, res.recomputes_scheduled) == \
        (g["swaps"], g["recomputes"])


def test_tensile_multi_job_reproduces_seed_plans(gold):
    a = synthetic_chain(n_ops=8, latency=2.0, job_id="a", seed=1)
    b = synthetic_chain(n_ops=8, latency=2.0, job_id="b", seed=2)
    ms = MemoryScheduler(PROFILE, SchedulerConfig(max_swap_ratio=0.5))
    ms.register_job(a)
    ms.register_job(b, offset=3.0)
    res = ms.schedule()
    g = gold["tensile_multi"]
    for j in ("a", "b"):
        assert_matches(fp_plan(res.plans[j]), g["plans"][j])
    assert res.final_report.peak_bytes == g["final_peak"]


def test_vdnn_capuchin_chain_reproduce_seed_plans(gold):
    seq = synthetic_chain(n_ops=12, latency=2.0, seed=0)
    assert_matches(fp_plan(vdnn_conv_plan(seq, PROFILE)),
                   gold["vdnn_chain"]["plan"])
    cc = capuchin_plan(seq, budget_bytes=50_000, profile=PROFILE)
    assert_matches(fp_plan(cc.plan), gold["capuchin_chain"]["plan"])


def test_all_policies_reproduce_seed_plans_on_captured_mlp(gold):
    seq, _, _ = capture_mlp(sizes=(64, 128, 128, 8), batch=16)
    res = schedule_single(seq, profile=MLP_PROFILE)
    assert_matches(fp_plan(res.plans[seq.job_id]), gold["tensile_mlp"]["plan"])
    assert res.final_report.peak_bytes == gold["tensile_mlp"]["final_peak"]
    assert_matches(fp_plan(vdnn_conv_plan(seq, MLP_PROFILE)),
                   gold["vdnn_mlp"]["plan"])
    cap = capuchin_plan(seq, budget_bytes=10_000, profile=MLP_PROFILE)
    assert_matches(fp_plan(cap.plan), gold["capuchin_mlp"]["plan"])
    assert cap.passive_iterations == gold["capuchin_mlp"]["passive_iterations"]
    cap2 = capuchin_plan(seq, budget_bytes=res.final_report.peak_bytes,
                         profile=MLP_PROFILE)
    assert_matches(fp_plan(cap2.plan),
                   gold["capuchin_mlp_tensile_budget"]["plan"])


# ---------------------------------------------------------------- registry
def test_registry_has_all_policies():
    assert {"vanilla", "vdnn", "capuchin", "tensile",
            "tensile+compressed-offload"} <= set(PIPELINES)
    with pytest.raises(KeyError):
        build_pipeline("no-such-policy")


def test_vanilla_pipeline_is_empty():
    seq = synthetic_chain(n_ops=6, seed=3)
    res = build_pipeline("vanilla", profile=PROFILE).plan([seq])
    plan = res.plans[seq.job_id]
    assert not plan.events and not plan.release_after_op
    assert res.swaps_scheduled == res.recomputes_scheduled == 0


def test_planning_pass_protocol_single_job():
    """A pass is usable standalone through the protocol signature
    run(seq, plan, report, profile) -> plan."""
    from repro.core.plan import SchedulingPlan
    seq = synthetic_chain(n_ops=12, latency=2.0, seed=0)
    plan = SchedulingPlan(job_id=seq.job_id)
    sp = SwapPass()
    out = sp.run(seq, plan, analyze([seq]), PROFILE)
    assert out is plan
    assert out.swap_outs(), "protocol run should schedule swaps"
    assert analyze([seq], {seq.job_id: out}).peak_bytes \
        <= analyze([seq]).peak_bytes


def test_custom_pass_composes():
    """New policies are pass configurations: a pipeline made of an ad-hoc
    pass runs under the same convergence loop."""
    from repro.core.passes import Pipeline

    class ReleaseEverythingPass(PlanningPass):
        name = "release-all"

        def setup(self, state):
            super().setup(state)
            self._done = False

        def step(self, report):
            if self._done:
                return False
            self._done = True
            for j, seq in self.state.jobs.items():
                self.state.plans[j].release_after_op.update(
                    seq.activity_analysis())
            return True

    seq = synthetic_chain(n_ops=8, seed=4)
    res = Pipeline([ReleaseEverythingPass()], name="custom",
                   profile=PROFILE).plan([seq])
    assert res.plans[seq.job_id].release_after_op
    assert res.pass_steps == {"release-all": 1}


# ------------------------------------------------------- compressed offload
def _tight_window_job():
    """A, 400 kB, is peak-causing but its swap-out window (0.2 s free before
    the peak instant) only fits the compressed transfer (~0.1 s), not the
    full-precision one (~0.4 s)."""
    tensors = {
        "A": TensorSpec("A", 400_000, kind=TensorKind.ACTIVATION, job_id="j"),
        "B": TensorSpec("B", 600_000, kind=TensorKind.ACTIVATION, job_id="j"),
        "c": TensorSpec("c", 1_000, kind=TensorKind.ACTIVATION, job_id="j"),
        "d": TensorSpec("d", 1_000, kind=TensorKind.ACTIVATION, job_id="j"),
    }
    ops = [
        Operator(0, "mk_a", (), ("A",), latency=0.1, job_id="j"),
        Operator(1, "use_a", ("A",), ("c",), latency=0.1, job_id="j"),
        Operator(2, "filler", ("c",), ("d",), latency=0.3, job_id="j"),
        Operator(3, "mk_b", ("d",), ("B",), latency=0.1, job_id="j"),
        Operator(4, "use_b", ("B",), (), latency=0.7, job_id="j"),
        Operator(5, "use_a2", ("A",), (), latency=0.1, job_id="j"),
    ]
    return AccessSequence("j", ops, tensors, initial_resident=[])


COMP_PROFILE = MachineProfile(host_link_bw=1e6, host_link_latency=1e-3,
                              compute_flops=1e9, mem_bw=1e9,
                              offload_quant_bw=1e8)


def test_compressed_offload_fits_where_plain_swap_cannot():
    seq = _tight_window_job()
    plain = build_pipeline("tensile", profile=COMP_PROFILE).plan([seq])
    comp = build_pipeline("tensile+compressed-offload",
                          profile=COMP_PROFILE).plan([seq])
    assert plain.swaps_scheduled == 0
    assert comp.pass_steps["compressed-offload"] == 1
    events = [e for e in comp.plans["j"].events if e.compressed]
    assert {e.event_type.value for e in events} == {"swap_out", "swap_in"}
    assert all(e.tensor_id == "A" for e in events)
    assert comp.final_report.peak_bytes < plain.final_report.peak_bytes
    # the booked channel time is the compressed transfer time
    for e in events:
        assert abs(e.duration
                   - COMP_PROFILE.compressed_swap_time(400_000)) < 1e-9


def test_compressed_offload_never_worsens_peak():
    for seed in (0, 1, 2):
        seq = synthetic_chain(n_ops=20, latency=0.2, seed=seed)
        prof = MachineProfile(host_link_bw=1e5, host_link_latency=1e-3,
                              compute_flops=1e9, mem_bw=1e9,
                              offload_quant_bw=1e9)
        plain = build_pipeline("tensile", profile=prof).plan([seq])
        comp = build_pipeline("tensile+compressed-offload",
                              profile=prof).plan([seq])
        assert comp.final_report.peak_bytes <= plain.final_report.peak_bytes


def test_compressed_swap_time_entry():
    """cost_model's offload-quant latency entry and the profile's
    compressed transfer time are consistent and strictly cheaper on the
    wire than the plain path for large-enough tensors."""
    from repro.core import CostModel
    cm = CostModel()
    n = 8 << 20
    lat = cm.offload_quant_latency(n)
    assert lat > 0
    assert cm.offload_quant_bandwidth(n) > 0
    prof = MachineProfile(host_link_bw=1e9,
                          offload_quant_bw=cm.offload_quant_bandwidth(n))
    assert prof.compressed_swap_time(n) < prof.swap_time(n)
    assert prof.transfer_time(n, compressed=True) == \
        prof.compressed_swap_time(n)


def test_compressed_plan_simulates_and_reduces_peak():
    seq = _tight_window_job()
    res = build_pipeline("tensile+compressed-offload",
                         profile=COMP_PROFILE).plan([seq])
    m = evaluate([seq], res.plans, COMP_PROFILE)
    assert m["MSR"] > 0
    assert m["peak"] < m["vanilla_peak"]
