"""Serving-plane acceptance: KV blocks as schedulable ledger tensors.

Pins the contracts the serving plane is built on:

* BlockTable/ledger invariants — bytes conserved across any
  evict/prefetch interleaving, eviction idempotent per block, release on
  sequence finish leaks nothing;
* KvResidencyPass — the cohort fits the budget and the eviction victim
  is the *coldest* sequence (largest decode-turn distance, the serving
  analogue of TENSILE's largest-reuse-distance rule);
* prefill-burst admission — requests admitted in priority order through
  PR 7's AdmissionQueue, never-fitting requests rejected, waiters
  admitted when a finish releases their reservation;
* decode bit-identity — serving the same trace with and without KV
  swapping on the real (reduced) model produces identical token ids;
* sim/real parity — the bare virtual ServeSession and the
  ServingEngine-driven run replay identical residency decision traces;
* the JobSpec serve wire format (schema 2, tolerant of schema-1 records
  and unknown serve keys) and a daemon end-to-end serve job.
"""
import math

import pytest

from repro.core import MachineProfile, MemoryEngine
from repro.serving import (BlockTable, KvResidencyPass, SeqView,
                           ServeSession, make_trace)
from repro.serving.traces import Request

PROFILE = MachineProfile(host_link_bw=16e9, compute_flops=5e10, mem_bw=1e10)

BPT = 512          # bytes per cache token (the reduced-tinyllama figure)
PROMPT, GEN = 4, 8
MAX_LEN = PROMPT + GEN


def _table(capacity=None, budget=None, bpt=BPT, block_tokens=4):
    eng = MemoryEngine(PROFILE, capacity_bytes=capacity, trace=True)
    view = eng.ledger.view("serve", budget)
    return eng, BlockTable(view, bpt, block_tokens, trace=eng.trace)


# ----------------------------------------------------------------------
# BlockTable / ledger invariants
# ----------------------------------------------------------------------
def test_block_table_bytes_conserved_across_evict_prefetch():
    eng, tab = _table()
    tab.grow("r0", 10)          # 3 blocks of 4 tokens
    total = tab.total_bytes("r0")
    assert total == 3 * tab.block_bytes
    assert tab.device_bytes("r0") == total and tab.host_bytes("r0") == 0
    assert eng.ledger.used == total

    freed = tab.evict("r0")
    assert freed == total
    assert tab.device_bytes("r0") == 0 and tab.host_bytes("r0") == total
    assert tab.device_bytes("r0") + tab.host_bytes("r0") == total
    assert eng.ledger.used == 0
    # idempotent: a second evict moves nothing
    assert tab.evict("r0") == 0
    assert tab.host_bytes("r0") == total

    restored = tab.prefetch("r0")
    assert restored == total
    assert tab.device_bytes("r0") == total and tab.host_bytes("r0") == 0
    assert eng.ledger.used == total
    assert tab.swapped_out_bytes == total and tab.swapped_in_bytes == total


def test_block_table_growth_is_block_granular():
    eng, tab = _table()
    new = tab.grow("r0", 4)
    assert len(new) == 1
    assert len(tab.grow("r0", 6)) == 1   # 6 tokens open block 2
    assert tab.n_blocks("r0") == 2
    assert tab.grow("r0", 8) == []       # 8 tokens still fit 2 blocks
    assert len(tab.grow("r0", 9)) == 1   # 9 tokens open block 3
    assert tab.footprint(9) == 3 * tab.block_bytes


def test_block_table_release_leaks_nothing():
    eng, tab = _table()
    tab.grow("a", 8)
    tab.grow("b", 8)
    tab.evict("a")                       # half the bytes parked on host
    freed = tab.release("a") + tab.release("b")
    assert freed == tab.block_bytes * 2  # only b's device blocks remained
    assert tab.sequences() == []
    assert tab.host_blocks("a") == [] and tab.host_blocks("b") == []
    assert eng.ledger.used == 0
    assert eng.ledger.resident_storages("serve") == []
    # the decision trace saw the release of every block
    actions = [r.action for r in eng.trace.records]
    assert actions.count("release") == 4


# ----------------------------------------------------------------------
# KvResidencyPass: budget-capped cohort, coldest-victim eviction
# ----------------------------------------------------------------------
def test_residency_pass_evicts_coldest_first():
    eng, tab = _table(bpt=1, block_tokens=4)   # 4-byte blocks
    views = [SeqView(rid="a", slot=0, pos=8, remaining=8, last_served=0.0),
             SeqView(rid="b", slot=1, pos=8, remaining=8, last_served=0.0),
             SeqView(rid="c", slot=2, pos=4, remaining=8, last_served=2.0)]
    for v in views:
        tab.grow(v.rid, v.pos)
    rp = KvResidencyPass(tab, budget_bytes=16)
    plan = rp.plan_turn(views)
    # group {a, b} at pos 8 decodes first; only `a` fits the budget
    assert [s.rid for s in plan.cohort] == ["a"]
    assert plan.chunk == 4
    # c's next turn is farther in the rotation than b's: c evicts first
    assert plan.evict[0] == "c"
    assert set(plan.evict) <= {"b", "c"}


def test_residency_pass_unbudgeted_never_evicts():
    eng, tab = _table(bpt=1, block_tokens=4)
    views = [SeqView(rid="a", slot=0, pos=8, remaining=4),
             SeqView(rid="b", slot=1, pos=8, remaining=4)]
    for v in views:
        tab.grow(v.rid, v.pos)
    plan = KvResidencyPass(tab, budget_bytes=None).plan_turn(views)
    assert [s.rid for s in plan.cohort] == ["a", "b"]
    assert plan.evict == [] and plan.prefetch == []


# ----------------------------------------------------------------------
# Virtual session: pressure behavior + prefill-burst admission
# ----------------------------------------------------------------------
def _session(requests, budget, schedule=True, **kw):
    eng = MemoryEngine(PROFILE, capacity_bytes=budget, trace=True)
    return eng, ServeSession(requests, engine=eng, max_sequences=4,
                             bytes_per_token=BPT, block_tokens=4,
                             budget_bytes=budget, schedule=schedule, **kw)


def test_virtual_session_scheduled_fits_budget_unscheduled_ooms():
    requests = make_trace("poisson", 6, seed=0, prompt_len=PROMPT,
                          gen_len=GEN)
    budget = BPT * (MAX_LEN * 2 + 2)     # ~2 of 4 slots resident
    _, sess = _session(requests, budget)
    rep = sess.run()
    assert rep.served == 6 and rep.oom_events == 0
    assert rep.peak_bytes <= budget
    assert rep.evictions > 0 and rep.prefetches > 0
    assert rep.tokens_generated == 6 * GEN
    assert math.isfinite(rep.ttft_p99)

    _, bare = _session(requests, budget, schedule=False)
    rep0 = bare.run()
    assert rep0.oom_events > 0           # the pressure is real
    assert rep0.peak_bytes > budget


def test_prefill_burst_admission_priority_order_and_rejection():
    reqs = [Request("r0", 0.0, PROMPT, GEN, priority=1.0),
            Request("r1", 0.0, PROMPT, GEN, priority=1.0),
            Request("r2", 0.0, PROMPT, GEN, priority=3.0),
            Request("r3", 0.0, PROMPT, GEN, priority=2.0),
            # can NEVER fit the oversubscribed serving capacity
            Request("r4", 0.0, PROMPT, 60, priority=5.0)]
    budget = 8192                        # admission cap = 2.5x = 20480
    _, sess = _session(reqs, budget)
    rep = sess.run()
    assert rep.rejected == ["r4"]
    assert rep.served == 4
    # burst admission is priority-ordered: r2 (3.0), r3 (2.0), then the
    # 1.0s; the fourth reservation only fits once a finish releases one
    assert rep.admission_order[:2] == ["r2", "r3"]
    assert set(rep.admission_order) == {"r0", "r1", "r2", "r3"}
    late = rep.admission_order[-1]
    assert rep.queue_wait[late] > 0.0
    assert rep.oom_events == 0


# ----------------------------------------------------------------------
# Real engine: bit-identity under swapping + sim/real parity
# ----------------------------------------------------------------------
@pytest.fixture(scope="module")
def engine():
    from repro.serving import ServingEngine
    return ServingEngine("tinyllama-1.1b", max_sequences=4,
                         max_len=MAX_LEN, seed=0)


@pytest.fixture(scope="module")
def trace6():
    return make_trace("poisson", 6, seed=0, prompt_len=PROMPT, gen_len=GEN)


def test_decode_bit_identical_with_and_without_swapping(engine, trace6):
    assert engine.bytes_per_token == BPT
    ref_rep, golden = engine.serve(trace6, budget_bytes=None, schedule=False)
    assert ref_rep.served == 6
    assert all(len(t) == GEN for t in golden.values())

    budget = BPT * (MAX_LEN * 2 + 2)
    mem = MemoryEngine(PROFILE, capacity_bytes=budget, trace=True)
    rep, out = engine.serve(trace6, budget_bytes=budget, schedule=True,
                            engine=mem)
    assert rep.oom_events == 0
    assert rep.peak_bytes <= budget
    assert rep.evictions > 0             # blocks really moved to host
    assert out == golden                 # ...and decode never noticed


def test_decode_bit_identical_on_the_batched_data_path(engine, trace6):
    """PR 9: restoring/saving KV cohorts through the batched kernels
    (one gather/scatter launch per cohort) must be invisible to decode —
    identical token ids, identical residency decisions, same pressure."""
    _, golden = engine.serve(trace6, budget_bytes=None, schedule=False)

    budget = BPT * (MAX_LEN * 2 + 2)
    mem_l = MemoryEngine(PROFILE, capacity_bytes=budget, trace=True)
    rep_l, out_l = engine.serve(trace6, budget_bytes=budget, schedule=True,
                                engine=mem_l)
    mem_b = MemoryEngine(PROFILE, capacity_bytes=budget, trace=True)
    rep_b, out_b = engine.serve(trace6, budget_bytes=budget, schedule=True,
                                engine=mem_b, batch_transfers=True)

    assert out_b == golden               # bit-identical to the unswapped run
    assert rep_b.oom_events == 0
    assert rep_b.evictions == rep_l.evictions > 0
    # same residency decisions as the legacy per-slot path: batching
    # changes the wire shape, never what moves
    assert mem_b.trace.keys() == mem_l.trace.keys()
    assert rep_b.swapped_out_bytes == rep_l.swapped_out_bytes
    assert rep_b.swapped_in_bytes == rep_l.swapped_in_bytes
    # cohorts really rode coalesced bookings, saving fixup latencies
    assert rep_b.batched_transfers > 0
    assert rep_b.saved_fixup_s > 0
    assert rep_l.batched_transfers == 0


def test_sim_real_parity_on_the_batched_data_path(engine, trace6):
    """The virtual ServeSession with batch_transfers replays the same
    decision trace as the real engine's batched path."""
    budget = BPT * (MAX_LEN * 2 + 2)
    mem_v = MemoryEngine(PROFILE, capacity_bytes=budget, trace=True)
    sim = ServeSession(trace6, engine=mem_v, max_sequences=4,
                       bytes_per_token=BPT, block_tokens=4,
                       budget_bytes=budget, schedule=True,
                       batch_transfers=True).run()
    mem_r = MemoryEngine(PROFILE, capacity_bytes=budget, trace=True)
    real, _ = engine.serve(trace6, budget_bytes=budget, schedule=True,
                           engine=mem_r, batch_transfers=True)
    assert mem_v.trace.keys() == mem_r.trace.keys()
    assert sim.peak_bytes == real.peak_bytes
    assert sim.evictions == real.evictions
    assert sim.tokens_generated == real.tokens_generated
    assert sim.batched_transfers == real.batched_transfers > 0
    assert sim.total_time == pytest.approx(real.total_time)


def test_sim_real_parity_on_a_served_mix(engine, trace6):
    budget = BPT * (MAX_LEN * 2 + 2)
    mem_v = MemoryEngine(PROFILE, capacity_bytes=budget, trace=True)
    sim = ServeSession(trace6, engine=mem_v, max_sequences=4,
                       bytes_per_token=BPT, block_tokens=4,
                       budget_bytes=budget, schedule=True).run()
    mem_r = MemoryEngine(PROFILE, capacity_bytes=budget, trace=True)
    real, _ = engine.serve(trace6, budget_bytes=budget, schedule=True,
                           engine=mem_r)
    # identical residency decision traces — the serving analogue of
    # tests/test_engine_parity.py
    assert mem_v.trace.keys() == mem_r.trace.keys()
    assert sim.peak_bytes == real.peak_bytes
    assert sim.oom_events == real.oom_events == 0
    assert sim.evictions == real.evictions
    assert sim.tokens_generated == real.tokens_generated
    assert sim.total_time == pytest.approx(real.total_time)


# ----------------------------------------------------------------------
# JobSpec serve wire format + daemon end-to-end
# ----------------------------------------------------------------------
def test_jobspec_serve_wire_roundtrip():
    from repro.service import JobSpec, ServeParams
    sp = ServeParams(arch="tinyllama-1.1b", max_sequences=2, n_requests=3,
                     prompt_len=2, gen_len=3, trace="burst")
    spec = JobSpec("s1", kind="serve", serve=sp, priority=2.0)
    d = spec.to_dict()
    assert d["kind"] == "serve" and d["serve"]["n_requests"] == 3
    back = JobSpec.from_dict(d)
    assert back.kind == "serve" and back.serve == sp
    # a serve spec with no params gets the defaults
    assert JobSpec("s2", kind="serve").serve is not None
    # train specs must not carry serve params
    with pytest.raises(ValueError):
        JobSpec("bad", kind="train", serve=sp)


def test_jobspec_schema_tolerance():
    from repro.service import JobSpec, ServeParams
    # schema-1 records (pre-serving) still parse, as train jobs
    legacy = {"schema": 1, "job_id": "old", "workload": "mlp"}
    spec = JobSpec.from_dict(legacy)
    assert spec.kind == "train" and spec.serve is None
    # unknown serve keys from a NEWER writer are tolerated
    sp = ServeParams.from_dict({"arch": "tinyllama-1.1b",
                                "a_future_field": 1})
    assert sp.arch == "tinyllama-1.1b"


def test_daemon_runs_a_serve_job_end_to_end(tmp_path):
    from repro.service import (JobState, SchedulerDaemon, ServeParams,
                               ServiceClient, JobSpec)
    root = str(tmp_path / "svc")
    daemon = SchedulerDaemon(root, poll_interval=0.01)
    client = ServiceClient(root)
    spec = JobSpec("lm-serve", kind="serve",
                   serve=ServeParams(max_sequences=2, n_requests=3,
                                     prompt_len=2, gen_len=3,
                                     trace="burst"))
    client.submit(spec)
    daemon.step()                        # pull the inbox before drain()
    assert daemon.drain(timeout=300)
    rec = daemon.store.get("lm-serve")
    assert rec.state is JobState.DONE, rec.error
    assert rec.measured_peak_bytes and rec.measured_peak_bytes > 0
    assert rec.predicted_peak_bytes and rec.predicted_peak_bytes > 0
