import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))


def hypothesis_or_stub():
    """Real hypothesis when installed (the `dev` extra provides it);
    otherwise stand-ins that skip ONLY the property tests, so the rest of
    the module still collects and runs — test modules do

        from conftest import hypothesis_or_stub
        given, settings, st = hypothesis_or_stub()

    instead of a bare `pytest.importorskip("hypothesis")`, which would
    silence every non-property test in the file too."""
    try:
        from hypothesis import given, settings, strategies as st
        return given, settings, st
    except ImportError:
        import pytest

        class _Strategies:
            def __getattr__(self, name):
                return lambda *a, **k: None

        def given(*a, **k):
            def deco(fn):
                # deliberately NOT functools.wraps: pytest must see a
                # zero-argument signature, not the property parameters
                # (it would try to resolve them as fixtures)
                def skipped():
                    pytest.skip("hypothesis not installed "
                                "(pip install -e .[dev])")
                skipped.__name__ = fn.__name__
                skipped.__doc__ = fn.__doc__
                return skipped
            return deco

        def settings(*a, **k):
            return lambda fn: fn

        return given, settings, _Strategies()
