"""Vectorized-sweep equivalence and planner hot-path regression tests.

The planner's event-sweep core (``peak_analysis.analyze``, the
``WindowSweep`` incremental variant, and ``engine.find_safe_points``) is
a vectorized numpy rewrite of the original per-event Algorithm-2 scan.
The originals are kept verbatim as ``_reference_sweep`` /
``_reference_safe_points``; this module pins byte-identical equivalence
across the golden-shaped cases and random timelines — which is what
keeps the golden seed plans stable — plus the memoization semantics the
incremental-replan latency contract rests on (plan content identity,
copy-on-write forking, busy-interval caching per plan version).
"""
from __future__ import annotations

import numpy as np

from repro.core import (MachineProfile, SchedulerConfig, analyze,
                        build_pipeline, find_safe_points, schedule_single,
                        vanilla_peak)
from repro.core.engine import _reference_safe_points
from repro.core.peak_analysis import WindowSweep, _reference_sweep
from repro.core.plan import EventType, ScheduleEvent, SchedulingPlan
from repro.core import plan as plan_mod

from conftest import hypothesis_or_stub
from helpers import synthetic_chain

given, settings, st = hypothesis_or_stub()

PROFILE = MachineProfile(host_link_bw=1e6, host_link_latency=1e-3,
                         compute_flops=1e9, mem_bw=1e9)


def planned_chain(n_ops=12, seed=0, latency=2.0, budget_frac=None,
                  job_id="chain"):
    """A chain plus a real pass-pipeline plan for it (the golden
    ``tensile_chain`` shape when called with the defaults)."""
    seq = synthetic_chain(n_ops=n_ops, latency=latency, seed=seed,
                          job_id=job_id)
    if budget_frac is None:
        res = schedule_single(seq, profile=PROFILE)
    else:
        budget = int(budget_frac * vanilla_peak(seq))
        res = build_pipeline(
            "tensile", profile=PROFILE,
            config=SchedulerConfig(memory_budget_bytes=budget,
                                   max_iterations=16)).plan([seq])
    return seq, res.plans[seq.job_id]


def assert_same_report(got, ref):
    """Every PeakReport field, byte-identical (lazy fields forced)."""
    assert got.peak_bytes == ref.peak_bytes
    assert got.peak_time == ref.peak_time
    assert got.peak_tensors == ref.peak_tensors
    assert got.timeline == ref.timeline
    assert got.last_input_access == ref.last_input_access
    assert got.per_job_peak == ref.per_job_peak


def assert_matches_reference(seqs, plans=None, offsets=None, window=None,
                             free_at_last_use=True):
    got = analyze(seqs, plans=plans, offsets=offsets, window=window,
                  free_at_last_use=free_at_last_use)
    ref = _reference_sweep(seqs, plans=plans, offsets=offsets,
                           window=window,
                           free_at_last_use=free_at_last_use)
    assert_same_report(got, ref)


def sp_tuples(points):
    return [(p.op_idx, p.time, p.resident_bytes) for p in points]


# ---------------------------------------------------------------------------
# analyze == _reference_sweep
# ---------------------------------------------------------------------------

def test_analyze_matches_reference_golden_chain():
    seq, plan = planned_chain()
    assert_matches_reference([seq])
    assert_matches_reference([seq], plans={seq.job_id: plan})
    assert_matches_reference([seq], plans={seq.job_id: plan},
                             free_at_last_use=False)


def test_analyze_matches_reference_windowed_and_offset():
    seq, plan = planned_chain(n_ops=10, seed=9, latency=1.0)
    T = seq.iteration_time
    for window in [(0.0, T), (0.25 * T, 0.75 * T), (0.9 * T, 0.95 * T)]:
        assert_matches_reference([seq], plans={seq.job_id: plan},
                                 window=window)
    other = synthetic_chain(n_ops=7, seed=4, job_id="j2")
    assert_matches_reference([seq, other], plans={seq.job_id: plan},
                             offsets={"j2": 0.37 * T})
    assert_matches_reference([seq, other], plans={seq.job_id: plan},
                             offsets={"j2": 0.37 * T},
                             window=(0.2 * T, 1.4 * T))


def test_analyze_matches_reference_random_timelines():
    for seed in range(1, 7):
        rng = np.random.default_rng(seed)
        n_ops = int(rng.integers(2, 30))
        seq, plan = planned_chain(
            n_ops=n_ops, seed=seed, latency=float(rng.uniform(0.5, 3.0)),
            budget_frac=float(rng.uniform(0.5, 0.9)))
        plans = {seq.job_id: plan}
        assert_matches_reference([seq], plans=plans)
        T = seq.iteration_time
        lo = float(rng.uniform(0, 0.8)) * T
        hi = lo + float(rng.uniform(0.05, 0.5)) * T
        assert_matches_reference([seq], plans=plans, window=(lo, hi))
        assert sp_tuples(find_safe_points(seq, plan)) == \
            sp_tuples(_reference_safe_points(seq, plan))


@settings(max_examples=20, deadline=None)
@given(st.integers(min_value=1, max_value=24),
       st.integers(min_value=0, max_value=10_000),
       st.booleans())
def test_property_analyze_matches_reference(n_ops, seed, falu):
    seq = synthetic_chain(n_ops=n_ops, seed=seed % 997,
                          latency=1.0 + (seed % 7) / 3.0)
    rng = np.random.default_rng(seed)
    seq2, plan = planned_chain(n_ops=max(2, n_ops), seed=seed % 997,
                               latency=1.0,
                               budget_frac=float(rng.uniform(0.4, 0.95)))
    assert_matches_reference([seq], free_at_last_use=falu)
    assert_matches_reference([seq2], plans={seq2.job_id: plan},
                             free_at_last_use=falu)
    assert sp_tuples(find_safe_points(seq2, plan,
                                      free_at_last_use=falu)) == \
        sp_tuples(_reference_safe_points(seq2, plan,
                                         free_at_last_use=falu))


# ---------------------------------------------------------------------------
# find_safe_points == _reference_safe_points, busy-interval caching
# ---------------------------------------------------------------------------

def test_safe_points_match_reference_golden_chain():
    seq, plan = planned_chain()
    assert sp_tuples(find_safe_points(seq, plan)) == \
        sp_tuples(_reference_safe_points(seq, plan))
    # no plan / trivial sequence edges
    assert sp_tuples(find_safe_points(seq, None)) == \
        sp_tuples(_reference_safe_points(seq, None))
    one = synthetic_chain(n_ops=1, job_id="one")
    assert find_safe_points(one, None) == _reference_safe_points(one, None)


def test_busy_intervals_built_once_per_plan_version():
    seq, plan = planned_chain(n_ops=10, seed=9, latency=1.0)
    assert plan.events, "needs a plan with in-flight transfers"
    before = plan_mod.BUSY_REBUILDS
    find_safe_points(seq, plan)
    find_safe_points(seq, plan)
    find_safe_points(seq, plan)
    assert plan_mod.BUSY_REBUILDS == before + 1
    # any content mutation bumps plan.version -> exactly one rebuild
    ev = plan.events[0]
    plan.add(ScheduleEvent(ev.event_type, ev.tensor_id, plan.job_id,
                           trigger_op=ev.trigger_op, delta=ev.delta,
                           start=ev.start, end=ev.end,
                           size_bytes=ev.size_bytes,
                           target_op=ev.target_op))
    find_safe_points(seq, plan)
    find_safe_points(seq, plan)
    assert plan_mod.BUSY_REBUILDS == before + 2


# ---------------------------------------------------------------------------
# WindowSweep == windowed analyze, incrementally
# ---------------------------------------------------------------------------

def test_window_sweep_matches_windowed_analyze():
    seq, plan = planned_chain(n_ops=10, seed=9, latency=1.0)
    T = seq.iteration_time
    sps = find_safe_points(seq, plan)
    t0 = sps[len(sps) // 2].time if sps else 0.4 * T
    ws = WindowSweep()
    work = plan.copy()
    assert_same_report(ws.report(seq, work, t0, T),
                       analyze([seq], plans={seq.job_id: work},
                               window=(t0, T)))
    # suffix-only mutation: the frozen prefix must be reused AND the
    # result must still equal a full windowed analyze
    frozen = ws._frozen
    tid = next(t for t in seq.tensors
               if seq.tensors[t].size_bytes > 0)
    work.add(ScheduleEvent(EventType.SWAP_OUT, tid, work.job_id,
                           trigger_op=len(seq.operators) - 2, delta=0.0,
                           start=t0 + 0.1, end=t0 + 0.2,
                           size_bytes=seq.tensors[tid].size_bytes))
    assert_same_report(ws.report(seq, work, t0, T),
                       analyze([seq], plans={seq.job_id: work},
                               window=(t0, T)))
    assert ws._frozen is frozen, "prefix re-frozen on a suffix-only edit"


# ---------------------------------------------------------------------------
# plan content identity (copy-on-write) and the whole-report memo
# ---------------------------------------------------------------------------

def test_plan_copy_shares_identity_until_mutation():
    p = SchedulingPlan(job_id="t")
    p.add(ScheduleEvent(EventType.SWAP_OUT, "a", "t", trigger_op=0,
                        delta=0.0, start=1.0, end=1.5, size_bytes=64))
    c = p.copy()
    assert (c.uid, c.version) == (p.uid, p.version)
    c.add(ScheduleEvent(EventType.SWAP_IN, "a", "t", trigger_op=0,
                        delta=0.4, start=1.9, end=2.0, size_bytes=64,
                        target_op=1))
    # first mutation of the copy forks it onto a fresh uid; the source's
    # identity is untouched
    assert c.uid != p.uid
    assert len(p.events) == 1
    # an un-forked mutation advances version under the same uid — every
    # (uid, version) pair still names exactly one content state
    uid, v = p.uid, p.version
    p.set_release("a", 2)
    assert p.uid == uid and p.version == v + 1


def test_set_release_bumps_version():
    p = SchedulingPlan(job_id="t")
    v = p.version
    p.set_release("a", 3)
    assert p.release_after_op["a"] == 3 and p.version == v + 1


def test_report_memo_hits_and_invalidates():
    seq, plan = planned_chain(n_ops=10, seed=9, latency=1.0)
    plans = {seq.job_id: plan}
    r1 = analyze([seq], plans=plans)
    assert analyze([seq], plans=plans) is r1
    # a content-identical copy (the no-change replan case) hits the SAME
    # memo row — this is what makes the steady-state incremental replan a
    # pure cache lookup
    assert analyze([seq], plans={seq.job_id: plan.copy()}) is r1
    # event mutation invalidates...
    ev = plan.events[0]
    plan.add(ScheduleEvent(EventType.SWAP_OUT, ev.tensor_id, plan.job_id,
                           trigger_op=ev.trigger_op, delta=0.0,
                           start=ev.start + 0.01, end=ev.end + 0.01,
                           size_bytes=ev.size_bytes))
    r2 = analyze([seq], plans=plans)
    assert r2 is not r1
    assert_same_report(r2, _reference_sweep([seq], plans=plans))
    # ...and so does a release-point edit (the VdnnSwapPass write path)
    tid = seq.operators[0].outputs[0]
    plan.set_release(tid, len(seq.operators) - 1)
    r3 = analyze([seq], plans=plans)
    assert r3 is not r2
    assert_same_report(r3, _reference_sweep([seq], plans=plans))


def test_report_memo_keyed_on_sequence_timeline_version():
    seq, plan = planned_chain(n_ops=8, seed=3, latency=1.0)
    plans = {seq.job_id: plan}
    r1 = analyze([seq], plans=plans)
    lat = [op.latency for op in seq.operators]
    lat[0] += 1.0
    seq.set_latencies(lat)
    r2 = analyze([seq], plans=plans)
    assert r2 is not r1
    assert_same_report(r2, _reference_sweep([seq], plans=plans))
