"""Launch-layer units: HLO collective parsing, roofline math, sharding
rules, and the §Perf levers (fused CE, microbatching, a2a MoE wiring)."""
import jax
import numpy as np
import pytest

from repro.configs import get_config, shapes_for, skipped_shapes_for
from repro.configs.base import ALL_SHAPES, ShapeSpec
from repro.launch.dryrun import (model_flops_for, parse_collectives)


# ----------------------------------------------------------- HLO parsing
SAMPLE_HLO = """
  %ag = bf16[8,128,256] all-gather(bf16[8,8,256] %x), replica_groups={{0,1,2,3,4,5,6,7,8,9,10,11,12,13,14,15}}, dimensions={1}
  %ar = f32[1024] all-reduce(f32[1024] %y), replica_groups={{0,1,2,3}}, to_apply=%sum
  %cp = bf16[64,64] collective-permute(bf16[64,64] %z), source_target_pairs={{0,1}}
  %a2a.1 = f32[16,32] all-to-all(f32[16,32] %w), replica_groups={{0,1,2,3,4,5,6,7}}
"""


def test_parse_collectives_kinds_and_sizes():
    c = parse_collectives(SAMPLE_HLO)
    assert set(c) == {"all-gather", "all-reduce", "collective-permute",
                      "all-to-all"}
    assert c["all-gather"]["bytes"] == 8 * 128 * 256 * 2
    # ring all-reduce: 2·size·(n-1)/n with n=4
    assert c["all-reduce"]["wire_bytes"] == pytest.approx(
        2 * 1024 * 4 * 3 / 4)
    assert c["collective-permute"]["wire_bytes"] == 64 * 64 * 2


def test_parse_collectives_ignores_done_ops():
    txt = "%d = f32[8] all-reduce-done(f32[8] %s)"
    assert parse_collectives(txt) == {}


# --------------------------------------------------------- model flops
def test_model_flops_train_vs_decode():
    cfg = get_config("tinyllama-1.1b")
    train = [s for s in ALL_SHAPES if s.name == "train_4k"][0]
    decode = [s for s in ALL_SHAPES if s.name == "decode_32k"][0]
    f_train = model_flops_for(cfg, train)
    f_dec = model_flops_for(cfg, decode)
    n = cfg.active_param_count()
    assert f_train == pytest.approx(6 * n * 256 * 4096)
    assert f_dec == pytest.approx(2 * n * 128)


def test_moe_model_flops_use_active_params():
    kimi = get_config("kimi-k2-1t-a32b")
    train = [s for s in ALL_SHAPES if s.name == "train_4k"][0]
    f = model_flops_for(kimi, train)
    assert f < 6 * kimi.param_count() * 256 * 4096 * 0.1  # 32B << 1T


# ------------------------------------------------------------ shape sets
def test_shape_assignment_and_skips():
    for arch in ("gemma-2b", "qwen2.5-14b", "whisper-base"):
        cfg = get_config(arch)
        names = [s.name for s in shapes_for(cfg)]
        assert names == ["train_4k", "prefill_32k", "decode_32k"]
        assert skipped_shapes_for(cfg)[0][0].name == "long_500k"
    for arch in ("jamba-1.5-large-398b", "mamba2-780m"):
        cfg = get_config(arch)
        assert "long_500k" in [s.name for s in shapes_for(cfg)]
        assert not skipped_shapes_for(cfg)


# --------------------------------------------------------- sharding rules
def test_mesh_rules_head_divisibility_fallback():
    from tests.test_distribution import run_with_devices
    out = run_with_devices("""
        from repro.configs import get_config
        from repro.launch.mesh import make_mesh
        from repro.launch.sharding import MeshRules
        mesh = make_mesh((1, 16), ("data", "model"))
        # qwen: 40 heads % 16 != 0 -> replicated heads
        r1 = MeshRules(mesh, cfg=get_config("qwen2.5-14b"))
        assert r1.table["heads"] is None
        # kimi: 64 heads ok; kv 8 not
        r2 = MeshRules(mesh, cfg=get_config("kimi-k2-1t-a32b"))
        assert r2.table["heads"] == "model"
        assert r2.table["kv_heads"] is None
        print("RULES_OK")
    """, n=16)
    assert "RULES_OK" in out


# ----------------------------------------------------------- perf levers
def test_fused_ce_matches_plain():
    import dataclasses
    from repro.models.registry import get_model
    cfg = get_config("tinyllama-1.1b").reduced()
    api = get_model(cfg)
    params, _ = api.init(jax.random.PRNGKey(0))
    batch = api.input_specs(ShapeSpec("s", 64, 2, "train"), abstract=False)
    l0 = float(api.loss(params, batch))
    api2 = get_model(dataclasses.replace(cfg, loss_chunk=16))
    l1 = float(api2.loss(params, batch))
    assert abs(l0 - l1) < 1e-4 * max(abs(l0), 1)


def test_microbatched_step_matches_full_batch():
    from repro.launch.sharding import MeshRules
    from repro.launch.steps import TrainStepConfig, build_train_step, \
        opt_state_for
    from repro.models.registry import get_model
    cfg = get_config("tinyllama-1.1b").reduced()
    api = get_model(cfg)
    params, _ = api.init(jax.random.PRNGKey(0))
    opt = opt_state_for(params)
    batch = api.input_specs(ShapeSpec("s", 32, 4, "train"), abstract=False)
    s1 = build_train_step(api, None, TrainStepConfig(microbatches=1))
    s2 = build_train_step(api, None, TrainStepConfig(microbatches=2))
    p1, _, m1 = jax.jit(s1)(params, opt, batch)
    p2, _, m2 = jax.jit(s2)(params, opt, batch)
    assert abs(float(m1["loss"]) - float(m2["loss"])) < 1e-3
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=2e-2, atol=2e-4)


def test_a2a_moe_single_device_fallback():
    """Without a model axis the a2a implementation must fall back to the
    scatter path and stay numerically correct."""
    import dataclasses
    from repro.models.registry import get_model
    cfg = get_config("moonshot-v1-16b-a3b").reduced()
    cfg.moe_impl = "a2a"
    cfg.capacity_factor = 8.0
    api = get_model(cfg)
    params, _ = api.init(jax.random.PRNGKey(0))
    batch = api.input_specs(ShapeSpec("s", 32, 2, "train"), abstract=False)
    loss = api.loss(params, batch)
    assert np.isfinite(float(loss))
