"""Scheduler-as-a-service acceptance: JobSpec wire format, admission
queue, durable job store, daemon lifecycle, crash recovery, and the
filesystem client.

The daemon tests run against a FakeController (admission logic is
transport- and JAX-free by design); one end-to-end test runs the real
``GlobalController`` with the registered ``"mlp"`` workload.
"""
import json
import os
import types

import pytest

from repro.service import (AdmissionQueue, JobRecord, JobSpec, JobState,
                           JobStore, SchedulerDaemon, ServiceClient,
                           SPEC_SCHEMA_VERSION, register_workload,
                           resolve_workload)


# ------------------------------------------------------------- JobSpec
def test_jobspec_wire_roundtrip():
    spec = JobSpec("j1", workload="mlp", workload_params={"size": "small"},
                   priority=2.0, iterations=3, budget_hint_bytes=123,
                   offset_frac=0.5, fingerprint="abc")
    wire = spec.to_dict()
    assert wire["schema"] == SPEC_SCHEMA_VERSION
    assert "payload" not in wire
    assert json.loads(json.dumps(wire)) == wire       # JSON-safe
    assert JobSpec.from_dict(wire) == spec


def test_jobspec_is_frozen_and_validates():
    spec = JobSpec("j1", workload="mlp")
    with pytest.raises(Exception):
        spec.job_id = "other"                         # frozen dataclass
    with pytest.raises(ValueError):
        JobSpec("")
    with pytest.raises(ValueError):
        JobSpec("j", iterations=0)
    with pytest.raises(ValueError):
        JobSpec("j", priority=0.0)
    with pytest.raises(ValueError):
        JobSpec("j", budget_hint_bytes=-1)
    with pytest.raises(ValueError):
        JobSpec("j", payload=(1, 2))                  # not a 4-tuple


def test_jobspec_payload_never_crosses_the_wire():
    spec = JobSpec("j1", payload=(lambda *a: None, 1, 2, 3))
    wire = spec.to_dict()
    assert "payload" not in wire
    back = JobSpec.from_dict({**wire, "payload": "smuggled"})
    assert back.payload is None


def test_jobspec_from_dict_tolerance():
    # unknown keys ignored (forward compatibility)
    spec = JobSpec.from_dict({"job_id": "j", "future_field": 1})
    assert spec.job_id == "j"
    with pytest.raises(ValueError):
        JobSpec.from_dict({"job_id": "j", "schema": 99})
    with pytest.raises(ValueError):
        JobSpec.from_dict({"workload": "mlp"})        # job_id missing
    with pytest.raises(ValueError):
        JobSpec.from_dict("not a dict")


def test_jobstate_terminal():
    assert JobState.DONE.terminal and JobState.FAILED.terminal \
        and JobState.REJECTED.terminal
    assert not (JobState.QUEUED.terminal or JobState.ADMITTED.terminal
                or JobState.RUNNING.terminal)


# ----------------------------------------------------------- workloads
def test_workload_registry_and_import_path():
    register_workload("svc-test", lambda x=1: ("fn", "p", "o", x))
    spec = JobSpec("j", workload="svc-test", workload_params={"x": 7})
    assert resolve_workload(spec) == ("fn", "p", "o", 7)
    with pytest.raises(ValueError):
        register_workload("bad:name", lambda: None)
    with pytest.raises(ValueError):
        resolve_workload(JobSpec("j", workload="no-such-workload"))
    with pytest.raises(ValueError):
        resolve_workload(JobSpec("j", workload="no.such.module:attr"))
    with pytest.raises(ValueError):
        resolve_workload(JobSpec("j"))                # neither ref nor payload
    # payload wins outright
    payload = ("f", "p", "o", "b")
    assert resolve_workload(JobSpec("j", workload="svc-test",
                                    payload=payload)) == payload


# ------------------------------------------------------ AdmissionQueue
def test_admission_queue_priority_and_backfill():
    q = AdmissionQueue(100)
    q.push("big", 80, priority=1.0)
    assert [j.job_id for j in q.pop_admissible()] == ["big"]
    assert q.reserved_bytes == 80
    q.push("blocked", 50, priority=9.0)
    q.push("small", 15, priority=1.0)
    # high-priority job is blocked (50 > 20 free) but keeps its place;
    # the small job backfills
    assert [j.job_id for j in q.pop_admissible()] == ["small"]
    assert [j.job_id for j in q.waiting] == ["blocked"]
    q.release("big")
    assert [j.job_id for j in q.pop_admissible()] == ["blocked"]
    assert q.reserved_bytes == 65
    assert q.max_reserved_bytes <= q.capacity_bytes


def test_admission_queue_rejects_never_admissible_and_duplicates():
    q = AdmissionQueue(100)
    with pytest.raises(ValueError):
        q.push("huge", 101)
    q.push("a", 10)
    with pytest.raises(ValueError):
        q.push("a", 10)                               # still waiting
    q.pop_admissible()
    with pytest.raises(ValueError):
        q.push("a", 10)                               # already admitted


def test_admission_queue_refine_shrinks_and_clamps():
    q = AdmissionQueue(100)
    q.push("a", 90)
    q.pop_admissible()
    assert q.refine("a", 40) == 40                    # measured shrink
    assert q.free_bytes == 60
    # growth past capacity is clamped to keep the ledger invariant
    assert q.refine("a", 500) == 100
    assert q.reserved_bytes == 100
    assert q.refine("ghost", 10) is None
    assert q.release("a") == 100
    assert q.reserved_bytes == 0


# ------------------------------------------------------------ JobStore
def test_jobstore_roundtrip_and_transitions(tmp_path):
    store = JobStore(str(tmp_path))
    rec = JobRecord(spec=JobSpec("j1", workload="mlp", iterations=2),
                    state=JobState.QUEUED, submitted_at=1.0)
    store.put(rec, now=1.0)
    store.transition("j1", JobState.ADMITTED, now=2.0,
                     predicted_peak_bytes=123, predicted_source="cost-model")
    store.transition("j1", JobState.RUNNING, now=3.0)
    store.transition("j1", JobState.DONE, now=4.0, measured_peak_bytes=99)
    # a FRESH instance reads the durable file
    again = JobStore(str(tmp_path)).get("j1")
    assert again.state is JobState.DONE
    assert again.admitted_at == 2.0 and again.started_at == 3.0 \
        and again.finished_at == 4.0
    assert again.predicted_peak_bytes == 123
    assert again.measured_peak_bytes == 99
    assert again.spec == rec.spec


def test_jobstore_corrupt_lines_skip_not_crash(tmp_path):
    path = tmp_path / "jobs.jsonl"
    good = JobRecord(spec=JobSpec("ok", workload="mlp")).to_dict()
    lines = [
        json.dumps({"kind": "header", "schema": JobStore.SCHEMA}),
        json.dumps(good),
        "{ not json at all",
        json.dumps({"kind": "job", "spec": {"schema": 99, "job_id": "bad"},
                    "state": "QUEUED"}),              # bad spec schema
        json.dumps({"kind": "job", "state": "QUEUED"}),   # no spec
        json.dumps(["not", "a", "dict"]),
    ]
    path.write_text("\n".join(lines) + "\n")
    store = JobStore(str(tmp_path))
    assert set(store.all()) == {"ok"}


def test_jobstore_header_mismatch_degrades_to_empty(tmp_path):
    path = tmp_path / "jobs.jsonl"
    rec = JobRecord(spec=JobSpec("j", workload="mlp")).to_dict()
    path.write_text(json.dumps({"kind": "header", "schema": 999}) + "\n"
                    + json.dumps(rec) + "\n")
    assert len(JobStore(str(tmp_path))) == 0
    path.write_text(json.dumps(rec) + "\n")           # no header at all
    assert len(JobStore(str(tmp_path))) == 0


def test_jobstore_recover_rules(tmp_path):
    store = JobStore(str(tmp_path))
    for jid, state, requeues in [("q", JobState.QUEUED, 0),
                                 ("a", JobState.ADMITTED, 0),
                                 ("r", JobState.RUNNING, 0),
                                 ("r2", JobState.RUNNING, 1),
                                 ("d", JobState.DONE, 0)]:
        store.put(JobRecord(spec=JobSpec(jid, workload="mlp"), state=state,
                            requeues=requeues), now=1.0)
    replayed, requeued, failed = store.recover(now=2.0)
    assert set(replayed) == {"q", "a"}
    assert requeued == ["r"] and store.get("r").requeues == 1
    assert store.get("r").state is JobState.QUEUED
    # a second orphaning burns the job instead of looping forever
    assert failed == ["r2"]
    assert store.get("r2").state is JobState.FAILED
    assert "orphaned" in store.get("r2").error
    assert store.get("d").state is JobState.DONE      # terminal untouched
    # durable: a fresh instance sees the recovered states
    assert JobStore(str(tmp_path)).get("r2").state is JobState.FAILED


# ---------------------------------------------------- daemon (faked)
class FakeHandle:
    def __init__(self, peak=0):
        self.done = False
        self.error = None
        self.stats = []
        self.peak_bytes = peak


class FakeController:
    """Admission-API double: capture_spec / predict_peak / submit."""

    def __init__(self, peaks):
        self.peaks = dict(peaks)      # job_id -> (predicted, source)
        self.handles = {}

    def capture_spec(self, spec):
        if spec.workload == "unresolvable":
            raise ValueError(f"job {spec.job_id!r}: unknown workload")
        return types.SimpleNamespace(
            seq=types.SimpleNamespace(job_id=spec.job_id))

    def predict_peak(self, seq, budget_hint_bytes=None):
        return self.peaks[seq.job_id]

    def submit(self, spec, captured=None):
        h = FakeHandle()
        self.handles[spec.job_id] = h
        return h


def _daemon(tmp_path, peaks, capacity):
    return SchedulerDaemon(str(tmp_path), controller=FakeController(peaks),
                           capacity_bytes=capacity, poll_interval=0.01)


def test_daemon_holds_then_admits_when_capacity_frees(tmp_path):
    d = _daemon(tmp_path, {"a": (800, "experience"),
                           "b": (300, "experience")}, capacity=1000)
    d.submit(JobSpec("a", workload="w"))
    d.submit(JobSpec("b", workload="w"))
    d.step(now=1.0)
    assert d.store.get("a").state is JobState.RUNNING
    assert d.store.get("b").state is JobState.QUEUED   # 300 > 200 free
    # a finishes -> reservation released -> b admitted
    d.controller.handles["a"].done = True
    d.controller.handles["a"].peak_bytes = 750
    d.step(now=2.0)
    assert d.store.get("a").state is JobState.DONE
    assert d.store.get("a").measured_peak_bytes == 750
    assert d.store.get("b").state is JobState.RUNNING
    assert d.store.get("b").started_at == 2.0


def test_daemon_refines_conservative_bound_after_profiled_iteration(tmp_path):
    d = _daemon(tmp_path, {"a": (900, "cost-model"),
                           "b": (300, "experience")}, capacity=1000)
    d.submit(JobSpec("a", workload="w"))
    d.submit(JobSpec("b", workload="w"))
    d.step(now=1.0)
    assert d.store.get("b").state is JobState.QUEUED
    # first profiled iteration: measured 400 << the 900 bound
    h = d.controller.handles["a"]
    h.stats.append(object())
    h.peak_bytes = 400
    d.step(now=2.0)
    assert d.store.get("a").measured_peak_bytes == 400
    assert d.store.get("b").state is JobState.RUNNING  # freed headroom admits
    assert d.queue.reserved_bytes == 700


def test_daemon_rejects_never_fitting_and_unresolvable(tmp_path):
    d = _daemon(tmp_path, {"huge": (2000, "cost-model")}, capacity=1000)
    d.submit(JobSpec("huge", workload="w"))
    assert d.store.get("huge").state is JobState.REJECTED
    assert "never admissible" in d.store.get("huge").error
    d.submit(JobSpec("nope", workload="unresolvable"))
    assert d.store.get("nope").state is JobState.REJECTED


def test_daemon_submit_is_idempotent(tmp_path):
    d = _daemon(tmp_path, {"a": (10, "experience")}, capacity=1000)
    r1 = d.submit(JobSpec("a", workload="w"))
    r2 = d.submit(JobSpec("a", workload="w", iterations=5))
    assert r2 is r1                                   # duplicate ignored
    d.step(now=1.0)
    assert d.store.get("a").state is JobState.RUNNING


def test_daemon_crash_recovery_requeues_orphan_exactly_once(tmp_path):
    # a "crashed daemon" left one of each non-terminal state behind
    store = JobStore(str(tmp_path))
    for jid, state in [("q", JobState.QUEUED), ("a", JobState.ADMITTED),
                       ("r", JobState.RUNNING)]:
        store.put(JobRecord(spec=JobSpec(jid, workload="w"), state=state,
                            submitted_at=1.0), now=1.0)
    peaks = {j: (10, "experience") for j in ("q", "a", "r")}
    d = _daemon(tmp_path, peaks, capacity=1000)
    assert set(d.recovered["replayed"]) == {"q", "a"}
    assert d.recovered["requeued_orphans"] == ["r"]
    assert d.store.get("r").requeues == 1
    d.step(now=2.0)
    assert all(d.store.get(j).state is JobState.RUNNING
               for j in ("q", "a", "r"))
    # crash AGAIN mid-run: everything was RUNNING, so q/a spend their one
    # re-queue and r — already re-queued once — is failed for good
    d2 = _daemon(tmp_path, peaks, capacity=1000)
    assert d2.recovered["failed_orphans"] == ["r"]
    assert d2.store.get("r").state is JobState.FAILED
    assert "orphaned" in d2.store.get("r").error
    assert set(d2.recovered["requeued_orphans"]) == {"q", "a"}


def test_daemon_drain_inbox_skips_corrupt_submissions(tmp_path):
    d = _daemon(tmp_path, {"ok": (10, "experience")}, capacity=1000)
    ok = JobSpec("ok", workload="w").to_dict()
    (tmp_path / "inbox" / "ok.json").write_text(json.dumps(ok))
    (tmp_path / "inbox" / "garbage.json").write_text("{ nope")
    (tmp_path / "inbox" / "badspec.json").write_text(
        json.dumps({"schema": 99, "job_id": "x"}))
    d.step(now=1.0)
    assert d.store.get("ok").state is JobState.RUNNING
    assert d.store.get("x") is None
    assert os.listdir(tmp_path / "inbox") == []       # nothing wedges


# ------------------------------------------------------------- client
def test_client_wire_submission_and_drain(tmp_path):
    d = _daemon(tmp_path, {"w1": (10, "experience")}, capacity=1000)
    client = ServiceClient(str(tmp_path))
    client.submit(JobSpec("w1", workload="w", iterations=2))
    client.drain()
    d.step(now=1.0)
    assert d.store.get("w1").state is JobState.RUNNING
    assert d._draining                                 # control file honored
    assert client.states()["w1"] == "RUNNING"
    d.controller.handles["w1"].done = True
    d.step(now=2.0)
    recs = client.wait(["w1"], timeout=5.0)
    assert recs["w1"].state is JobState.DONE


def test_client_refuses_payload_specs(tmp_path):
    client = ServiceClient(str(tmp_path))
    with pytest.raises(ValueError):
        client.submit(JobSpec("p", payload=("f", 1, 2, 3)))
    with pytest.raises(ValueError):
        client.submit(JobSpec("p"))                   # no workload either


# ------------------------------------------- deprecated launch() shim
def test_launch_shim_warns_and_still_runs():
    jax = pytest.importorskip("jax")
    from helpers import mlp_params, mlp_train_step

    from repro.core import GlobalController
    from repro.optim.adam import adamw_init
    p = mlp_params(jax.random.PRNGKey(0), [32, 64, 64, 4])
    o = adamw_init(p)
    batch = (jax.random.normal(jax.random.PRNGKey(1), (8, 32)),
             jax.random.normal(jax.random.PRNGKey(2), (8, 4)))
    gc = GlobalController()
    with pytest.warns(DeprecationWarning, match="submit"):
        h = gc.launch(mlp_train_step, p, o, batch, job_id="shim-job",
                      iterations=1)
    gc.wait(timeout=300)
    assert h.done and h.error is None
    assert h.spec is not None and h.spec.job_id == "shim-job"


# ------------------------------------------------- real controller e2e
def test_daemon_real_controller_end_to_end(tmp_path):
    pytest.importorskip("jax")
    d = SchedulerDaemon(str(tmp_path), poll_interval=0.01)
    d.submit(JobSpec("e2e", workload="mlp",
                     workload_params={"size": "small"}, iterations=2))
    assert d.drain(timeout=300)
    rec = d.store.get("e2e")
    assert rec.state is JobState.DONE
    assert rec.predicted_peak_bytes > 0 and rec.predicted_source
    assert rec.measured_peak_bytes > 0
    # the wire-format record survives a fresh read
    again = JobStore(str(tmp_path)).get("e2e")
    assert again.state is JobState.DONE
