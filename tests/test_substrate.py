"""Substrate tests: optimizer, compression, data pipeline, checkpointing,
fault tolerance, stragglers, elastic planning."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import hypothesis_or_stub

given, settings, st = hypothesis_or_stub()

from repro.checkpoint.manager import CheckpointManager
from repro.data.pipeline import DataConfig, Prefetcher, TokenStream
from repro.optim.adam import adamw_init, adamw_update
from repro.optim.compression import (ef_compress_grads, quantize_dequantize,
                                     wire_bytes_ratio)
from repro.runtime.elastic import plan_elastic_mesh
from repro.runtime.fault_tolerance import FTConfig, resilient_train_loop
from repro.runtime.stragglers import StragglerConfig, StragglerMonitor


# ---------------------------------------------------------------- optimizer
def test_adamw_matches_numpy_reference():
    p = {"w": jnp.asarray([[1.0, -2.0], [0.5, 3.0]])}
    g = {"w": jnp.asarray([[0.1, -0.2], [0.3, 0.0]])}
    st_ = adamw_init(p)
    p1, st1 = adamw_update(p, g, st_, lr=0.1, b1=0.9, b2=0.999,
                           weight_decay=0.0)
    m = 0.1 * np.asarray(g["w"])
    v = 0.001 * np.asarray(g["w"]) ** 2
    mhat = m / (1 - 0.9)
    vhat = v / (1 - 0.999)
    want = np.asarray(p["w"]) - 0.1 * mhat / (np.sqrt(vhat) + 1e-8)
    np.testing.assert_allclose(np.asarray(p1["w"]), want, rtol=1e-5)
    assert int(st1.step) == 1


def test_adamw_master_weights_bf16():
    p = {"w": jnp.ones((8,), jnp.bfloat16)}
    st_ = adamw_init(p, use_master=True)
    g = {"w": jnp.full((8,), 1e-3, jnp.bfloat16)}
    p2, st2 = adamw_update(p, g, st_, lr=1e-4)
    assert p2["w"].dtype == jnp.bfloat16
    assert st2.master["w"].dtype == jnp.float32
    # master accumulates sub-bf16 updates
    assert float(jnp.max(jnp.abs(st2.master["w"] - 1.0))) > 0


def test_grad_clipping():
    p = {"w": jnp.zeros((4,))}
    g = {"w": jnp.full((4,), 100.0)}
    st_ = adamw_init(p)
    p1, _ = adamw_update(p, g, st_, lr=1.0, grad_clip_norm=1.0)
    assert np.isfinite(np.asarray(p1["w"])).all()


# -------------------------------------------------------------- compression
def test_quant_dequant_error_bound():
    x = jax.random.normal(jax.random.PRNGKey(0), (1000,)) * 5
    xq = quantize_dequantize(x)
    assert float(jnp.max(jnp.abs(xq - x))) <= float(
        jnp.max(jnp.abs(x))) / 127 + 1e-6
    assert wire_bytes_ratio(jnp.float32) < 0.26


def test_error_feedback_unbiased_over_steps():
    """EF property: the accumulated compressed signal tracks the raw sum
    (residual stays bounded, error does not accumulate)."""
    rng = np.random.default_rng(0)
    opt = adamw_init({"w": jnp.zeros((512,))}, grad_compression=True)
    total_raw = np.zeros(512)
    total_comp = np.zeros(512)
    for _ in range(30):
        g = {"w": jnp.asarray(rng.standard_normal(512) * 1e-3, jnp.float32)}
        cg, opt = ef_compress_grads(g, opt)
        total_raw += np.asarray(g["w"])
        total_comp += np.asarray(cg["w"])
    resid = np.abs(total_raw - total_comp).max()
    one_step_err = 2e-3 / 127 * 3
    assert resid < one_step_err * 3  # residual bounded, not growing ~30×


def test_training_with_compression_converges():
    from helpers import mlp_params, mlp_forward
    p = mlp_params(jax.random.PRNGKey(0), [16, 32, 4])
    opt = adamw_init(p, grad_compression=True)
    x = jax.random.normal(jax.random.PRNGKey(1), (32, 16))
    y = jax.random.normal(jax.random.PRNGKey(2), (32, 4))

    @jax.jit
    def step(p, opt):
        loss, g = jax.value_and_grad(
            lambda pp: jnp.mean((mlp_forward(pp, x) - y) ** 2))(p)
        g, opt = ef_compress_grads(g, opt)
        p, opt = adamw_update(p, g, opt, lr=3e-3)
        return p, opt, loss

    losses = []
    for _ in range(40):
        p, opt, l = step(p, opt)
        losses.append(float(l))
    assert losses[-1] < 0.7 * losses[0]


# ---------------------------------------------------------------------- data
def test_stream_determinism_and_resume():
    cfg = DataConfig(seq_len=32, global_batch=4, vocab_size=128, seed=7)
    s1 = TokenStream(cfg)
    batches = [s1.batch_at(i)["tokens"] for i in range(5)]
    s2 = TokenStream(cfg)
    s2.load_state_dict({"step": 3})
    np.testing.assert_array_equal(s2.batch_at(3)["tokens"], batches[3])
    # host sharding: different hosts → different data
    h0 = TokenStream(cfg, host_id=0, n_hosts=2).batch_at(0)["tokens"]
    h1 = TokenStream(cfg, host_id=1, n_hosts=2).batch_at(0)["tokens"]
    assert h0.shape[0] == 2 and not np.array_equal(h0, h1)


def test_labels_are_shifted_tokens():
    cfg = DataConfig(seq_len=16, global_batch=2, vocab_size=64)
    b = TokenStream(cfg).batch_at(0)
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


def test_prefetcher():
    cfg = DataConfig(seq_len=8, global_batch=2, vocab_size=32)
    stream = TokenStream(cfg)
    pf = Prefetcher(stream, depth=2)
    b1 = next(pf)
    b2 = next(pf)
    assert b1["tokens"].shape == (2, 8)
    assert not np.array_equal(b1["tokens"], b2["tokens"])
    pf.close()


# ---------------------------------------------------------------- checkpoint
def test_checkpoint_roundtrip_and_atomicity(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    state = {"w": jnp.arange(8, dtype=jnp.float32),
             "nested": {"b": jnp.ones((3,))}}
    mgr.save(10, state)
    mgr.save(20, state)
    # a fake torn save must be ignored
    os.makedirs(tmp_path / "step_000000030")
    assert mgr.latest_step() == 20
    restored, meta = mgr.restore(template=state)
    np.testing.assert_array_equal(np.asarray(restored["w"]),
                                  np.asarray(state["w"]))
    assert meta["step"] == 20


def test_checkpoint_gc_keep(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    state = {"w": jnp.zeros((4,))}
    for s in (1, 2, 3, 4):
        mgr.save(s, state)
    steps = sorted(int(n.split("_")[1]) for n in os.listdir(tmp_path)
                   if n.startswith("step_"))
    assert steps == [3, 4]


def test_checkpoint_async(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    state = {"w": jnp.ones((128,))}
    mgr.save_async(5, state)
    mgr.wait()
    assert mgr.latest_step() == 5


# ----------------------------------------------------------- fault tolerance
def test_restart_on_injected_failure(tmp_path):
    from helpers import mlp_params, mlp_forward
    p = mlp_params(jax.random.PRNGKey(0), [8, 16, 2])
    opt = adamw_init(p)

    def step(params, opt_state, batch):
        x, y = batch
        loss, g = jax.value_and_grad(
            lambda pp: jnp.mean((mlp_forward(pp, x) - y) ** 2))(params)
        params, opt_state = adamw_update(params, g, opt_state, lr=1e-3)
        return params, opt_state, {"loss": loss}

    def data():
        k = jax.random.PRNGKey(3)
        while True:
            yield (jax.random.normal(k, (4, 8)),
                   jax.random.normal(k, (4, 2)))

    ft = FTConfig(ckpt_dir=str(tmp_path), ckpt_every=5, max_restarts=2,
                  async_save=False)
    res = resilient_train_loop(step, (p, opt), data(), 20, ft=ft,
                               fail_at={12: 1})
    assert res.restarts == 1
    assert res.final_step == 19
    assert not res.preempted
    assert all(np.isfinite(m["loss"]) for m in res.metrics_history)


def test_restart_exhaustion_raises(tmp_path):
    ft = FTConfig(ckpt_dir=str(tmp_path), ckpt_every=100, max_restarts=1,
                  async_save=False)

    def step(p, o, b):
        return p, o, {"loss": jnp.zeros(())}

    with pytest.raises(RuntimeError):
        resilient_train_loop(step, ((), ()), iter(lambda: ((), ()), 1),
                             10, ft=ft, fail_at={3: 5})


# -------------------------------------------------------------- stragglers
def test_straggler_detection_and_rebalance():
    mon = StragglerMonitor(n_hosts=8, config=StragglerConfig(
        window=10, z_threshold=3.0, min_samples=5))
    for step in range(10):
        for h in range(8):
            t = 1.0 if h != 3 else 2.5  # host 3 is slow
            mon.record(h, step, t + 0.01 * (h + step % 3))
    flagged = mon.stragglers()
    assert flagged and flagged[0][0] == 3
    plan = mon.rebalance({h: 4 for h in range(8)})
    assert plan[3] == 3 and sum(plan.values()) == 32


def test_straggler_eviction_streak():
    mon = StragglerMonitor(n_hosts=4, config=StragglerConfig(
        window=5, evict_after=3, min_samples=3))
    for step in range(12):
        for h in range(4):
            mon.record(h, step, 10.0 if h == 1 else 1.0)
        mon.stragglers()
    assert 1 in mon.should_evict()


# ------------------------------------------------------------------ elastic
def test_elastic_mesh_planning():
    plan = plan_elastic_mesh(512, prev_tp=16)
    assert plan.mesh_shape == (32, 16) and plan.kept_model_degree
    plan2 = plan_elastic_mesh(384, prev_tp=16)  # 384 = 24×16
    assert plan2.tp_degree == 16
    plan3 = plan_elastic_mesh(100, prev_tp=16)  # keep largest pow2 divisor
    assert plan3.tp_degree == 4 and plan3.dp_degree == 25
