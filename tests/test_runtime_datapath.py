"""PR-9 runtime data path: DMA coalescing, the double-buffered async
swap stream, buffered telemetry, and the batched KV-block kernels."""
import threading

import numpy as np
import pytest

from repro.core.engine import DmaChannel
from repro.core.executor import AsyncSwapExecutor
from repro.core.telemetry import TelemetryHub, record_schemas

FIX = 15e-6      # per-transfer fixup (setup) latency
OVER = 2e-6      # per-extra-member batch overhead


# ----------------------------------------------------------------------
# DmaChannel coalescing (virtual time)
# ----------------------------------------------------------------------
class TestDmaCoalescing:
    def test_off_by_default_bookings_identical(self):
        plain, tagged = DmaChannel(), DmaChannel()
        slots_plain, slots_tagged = [], []
        t = 0.0
        for dur in (3e-4, 1e-4, 2e-4):
            slots_plain.append(plain.acquire(t, dur))
            # direction/fixup tags must be inert while coalesce=False
            slots_tagged.append(tagged.acquire(t, dur, direction="in",
                                               fixup=FIX))
            t = slots_plain[-1][1]
        assert slots_plain == slots_tagged
        assert tagged.batched_transfers == 0
        assert tagged.coalesced_bookings == 0
        assert tagged.saved_fixup_s == 0.0
        assert tagged.busy_until == plain.busy_until

    def test_adjacent_same_direction_merge_pays_one_fixup(self):
        ch = DmaChannel(coalesce=True, coalesce_window=1e-3,
                        batch_overhead_s=OVER)
        d0, d1 = 3e-4, 2e-4
        s0, e0 = ch.acquire(0.0, FIX + d0, direction="in", fixup=FIX)
        assert (s0, e0) == (0.0, FIX + d0)
        # second booking lands at the tail within the window: it merges,
        # paying its payload + batch overhead instead of another fixup
        s1, e1 = ch.acquire(e0, FIX + d1, direction="in", fixup=FIX)
        assert s1 == e0
        assert e1 == pytest.approx(e0 + d1 + OVER)
        assert ch.busy_until == pytest.approx(e1)
        assert ch.batched_transfers == 1
        assert ch.coalesced_bookings == 2     # opener + merged member
        assert ch.saved_fixup_s == pytest.approx(FIX - OVER)

    def test_direction_change_breaks_the_batch(self):
        ch = DmaChannel(coalesce=True, coalesce_window=1e-3,
                        batch_overhead_s=OVER)
        _, e0 = ch.acquire(0.0, FIX + 3e-4, direction="out", fixup=FIX)
        s1, e1 = ch.acquire(e0, FIX + 2e-4, direction="in", fixup=FIX)
        # opposite direction: a fresh full-cost slot, nothing coalesced
        assert (s1, e1) == (e0, e0 + FIX + 2e-4)
        assert ch.batched_transfers == 0
        assert ch.saved_fixup_s == 0.0

    def test_gap_beyond_window_breaks_the_batch(self):
        ch = DmaChannel(coalesce=True, coalesce_window=1e-5,
                        batch_overhead_s=OVER)
        _, e0 = ch.acquire(0.0, FIX + 3e-4, direction="in", fixup=FIX)
        late = e0 + 5e-4   # well past the window
        s1, e1 = ch.acquire(late, FIX + 2e-4, direction="in", fixup=FIX)
        assert (s1, e1) == (late, late + FIX + 2e-4)
        assert ch.batched_transfers == 0

    def test_merged_tail_refund_restores_the_batch_end(self):
        ch = DmaChannel(coalesce=True, coalesce_window=1e-3,
                        batch_overhead_s=OVER)
        _, e0 = ch.acquire(0.0, FIX + 3e-4, direction="in", fixup=FIX)
        s1, e1 = ch.acquire(e0, FIX + 2e-4, direction="in", fixup=FIX)
        assert ch.try_refund(s1, e1)
        assert ch.busy_until == pytest.approx(e0)

    def test_acquire_batch_matches_sequential_merges(self):
        durs = [3e-4, 2e-4, 1e-4]
        # booking the cohort explicitly ...
        batch = DmaChannel(coalesce=True, batch_overhead_s=OVER)
        s, e = batch.acquire_batch(0.0, durs, fixup=FIX, direction="in")
        assert (s, e) == (0.0, pytest.approx(FIX + sum(durs)
                                             + OVER * (len(durs) - 1)))
        assert batch.batched_transfers == 1
        assert batch.coalesced_bookings == len(durs)
        assert batch.saved_fixup_s == pytest.approx(
            (FIX - OVER) * (len(durs) - 1))
        # ... costs exactly what back-to-back window merges cost
        seq = DmaChannel(coalesce=True, coalesce_window=1e-3,
                         batch_overhead_s=OVER)
        t = 0.0
        for d in durs:
            _, t = seq.acquire(t, FIX + d, direction="in", fixup=FIX)
        assert t == pytest.approx(e)
        assert seq.saved_fixup_s == pytest.approx(batch.saved_fixup_s)

    def test_acquire_batch_degenerate_sizes(self):
        ch = DmaChannel(coalesce=True, batch_overhead_s=OVER)
        assert ch.acquire_batch(1.0, [], fixup=FIX) == (1.0, 1.0)
        s, e = ch.acquire_batch(1.0, [2e-4], fixup=FIX, direction="out")
        assert (s, e) == (1.0, 1.0 + FIX + 2e-4)  # single == plain acquire
        assert ch.batched_transfers == 0


# ----------------------------------------------------------------------
# AsyncSwapExecutor: queued same-direction transfers share one launch
# ----------------------------------------------------------------------
def test_queued_prefetches_coalesce_into_one_launch():
    ch = DmaChannel()
    ex = AsyncSwapExecutor(ch)
    try:
        started, gate = threading.Event(), threading.Event()

        def slow_out():
            started.set()
            gate.wait(5.0)

        ex.submit("out:x", slow_out)
        assert started.wait(5.0)
        # while the swap-out occupies the worker, two prefetches queue up
        done_a = ex.submit("in:a", lambda: None)
        done_b = ex.submit("in:b", lambda: None)
        gate.set()
        assert done_a.wait(5.0) and done_b.wait(5.0)
        ex.drain()
        # regression: both queued prefetches ride ONE transfer_batch launch
        assert ["in:a", "in:b"] in ex.batches
        assert ch.batched_transfers == 1
        assert ch.coalesced_bookings == 2
    finally:
        ex.stop()


def test_direction_change_defers_to_the_next_launch():
    ch = DmaChannel()
    ex = AsyncSwapExecutor(ch)
    try:
        started, gate = threading.Event(), threading.Event()

        def slow_out():
            started.set()
            gate.wait(5.0)

        ex.submit("out:x", slow_out)
        assert started.wait(5.0)
        evs = [ex.submit("in:a", lambda: None),
               ex.submit("out:y", lambda: None),
               ex.submit("in:b", lambda: None)]
        gate.set()
        for ev in evs:
            assert ev.wait(5.0)
        ex.drain()
        # FIFO order across the direction change is preserved: the "out"
        # item breaks the in-batch, so in:a and in:b cannot share a launch
        flat = [k for b in ex.batches for k in b]
        assert flat == ["out:x", "in:a", "out:y", "in:b"]
        assert all(len(b) == 1 for b in ex.batches)
    finally:
        ex.stop()


# ----------------------------------------------------------------------
# TelemetryHub per-thread buffering
# ----------------------------------------------------------------------
def _emit(hub: TelemetryHub) -> None:
    hub.record_op("j", 0, 1e-3, prim="dot", flops=10.0, t=0.1)
    hub.record_transfer("j", "s0", "out", 1024, 2e-3, t=0.2)
    hub.record_stall("j", 1, 5e-4, "passive_in", t=0.3)
    hub.record_residency("j", "s0", "free", 0, t=0.4)
    hub.record_op("j", 1, 2e-3, prim="add", t=0.5)


def test_buffered_telemetry_identical_to_unbuffered():
    direct = TelemetryHub(clock="virtual")
    _emit(direct)
    buffered = TelemetryHub(clock="virtual")
    buffered.begin_buffering()
    _emit(buffered)
    # nothing published until the op-boundary flush ...
    assert buffered.ops.get("j") is None
    buffered.end_buffering()
    # ... then streams, order and record content match the direct path
    assert buffered.ops == direct.ops
    assert buffered.transfers == direct.transfers
    assert buffered.stalls == direct.stalls
    assert buffered.residency == direct.residency
    # the EWMA fold happens at publish time and matches too
    assert buffered._ewma == direct._ewma


def test_record_schemas_are_pinned():
    assert record_schemas() == {
        "op": ("job_id", "iteration", "op_idx", "prim", "latency_s",
               "flops", "bytes_accessed", "t"),
        "transfer": ("job_id", "iteration", "storage", "direction",
                     "size_bytes", "duration_s", "compressed", "passive",
                     "t"),
        "stall": ("job_id", "iteration", "op_idx", "cause", "duration_s",
                  "t"),
        "residency": ("job_id", "iteration", "storage", "action",
                      "resident_bytes", "t"),
    }


# ----------------------------------------------------------------------
# Batched KV-block kernels vs the jnp oracles
# ----------------------------------------------------------------------
@pytest.fixture(scope="module")
def kv_pool():
    rng = np.random.default_rng(7)
    pool = rng.standard_normal((16, 256)).astype(np.float32)
    return pool, rng


def test_kv_block_gather_matches_ref(kv_pool):
    from repro.kernels.kv_block_copy import kv_block_gather
    from repro.kernels.ref import kv_block_gather_ref

    pool, rng = kv_pool
    for k in (1, 3, 7):
        idx = np.asarray(rng.permutation(pool.shape[0])[:k], np.int32)
        got = np.asarray(kv_block_gather(pool, idx))
        want = np.asarray(kv_block_gather_ref(pool, idx))
        np.testing.assert_array_equal(got, want)


def test_kv_block_scatter_matches_ref(kv_pool):
    from repro.kernels.kv_block_copy import kv_block_scatter
    from repro.kernels.ref import kv_block_scatter_ref

    pool, rng = kv_pool
    for k in (1, 4):
        idx = np.asarray(rng.permutation(pool.shape[0])[:k], np.int32)
        blocks = rng.standard_normal((k, pool.shape[1])).astype(np.float32)
        got = np.asarray(kv_block_scatter(pool, idx, blocks))
        want = np.asarray(kv_block_scatter_ref(pool, idx, blocks))
        np.testing.assert_array_equal(got, want)
        # rows outside idx pass through bit-identically
        untouched = np.setdiff1d(np.arange(pool.shape[0]), idx)
        np.testing.assert_array_equal(got[untouched], pool[untouched])


def test_kv_gather_scatter_roundtrip_is_identity(kv_pool):
    from repro.kernels.kv_block_copy import kv_block_gather, kv_block_scatter

    pool, rng = kv_pool
    idx = np.asarray(rng.permutation(pool.shape[0])[:5], np.int32)
    rows = kv_block_gather(pool, idx)
    back = np.asarray(kv_block_scatter(pool, idx, rows))
    np.testing.assert_array_equal(back, pool)
