"""Shared test fixtures: tiny train steps + synthetic access sequences."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import capture_train_step
from repro.core.access import (AccessSequence, Operator, TensorKind,
                               TensorSpec)
from repro.optim.adam import adamw_init, adamw_update


def mlp_params(key, sizes):
    ps = []
    for i in range(len(sizes) - 1):
        key, k = jax.random.split(key)
        ps.append({"w": jax.random.normal(k, (sizes[i], sizes[i + 1])) * 0.02,
                   "b": jnp.zeros(sizes[i + 1])})
    return ps


def mlp_forward(params, x):
    h = x
    for i, p in enumerate(params):
        h = h @ p["w"] + p["b"]
        if i < len(params) - 1:
            h = jnp.tanh(h)
    return h


def mlp_train_step(params, opt_state, batch):
    x, y = batch

    def loss_fn(p):
        return jnp.mean((mlp_forward(p, x) - y) ** 2)

    loss, grads = jax.value_and_grad(loss_fn)(params)
    params, opt_state = adamw_update(params, grads, opt_state, lr=1e-3)
    return params, opt_state, loss


def capture_mlp(sizes=(64, 128, 128, 8), batch=16, job_id="job0"):
    params = mlp_params(jax.random.PRNGKey(0), list(sizes))
    opt = adamw_init(params)
    x = jax.random.normal(jax.random.PRNGKey(1), (batch, sizes[0]))
    y = jax.random.normal(jax.random.PRNGKey(2), (batch, sizes[-1]))
    seq, closed = capture_train_step(mlp_train_step, params, opt, (x, y),
                                     job_id=job_id)
    return seq, closed, (params, opt, (x, y))


def synthetic_chain(n_ops=10, sizes=None, latency=1.0, job_id="chain",
                    with_params=True, seed=0) -> AccessSequence:
    """A linear producer-consumer chain with a backward-like reuse pattern:
    act_i produced by op_i, consumed by op_{i+1} and op_{2n-i} (mirror)."""
    rng = np.random.default_rng(seed)
    n_t = n_ops
    sizes = sizes or (rng.integers(1, 64, n_t) * 1024).tolist()
    tensors = {}
    ops = []
    if with_params:
        tensors["p0"] = TensorSpec("p0", 8 * 1024, kind=TensorKind.PARAM,
                                   job_id=job_id)
    for i in range(n_t):
        tensors[f"a{i}"] = TensorSpec(f"a{i}", int(sizes[i]),
                                      kind=TensorKind.ACTIVATION,
                                      job_id=job_id)
    total = 2 * n_ops
    for i in range(n_ops):
        ins = [f"a{i-1}"] if i > 0 else []
        if with_params:
            ins.append("p0")
        ops.append(Operator(idx=i, name=f"fwd{i}", inputs=tuple(ins),
                            outputs=(f"a{i}",), latency=latency,
                            job_id=job_id))
    for j in range(n_ops):
        i = n_ops - 1 - j
        idx = n_ops + j
        ins = [f"a{i}"]
        outs = ()
        ops.append(Operator(idx=idx, name=f"bwd{i}", inputs=tuple(ins),
                            outputs=outs, latency=latency, job_id=job_id))
    initial = ["p0"] if with_params else []
    return AccessSequence(job_id, ops, tensors, initial_resident=initial)
