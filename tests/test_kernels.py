"""Per-kernel shape/dtype sweeps against the pure-jnp oracles (interpret
mode executes the kernel bodies in Python on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import hypothesis_or_stub

given, settings, st = hypothesis_or_stub()

from repro.kernels import ref
from repro.kernels.ops import (dequantize_from_offload, flash_attention,
                               quantize_for_offload, ssd_intra_chunk)

KEY = jax.random.PRNGKey(0)


@pytest.mark.parametrize("b,sq,skv,h,kvh,d,causal,dtype", [
    (2, 128, 128, 4, 2, 64, True, jnp.float32),
    (1, 200, 200, 8, 1, 32, True, jnp.float32),      # MQA, ragged seq
    (2, 64, 256, 4, 4, 128, False, jnp.float32),     # cross-shaped
    (1, 384, 384, 6, 2, 112, True, jnp.float32),     # kimi head_dim
    (2, 256, 256, 4, 2, 64, True, jnp.bfloat16),
    (1, 96, 96, 2, 2, 256, True, jnp.float32),       # gemma head_dim
])
def test_flash_attention_sweep(b, sq, skv, h, kvh, d, causal, dtype):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (b, sq, h, d), jnp.float32).astype(dtype)
    k = jax.random.normal(ks[1], (b, skv, kvh, d), jnp.float32).astype(dtype)
    v = jax.random.normal(ks[2], (b, skv, kvh, d), jnp.float32).astype(dtype)
    out = flash_attention(q, k, v, causal=causal)
    want = ref.flash_attention_ref(q, k, v, causal=causal)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


def test_flash_attention_sliding_window():
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (1, 256, 4, 64))
    k = jax.random.normal(ks[1], (1, 256, 2, 64))
    v = jax.random.normal(ks[2], (1, 256, 2, 64))
    out = flash_attention(q, k, v, causal=True, sliding_window=64)
    want = ref.flash_attention_ref(q, k, v, causal=True, sliding_window=64)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("b,nc,q,h,p,n", [
    (2, 3, 64, 4, 16, 32),
    (1, 2, 128, 2, 64, 128),   # mamba2-780m tile
    (1, 5, 32, 8, 64, 16),     # jamba tile
])
def test_ssd_intra_chunk_sweep(b, nc, q, h, p, n):
    ks = jax.random.split(KEY, 5)
    xc = jax.random.normal(ks[0], (b, nc, q, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, nc, q, h)))
    da = -jax.nn.softplus(jax.random.normal(ks[2], (b, nc, q, h)))
    bc = jax.random.normal(ks[3], (b, nc, q, n))
    cc = jax.random.normal(ks[4], (b, nc, q, n))
    y, stt = ssd_intra_chunk(xc, dt, da, bc, cc)
    y_ref, st_ref = ref.ssd_intra_chunk_ref(xc, dt, da, bc, cc)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(stt), np.asarray(st_ref),
                               rtol=1e-4, atol=1e-4)


@settings(max_examples=20, deadline=None)
@given(rows=st.integers(1, 40), cols=st.integers(1, 700),
       scale=st.floats(1e-3, 1e3), seed=st.integers(0, 999))
def test_quant_roundtrip_property(rows, cols, scale, seed):
    x = jax.random.normal(jax.random.PRNGKey(seed), (rows, cols)) * scale
    q, s, meta = quantize_for_offload(x)
    xr = dequantize_from_offload(q, s, meta)
    assert xr.shape == x.shape
    # per-block error bound: absmax/127 per element
    err = np.abs(np.asarray(xr) - np.asarray(x))
    bound = np.max(np.abs(np.asarray(x))) / 127.0 + 1e-7
    assert err.max() <= bound * 1.01


def test_quant_matches_numpy_ref():
    x = jax.random.normal(KEY, (37, 129)) * 3
    q, s, meta = quantize_for_offload(x)
    q2, s2, meta2 = ref.quantize_blocked_ref(np.asarray(x))
    np.testing.assert_array_equal(np.asarray(q).reshape(-1),
                                  q2.reshape(-1))
    xr = ref.dequantize_blocked_ref(np.asarray(q), np.asarray(s), meta2)
    np.testing.assert_allclose(
        xr, np.asarray(dequantize_from_offload(q, s, meta)), rtol=1e-6)


def test_flash_inside_model_forward():
    """Kernel path wired through the attention block (prefill/serving)."""
    import dataclasses

    from repro.configs import get_config
    from repro.models.attention import attention_block
    from repro.models.layers import ParamBuilder
    from repro.models.attention import init_attention

    cfg = get_config("tinyllama-1.1b").reduced()
    b = ParamBuilder(KEY, jnp.float32)
    init_attention(b, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                   cfg.head_dim, cfg.qkv_bias)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 96, cfg.d_model))
    pos = jnp.broadcast_to(jnp.arange(96), (2, 96))
    y0 = attention_block(b.params, x, pos, cfg=cfg)
    cfg2 = dataclasses.replace(cfg, use_flash_kernel=True)
    y1 = attention_block(b.params, x, pos, cfg=cfg2)
    np.testing.assert_allclose(np.asarray(y0), np.asarray(y1),
                               rtol=5e-4, atol=5e-4)
