"""Algorithm 2 (GPU memory peak analysis) unit tests."""

from repro.core.access import (AccessSequence, Operator, TensorKind,
                               TensorSpec)
from repro.core.peak_analysis import analyze, unroll, vanilla_peak
from repro.core.plan import EventType, ScheduleEvent, SchedulingPlan

from helpers import synthetic_chain


def tiny_seq():
    """op0: (in) -> a (100B); op1: a -> b (200B); op2: a,b -> out (50B)."""
    tensors = {
        "in": TensorSpec("in", 10, kind=TensorKind.INPUT),
        "a": TensorSpec("a", 100),
        "b": TensorSpec("b", 200),
        "out": TensorSpec("out", 50, kind=TensorKind.OUTPUT),
    }
    ops = [
        Operator(0, "op0", ("in",), ("a",), latency=1.0),
        Operator(1, "op1", ("a",), ("b",), latency=1.0),
        Operator(2, "op2", ("a", "b"), ("out",), latency=1.0),
    ]
    return AccessSequence("t", ops, tensors, initial_resident=["in"])


def test_hand_computed_peak():
    seq = tiny_seq()
    rep = analyze([seq])
    # in freed after op0; during op2 (t∈[2,3)): a + b + out co-resident
    assert rep.peak_bytes == 100 + 200 + 50
    ids = rep.mpt_ids()
    assert set(ids) >= {"a", "b", "out"}


def test_vanilla_no_free_is_higher_or_equal():
    seq = synthetic_chain(n_ops=12, seed=3)
    assert vanilla_peak(seq, free_at_last_use=False) >= \
        analyze([seq]).peak_bytes


def test_updated_param_aliases_storage():
    tensors = {
        "p": TensorSpec("p", 1000, kind=TensorKind.PARAM),
        "g": TensorSpec("g", 1000, kind=TensorKind.GRAD),
        "p_new": TensorSpec("p_new", 1000, kind=TensorKind.PARAM,
                            updates="p"),
    }
    ops = [
        Operator(0, "fwd", ("p",), ("g",), latency=1.0),
        Operator(1, "upd", ("p", "g"), ("p_new",), latency=1.0),
    ]
    seq = AccessSequence("t", ops, tensors, initial_resident=["p"])
    rep = analyze([seq])
    # p_new reuses p's storage: peak = p + g, NOT p + g + p_new
    assert rep.peak_bytes == 2000


def test_swap_events_change_peak():
    seq = tiny_seq()
    base = analyze([seq]).peak_bytes
    plan = SchedulingPlan(job_id="t")
    # swap `a` out right after op1 consumed it, back before op2
    plan.add(ScheduleEvent(EventType.SWAP_OUT, "a", "t", trigger_op=1,
                           delta=0.0, start=1.0, end=1.5, size_bytes=100))
    plan.add(ScheduleEvent(EventType.SWAP_IN, "a", "t", trigger_op=1,
                           delta=0.4, start=1.9, end=2.0, size_bytes=100,
                           target_op=2))
    rep = analyze([seq], plans={"t": plan})
    # 'a' absent during (1.5, 2.0) but b alloc at t2 and out at t3 —
    # peak at t3: in + a + b + out unchanged... but at 2.0 a returns, so
    # peak is the same interval; a was only out between its uses
    assert rep.peak_bytes <= base


def test_multi_job_merge_offsets():
    s1 = synthetic_chain(n_ops=6, job_id="j1", seed=1)
    s2 = synthetic_chain(n_ops=6, job_id="j2", seed=2)
    together = analyze([s1, s2]).peak_bytes
    apart = analyze([s1, s2],
                    offsets={"j2": s1.iteration_time * 2}).peak_bytes
    assert apart <= together
    assert analyze([s1]).peak_bytes <= together


def test_unroll_keeps_persistent_identity():
    seq = synthetic_chain(n_ops=4, job_id="u", seed=5)
    u2 = unroll(seq, 2)
    assert len(u2.operators) == 2 * len(seq.operators)
    # param appears once (shared storage); activations duplicated
    assert "p0" in u2.tensors
    assert "a0~0" in u2.tensors and "a0~1" in u2.tensors


def test_peak_time_and_timeline_monotonic_bytes():
    seq = synthetic_chain(n_ops=8, seed=7)
    rep = analyze([seq])
    assert rep.peak_time >= 0
    peak_seen = max(m for _, m in rep.timeline)
    assert peak_seen == rep.peak_bytes
