"""Distribution tests that need >1 device: run in a subprocess with
XLA_FLAGS (the main test process keeps the default single device, per the
dry-run contract)."""
import os
import subprocess
import sys
import textwrap


SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_with_devices(code: str, n: int = 8, timeout: int = 420) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n}"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env,
                         timeout=timeout)
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"
    return out.stdout


def test_sharded_train_step_runs():
    out = run_with_devices("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_config
        from repro.models.registry import get_model
        from repro.launch.mesh import make_mesh
        from repro.launch.sharding import MeshRules, use_rules
        from repro.launch.steps import TrainStepConfig, build_train_step, opt_state_for
        from repro.configs.base import ShapeSpec

        cfg = get_config("tinyllama-1.1b").reduced()
        api = get_model(cfg)
        mesh = make_mesh((4, 2), ("data", "model"))
        rules = MeshRules(mesh, cfg=cfg)
        params, axes = api.init(jax.random.PRNGKey(0))
        p_shard = rules.param_shardings(axes)
        params = jax.tree.map(jax.device_put, params, p_shard)
        opt = opt_state_for(params)
        batch = api.input_specs(ShapeSpec("s", 64, 8, "train"), abstract=False)
        step = build_train_step(api, rules, TrainStepConfig())
        jitted = jax.jit(step, donate_argnums=(0, 1))
        p2, o2, m = jitted(params, opt, batch)
        l1 = float(m["loss"])
        p3, o3, m2 = jitted(p2, o2, batch)
        assert np.isfinite(l1) and np.isfinite(float(m2["loss"]))
        assert float(m2["loss"]) < l1 * 1.2
        print("SHARDED_OK", l1, float(m2["loss"]))
    """)
    assert "SHARDED_OK" in out


def test_sharded_equals_single_device():
    out = run_with_devices("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_config
        from repro.models.registry import get_model
        from repro.launch.mesh import make_mesh
        from repro.launch.sharding import MeshRules
        from repro.launch.steps import build_prefill_step
        from repro.configs.base import ShapeSpec

        cfg = get_config("moonshot-v1-16b-a3b").reduced()
        cfg.moe_impl = "scatter"
        cfg.capacity_factor = 8.0
        api = get_model(cfg)
        params, axes = api.init(jax.random.PRNGKey(0))
        batch = api.input_specs(ShapeSpec("s", 64, 4, "prefill"), abstract=False)
        # single device
        logits0 = jax.jit(lambda p, b: api.forward(p, b)[0])(params, batch)
        # sharded
        mesh = make_mesh((2, 4), ("data", "model"))
        rules = MeshRules(mesh, cfg=cfg)
        p_shard = rules.param_shardings(axes)
        ps = jax.tree.map(jax.device_put, params, p_shard)
        step = build_prefill_step(api, rules)
        logits1 = jax.jit(step)(ps, batch)
        np.testing.assert_allclose(np.asarray(logits0, np.float32),
                                   np.asarray(logits1, np.float32),
                                   rtol=3e-2, atol=3e-3)
        print("EQUAL_OK")
    """)
    assert "EQUAL_OK" in out


def test_compressed_psum_collective():
    out = run_with_devices("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.launch.mesh import make_mesh
        from repro.optim.compression import compressed_psum_mean

        mesh = make_mesh((4,), ("pod",))
        x = jax.random.normal(jax.random.PRNGKey(0), (4, 4096))

        def f(xs):
            return compressed_psum_mean(xs, "pod")

        y = jax.jit(jax.shard_map(f, mesh=mesh, in_specs=P("pod"),
                                  out_specs=P("pod")))(x)
        want = jnp.broadcast_to(jnp.mean(x, axis=0, keepdims=True), x.shape)
        err = float(jnp.max(jnp.abs(y - want)))
        bound = float(jnp.max(jnp.abs(x))) / 127 * 2
        assert err <= bound, (err, bound)
        print("PSUM_OK", err)
    """)
    assert "PSUM_OK" in out


def test_elastic_reshard_8_to_4():
    out = run_with_devices("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_config
        from repro.models.registry import get_model
        from repro.launch.mesh import make_mesh
        from repro.launch.sharding import MeshRules
        from repro.runtime.elastic import reshard_state

        cfg = get_config("tinyllama-1.1b").reduced()
        api = get_model(cfg)
        params, axes = api.init(jax.random.PRNGKey(0))
        mesh8 = make_mesh((4, 2), ("data", "model"))
        rules8 = MeshRules(mesh8, cfg=cfg)
        p8 = jax.tree.map(jax.device_put, params,
                          rules8.param_shardings(axes))
        # shrink to 4 devices (preemption took half the fleet)
        mesh4 = make_mesh((2, 2), ("data", "model"))
        p4, rules4 = reshard_state(p8, axes, mesh4, cfg=cfg)
        for a, b in zip(jax.tree.leaves(p8), jax.tree.leaves(p4)):
            np.testing.assert_allclose(np.asarray(a, np.float32),
                                       np.asarray(b, np.float32))
        print("ELASTIC_OK")
    """)
    assert "ELASTIC_OK" in out


def test_production_mesh_shapes():
    out = run_with_devices("""
        from repro.launch.mesh import make_production_mesh
        m1 = make_production_mesh(multi_pod=False)
        assert dict(m1.shape) == {"data": 16, "model": 16}
        m2 = make_production_mesh(multi_pod=True)
        assert dict(m2.shape) == {"pod": 2, "data": 16, "model": 16}
        print("MESH_OK")
    """, n=512)
    assert "MESH_OK" in out
