"""Moonlight-16B-A3B-style MoE: 64 experts top-6, MHA (kv=16)
[hf:moonshotai/Moonlight-16B-A3B]."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="moonshot-v1-16b-a3b",
    arch_family="moe",
    n_layers=48, d_model=2048, n_heads=16, n_kv_heads=16, head_dim=128,
    d_ff=1408, vocab_size=163840,
    n_experts=64, top_k=6, moe_d_ff=1408,
    mlp_act="swiglu", rope_theta=5e4,
    citation="hf:moonshotai/Moonlight-16B-A3B; hf",
)
