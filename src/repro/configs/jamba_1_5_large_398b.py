"""Jamba-1.5-Large — hybrid Mamba+attention 1:7 interleave with MoE
[arXiv:2403.19887].  72 layers = 9 scanned super-blocks of 8 layers
(attention at in-block index 3, the rest Mamba-2-style SSD mixers); MoE on
every odd in-block layer (16 experts, top-2)."""
from .base import LayerSpec, ModelConfig

_BLOCK = tuple(
    LayerSpec(mixer=("attn" if i == 3 else "mamba"),
              ffn=("moe" if i % 2 == 1 else "dense"))
    for i in range(8))

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b",
    arch_family="hybrid",
    n_layers=72, d_model=8192, n_heads=64, n_kv_heads=8, head_dim=128,
    d_ff=24576, vocab_size=65536,
    n_experts=16, top_k=2, moe_d_ff=24576,
    block=_BLOCK,
    ssm_state=16, ssm_head_dim=64, ssm_expand=2, ssm_chunk=256,
    mlp_act="swiglu", rope_theta=1e4,
    citation="arXiv:2403.19887; hf",
)
