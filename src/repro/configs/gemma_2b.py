"""Gemma 2B — GeGLU, head_dim 256, MQA (kv=1), tied embeddings
[arXiv:2403.08295]."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="gemma-2b",
    arch_family="dense",
    n_layers=18, d_model=2048, n_heads=8, n_kv_heads=1, head_dim=256,
    d_ff=16384, vocab_size=256000,
    mlp_act="geglu", tie_embeddings=True, rope_theta=1e4,
    citation="arXiv:2403.08295; hf",
)
