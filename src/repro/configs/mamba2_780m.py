"""Mamba-2 780M — attention-free SSD [arXiv:2405.21060]."""
from .base import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="mamba2-780m",
    arch_family="ssm",
    n_layers=48, d_model=1536, n_heads=0, n_kv_heads=0, head_dim=0,
    d_ff=0, vocab_size=50280,
    block=(LayerSpec(mixer="mamba", ffn="none"),),
    ssm_state=128, ssm_head_dim=64, ssm_expand=2, ssm_chunk=256,
    citation="arXiv:2405.21060; unverified",
)
