"""Whisper-base — encoder-decoder speech backbone [arXiv:2212.04356].
Conv frontend is a STUB: input_specs() provides precomputed frame
embeddings; decoder length = seq_len // enc_seq_ratio (DESIGN.md §5)."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-base",
    arch_family="audio",
    n_layers=6, d_model=512, n_heads=8, n_kv_heads=8, head_dim=64,
    d_ff=2048, vocab_size=51865,
    enc_dec=True, n_enc_layers=6, enc_seq_ratio=4,
    frontend="audio_stub",
    mlp_act="gelu", qkv_bias=True, rope_theta=1e4,
    citation="arXiv:2212.04356; unverified",
)
