"""Minitron-4B — pruned Nemotron (squared-ReLU MLP) [arXiv:2407.14679]."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="minitron-4b",
    arch_family="dense",
    n_layers=32, d_model=3072, n_heads=24, n_kv_heads=8, head_dim=128,
    d_ff=9216, vocab_size=256000,
    mlp_act="relu2", rope_theta=1e4,
    citation="arXiv:2407.14679; hf",
)
