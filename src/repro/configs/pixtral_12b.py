"""Pixtral-12B — Mistral-Nemo-style backbone + ViT frontend STUB
[hf:mistralai/Pixtral-12B-2409]: input_specs() provides 1024 precomputed
patch embeddings prepended to the text tokens."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="pixtral-12b",
    arch_family="vlm",
    n_layers=40, d_model=5120, n_heads=32, n_kv_heads=8, head_dim=128,
    d_ff=14336, vocab_size=131072,
    frontend="vision_stub", n_patches=1024,
    mlp_act="swiglu", rope_theta=1e6,
    citation="hf:mistralai/Pixtral-12B-2409; unverified",
)
