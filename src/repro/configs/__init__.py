"""Assigned-architecture configs (``--arch <id>``).  All ten architectures
from the assignment, exact dims as specified; reduced smoke variants via
``get_config(name).reduced()``."""
from typing import Dict, List

from .base import (ALL_SHAPES, DECODE_32K, LONG_500K, PREFILL_32K, TRAIN_4K,
                   LayerSpec, ModelConfig, ShapeSpec, shapes_for,
                   skipped_shapes_for)

from . import (gemma_2b, jamba_1_5_large_398b, kimi_k2_1t_a32b, mamba2_780m,
               minitron_4b, moonshot_v1_16b_a3b, pixtral_12b, qwen2_5_14b,
               tinyllama_1_1b, whisper_base)

_MODULES = [jamba_1_5_large_398b, whisper_base, kimi_k2_1t_a32b,
            moonshot_v1_16b_a3b, gemma_2b, qwen2_5_14b, minitron_4b,
            tinyllama_1_1b, pixtral_12b, mamba2_780m]

CONFIGS: Dict[str, ModelConfig] = {m.CONFIG.name: m.CONFIG for m in _MODULES}


def get_config(name: str) -> ModelConfig:
    import dataclasses
    if name not in CONFIGS:
        raise KeyError(f"unknown arch {name!r}; available: {sorted(CONFIGS)}")
    return dataclasses.replace(CONFIGS[name])


def list_configs() -> List[str]:
    return [m.CONFIG.name for m in _MODULES]
