"""Kimi K2 — trillion-parameter MoE, 384 experts top-8 [arXiv:2501.kimi2].
Layer 0 is dense (d_ff=18432, per the released config), the remaining 60
layers are MoE with one shared expert.  head_dim = 7168/64 = 112 per the
assignment's GQA spec (the release uses MLA; the spec overrides — noted in
DESIGN.md §5 and in the roofline: 112 is not 128-aligned on the MXU)."""
from .base import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="kimi-k2-1t-a32b",
    arch_family="moe",
    n_layers=61, d_model=7168, n_heads=64, n_kv_heads=8, head_dim=112,
    d_ff=2048, vocab_size=163840,
    n_experts=384, top_k=8, moe_d_ff=2048, n_shared_experts=1,
    prefix=(LayerSpec(mixer="attn", ffn="dense"),), prefix_d_ff=18432,
    mlp_act="swiglu", rope_theta=5e4,
    citation="arXiv:2501.kimi2; unverified",
)
