"""TinyLlama 1.1B — llama2 architecture, small [arXiv:2401.02385]."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="tinyllama-1.1b",
    arch_family="dense",
    n_layers=22, d_model=2048, n_heads=32, n_kv_heads=4, head_dim=64,
    d_ff=5632, vocab_size=32000,
    mlp_act="swiglu", rope_theta=1e4,
    citation="arXiv:2401.02385; hf",
)
