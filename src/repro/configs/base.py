"""Model / parallelism configuration system.

Every assigned architecture is a `ModelConfig` in its own module under
`repro.configs`; `repro.configs.get_config(name)` resolves them, and
`--arch <id>` on the launchers selects one.  Reduced (smoke-test) variants
come from `ModelConfig.reduced()`.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple


@dataclasses.dataclass(frozen=True)
class LayerSpec:
    """One layer inside a (possibly repeating) super-block."""
    mixer: str = "attn"      # "attn" | "mamba"
    ffn: str = "dense"       # "dense" | "moe" | "none"


@dataclasses.dataclass
class ModelConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                      # 0 → d_model // n_heads
    arch_family: str = "dense"             # dense|moe|ssm|hybrid|audio|vlm

    # ---- MoE ----
    n_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0                      # per-expert hidden (0 → d_ff)
    n_shared_experts: int = 0
    capacity_factor: float = 1.0
    moe_impl: str = "scatter"              # "scatter" (EP) | "dense" (tiny ref)

    # ---- layer layout ----
    # the model is `n_repeats` copies (scanned) of `block` (unrolled inside),
    # optionally preceded by `prefix` layers (unscanned).
    block: Tuple[LayerSpec, ...] = ()
    prefix: Tuple[LayerSpec, ...] = ()
    prefix_d_ff: int = 0                   # d_ff for prefix dense layers

    # ---- encoder-decoder (whisper) ----
    enc_dec: bool = False
    n_enc_layers: int = 0
    enc_seq_ratio: int = 4                 # enc len = seq_len // ratio

    # ---- SSM (Mamba-2 / SSD) ----
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_chunk: int = 256
    ssm_conv_width: int = 4

    # ---- misc architecture ----
    mlp_act: str = "swiglu"                # swiglu|geglu|gelu
    qkv_bias: bool = False
    rope_theta: float = 1e4
    norm_eps: float = 1e-6
    sliding_window: int = 0                # 0 = full attention
    tie_embeddings: bool = False

    # ---- modality frontend (STUB: input_specs provides embeddings) ----
    frontend: str = "none"                 # none|audio_stub|vision_stub
    n_patches: int = 0                     # vlm: image patches prepended

    # ---- numerics / runtime ----
    dtype: str = "bfloat16"
    remat: str = "block"                   # none|block|full — see launch.steps
    attn_chunk: int = 1024                 # q-chunk for memory-efficient attn
    loss_chunk: int = 0                    # fused unembed+CE seq chunk (0=off)
    use_flash_kernel: bool = False         # Pallas path (TPU)
    citation: str = ""

    # ------------------------------------------------------------------
    def __post_init__(self):
        if self.head_dim == 0 and self.n_heads:
            self.head_dim = self.d_model // self.n_heads
        if self.moe_d_ff == 0:
            self.moe_d_ff = self.d_ff
        if not self.block:
            ffn = "moe" if self.n_experts else ("none" if self.d_ff == 0 else "dense")
            mixer = "mamba" if self.arch_family == "ssm" else "attn"
            self.block = (LayerSpec(mixer=mixer, ffn=ffn),)

    # ------------------------------------------------------------------
    @property
    def padded_vocab(self) -> int:
        """Vocab rounded up to 256 so the unembedding shards over the
        16-way model axis (Megatron-style vocab padding)."""
        return -(-self.vocab_size // 256) * 256

    @property
    def n_repeats(self) -> int:
        body = self.n_layers - len(self.prefix)
        assert body % len(self.block) == 0, \
            f"{self.name}: {body} layers not divisible by block {len(self.block)}"
        return body // len(self.block)

    @property
    def is_attention_free(self) -> bool:
        specs = list(self.block) + list(self.prefix)
        return all(s.mixer != "attn" for s in specs)

    @property
    def has_subquadratic_path(self) -> bool:
        """long_500k eligibility: SSM / hybrid archs (decode is state-bound
        or linear in the small attention fraction)."""
        return self.arch_family in ("ssm", "hybrid")

    # ---- parameter counting -------------------------------------------
    def _mixer_params(self, spec: LayerSpec) -> int:
        d, hd = self.d_model, self.head_dim
        if spec.mixer == "attn":
            q = d * self.n_heads * hd
            kv = 2 * d * self.n_kv_heads * hd
            o = self.n_heads * hd * d
            bias = (self.n_heads + 2 * self.n_kv_heads) * hd if self.qkv_bias else 0
            return q + kv + o + bias
        # mamba2: in_proj (d -> 2*dinner + 2*ngroups*state + nheads), conv,
        # out_proj, A/D/dt
        dinner = self.ssm_expand * d
        nheads = dinner // self.ssm_head_dim
        in_p = d * (2 * dinner + 2 * self.ssm_state + nheads)
        conv = (dinner + 2 * self.ssm_state) * self.ssm_conv_width
        out_p = dinner * d
        return in_p + conv + out_p + 3 * nheads

    def _ffn_params(self, spec: LayerSpec, d_ff: Optional[int] = None) -> int:
        d = self.d_model
        if spec.ffn == "none":
            return 0
        if spec.ffn == "moe":
            f = self.moe_d_ff
            gates = 3 if self.mlp_act in ("swiglu", "geglu") else 2
            per = gates * d * f
            return (self.n_experts + self.n_shared_experts) * per + d * self.n_experts
        f = d_ff or self.d_ff
        gates = 3 if self.mlp_act in ("swiglu", "geglu") else 2
        return gates * d * f

    def param_count(self) -> int:
        d = self.d_model
        total = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        norms_per_layer = 2 * d

        def layer_params(spec: LayerSpec, d_ff=None) -> int:
            return self._mixer_params(spec) + self._ffn_params(spec, d_ff) \
                + norms_per_layer

        for spec in self.prefix:
            total += layer_params(spec, self.prefix_d_ff or self.d_ff)
        for _ in range(self.n_repeats):
            for spec in self.block:
                total += layer_params(spec)
        if self.enc_dec:
            # encoder stack + per-decoder-layer cross attention
            enc_spec = LayerSpec(mixer="attn", ffn="dense")
            total += self.n_enc_layers * layer_params(enc_spec)
            total += self.n_layers * (2 * self.d_model * self.n_heads
                                      * self.head_dim + self.d_model
                                      * self.n_heads * self.head_dim
                                      + self.d_model * self.n_kv_heads
                                      * self.head_dim)
        return total

    def active_param_count(self) -> int:
        """Per-token active parameters (MoE: top-k + shared experts only)."""
        if not self.n_experts:
            return self.param_count()
        d = self.d_model
        total = self.vocab_size * d * (1 if self.tie_embeddings else 2)

        def layer_active(spec: LayerSpec, d_ff=None) -> int:
            mix = self._mixer_params(spec)
            if spec.ffn == "moe":
                gates = 3 if self.mlp_act in ("swiglu", "geglu") else 2
                per = gates * d * self.moe_d_ff
                ffn = (self.top_k + self.n_shared_experts) * per
            else:
                ffn = self._ffn_params(spec, d_ff)
            return mix + ffn + 2 * d

        for spec in self.prefix:
            total += layer_active(spec, self.prefix_d_ff or self.d_ff)
        for _ in range(self.n_repeats):
            for spec in self.block:
                total += layer_active(spec)
        return total

    # ------------------------------------------------------------------
    def reduced(self, **overrides) -> "ModelConfig":
        """Smoke-test variant of the same family: tiny dims, same structure."""
        block = self.block
        prefix = self.prefix
        n_layers = len(prefix) + len(block)  # one super-block
        small = dataclasses.replace(
            self,
            name=self.name + "-reduced",
            n_layers=n_layers,
            d_model=min(self.d_model, 128),
            n_heads=min(self.n_heads, 4),
            n_kv_heads=min(self.n_kv_heads, 2),
            head_dim=32,
            d_ff=min(self.d_ff, 256) if self.d_ff else 0,
            moe_d_ff=min(self.moe_d_ff, 128) if self.moe_d_ff else 0,
            prefix_d_ff=min(self.prefix_d_ff, 256) if self.prefix_d_ff else 0,
            vocab_size=min(self.vocab_size, 512),
            n_experts=min(self.n_experts, 8) if self.n_experts else 0,
            top_k=min(self.top_k, 2) if self.top_k else 0,
            n_enc_layers=min(self.n_enc_layers, 2),
            ssm_state=min(self.ssm_state, 32) if self.ssm_state else 0,
            ssm_head_dim=16 if self.ssm_state else self.ssm_head_dim,
            ssm_chunk=32,
            n_patches=min(self.n_patches, 16),
            attn_chunk=64,
            dtype="float32",
        )
        for k, v in overrides.items():
            object.__setattr__(small, k, v)
        return small


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    """One assigned input-shape cell."""
    name: str
    seq_len: int
    global_batch: int
    kind: str            # "train" | "prefill" | "decode"


TRAIN_4K = ShapeSpec("train_4k", 4096, 256, "train")
PREFILL_32K = ShapeSpec("prefill_32k", 32768, 32, "prefill")
DECODE_32K = ShapeSpec("decode_32k", 32768, 128, "decode")
LONG_500K = ShapeSpec("long_500k", 524288, 1, "decode")

ALL_SHAPES: Tuple[ShapeSpec, ...] = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)


def shapes_for(cfg: ModelConfig) -> List[ShapeSpec]:
    """The assigned shape set, with the mandated skips (DESIGN.md §5):
    long_500k only for SSM/hybrid archs."""
    out = [TRAIN_4K, PREFILL_32K, DECODE_32K]
    if cfg.has_subquadratic_path:
        out.append(LONG_500K)
    return out


def skipped_shapes_for(cfg: ModelConfig) -> List[Tuple[ShapeSpec, str]]:
    if cfg.has_subquadratic_path:
        return []
    return [(LONG_500K, "SKIP(full-attn): pure full-attention arch; "
                        "assignment mandates skip for long_500k")]
