"""ServingEngine: the real continuous-batching runtime over a jitted model.

The monolithic ``launch/serve.py::main`` is dismantled into the
maxtext-shaped serving surface:

    eng = ServingEngine("tinyllama-1.1b", max_sequences=4, max_len=64)
    pr = eng.prefill(prompt_tokens, rid="r0")   # compute burst, first token
    eng.insert(pr, slot=0)                      # splice into the batch cache
    eng.generate()                              # one decode round

and a batch driver, ``serve(requests, ...)``, that wires the engine's
side-effect hooks into a :class:`~repro.serving.session.ServeSession` so
the *same* loop that simulates a served mix in virtual time drives real
jitted decode steps here — evictions copy a sequence's occupied cache
blocks to host, its decode turn restores them first.

Why restoration is a correctness requirement and not just accounting: the
model's ``decode_step`` takes one scalar index, so every decode turn
writes position ``index`` of *every* batch row.  A slot sitting out a turn
whose index falls inside its valid prefix gets that prefix scribbled.  The
engine therefore keeps a host-side shadow copy of every live slot that is
not in the decoding cohort and restores it before the slot's own turn —
which is exactly the evict/prefetch motion the residency pass schedules,
applied to the real arrays.  Batch rows are computationally independent,
so a served run under memory pressure is **bit-identical** to the
unpressured run (pinned by tests/test_serving.py).
"""

from __future__ import annotations

import dataclasses
import zlib
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import get_config
from ..core.engine import MemoryEngine
from ..core.plan import MachineProfile
from ..launch.mesh import make_host_mesh
from ..launch.sharding import MeshRules, use_rules
from ..launch.steps import build_serve_step
from ..models.registry import get_model
from .residency import SeqView, build_horizon
from .session import SeqState, ServeHooks, ServeReport, ServeSession
from .traces import Request


@dataclasses.dataclass
class _LeafAxes:
    """Which axes of one cache leaf index the batch slot / the position."""

    batch: Optional[int]
    length: Optional[int]


def _cache_leaf_axes(api, batch: int, max_len: int) -> List[_LeafAxes]:
    """Classify cache leaves by diffing abstract shapes: the axis that
    changes when ``batch`` grows is the slot axis, the one that changes
    with ``max_len`` is the position axis (absent for positionless state
    like SSM carries).  Shape-diffing keeps this arch-agnostic."""
    def shapes(b, m):
        tree = jax.eval_shape(lambda: api.init_cache(b, m)[0])
        return [x.shape for x in jax.tree_util.tree_leaves(tree)]

    base = shapes(batch, max_len)
    bgrow = shapes(batch + 1, max_len)
    lgrow = shapes(batch, max_len + 1)

    def diff_axis(a, b):
        for i, (x, y) in enumerate(zip(a, b)):
            if x != y:
                return i
        return None

    return [_LeafAxes(batch=diff_axis(s, sb), length=diff_axis(s, sl))
            for s, sb, sl in zip(base, bgrow, lgrow)]


def _slot_index(spec: _LeafAxes, ndim: int, slot, lo: int, hi: int):
    idx: List = [slice(None)] * ndim
    if spec.batch is not None:
        idx[spec.batch] = slot
    if spec.length is not None:
        idx[spec.length] = slice(lo, hi)
    return tuple(idx)


@dataclasses.dataclass
class PrefillResult:
    """A prefilled prompt: its single-slot cache, ready to splice in."""

    rid: str
    prompt: np.ndarray
    prompt_len: int
    first_token: int
    cache: object            # batch-1 cache pytree, positions [0, prompt_len)


class ServingEngine:
    """Continuous-batching decode over one shared jitted cache."""

    def __init__(self, arch: str = "tinyllama-1.1b", *, reduced: bool = True,
                 max_sequences: int = 4, max_len: int = 64, seed: int = 0):
        cfg = get_config(arch)
        if reduced:
            cfg = cfg.reduced()
            if cfg.n_experts:
                cfg.moe_impl = "dense"
        if cfg.enc_dec:
            raise ValueError(
                "ServingEngine serves decoder-only LMs; encoder-decoder "
                "arches still go through the forward/decode driver")
        self.cfg = cfg
        self.api = get_model(cfg)
        self.max_sequences = int(max_sequences)
        self.max_len = int(max_len)
        try:
            self.rules: Optional[MeshRules] = MeshRules(make_host_mesh(),
                                                        cfg=cfg)
        except Exception:  # mesh API unavailable: run unsharded
            self.rules = None
        self.params, _ = self.api.init(jax.random.PRNGKey(seed))
        self.cache, _ = self.api.init_cache(self.max_sequences, self.max_len)
        serve_step = build_serve_step(self.api, self.rules)
        if self.rules is not None:
            with use_rules(self.rules):
                self._step = jax.jit(serve_step)
        else:
            self._step = jax.jit(serve_step)
        self._axes = _cache_leaf_axes(self.api, self.max_sequences,
                                      self.max_len)
        # per-token-per-sequence cache bytes, from abstract shapes only
        one, _ = self.api.abstract_cache(1, 1)
        self.bytes_per_token = int(sum(
            int(np.prod(x.shape)) * np.dtype(x.dtype).itemsize
            for x in jax.tree_util.tree_leaves(one)))
        # live serving state
        self._tok = np.zeros((self.max_sequences, 1), np.int32)
        self._states: Dict[str, SeqState] = {}
        self._outputs: Dict[str, List[int]] = {}
        self._shadow: Dict[str, Dict[int, np.ndarray]] = {}
        self._channel = None   # bound by serve() for transfer accounting
        self._batch_kv = False  # serve(batch_transfers=True) flips this

    # -- deterministic prompts (rid-keyed, run-independent) -------------

    def prompt_for(self, rid: str, prompt_len: int) -> np.ndarray:
        key = jax.random.PRNGKey(zlib.crc32(rid.encode()) & 0x7FFFFFFF)
        hi = min(self.cfg.vocab_size, 64)
        return np.asarray(
            jax.random.randint(key, (prompt_len,), 0, hi, jnp.int32))

    # -- cache slicing --------------------------------------------------

    def _leaves(self):
        return jax.tree_util.tree_flatten(self.cache)

    def _save_slot(self, s: SeqState) -> int:
        """Shadow-copy a slot's occupied cache region to host.  Returns
        bytes copied; no-op if already shadowed."""
        if s.rid in self._shadow:
            return 0
        leaves, _ = self._leaves()
        saved: Dict[int, np.ndarray] = {}
        nbytes = 0
        for i, (leaf, spec) in enumerate(zip(leaves, self._axes)):
            if spec.batch is None:
                continue
            idx = _slot_index(spec, leaf.ndim, s.slot, 0, s.pos)
            arr = np.asarray(leaf[idx])
            saved[i] = arr
            nbytes += arr.nbytes
        self._shadow[s.rid] = saved
        return nbytes

    def _restore_slot(self, s: SeqState) -> int:
        """Write a slot's shadow copy back into the shared cache (its
        device region was scribbled by other cohorts' turns)."""
        saved = self._shadow.pop(s.rid, None)
        if saved is None:
            return 0
        leaves, treedef = self._leaves()
        nbytes = 0
        for i, arr in saved.items():
            spec = self._axes[i]
            idx = _slot_index(spec, leaves[i].ndim, s.slot, 0, s.pos)
            leaves[i] = leaves[i].at[idx].set(jnp.asarray(arr))
            nbytes += arr.nbytes
        self.cache = jax.tree_util.tree_unflatten(treedef, leaves)
        return nbytes

    def _reduced_axis(self, spec: _LeafAxes) -> Optional[int]:
        """Where the length axis lands once the batch axis is removed —
        the axis the per-slot shadow slices along."""
        if spec.length is None:
            return None
        return spec.length - (1 if spec.batch < spec.length else 0)

    def _save_slots(self, states: List[SeqState]) -> int:
        """Batched shadow save: one ``kv_block_gather`` launch per cache
        leaf moves every slot's row at once, then per-state occupied
        prefixes are sliced out in the legacy per-slot shadow format (so
        either restore path can consume them).  Returns bytes copied."""
        todo = [s for s in states if s.rid not in self._shadow]
        if not todo:
            return 0
        if len(todo) == 1:
            return self._save_slot(todo[0])
        from ..kernels.kv_block_copy import kv_block_gather
        leaves, _ = self._leaves()
        slots = jnp.asarray([s.slot for s in todo], jnp.int32)
        shadows: Dict[str, Dict[int, np.ndarray]] = {s.rid: {} for s in todo}
        nbytes = 0
        for i, (leaf, spec) in enumerate(zip(leaves, self._axes)):
            if spec.batch is None:
                continue
            moved = jnp.moveaxis(leaf, spec.batch, 0)
            pool = moved.reshape(moved.shape[0], -1)
            rows = kv_block_gather(pool, slots).reshape(
                (len(todo),) + moved.shape[1:])
            red = self._reduced_axis(spec)
            for k, s in enumerate(todo):
                row = rows[k]
                if red is not None:
                    sl: List = [slice(None)] * row.ndim
                    sl[red] = slice(0, s.pos)
                    row = row[tuple(sl)]
                arr = np.asarray(row)
                shadows[s.rid][i] = arr
                nbytes += arr.nbytes
        for s in todo:
            self._shadow[s.rid] = shadows[s.rid]
        return nbytes

    def _restore_slots(self, states: List[SeqState]) -> int:
        """Batched shadow restore: per cache leaf, gather the cohort's
        current rows in one launch, patch each occupied prefix from its
        shadow, and scatter the rows back in one launch.  Suffix regions
        round-trip their own bytes, so the result is bit-identical to
        per-slot ``_restore_slot`` calls.  Returns bytes written."""
        todo = [s for s in states if s.rid in self._shadow]
        if not todo:
            return 0
        if len(todo) == 1:
            return self._restore_slot(todo[0])
        from ..kernels.kv_block_copy import kv_block_gather, kv_block_scatter
        leaves, treedef = self._leaves()
        slots = jnp.asarray([s.slot for s in todo], jnp.int32)
        nbytes = 0
        for i, spec in enumerate(self._axes):
            if spec.batch is None:
                continue
            moved = jnp.moveaxis(leaves[i], spec.batch, 0)
            pool = moved.reshape(moved.shape[0], -1)
            rows = kv_block_gather(pool, slots).reshape(
                (len(todo),) + moved.shape[1:])
            red = self._reduced_axis(spec)
            for k, s in enumerate(todo):
                arr = self._shadow[s.rid].get(i)
                if arr is None:
                    continue
                nbytes += arr.nbytes
                if red is None:
                    rows = rows.at[k].set(jnp.asarray(arr))
                else:
                    sl = [slice(None)] * rows.ndim
                    sl[0] = k
                    sl[red + 1] = slice(0, s.pos)
                    rows = rows.at[tuple(sl)].set(jnp.asarray(arr))
            newpool = kv_block_scatter(pool, slots,
                                       rows.reshape(len(todo), -1))
            leaves[i] = jnp.moveaxis(newpool.reshape(moved.shape), 0,
                                     spec.batch)
        for s in todo:
            self._shadow.pop(s.rid, None)
        self.cache = jax.tree_util.tree_unflatten(treedef, leaves)
        return nbytes

    def _xfer(self, fn):
        if self._channel is not None:
            return self._channel.transfer(fn)
        return fn()

    # -- the maxtext-shaped surface -------------------------------------

    def prefill(self, prompt: Sequence[int], rid: str = "r?") -> PrefillResult:
        """Run one prompt through a fresh single-slot cache (the compute
        burst); the last position's logits give the first sampled token."""
        prompt = np.asarray(prompt, np.int32)
        cache, _ = self.api.init_cache(1, self.max_len)
        logits = None
        for i in range(len(prompt)):
            batch = {"tokens": jnp.asarray(prompt[i:i + 1][None, :])}
            logits, cache = self._step(self.params, cache, batch,
                                       jnp.int32(i))
        first = int(jnp.argmax(logits[0, -1]))
        return PrefillResult(rid=rid, prompt=prompt, prompt_len=len(prompt),
                             first_token=first, cache=cache)

    def insert(self, pr: PrefillResult, slot: int,
               state: Optional[SeqState] = None) -> None:
        """Splice a prefilled sequence into the shared cache at ``slot``."""
        src_axes = _cache_leaf_axes(self.api, 1, self.max_len)
        src_leaves = jax.tree_util.tree_leaves(pr.cache)
        leaves, treedef = self._leaves()
        for i, (leaf, spec, src, sspec) in enumerate(
                zip(leaves, self._axes, src_leaves, src_axes)):
            if spec.batch is None:
                continue
            dst = _slot_index(spec, leaf.ndim, slot, 0, pr.prompt_len)
            srcidx = _slot_index(sspec, src.ndim, 0, 0, pr.prompt_len)
            leaves[i] = leaf.at[dst].set(src[srcidx])
        self.cache = jax.tree_util.tree_unflatten(treedef, leaves)
        self._tok[slot, 0] = pr.first_token
        self._outputs.setdefault(pr.rid, []).append(pr.first_token)
        if state is None:
            state = SeqState(rid=pr.rid, slot=slot, prompt_len=pr.prompt_len,
                             gen_len=0, priority=1.0, arrival=0.0,
                             pos=pr.prompt_len, generated=1)
        self._states[pr.rid] = state

    def _decode_turn(self, cohort: List[SeqState], start_pos: int,
                     chunk: int) -> None:
        """One chunked decode turn: restore the cohort's shadows, shadow
        every other live slot (their region [start_pos, start_pos+chunk)
        is about to be scribbled), then step ``chunk`` tokens."""
        cohort_ids = {s.rid for s in cohort}
        others = [st for rid, st in self._states.items()
                  if rid not in cohort_ids]
        if self._batch_kv:
            # batched data path: one gather/scatter launch set per turn
            # moves the whole cohort's blocks (and shadows every bystander)
            self._xfer(lambda: self._restore_slots(cohort))
            self._xfer(lambda: self._save_slots(others))
        else:
            for s in cohort:
                self._xfer(lambda s=s: self._restore_slot(s))
            for st in others:
                self._xfer(lambda st=st: self._save_slot(st))
        for k in range(chunk):
            idx = start_pos + k
            batch = {"tokens": jnp.asarray(self._tok)}
            logits, self.cache = self._step(self.params, self.cache, batch,
                                            jnp.int32(idx))
            nxt = np.asarray(jnp.argmax(logits[:, -1], axis=-1),
                             dtype=np.int32)
            for s in cohort:
                self._tok[s.slot, 0] = nxt[s.slot]
                self._outputs[s.rid].append(int(nxt[s.slot]))

    def generate(self) -> Dict[str, int]:
        """One decode round for the front position-aligned group (the
        standalone surface; ``serve`` drives turns via the session).
        Returns the token each served sequence produced."""
        views = [SeqView(rid=s.rid, slot=s.slot, pos=s.pos,
                         remaining=max(s.remaining, 1),
                         last_served=s.last_served)
                 for s in self._states.values()]
        if not views:
            return {}
        horizon = build_horizon(views)
        front = horizon.turns[0]
        cohort = [self._states[r] for r in front.rids]
        self._decode_turn(cohort, front.pos, 1)
        out = {}
        for s in cohort:
            s.pos += 1
            s.generated += 1
            s.remaining = max(s.remaining - 1, 0)
            out[s.rid] = self._outputs[s.rid][-1]
        return out

    # -- session hooks --------------------------------------------------

    def _hooks(self) -> ServeHooks:
        def on_insert(s: SeqState) -> None:
            pr = self.prefill(self.prompt_for(s.rid, s.prompt_len), rid=s.rid)
            self.insert(pr, s.slot, state=s)

        def on_evict(rid: str) -> None:
            s = self._states.get(rid)
            if s is not None:
                self._xfer(lambda: self._save_slot(s))

        def on_prefetch(rid: str) -> None:
            # data motion is deferred to the slot's decode turn (the
            # restore there is what guarantees bit-identity); the ledger
            # side already accounted the transfer in virtual time
            pass

        def on_finish(s: SeqState) -> None:
            self._shadow.pop(s.rid, None)
            self._states.pop(s.rid, None)
            self._tok[s.slot, 0] = 0

        return ServeHooks(on_insert=on_insert, on_decode=self._decode_turn,
                          on_evict=on_evict, on_prefetch=on_prefetch,
                          on_finish=on_finish)

    # -- the batch driver -----------------------------------------------

    def serve(self, requests: Sequence[Request], *,
              budget_bytes: Optional[int] = None, schedule: bool = True,
              block_tokens: int = 4,
              engine: Optional[MemoryEngine] = None,
              oversubscription: float = 2.5,
              job_id: str = "serve",
              batch_transfers: bool = False,
              ) -> Tuple[ServeReport, Dict[str, List[int]]]:
        """Serve a request trace for real: a ServeSession makes every
        residency decision against the shared ledger; this engine's hooks
        execute them on the jitted model.  Returns the session report and
        the per-request generated token ids."""
        mem = engine or MemoryEngine(profile=MachineProfile(),
                                     capacity_bytes=None, trace=True)
        self._states.clear()
        self._outputs.clear()
        self._shadow.clear()
        self._tok[:] = 0
        self._channel = mem.channel
        self._batch_kv = bool(batch_transfers)
        try:
            session = ServeSession(
                requests, engine=mem, job_id=job_id,
                max_sequences=self.max_sequences,
                bytes_per_token=self.bytes_per_token,
                block_tokens=block_tokens, budget_bytes=budget_bytes,
                schedule=schedule, oversubscription=oversubscription,
                batch_transfers=batch_transfers,
                hooks=self._hooks())
            report = session.run()
        finally:
            self._channel = None
            self._batch_kv = False
        return report, {rid: list(toks) for rid, toks in
                        self._outputs.items()}
