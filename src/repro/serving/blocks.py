"""BlockTable: per-sequence KV-cache blocks as schedulable ledger storages.

The serving plane's memory unit is the *KV block*: ``block_tokens`` worth of
one sequence's cache, named ``kv/<rid>/b<i>`` and registered in the shared
:class:`~repro.core.engine.DeviceLedger` under the serving job's id.  That
makes a sequence's cache footprint visible to everything the training plane
already has — per-job accounting, the global peak, OOM counting, the
BudgetArbiter's slices — without a parallel bookkeeping world.

Residency invariants the table maintains (pinned by tests/test_serving.py):

* bytes are conserved: ``device_bytes(rid) + host_bytes(rid)`` equals the
  total allocated for the sequence across any evict/prefetch interleaving;
* eviction is idempotent per block (ledger keying makes double-free a
  no-op) and every evicted block has exactly one host entry;
* ``release(rid)`` on sequence finish leaks nothing: no ledger residency,
  no host entry, no table row survives it.

All device-byte mutations go through one :class:`JobLedgerView`, so the
cross-job invariants (global peak, capacity OOM events) cannot be bypassed
from the serving side.
"""

from __future__ import annotations

import math
from typing import Dict, List, Optional

from ..core.engine import EngineTrace, JobLedgerView


class BlockTable:
    """Maps live sequences to their KV-cache blocks in the device ledger."""

    def __init__(self, view: JobLedgerView, bytes_per_token: int,
                 block_tokens: int = 4,
                 trace: Optional[EngineTrace] = None):
        if bytes_per_token <= 0 or block_tokens <= 0:
            raise ValueError("bytes_per_token and block_tokens must be > 0")
        self.view = view
        self.bytes_per_token = int(bytes_per_token)
        self.block_tokens = int(block_tokens)
        self.block_bytes = self.bytes_per_token * self.block_tokens
        self.trace = trace
        # rid -> ordered block storage ids; parallel host-residency set
        self._blocks: Dict[str, List[str]] = {}
        self._tokens: Dict[str, int] = {}
        self._host: set = set()
        # lifetime counters the session's report distills
        self.swapped_out_bytes = 0
        self.swapped_in_bytes = 0

    # -- naming ---------------------------------------------------------

    @staticmethod
    def storage_id(rid: str, i: int) -> str:
        return f"kv/{rid}/b{i}"

    def blocks_of(self, rid: str) -> List[str]:
        return list(self._blocks.get(rid, ()))

    def n_blocks(self, rid: str) -> int:
        return len(self._blocks.get(rid, ()))

    def blocks_for_tokens(self, n_tokens: int) -> int:
        return int(math.ceil(n_tokens / self.block_tokens)) if n_tokens else 0

    def footprint(self, n_tokens: int) -> int:
        """Device bytes a ``n_tokens``-deep cache occupies (whole blocks —
        the page granularity the ledger accounts at)."""
        return self.blocks_for_tokens(n_tokens) * self.block_bytes

    # -- queries --------------------------------------------------------

    def sequences(self) -> List[str]:
        return sorted(self._blocks)

    def device_bytes(self, rid: str) -> int:
        return sum(self.block_bytes for st in self._blocks.get(rid, ())
                   if self.view.ledger.is_resident(self.view.job_id, st))

    def host_bytes(self, rid: str) -> int:
        return sum(self.block_bytes for st in self._blocks.get(rid, ())
                   if st in self._host)

    def total_bytes(self, rid: str) -> int:
        return len(self._blocks.get(rid, ())) * self.block_bytes

    def is_resident(self, rid: str) -> bool:
        """True when every block of ``rid`` is on the device."""
        blocks = self._blocks.get(rid, ())
        led = self.view.ledger
        return all(led.is_resident(self.view.job_id, st) for st in blocks)

    def host_blocks(self, rid: str) -> List[str]:
        return [st for st in self._blocks.get(rid, ()) if st in self._host]

    # -- mutations ------------------------------------------------------

    def grow(self, rid: str, n_tokens: int,
             t: Optional[float] = None) -> List[str]:
        """Ensure ``rid`` owns blocks covering ``n_tokens`` tokens; newly
        created blocks are allocated device-resident.  Returns the new
        block storage ids (empty when the last block still has room)."""
        blocks = self._blocks.setdefault(rid, [])
        self._tokens[rid] = max(self._tokens.get(rid, 0), int(n_tokens))
        need = self.blocks_for_tokens(n_tokens)
        new: List[str] = []
        while len(blocks) < need:
            st = self.storage_id(rid, len(blocks))
            blocks.append(st)
            self.view.alloc(st, self.block_bytes, t)
            new.append(st)
        return new

    def evict(self, rid: str, t: Optional[float] = None) -> int:
        """Swap every device-resident block of ``rid`` out to host.
        Returns the bytes freed on device."""
        freed = 0
        for st in self._blocks.get(rid, ()):
            if not self.view.ledger.is_resident(self.view.job_id, st):
                continue
            if self.trace is not None:
                self.trace.record("swap_out", self.view.job_id, st)
            freed += self.view.free(st, t)
            self._host.add(st)
        self.swapped_out_bytes += freed
        return freed

    def prefetch(self, rid: str, t: Optional[float] = None) -> int:
        """Swap every host-parked block of ``rid`` back in.  Returns the
        bytes restored to device."""
        restored = 0
        for st in self._blocks.get(rid, ()):
            if st not in self._host:
                continue
            if self.trace is not None:
                self.trace.record("swap_in", self.view.job_id, st)
            self.view.alloc(st, self.block_bytes, t)
            self._host.discard(st)
            restored += self.block_bytes
        self.swapped_in_bytes += restored
        return restored

    def evict_many(self, rids: List[str],
                   t: Optional[float] = None) -> int:
        """Swap several sequences out as one cohort (a single coalesced
        channel booking on the caller's side).  Per-block ledger motion
        and trace records are identical to sequential ``evict`` calls in
        rid order — batching changes the transfer *timing*, never the
        residency decisions.  Returns total device bytes freed."""
        return sum(self.evict(rid, t) for rid in rids)

    def prefetch_many(self, rids: List[str],
                      t: Optional[float] = None) -> int:
        """Swap several sequences' host-parked blocks back in as one
        cohort; trace/ledger-identical to sequential ``prefetch`` calls.
        Returns total bytes restored to device."""
        return sum(self.prefetch(rid, t) for rid in rids)

    def release(self, rid: str, t: Optional[float] = None) -> int:
        """Sequence finished: free device blocks, drop host copies, forget
        the row.  Returns the device bytes freed; afterwards no trace of
        ``rid`` remains anywhere (the no-leak invariant)."""
        freed = 0
        for st in self._blocks.pop(rid, ()):
            freed += self.view.free(st, t)
            self._host.discard(st)
            if self.trace is not None:
                self.trace.record("release", self.view.job_id, st)
        self._tokens.pop(rid, None)
        return freed
