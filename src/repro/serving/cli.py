"""Serving CLI: thin wrapper over :class:`ServingEngine.serve`.

    PYTHONPATH=src python -m repro.serving.cli --arch tinyllama-1.1b \
        --requests 8 --trace burst --prompt-len 8 --gen 8 --budget-kb 24

Replaces the monolithic ``repro.launch.serve`` driver: the engine owns the
model and the cache, the session owns the continuous-batching loop, and
this module only parses flags and prints the report.
"""
from __future__ import annotations

import argparse

from ..core.engine import MemoryEngine
from ..core.plan import MachineProfile
from .engine import ServingEngine
from .traces import TRACE_NAMES, make_trace


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description="continuous-batching LM serving")
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--max-sequences", type=int, default=4,
                    help="batch slots in the shared decode cache")
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--gen", type=int, default=8)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--trace", default="burst", choices=TRACE_NAMES)
    ap.add_argument("--block-tokens", type=int, default=4)
    ap.add_argument("--budget-kb", type=int, default=0,
                    help="serving KV budget (KiB); 0 = unbudgeted")
    ap.add_argument("--no-schedule", action="store_true",
                    help="disable KV residency scheduling (baseline)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    eng = ServingEngine(args.arch, reduced=args.reduced,
                        max_sequences=args.max_sequences,
                        max_len=args.prompt_len + args.gen, seed=args.seed)
    requests = make_trace(args.trace, args.requests, seed=args.seed,
                          prompt_len=args.prompt_len, gen_len=args.gen)
    budget = args.budget_kb * 1024 or None
    mem = MemoryEngine(profile=MachineProfile(), capacity_bytes=budget,
                       trace=True)
    report, outputs = eng.serve(requests, budget_bytes=budget,
                                schedule=not args.no_schedule,
                                block_tokens=args.block_tokens, engine=mem)
    print(f"[serve] arch={eng.cfg.name} requests={report.n_requests} "
          f"served={report.served} tokens={report.tokens_generated} "
          f"({report.tokens_per_s:.1f} tok/s virtual)")
    print(f"[serve] ttft p99={report.ttft_p99 * 1e3:.2f}ms "
          f"oom_events={report.oom_events} peak={report.peak_bytes}B "
          f"evictions={report.evictions} prefetches={report.prefetches} "
          f"stall={report.stall_time * 1e3:.2f}ms")
    print("[serve] sample generations (token ids):")
    for rid in sorted(outputs)[:2]:
        print(f"    {rid}: {outputs[rid][:16]}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
