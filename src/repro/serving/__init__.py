"""The serving plane: continuous-batching LM decode with KV-cache
residency scheduling.

Per-sequence KV-cache blocks are schedulable tensors in the shared
``DeviceLedger``/``DmaChannel`` machinery (``BlockTable``), planned per
decode turn against a rolling request-driven horizon (``KvResidencyPass``)
by one loop (``ServeSession``) that runs either in virtual time or — via
hooks — drives the real jitted :class:`ServingEngine`.
"""

from .blocks import BlockTable
from .engine import PrefillResult, ServingEngine
from .residency import (DecodeHorizon, DecodeTurn, KvResidencyPass, SeqView,
                        TurnPlan, build_horizon)
from .session import SeqState, ServeHooks, ServeReport, ServeSession
from .traces import Request, TRACE_NAMES, make_trace

__all__ = [
    "BlockTable", "DecodeHorizon", "DecodeTurn", "KvResidencyPass",
    "PrefillResult", "Request", "SeqState", "SeqView", "ServeHooks",
    "ServeReport", "ServeSession", "ServingEngine", "TRACE_NAMES",
    "TurnPlan", "build_horizon", "make_trace",
]
