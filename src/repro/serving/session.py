"""ServeSession: the continuous-batching decode loop, virtual- or real-time.

One loop serves both runtimes (the training plane's sim/executor split,
re-done for serving):

* run it bare and it is the **virtual-time simulator** — KV blocks move
  through the shared ``DeviceLedger``/``DmaChannel`` on a virtual clock,
  producing tokens/sec, TTFT percentiles, OOM counts and an engine trace
  without touching a model;
* hand it :class:`ServeHooks` and every decision additionally drives the
  real :class:`~repro.serving.engine.ServingEngine` — actual prefill,
  cohort decode steps, and physical cache-block copies to host and back.

Because all residency decisions (cohort choice, evictions, fetches,
prefetches) are made *here*, from ledger state the two runtimes share by
construction, the sim and the real engine replay identical decision
traces — the serving analogue of ``tests/test_engine_parity.py``.

The loop per tick:

1. arrivals land in the prefill **admission queue** (PR 7's
   ``AdmissionQueue``) — a prefill burst is admitted the way a training
   job is: predicted KV footprint reserved against the serving capacity,
   priority order with greedy backfill;
2. admitted requests take free slots: prefill runs (a compute burst),
   the prompt's blocks are allocated, TTFT is the first token out;
3. :class:`~repro.serving.residency.KvResidencyPass` plans the next
   decode turn against the rolling horizon; the session executes it —
   evictions and fetches serialize on the DMA channel before the turn,
   lookahead prefetches overlap the turn's compute;
4. finished sequences release every block (no leak) and free their slot
   and admission reservation, which can admit waiting prefills.
"""

from __future__ import annotations

import dataclasses
from collections import deque
from typing import Callable, Dict, List, Optional, Sequence

from ..core.engine import MemoryEngine
from ..service.queue import AdmissionQueue
from .blocks import BlockTable
from .residency import KvResidencyPass, SeqView
from .traces import Request


@dataclasses.dataclass
class SeqState:
    """One live sequence: a request bound to a batch slot."""

    rid: str
    slot: int
    prompt_len: int
    gen_len: int
    priority: float
    arrival: float
    pos: int = 0              # tokens in the cache
    generated: int = 0
    remaining: int = 0        # generation tokens still wanted
    ready_at: float = 0.0     # earliest turn start (prefetch completion)
    last_served: float = -1.0
    ttft: Optional[float] = None


@dataclasses.dataclass
class ServeHooks:
    """Side-effect callbacks the real engine wires in.  All optional; the
    bare virtual session passes none."""

    on_insert: Optional[Callable[[SeqState], None]] = None
    on_decode: Optional[Callable[[List[SeqState], int, int], None]] = None
    on_evict: Optional[Callable[[str], None]] = None
    on_prefetch: Optional[Callable[[str], None]] = None
    on_finish: Optional[Callable[[SeqState], None]] = None


@dataclasses.dataclass
class ServeReport:
    """What one served mix measured."""

    job_id: str
    n_requests: int
    served: int
    rejected: List[str]
    tokens_generated: int
    total_time: float
    tokens_per_s: float
    ttft: Dict[str, float]
    ttft_mean: float
    ttft_p99: float
    queue_wait: Dict[str, float]
    admission_order: List[str]
    peak_bytes: int           # serving job's ledger peak
    oom_events: int           # device-wide OOM events during the run
    stall_time: float         # decode turns delayed by late swap-ins
    evictions: int
    prefetches: int
    swapped_out_bytes: int
    swapped_in_bytes: int
    turns: int
    stats: List[dict] = dataclasses.field(default_factory=list)
    # coalesced-transfer accounting (0 for a non-batching session)
    batched_transfers: int = 0
    saved_fixup_s: float = 0.0


def _quantile(xs: Sequence[float], q: float) -> float:
    if not xs:
        return float("inf")
    s = sorted(xs)
    if len(s) == 1:
        return s[0]
    i = q * (len(s) - 1)
    lo = int(i)
    hi = min(lo + 1, len(s) - 1)
    return s[lo] + (s[hi] - s[lo]) * (i - lo)


class ServeSession:
    def __init__(self, requests: Sequence[Request], *,
                 engine: MemoryEngine,
                 job_id: str = "serve",
                 max_sequences: int = 4,
                 bytes_per_token: int = 1024,
                 block_tokens: int = 4,
                 budget_bytes: Optional[int] = None,
                 schedule: bool = True,
                 oversubscription: float = 2.5,
                 decode_round_time: float = 1e-3,
                 prefill_token_time: float = 1e-4,
                 batch_transfers: bool = False,
                 hooks: Optional[ServeHooks] = None,
                 progress: Optional[Callable[[dict], None]] = None):
        self.requests = sorted(requests, key=lambda r: (r.arrival, r.rid))
        self.engine = engine
        self.job_id = job_id
        self.max_sequences = max_sequences
        self.view = engine.ledger.view(job_id, budget_bytes)
        self.table = BlockTable(self.view, bytes_per_token,
                                block_tokens, trace=engine.trace)
        self.budget = budget_bytes if schedule else None
        self.schedule = schedule
        self.resident_pass = KvResidencyPass(self.table, self.budget)
        # prefill-burst admission: reservations are full KV footprints
        # against the serving capacity; the residency scheduler is what
        # makes oversubscription (> 1x device slice live at once) safe
        self.admission: Optional[AdmissionQueue] = None
        if schedule and budget_bytes is not None:
            cap = int(budget_bytes * oversubscription)
            self.admission = AdmissionQueue(cap)
        self.decode_round_time = decode_round_time
        self.prefill_token_time = prefill_token_time
        # batched data path: direction-grouped cohorts book ONE coalesced
        # channel slot (single fixup latency + dma_batch_overhead per
        # extra member) instead of one full transfer setup per rid.
        # Off by default — the per-rid path's timing is pinned by the
        # committed serving-scenario baselines.
        self.batch_transfers = bool(batch_transfers)
        self.batched_transfers = 0
        self.saved_fixup_s = 0.0
        self.hooks = hooks or ServeHooks()
        self.progress = progress
        self._bw = max(engine.profile.host_link_bw, 1.0)

    # -- helpers --------------------------------------------------------

    def _call(self, fn: Optional[Callable], *args) -> None:
        if fn is not None:
            fn(*args)

    def _xfer(self, nbytes: int) -> float:
        return nbytes / self._bw + self.engine.profile.host_link_latency

    def _acquire_group(self, t: float, pairs, direction: str):
        """Book one coalesced channel slot for a same-direction cohort of
        (rid, nbytes) transfers.  Returns the batch (start, end)."""
        if not pairs:
            return t, t
        prof = self.engine.profile
        start, end = self.engine.channel.acquire_batch(
            t, [nb / self._bw for _, nb in pairs],
            fixup=prof.host_link_latency, direction=direction,
            member_overhead=prof.dma_batch_overhead)
        if len(pairs) > 1:
            self.batched_transfers += 1
            self.saved_fixup_s += (len(pairs) - 1) * max(
                prof.host_link_latency - prof.dma_batch_overhead, 0.0)
        return start, end

    # -- the loop -------------------------------------------------------

    def run(self) -> ServeReport:
        t = 0.0
        pending = deque(self.requests)
        by_rid = {r.rid: r for r in self.requests}
        admitted: deque = deque()
        live: Dict[str, SeqState] = {}
        free_slots = list(range(self.max_sequences))
        ttft: Dict[str, float] = {}
        queue_wait: Dict[str, float] = {}
        admission_order: List[str] = []
        rejected: List[str] = []
        in_queue: set = set()
        tokens = 0
        stall = 0.0
        evictions = prefetches = turns = 0
        stats: List[dict] = []

        def arrive(now: float) -> None:
            while pending and pending[0].arrival <= now + 1e-12:
                r = pending.popleft()
                if self.admission is None:
                    admitted.append(r.rid)
                    queue_wait[r.rid] = 0.0
                    continue
                predicted = self.table.footprint(r.total_tokens)
                try:
                    self.admission.push(r.rid, predicted,
                                        priority=r.priority, source="serve",
                                        enqueued_at=r.arrival)
                    in_queue.add(r.rid)
                except ValueError:
                    # a request that can NEVER fit the serving capacity:
                    # rejected, same tolerance as the daemon's inbox
                    rejected.append(r.rid)

        def admit(now: float) -> None:
            if self.admission is None:
                return
            for qj in self.admission.pop_admissible(now):
                admitted.append(qj.job_id)
                admission_order.append(qj.job_id)
                in_queue.discard(qj.job_id)
                queue_wait[qj.job_id] = now - by_rid[qj.job_id].arrival

        def finish(s: SeqState, now: float) -> None:
            self.table.release(s.rid, now)
            if self.admission is not None:
                self.admission.release(s.rid)
            live.pop(s.rid, None)
            free_slots.append(s.slot)
            free_slots.sort()
            self._call(self.hooks.on_finish, s)

        while pending or in_queue or admitted or live:
            arrive(t)
            admit(t)

            # slot assignment + prefill bursts (serialized compute)
            while admitted and free_slots:
                rid = admitted.popleft()
                r = by_rid[rid]
                slot = free_slots.pop(0)
                burst = r.prompt_len * self.prefill_token_time
                if self.budget is not None and self.batch_transfers:
                    # batched path: the SAME victims the per-rid loop
                    # picks, but their copies-out coalesce into one
                    # booking that overlaps the prefill compute burst —
                    # the prompt's blocks are grown only after both the
                    # burst AND the batch end, so the ledger frees always
                    # precede the allocation they make room for
                    need = self.table.footprint(r.prompt_len)
                    victims = []
                    projected = self.view.used
                    for v in sorted(live.values(),
                                    key=lambda s: s.last_served):
                        if projected + need <= self.budget:
                            break
                        nbytes = self.table.device_bytes(v.rid)
                        if nbytes <= 0:
                            continue
                        victims.append((v.rid, nbytes))
                        projected -= nbytes
                    if victims:
                        _, end = self._acquire_group(t, victims, "out")
                        self.table.evict_many([v for v, _ in victims], end)
                        for vrid, _ in victims:
                            self._call(self.hooks.on_evict, vrid)
                        evictions += len(victims)
                        t = max(t + burst, end)
                    else:
                        t += burst
                elif self.budget is not None:
                    # make room for the prompt's blocks BEFORE the burst:
                    # admission oversubscribes the budget on purpose, so a
                    # prefill landing between decode turns must push the
                    # coldest resident sequences to host first (the decode
                    # path's eviction planning only runs per turn)
                    need = self.table.footprint(r.prompt_len)
                    for v in sorted(live.values(),
                                    key=lambda s: s.last_served):
                        if self.view.used + need <= self.budget:
                            break
                        nbytes = self.table.device_bytes(v.rid)
                        if nbytes <= 0:
                            continue
                        _, end = self.engine.channel.acquire(
                            t, self._xfer(nbytes))
                        self.table.evict(v.rid, end)
                        self._call(self.hooks.on_evict, v.rid)
                        evictions += 1
                        t = max(t, end)
                    t += burst
                else:
                    t += burst
                s = SeqState(rid=rid, slot=slot, prompt_len=r.prompt_len,
                             gen_len=r.gen_len, priority=r.priority,
                             arrival=r.arrival, pos=r.prompt_len,
                             generated=1, remaining=r.gen_len - 1,
                             last_served=t)
                self.table.grow(rid, r.prompt_len, t)
                ttft[rid] = t - r.arrival   # first token: end of prefill
                tokens += 1
                live[rid] = s
                self._call(self.hooks.on_insert, s)
                if s.remaining <= 0:
                    finish(s, t)

            if not live:
                if pending:
                    t = max(t, pending[0].arrival)
                    continue
                if admitted or in_queue:
                    # waiting on reservations that only free on finish —
                    # with nothing live this cannot progress; bail rather
                    # than spin (callers see the shortfall in `served`)
                    break
                continue

            plan = self.resident_pass.plan_turn(
                [SeqView(rid=s.rid, slot=s.slot, pos=s.pos,
                         remaining=s.remaining, last_served=s.last_served)
                 for s in live.values()])
            if plan is None:
                break
            cohort = [live[v.rid] for v in plan.cohort]

            # evictions serialize on the channel before the turn; device
            # bytes are freed when the copy-out completes
            turn_start = t
            cohorts = (self.resident_pass.transfer_cohorts(plan)
                       if self.batch_transfers else None)
            if cohorts is not None:
                ev = cohorts["evict"]
                if ev:
                    _, end = self._acquire_group(t, ev, "out")
                    self.table.evict_many([r for r, _ in ev], end)
                    for erid, _ in ev:
                        self._call(self.hooks.on_evict, erid)
                    evictions += len(ev)
                    turn_start = max(turn_start, end)
                fe = cohorts["fetch"]
                if fe:
                    start, end = self._acquire_group(turn_start, fe, "in")
                    self.table.prefetch_many([r for r, _ in fe], start)
                    for frid, _ in fe:
                        self._call(self.hooks.on_prefetch, frid)
                    prefetches += len(fe)
                    turn_start = max(turn_start, end)
            else:
                for rid in plan.evict:
                    nbytes = self.table.device_bytes(rid)
                    _, end = self.engine.channel.acquire(
                        t, self._xfer(nbytes))
                    self.table.evict(rid, end)
                    self._call(self.hooks.on_evict, rid)
                    evictions += 1
                    turn_start = max(turn_start, end)
                # mandatory fetches: the cohort's turn came while its
                # blocks were parked on host — a late prefetch is a stall
                for rid in plan.fetch:
                    nbytes = self.table.host_bytes(rid)
                    start, end = self.engine.channel.acquire(
                        turn_start, self._xfer(nbytes))
                    self.table.prefetch(rid, start)
                    self._call(self.hooks.on_prefetch, rid)
                    prefetches += 1
                    turn_start = max(turn_start, end)
            ready = max((s.ready_at for s in cohort), default=0.0)
            turn_start = max(turn_start, ready)
            stall += turn_start - t

            # the decode turn: grow blocks, step the cohort
            chunk = plan.chunk
            start_pos = cohort[0].pos
            for s in cohort:
                self.table.grow(s.rid, s.pos + chunk, turn_start)
            self._call(self.hooks.on_decode, cohort, start_pos, chunk)
            turn_end = turn_start + chunk * self.decode_round_time
            for s in cohort:
                s.pos += chunk
                s.generated += chunk
                s.remaining -= chunk
                s.last_served = turn_start
            tokens += chunk * len(cohort)
            turns += 1
            rec = self.engine.recorder
            if rec is not None:
                # observability tap: the decode turn as a span on the
                # serve job's track plus the KV residency counter — the
                # block-level transfers already flow through the hub
                rec.span("decode_turn", turn_start,
                         turn_end - turn_start, job_id=self.job_id,
                         cat="serve", cohort=len(cohort), chunk=chunk,
                         start_pos=start_pos)
                rec.counter(f"kv_resident:{self.job_id}", turn_end,
                            self.engine.ledger.job_bytes(self.job_id))

            # lookahead prefetches overlap the turn's compute: book the
            # channel now so the next group's blocks land before its turn
            if cohorts is not None:
                pf = cohorts["prefetch"]
                if pf:
                    start, end = self._acquire_group(turn_start, pf, "in")
                    self.table.prefetch_many([r for r, _ in pf], start)
                    for prid, _ in pf:
                        if prid in live:
                            live[prid].ready_at = max(
                                live[prid].ready_at, end)
                        self._call(self.hooks.on_prefetch, prid)
                    prefetches += len(pf)
            else:
                for rid in plan.prefetch:
                    nbytes = self.table.host_bytes(rid)
                    start, end = self.engine.channel.acquire(
                        turn_start, self._xfer(nbytes))
                    self.table.prefetch(rid, start)
                    if rid in live:
                        live[rid].ready_at = max(live[rid].ready_at, end)
                    self._call(self.hooks.on_prefetch, rid)
                    prefetches += 1

            for s in list(cohort):
                if s.remaining <= 0:
                    finish(s, turn_end)
            t = turn_end
            row = {"t": t, "cohort": len(cohort), "chunk": chunk,
                   "used": self.view.used, "peak": self.view.peak,
                   "oom_events": self.engine.ledger.oom_events,
                   "live": len(live)}
            stats.append(row)
            if self.progress is not None:
                self.progress(row)
            arrive(t)
            admit(t)

        waits = list(ttft.values())
        return ServeReport(
            job_id=self.job_id, n_requests=len(self.requests),
            served=len(ttft), rejected=rejected,
            tokens_generated=tokens, total_time=t,
            tokens_per_s=tokens / t if t > 0 else 0.0,
            ttft=ttft,
            ttft_mean=sum(waits) / len(waits) if waits else float("inf"),
            ttft_p99=_quantile(waits, 0.99),
            queue_wait=queue_wait, admission_order=admission_order,
            peak_bytes=self.view.peak,
            oom_events=self.engine.ledger.oom_events,
            stall_time=stall, evictions=evictions, prefetches=prefetches,
            swapped_out_bytes=self.table.swapped_out_bytes,
            swapped_in_bytes=self.table.swapped_in_bytes,
            turns=turns, stats=stats,
            batched_transfers=self.batched_transfers,
            saved_fixup_s=self.saved_fixup_s)
