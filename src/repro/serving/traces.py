"""Deterministic request-arrival traces for the serving plane.

A trace is a list of :class:`Request` objects — arrival time, prompt and
generation lengths, priority — produced by a *named* generator so a
``JobSpec`` can reference the workload shape over the wire ("steady",
"burst", "poisson") instead of shipping the request list itself.  All
generators are seeded and pure: the same (name, n, seed, shape params)
always yields byte-identical traces, which is what makes the sim/real
parity tests and the benchmark gate reproducible.
"""

from __future__ import annotations

import dataclasses
import random
from typing import List


@dataclasses.dataclass(frozen=True)
class Request:
    """One serving request: arrives at ``arrival`` (virtual seconds),
    carries a ``prompt_len``-token prompt and wants ``gen_len`` generated
    tokens.  ``priority`` feeds the prefill-burst admission queue."""

    rid: str
    arrival: float
    prompt_len: int
    gen_len: int
    priority: float = 1.0

    @property
    def total_tokens(self) -> int:
        return self.prompt_len + self.gen_len


def _lengths(rng: random.Random, n: int, prompt_len: int, gen_len: int,
             uniform: bool) -> List[tuple]:
    """Per-request (prompt, gen) lengths.  ``uniform`` pins every request
    to the mean (the real engine's cohort decode needs position-aligned
    waves); otherwise lengths jitter +-50 % around the mean."""
    if uniform:
        return [(prompt_len, gen_len)] * n
    out = []
    for _ in range(n):
        p = max(1, int(prompt_len * (0.5 + rng.random())))
        g = max(1, int(gen_len * (0.5 + rng.random())))
        out.append((p, g))
    return out


def make_trace(name: str, n_requests: int, *, seed: int = 0,
               prompt_len: int = 8, gen_len: int = 8,
               mean_gap: float = 0.002, priority: float = 1.0,
               uniform_lengths: bool = True) -> List[Request]:
    """Build the named arrival trace.

    ``steady``  — one request every ``mean_gap`` seconds.
    ``burst``   — all requests arrive at t=0 (the prefill-burst admission
                  stressor: a flash crowd into a decode-heavy mix).
    ``poisson`` — exponential inter-arrival gaps with mean ``mean_gap``.
    """
    rng = random.Random(seed)
    lens = _lengths(rng, n_requests, prompt_len, gen_len, uniform_lengths)
    if name == "steady":
        arrivals = [i * mean_gap for i in range(n_requests)]
    elif name == "burst":
        arrivals = [0.0] * n_requests
    elif name == "poisson":
        t, arrivals = 0.0, []
        for _ in range(n_requests):
            arrivals.append(t)
            t += rng.expovariate(1.0 / mean_gap)
    else:
        raise ValueError(f"unknown request trace {name!r} "
                         "(known: steady, burst, poisson)")
    return [Request(rid=f"r{i}", arrival=arrivals[i], prompt_len=lens[i][0],
                    gen_len=lens[i][1], priority=priority)
            for i in range(n_requests)]


TRACE_NAMES = ("steady", "burst", "poisson")
