"""KvResidencyPass: plan KV-block evict/prefetch against the decode timeline.

Training passes (``core/passes.py``) plan against a *fixed iteration DAG*:
every tensor access is known up front, so a plan is a list of (trigger op,
delta) events replayed each iteration.  Serving breaks that assumption —
the timeline is a rolling, request-driven horizon: sequences arrive, grow a
block per ``block_tokens`` decoded tokens, and finish, so the planner runs
*per decode turn* over the current continuous-batching state instead of
once per plan version.

The decode timeline it plans against is the cohort rotation: live
sequences group by cache position (the model's decode step takes one scalar
index, so a cohort must be position-aligned), and groups take decode turns
round-robin, least-recently-served first.  Under budget pressure the pass

* caps the cohort at what fits the serving job's arbiter slice,
* evicts the *coldest* resident sequences — the ones whose next decode
  turn is farthest in the rotation (the serving analogue of TENSILE's
  largest-reuse-distance victim rule), and
* books prefetches on the shared ``DmaChannel`` for the *next* group in
  the rotation, overlapped with the current turn's compute so the blocks
  land before their decode turn starts (swap-in ahead of the access,
  paper §IV-B, with the trigger being a decode turn instead of an op).

The pass is pure: ``plan_turn`` reads table + sequence state and returns a
:class:`TurnPlan`; the session executes it.  Determinism here is what the
sim/real parity test pins — both runtimes replay identical decisions.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

from .blocks import BlockTable


@dataclasses.dataclass
class SeqView:
    """What the planner may know about one live sequence."""

    rid: str
    slot: int
    pos: int                 # tokens already in the cache (prompt + generated)
    remaining: int           # generation tokens still wanted
    last_served: float = -1.0  # virtual time of its group's last decode turn


@dataclasses.dataclass
class DecodeTurn:
    """One upcoming decode turn: a position-aligned group of sequences."""

    pos: int
    rids: List[str]


@dataclasses.dataclass
class DecodeHorizon:
    """The rolling lookahead: cohort groups in rotation order.  Index 0 is
    the turn being planned; higher indices are colder."""

    turns: List[DecodeTurn]

    def distance(self, rid: str) -> int:
        for i, turn in enumerate(self.turns):
            if rid in turn.rids:
                return i
        return len(self.turns)


@dataclasses.dataclass
class TurnPlan:
    """The pass's decision for one decode turn."""

    cohort: List[SeqView]            # sequences decoding this turn
    evict: List[str]                 # rids to swap out before the turn
    fetch: List[str]                 # cohort rids whose blocks MUST come
    #                                  back from host before the turn
    prefetch: List[str]              # lookahead rids swapped in during it
    chunk: int                       # tokens each cohort member decodes
    horizon: DecodeHorizon


def build_horizon(seqs: Sequence[SeqView]) -> DecodeHorizon:
    """Group live sequences by cache position; order groups by how long
    ago they were served (oldest first), then by position and lead slot —
    a deterministic round-robin rotation."""
    groups: Dict[int, List[SeqView]] = {}
    for s in seqs:
        groups.setdefault(s.pos, []).append(s)
    ordered = sorted(
        groups.values(),
        key=lambda g: (min(s.last_served for s in g), g[0].pos,
                       min(s.slot for s in g)))
    return DecodeHorizon(turns=[
        DecodeTurn(pos=g[0].pos, rids=[s.rid for s in sorted(
            g, key=lambda s: s.slot)]) for g in ordered])


class KvResidencyPass:
    """Plans block residency for one decode turn at a time."""

    def __init__(self, table: BlockTable, budget_bytes: Optional[int],
                 chunk_tokens: Optional[int] = None):
        self.table = table
        self.budget = budget_bytes
        self.chunk_tokens = chunk_tokens or table.block_tokens

    # -- per-sequence byte math ----------------------------------------

    def _working_set(self, s: SeqView, chunk: int) -> int:
        """Device bytes sequence ``s`` needs while decoding ``chunk``
        tokens: its whole cache (attention reads every position) plus the
        blocks the chunk grows into."""
        return self.table.footprint(s.pos + chunk)

    # -- the planning rule ---------------------------------------------

    def plan_turn(self, seqs: Sequence[SeqView]) -> Optional[TurnPlan]:
        """Decide the next decode turn.  Returns None when nothing is
        live.  Called once per turn by the session — the rolling-horizon
        replacement for a per-plan-version pipeline run."""
        live = [s for s in seqs if s.remaining > 0]
        if not live:
            return None
        horizon = build_horizon(live)
        by_rid = {s.rid: s for s in live}
        group = [by_rid[r] for r in horizon.turns[0].rids]
        chunk = min(self.chunk_tokens, min(s.remaining for s in group))

        # cohort: greedily take the group's sequences (slot order) while
        # their combined working set fits the budget; always at least one
        cohort: List[SeqView] = []
        need = 0
        for s in group:
            w = self._working_set(s, chunk)
            if cohort and self.budget is not None and need + w > self.budget:
                break
            cohort.append(s)
            need += w
        cohort_ids = {s.rid for s in cohort}
        chunk = min(chunk, min(s.remaining for s in cohort))

        # cohort members whose blocks were evicted while they were cold
        # must be fetched back before the turn — their access came due
        fetch = [s.rid for s in cohort if self.table.host_bytes(s.rid) > 0]
        if self.budget is None:
            return TurnPlan(cohort=cohort, evict=[], fetch=fetch,
                            prefetch=[], chunk=chunk, horizon=horizon)

        # project device usage through the turn: live bytes + the blocks
        # the chunk grows into + the mandatory fetches landing on device;
        # evict coldest resident non-cohort sequences until it fits
        growth = sum(max(self._working_set(s, chunk)
                         - self.table.device_bytes(s.rid)
                         - self.table.host_bytes(s.rid), 0) for s in cohort)
        projected = (self.table.view.used + growth
                     + sum(self.table.host_bytes(r) for r in fetch))
        victims = sorted(
            (s for s in live if s.rid not in cohort_ids
             and self.table.device_bytes(s.rid) > 0),
            key=lambda s: (-horizon.distance(s.rid), -s.slot))
        evict: List[str] = []
        for v in victims:
            if projected <= self.budget:
                break
            projected -= self.table.device_bytes(v.rid)
            evict.append(v.rid)

        # prefetch the next turn's group if its blocks are parked on host
        # and the post-eviction projection leaves room for them
        prefetch: List[str] = []
        for turn in horizon.turns[1:2]:
            for rid in turn.rids:
                hb = self.table.host_bytes(rid)
                if hb and rid not in evict \
                        and projected + hb <= self.budget:
                    prefetch.append(rid)
                    projected += hb
        return TurnPlan(cohort=cohort, evict=evict, fetch=fetch,
                        prefetch=prefetch, chunk=chunk, horizon=horizon)

    # -- batched transfer emission -------------------------------------

    def transfer_cohorts(self, plan: TurnPlan) -> Dict[str, list]:
        """Distill a :class:`TurnPlan` into direction-grouped transfer
        cohorts, ``{"evict"|"fetch"|"prefetch": [(rid, nbytes), ...]}``
        with zero-byte members dropped — each group is one coalesced
        ``DmaChannel.acquire_batch`` booking for a batching session
        (single fixup latency for the whole cohort)."""
        ev = [(r, self.table.device_bytes(r)) for r in plan.evict]
        fe = [(r, self.table.host_bytes(r)) for r in plan.fetch]
        pf = [(r, self.table.host_bytes(r)) for r in plan.prefetch]
        return {"evict": [(r, b) for r, b in ev if b > 0],
                "fetch": [(r, b) for r, b in fe if b > 0],
                "prefetch": [(r, b) for r, b in pf if b > 0]}
