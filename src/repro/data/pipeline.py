"""Data pipeline: deterministic sharded token streams with background
host prefetch.

Production shape: each host produces only ITS batch shard (`host_slice`),
the stream is seedable + checkpointable (the step counter is part of the
training checkpoint, so restart resumes mid-epoch deterministically), and a
double-buffering prefetch thread overlaps host data generation with device
compute (the host-side analogue of TENSILE's swap/compute overlap).

Sources: synthetic LM token stream (default — zipfian tokens with a simple
Markov structure so the loss actually decreases), or a memory-mapped token
file (np.memmap) for real corpora.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Dict, Iterator, Optional

import numpy as np


@dataclasses.dataclass
class DataConfig:
    seq_len: int = 512
    global_batch: int = 8
    vocab_size: int = 512
    seed: int = 0
    kind: str = "synthetic"        # synthetic | memmap
    path: Optional[str] = None     # for memmap
    # modality stubs
    frontend: str = "none"
    n_patches: int = 0
    d_model: int = 0
    enc_dec: bool = False
    enc_seq_ratio: int = 4


class TokenStream:
    """Deterministic, seekable token-batch stream."""

    def __init__(self, cfg: DataConfig, host_id: int = 0, n_hosts: int = 1):
        assert cfg.global_batch % n_hosts == 0, \
            "global batch must divide across hosts"
        self.cfg = cfg
        self.host_id = host_id
        self.n_hosts = n_hosts
        self.local_batch = cfg.global_batch // n_hosts
        self.step = 0
        if cfg.kind == "memmap":
            assert cfg.path, "memmap source needs a path"
            self._tokens = np.memmap(cfg.path, dtype=np.int32, mode="r")
        else:
            self._tokens = None

    # -- checkpointable state ------------------------------------------
    def state_dict(self) -> Dict[str, int]:
        return {"step": self.step, "seed": self.cfg.seed,
                "host_id": self.host_id}

    def load_state_dict(self, d: Dict[str, int]) -> None:
        self.step = int(d["step"])

    # ------------------------------------------------------------------
    def _rng(self, step: int) -> np.random.Generator:
        return np.random.default_rng(
            (self.cfg.seed * 1_000_003 + step) * 4096 + self.host_id)

    def _synthetic(self, step: int) -> np.ndarray:
        cfg = self.cfg
        rng = self._rng(step)
        b, s, v = self.local_batch, cfg.seq_len + 1, cfg.vocab_size
        # zipf-ish marginals + deterministic successor structure: tokens
        # depend on their predecessor, so an LM can reduce loss quickly
        base = rng.zipf(1.5, size=(b, s)).astype(np.int64) % v
        succ = (np.arange(v) * 31 + 7) % v
        mask = rng.random((b, s)) < 0.7
        out = base.copy()
        for t in range(1, s):
            out[:, t] = np.where(mask[:, t], succ[out[:, t - 1]], base[:, t])
        return out.astype(np.int32)

    def _memmap_batch(self, step: int) -> np.ndarray:
        cfg = self.cfg
        b, s = self.local_batch, cfg.seq_len + 1
        n = self._tokens.shape[0] - s - 1
        rng = self._rng(step)
        starts = rng.integers(0, n, size=b)
        return np.stack([self._tokens[st:st + s] for st in starts]).astype(
            np.int32)

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        toks = (self._memmap_batch(step) if cfg.kind == "memmap"
                else self._synthetic(step))
        if cfg.enc_dec:
            s_dec = max(cfg.seq_len // cfg.enc_seq_ratio, 8)
            rng = self._rng(step)
            feats = rng.standard_normal(
                (self.local_batch, cfg.seq_len, cfg.d_model)).astype(
                np.float32)
            return {"audio_feats": feats,
                    "tokens": toks[:, :s_dec],
                    "labels": toks[:, 1:s_dec + 1]}
        batch = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
        if cfg.frontend == "vision_stub":
            rng = self._rng(step)
            batch["extra_embeds"] = rng.standard_normal(
                (self.local_batch, cfg.n_patches, cfg.d_model)).astype(
                np.float32)
        return batch

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        while True:
            yield self.batch_at(self.step)
            self.step += 1


class Prefetcher:
    """Double-buffered background prefetch (overlaps data generation /
    host→device transfer with compute)."""

    def __init__(self, stream: TokenStream, depth: int = 2,
                 to_device=None):
        self.stream = stream
        self.to_device = to_device or (lambda x: x)
        self.q: "queue.Queue" = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self.thread = threading.Thread(target=self._worker, daemon=True)
        self.thread.start()

    def _worker(self):
        it = iter(self.stream)
        while not self._stop.is_set():
            try:
                batch = next(it)
            except StopIteration:
                break
            put_done = False
            while not put_done and not self._stop.is_set():
                try:
                    self.q.put(self.to_device(batch), timeout=0.1)
                    put_done = True
                except queue.Full:
                    continue

    def __next__(self):
        return self.q.get()

    def __iter__(self):
        return self

    def close(self):
        self._stop.set()
