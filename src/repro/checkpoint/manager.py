"""Checkpointing: atomic, per-shard, async, elastic-restore.

Layout (one directory per step):
    ckpt_dir/
      step_000120/
        meta.json                 — step, pytree structure, mesh, data state
        shard_00000.npz           — this host's param/opt leaves (zstd)
        COMMIT                    — written last; restore ignores dirs
                                    without it (atomicity marker)

Fault-tolerance contract:
  * `save` is all-or-nothing per step directory (COMMIT marker).
  * `save_async` runs on a background thread; at most one in flight —
    training overlaps the serialization (TENSILE-style compute/IO overlap).
  * `restore` takes the CURRENT mesh/sharding: leaves are re-sharded on
    load (`jax.device_put`), so restoring onto a different device count —
    elastic scale-up/down — works (tests/test_checkpoint.py proves 8→4).
  * `latest_step` + `gc_keep` implement the restart loop's rolling window.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np

try:
    import zstandard as _zstd
except Exception:  # pragma: no cover
    _zstd = None


def _flatten_with_paths(tree):
    # jax.tree.flatten_with_path landed after 0.4.x; fall back to tree_util
    flatten = getattr(jax.tree, "flatten_with_path",
                      jax.tree_util.tree_flatten_with_path)
    flat, treedef = flatten(tree)
    return [("/".join(str(p) for p in path), leaf) for path, leaf in flat], \
        treedef


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3, host_id: int = 0,
                 n_hosts: int = 1):
        self.dir = directory
        self.keep = keep
        self.host_id = host_id
        self.n_hosts = n_hosts
        os.makedirs(directory, exist_ok=True)
        self._async_thread: Optional[threading.Thread] = None
        self._async_err: Optional[BaseException] = None

    # ------------------------------------------------------------------
    def _step_dir(self, step: int) -> str:
        return os.path.join(self.dir, f"step_{step:09d}")

    def latest_step(self) -> Optional[int]:
        best = None
        for name in os.listdir(self.dir):
            if not name.startswith("step_"):
                continue
            d = os.path.join(self.dir, name)
            if not os.path.exists(os.path.join(d, "COMMIT")):
                continue  # incomplete (crashed mid-save)
            step = int(name.split("_")[1])
            best = step if best is None else max(best, step)
        return best

    # ------------------------------------------------------------------
    def save(self, step: int, state: Any,
             extra_meta: Optional[Dict] = None) -> str:
        """Synchronous atomic save of a pytree of jax/np arrays."""
        d = self._step_dir(step)
        tmp = d + f".tmp{self.host_id}"
        os.makedirs(tmp if self.n_hosts > 1 else tmp, exist_ok=True)
        leaves, treedef = _flatten_with_paths(state)
        arrays = {}
        for i, (path, leaf) in enumerate(leaves):
            arr = np.asarray(leaf)
            arrays[f"leaf_{i}"] = arr
        buf_path = os.path.join(tmp, f"shard_{self.host_id:05d}.npz")
        np.savez(buf_path, **arrays)
        if _zstd is not None:
            with open(buf_path, "rb") as f:
                raw = f.read()
            with open(buf_path + ".zst", "wb") as f:
                f.write(_zstd.ZstdCompressor(level=1).compress(raw))
            os.remove(buf_path)
        meta = {
            "step": step,
            "paths": [p for p, _ in leaves],
            "dtypes": [str(np.asarray(l).dtype) for _, l in leaves],
            "shapes": [list(np.asarray(l).shape) for _, l in leaves],
            "n_hosts": self.n_hosts,
            "time": time.time(),
        }
        meta.update(extra_meta or {})
        with open(os.path.join(tmp, "meta.json"), "w") as f:
            json.dump(meta, f)
        if os.path.isdir(d):
            shutil.rmtree(d)
        os.replace(tmp, d)
        with open(os.path.join(d, "COMMIT"), "w") as f:
            f.write(str(step))
        self._gc()
        return d

    def save_async(self, step: int, state: Any,
                   extra_meta: Optional[Dict] = None) -> None:
        """Background save; joins any previous in-flight save first."""
        self.wait()
        # snapshot to host memory before returning control
        host_state = jax.tree.map(lambda x: np.asarray(x), state)

        def work():
            try:
                self.save(step, host_state, extra_meta)
            except BaseException as e:  # noqa: BLE001
                self._async_err = e

        self._async_thread = threading.Thread(target=work, daemon=True)
        self._async_thread.start()

    def wait(self) -> None:
        if self._async_thread is not None:
            self._async_thread.join()
            self._async_thread = None
        if self._async_err is not None:
            err, self._async_err = self._async_err, None
            raise err

    # ------------------------------------------------------------------
    def restore(self, step: Optional[int] = None, template: Any = None,
                shardings: Any = None) -> Tuple[Any, Dict]:
        """Restore; optionally reshard onto `shardings` (elastic restore —
        the new mesh may have a different device count)."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no committed checkpoint in {self.dir}")
        d = self._step_dir(step)
        with open(os.path.join(d, "meta.json")) as f:
            meta = json.load(f)
        buf_path = os.path.join(d, f"shard_{self.host_id:05d}.npz")
        if not os.path.exists(buf_path) and os.path.exists(buf_path + ".zst"):
            with open(buf_path + ".zst", "rb") as f:
                raw = _zstd.ZstdDecompressor().decompress(f.read())
            with open(buf_path, "wb") as f:
                f.write(raw)
        data = np.load(buf_path)
        leaves = [data[f"leaf_{i}"] for i in range(len(meta["paths"]))]
        if template is not None:
            treedef = jax.tree.structure(template)
            state = jax.tree.unflatten(treedef, leaves)
        else:
            state = leaves
        if shardings is not None:
            state = jax.tree.map(
                lambda x, s: jax.device_put(x, s), state, shardings)
        return state, meta

    # ------------------------------------------------------------------
    def _gc(self) -> None:
        steps = sorted(
            int(n.split("_")[1]) for n in os.listdir(self.dir)
            if n.startswith("step_") and os.path.exists(
                os.path.join(self.dir, n, "COMMIT")))
        for s in steps[:-self.keep]:
            shutil.rmtree(self._step_dir(s), ignore_errors=True)
