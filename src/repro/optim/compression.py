"""Gradient compression for the cross-pod exchange.

Int8 block-quantized gradients with **error feedback** (residual carried to
the next step — Seide et al. 2014 / EF-SGD): the quantization error does
not bias the optimizer, it is re-injected next step.

Two layers:
* `compressed_allreduce` — a real collective: quantize → int32 psum →
  dequantize with a max-reduced scale, usable inside shard_map.  Unit
  tests run it on a host-device mesh and check the error bound.
* `ef_compress_grads` — the train-step integration: quantize/dequantize
  with error feedback applied to the already-reduced gradients.  On the
  compiled pjit path the DP reduction itself is GSPMD-inserted, so the
  numerics of compression are exercised here while the byte saving on the
  pod links is accounted analytically in the roofline (collective bytes ×
  compression ratio); the shard_map collective above is the
  mechanism a torch-style explicit-DP runtime would call.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp

BLOCK = 2048


def _quant(x):
    flat = x.reshape(-1)
    pad = -flat.size % BLOCK
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, BLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=-1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return q, scale, pad


def _dequant(q, scale, pad, shape, dtype):
    flat = (q.astype(jnp.float32) * scale).reshape(-1)
    if pad:
        flat = flat[:-pad]
    return flat.reshape(shape).astype(dtype)


def quantize_dequantize(x):
    q, s, pad = _quant(x.astype(jnp.float32))
    return _dequant(q, s, pad, x.shape, x.dtype)


def compressed_psum_mean(x, axis_name: str):
    """Real compressed collective (use inside shard_map): int8-quantize the
    local contribution, integer-psum, dequantize with a max-combined scale.
    Bytes on the wire: 1 B/element + 4/BLOCK ≈ 25% of fp32."""
    n = jax.lax.psum(1, axis_name)
    q, scale, pad = _quant(x.astype(jnp.float32))
    scale_max = jax.lax.pmax(scale, axis_name)
    # renormalize local values to the shared scale, then integer-reduce
    q_shared = jnp.clip(jnp.round(q.astype(jnp.float32) * scale / scale_max),
                        -127, 127).astype(jnp.int32)
    summed = jax.lax.psum(q_shared, axis_name)
    out = _dequant(summed, scale_max, pad, x.shape, x.dtype)
    return out / n


def ef_compress_grads(grads: Any, opt_state) -> Tuple[Any, Any]:
    """Error-feedback int8 compression over the gradient pytree.

    The residual lives in `opt_state.ef` (create the state with
    ``adamw_init(..., grad_compression=True)``).
    """
    residual = opt_state.ef if opt_state.ef != () else jax.tree.map(
        lambda g: jnp.zeros(g.shape, jnp.float32), grads)

    def comp(g, r):
        x = g.astype(jnp.float32) + r
        xq = quantize_dequantize(x)
        return xq.astype(g.dtype), x - xq.astype(jnp.float32)

    pairs = jax.tree.map(comp, grads, residual)
    new_grads = jax.tree.map(lambda p: p[0], pairs,
                             is_leaf=lambda x: isinstance(x, tuple))
    new_resid = jax.tree.map(lambda p: p[1], pairs,
                             is_leaf=lambda x: isinstance(x, tuple))
    return new_grads, opt_state._replace(ef=new_resid)


def wire_bytes_ratio(dtype) -> float:
    """Compressed bytes / uncompressed bytes for the collective."""
    return (1.0 + 4.0 / BLOCK) / jnp.dtype(dtype).itemsize
