"""AdamW from scratch (paper §II notes Adam's 1st/2nd moment vectors as
prime scheduling targets — they double the parameter footprint, which is
exactly what TENSILE's Opt-phase offloading removes from the device).

Pure-pytree implementation; no optax.  Supports:
  * decoupled weight decay (AdamW)
  * optional fp32 master copies when training params are bf16
  * optional host-offloaded moments (the TENSILE across-iteration schedule):
    the train-step builder places these leaves in `pinned_host` memory when
    the backend supports it.
"""
from __future__ import annotations

from typing import Any, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


class AdamState(NamedTuple):
    step: jnp.ndarray
    mu: Any          # 1st moment pytree
    nu: Any          # 2nd moment pytree
    master: Any      # fp32 master params (or empty tuple)
    ef: Any = ()     # error-feedback residual (grad compression)


def adamw_init(params: Any, *, use_master: bool = False,
               moment_dtype=jnp.float32,
               grad_compression: bool = False) -> AdamState:
    mu = jax.tree.map(lambda p: jnp.zeros(p.shape, moment_dtype), params)
    nu = jax.tree.map(lambda p: jnp.zeros(p.shape, moment_dtype), params)
    master = (jax.tree.map(lambda p: p.astype(jnp.float32), params)
              if use_master else ())
    ef = (jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
          if grad_compression else ())
    return AdamState(step=jnp.zeros((), jnp.int32), mu=mu, nu=nu,
                     master=master, ef=ef)


def adamw_update(params: Any, grads: Any, state: AdamState, *,
                 lr: float = 1e-3, b1: float = 0.9, b2: float = 0.999,
                 eps: float = 1e-8, weight_decay: float = 0.0,
                 grad_clip_norm: Optional[float] = None,
                 ) -> Tuple[Any, AdamState]:
    step = state.step + 1
    stepf = step.astype(jnp.float32)

    if grad_clip_norm is not None:
        gnorm = global_norm(grads)
        scale = jnp.minimum(1.0, grad_clip_norm / (gnorm + 1e-12))
        grads = jax.tree.map(lambda g: g * scale, grads)

    bc1 = 1.0 - b1 ** stepf
    bc2 = 1.0 - b2 ** stepf

    def upd(p, g, m, v, pm):
        g32 = g.astype(jnp.float32)
        m = b1 * m.astype(jnp.float32) + (1 - b1) * g32
        v = b2 * v.astype(jnp.float32) + (1 - b2) * g32 * g32
        mhat = m / bc1
        vhat = v / bc2
        base = (pm if pm is not None else p).astype(jnp.float32)
        new = base - lr * (mhat / (jnp.sqrt(vhat) + eps) + weight_decay * base)
        return new, m, v

    use_master = state.master != ()
    leaves_p, tdef = jax.tree.flatten(params)
    leaves_g = jax.tree.flatten(grads)[0]
    leaves_m = jax.tree.flatten(state.mu)[0]
    leaves_v = jax.tree.flatten(state.nu)[0]
    leaves_pm = jax.tree.flatten(state.master)[0] if use_master else [None] * len(leaves_p)

    new_p, new_m, new_v, new_pm = [], [], [], []
    for p, g, m, v, pm in zip(leaves_p, leaves_g, leaves_m, leaves_v, leaves_pm):
        n32, m2, v2 = upd(p, g, m, v, pm)
        new_m.append(m2.astype(m.dtype))
        new_v.append(v2.astype(v.dtype))
        if use_master:
            new_pm.append(n32)
        new_p.append(n32.astype(p.dtype))

    params_out = jax.tree.unflatten(tdef, new_p)
    mu = jax.tree.unflatten(tdef, new_m)
    nu = jax.tree.unflatten(tdef, new_v)
    master = jax.tree.unflatten(tdef, new_pm) if use_master else ()
    return params_out, AdamState(step=step, mu=mu, nu=nu, master=master,
                                 ef=state.ef)


def global_norm(tree: Any) -> jnp.ndarray:
    leaves = jax.tree.leaves(tree)
    if not leaves:
        return jnp.zeros(())
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))


def sgd_update(params: Any, grads: Any, lr: float) -> Any:
    return jax.tree.map(lambda p, g: (p.astype(jnp.float32)
                                      - lr * g.astype(jnp.float32)).astype(p.dtype),
                        params, grads)
