"""Priority admission queue gated on predicted peak memory.

The queue is a pure data structure — no threads, no clocks — so the live
daemon (real time) and the scenario suite's virtual-time overload replay
(``benchmarks/scenarios.py``) exercise the *same* admission policy.

Policy: jobs wait in ``(-priority, arrival)`` order.  A job is admitted when
its predicted peak fits the unreserved capacity; the scan greedily backfills
past a blocked job so small jobs are not starved behind a large head-of-line
job, but a blocked higher-priority job keeps its place for the next pass.
Admission *reserves* the predicted peak; the reservation is refined to the
measured peak after the job's first profiled iteration (shrinking a
conservative cost-model bound frees headroom and can admit waiting jobs) and
released when the job finishes.

Invariant (the CI admission contract): the sum of live reservations never
exceeds capacity.  ``max_reserved_bytes`` tracks the high-water mark so the
contract is auditable after a run.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

PREDICTED_SOURCE_EXPERIENCE = "experience"
PREDICTED_SOURCE_COST_MODEL = "cost-model"
PREDICTED_SOURCE_MEASURED = "measured"


@dataclasses.dataclass
class QueuedJob:
    job_id: str
    predicted_peak_bytes: int
    priority: float = 1.0
    source: str = PREDICTED_SOURCE_COST_MODEL
    enqueued_at: float = 0.0
    seq_no: int = 0

    def sort_key(self):
        return (-self.priority, self.seq_no)


class AdmissionQueue:
    """Admission by predicted peak against a fixed byte capacity."""

    def __init__(self, capacity_bytes: int):
        if capacity_bytes <= 0:
            raise ValueError("capacity_bytes must be > 0")
        self.capacity_bytes = int(capacity_bytes)
        self._waiting: List[QueuedJob] = []
        self._reservations: Dict[str, int] = {}
        self._sources: Dict[str, str] = {}
        self._seq = 0
        self.max_reserved_bytes = 0
        # (job_id, reserved_at_admission) in admission order, for audits.
        self.admission_log: List[tuple] = []

    # -- waiting set ---------------------------------------------------------

    def push(self, job_id: str, predicted_peak_bytes: int,
             priority: float = 1.0,
             source: str = PREDICTED_SOURCE_COST_MODEL,
             enqueued_at: float = 0.0) -> QueuedJob:
        """Enqueue a job.  Raises ``ValueError`` if it can *never* fit —
        the caller records it REJECTED instead of letting it starve."""
        predicted = int(predicted_peak_bytes)
        if predicted > self.capacity_bytes:
            raise ValueError(
                f"job {job_id!r}: predicted peak {predicted} exceeds device "
                f"capacity {self.capacity_bytes} — never admissible"
            )
        if any(q.job_id == job_id for q in self._waiting) \
                or job_id in self._reservations:
            raise ValueError(f"job {job_id!r} already queued or admitted")
        self._seq += 1
        job = QueuedJob(job_id=job_id, predicted_peak_bytes=max(predicted, 0),
                        priority=priority, source=source,
                        enqueued_at=enqueued_at, seq_no=self._seq)
        self._waiting.append(job)
        self._waiting.sort(key=QueuedJob.sort_key)
        return job

    def remove(self, job_id: str) -> bool:
        """Drop a still-waiting job (cancellation)."""
        n = len(self._waiting)
        self._waiting = [q for q in self._waiting if q.job_id != job_id]
        return len(self._waiting) < n

    @property
    def waiting(self) -> List[QueuedJob]:
        return list(self._waiting)

    def __len__(self) -> int:
        return len(self._waiting)

    # -- reservation ledger --------------------------------------------------

    @property
    def reserved_bytes(self) -> int:
        return sum(self._reservations.values())

    @property
    def free_bytes(self) -> int:
        return self.capacity_bytes - self.reserved_bytes

    @property
    def reservations(self) -> Dict[str, int]:
        return dict(self._reservations)

    def refine(self, job_id: str, measured_peak_bytes: int) -> Optional[int]:
        """Replace an admitted job's reservation with its measured peak
        (first profiled iteration).  Returns the new reservation, or None
        if the job holds no reservation.  Growing past capacity is clamped —
        the plan certifies the job under its budget; the clamp only keeps
        the ledger's invariant intact under measurement noise."""
        if job_id not in self._reservations:
            return None
        old = self._reservations[job_id]
        new = max(1, min(int(measured_peak_bytes),
                         old + self.free_bytes))  # never exceed capacity
        self._reservations[job_id] = new
        self._sources[job_id] = PREDICTED_SOURCE_MEASURED
        self.max_reserved_bytes = max(self.max_reserved_bytes,
                                      self.reserved_bytes)
        return new

    def release(self, job_id: str) -> Optional[int]:
        """Free a finished (or failed) job's reservation."""
        self._sources.pop(job_id, None)
        return self._reservations.pop(job_id, None)

    def source_of(self, job_id: str) -> Optional[str]:
        return self._sources.get(job_id)

    # -- admission -----------------------------------------------------------

    def pop_admissible(self, now: float = 0.0) -> List[QueuedJob]:
        """Admit every waiting job that fits the unreserved capacity.

        Scans in priority order with greedy backfill: a blocked job is
        skipped (it keeps its place), later smaller jobs may still be
        admitted.  Reservations are taken immediately, so the returned
        admitted set is capacity-sound by construction.
        """
        admitted: List[QueuedJob] = []
        still_waiting: List[QueuedJob] = []
        for job in self._waiting:
            if job.predicted_peak_bytes <= self.free_bytes:
                self._reservations[job.job_id] = job.predicted_peak_bytes
                self._sources[job.job_id] = job.source
                self.max_reserved_bytes = max(self.max_reserved_bytes,
                                              self.reserved_bytes)
                self.admission_log.append((job.job_id,
                                           job.predicted_peak_bytes, now))
                admitted.append(job)
            else:
                still_waiting.append(job)
        self._waiting = still_waiting
        return admitted
