"""The scheduler daemon: a long-lived event loop that owns the device.

``SchedulerDaemon`` wraps one ``GlobalController`` and accepts jobs from
independent clients over a filesystem inbox (``<root>/inbox/*.json``, one
serialized ``JobSpec`` per file — socket transport can come later; the wire
format is the spec, not the transport).  Each job moves through the durable
``JobStore``:

    QUEUED --admission--> ADMITTED --submit--> RUNNING --> DONE | FAILED
       \\--(predicted peak can never fit)--> REJECTED

Admission is the ``AdmissionQueue``: a job is admitted only when its
predicted peak (``ExperienceStore`` fingerprint for warm jobs, conservative
cost-model bound for cold ones — ``GlobalController.predict_peak``) fits the
unreserved ``BudgetArbiter`` capacity.  Reservations are refined to measured
peaks after the first profiled iteration and released on finish, both of
which can admit waiting jobs.

Crash recovery is delegated to ``JobStore.recover`` at startup: QUEUED and
ADMITTED jobs are replayed into the admission queue, RUNNING orphans are
re-queued exactly once.  A heartbeat file (``<root>/daemon.json``) lets
clients see liveness and drain progress.
"""

from __future__ import annotations

import json
import os
import threading
import time as _time
from typing import Any, Dict, List, Optional

from ..core.multiplexer import CapturedJob, GlobalController
from ..obs.metrics import MetricsRegistry
from .jobspec import JobSpec, JobState
from .queue import AdmissionQueue
from .store import JobRecord, JobStore

INBOX_DIR = "inbox"
HEARTBEAT_FILE = "daemon.json"
METRICS_FILE = "metrics.prom"
CONTROL_PREFIX = "ctl-"


class SchedulerDaemon:
    """Event loop around a ``GlobalController`` with admission control.

    ``controller`` is injectable for tests (anything with ``capture_spec``,
    ``predict_peak`` and ``submit``); by default a real ``GlobalController``
    is built owning the device, with an ``ExperienceStore`` under
    ``<root>/experience`` so admission predictions warm up across runs.
    """

    def __init__(self, root: str,
                 controller: Optional[Any] = None,
                 capacity_bytes: Optional[int] = None,
                 poll_interval: float = 0.05,
                 controller_kwargs: Optional[Dict[str, Any]] = None):
        self.root = root
        self.inbox = os.path.join(root, INBOX_DIR)
        os.makedirs(self.inbox, exist_ok=True)
        if controller is None:
            kwargs = dict(controller_kwargs or {})
            kwargs.setdefault("arbiter_policy", "priority")
            kwargs.setdefault("experience_dir",
                              os.path.join(root, "experience"))
            if capacity_bytes is not None:
                kwargs.setdefault("device_capacity", capacity_bytes)
            controller = GlobalController(**kwargs)
        self.controller = controller
        if capacity_bytes is None:
            arb = getattr(controller, "arbiter", None)
            if arb is not None:
                capacity_bytes = arb.capacity
            else:
                capacity_bytes = controller.profile.device_memory_bytes
        self.capacity_bytes = int(capacity_bytes)
        self.store = JobStore(root)
        self.queue = AdmissionQueue(self.capacity_bytes)
        self.poll_interval = poll_interval
        # job_id -> CapturedJob (capture happens once, pre-admission)
        self._captured: Dict[str, CapturedJob] = {}
        self._handles: Dict[str, Any] = {}
        self._refined: set = set()
        self._draining = False
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None
        # observability: a Prometheus-style registry written next to the
        # heartbeat every tick, plus an optional trace recorder for
        # state-transition instants (None = zero overhead)
        self.metrics = MetricsRegistry()
        self.metrics_path = os.path.join(root, METRICS_FILE)
        self.recorder = None
        self._transitions = self.metrics.counter(
            "tensile_state_transitions_total",
            "job state transitions since daemon start")
        self._last_metrics = 0.0
        self.recovered = self.recover()

    def attach_recorder(self, recorder) -> None:
        """Forward state-transition instants to a trace recorder."""
        self.recorder = recorder

    def _transition(self, job_id: str, state: JobState, now: float,
                    **kw) -> None:
        """``JobStore.transition`` + the observability fan-out: every
        state change bumps the transitions counter and (with a recorder
        attached) lands as an instant event on the trace timeline."""
        self.store.transition(job_id, state, now, **kw)
        self._transitions.inc(state=state.value)
        if self.recorder is not None:
            self.recorder.instant(f"job:{state.value}", now,
                                  job_id=job_id)

    # -- crash recovery ------------------------------------------------------

    def recover(self) -> Dict[str, List[str]]:
        """Replay the durable store into the live queue (startup)."""
        now = _time.time()
        replayed, requeued, failed = self.store.recover(now)
        for rec in sorted(self.store.by_state(JobState.QUEUED),
                          key=lambda r: r.submitted_at):
            self._enqueue(rec, now)
        return {"replayed": replayed, "requeued_orphans": requeued,
                "failed_orphans": failed}

    # -- submission ----------------------------------------------------------

    def submit(self, spec: JobSpec) -> JobRecord:
        """In-process submission (the inbox path funnels here).  ``job_id``
        is the idempotency key: re-submitting a known, non-terminal job is
        a no-op returning the existing record."""
        now = _time.time()
        existing = self.store.get(spec.job_id)
        if existing is not None and not existing.state.terminal:
            return existing
        rec = JobRecord(spec=spec, state=JobState.QUEUED, submitted_at=now)
        self.store.put(rec, now)
        self._enqueue(rec, now)
        return rec

    def _enqueue(self, rec: JobRecord, now: float) -> None:
        """Predict the job's peak and push it into the admission queue.
        Unresolvable workloads and never-fitting peaks become REJECTED —
        a bad submission must not take the daemon down."""
        spec = rec.spec
        try:
            captured = self._captured.get(spec.job_id)
            if captured is None:
                captured = self.controller.capture_spec(spec)
                self._captured[spec.job_id] = captured
            predicted, source = self.controller.predict_peak(
                captured.seq, budget_hint_bytes=spec.budget_hint_bytes)
            rec.predicted_peak_bytes = int(predicted)
            rec.predicted_source = source
            self.queue.push(spec.job_id, predicted,
                            priority=spec.priority or 1.0,
                            source=source, enqueued_at=now)
            self.store.put(rec, now)
        except ValueError as exc:
            self._transition(spec.job_id, JobState.REJECTED, now,
                             error=str(exc))
            self._captured.pop(spec.job_id, None)
        except Exception as exc:  # noqa: BLE001 - capture blew up
            self._transition(spec.job_id, JobState.FAILED, now,
                             error=f"capture failed: {exc}")
            self._captured.pop(spec.job_id, None)

    # -- event loop ----------------------------------------------------------

    def step(self, now: Optional[float] = None) -> int:
        """One tick: drain the inbox, poll running jobs, admit what fits.
        Returns the number of state changes (0 == idle tick)."""
        now = _time.time() if now is None else now
        changes = self._drain_inbox(now)
        changes += self._poll_running(now)
        changes += self._try_admit(now)
        self._write_heartbeat(now)
        return changes

    def _drain_inbox(self, now: float) -> int:
        changes = 0
        try:
            names = sorted(os.listdir(self.inbox))
        except OSError:
            return 0
        for name in names:
            if not name.endswith(".json"):
                continue  # client temp files (.json.tmp.*) are invisible
            path = os.path.join(self.inbox, name)
            try:
                with open(path, "r", encoding="utf-8") as f:
                    data = json.load(f)
            except (OSError, ValueError):
                # half-written or corrupt submissions: skip, never crash;
                # the file is removed so it cannot wedge the inbox forever
                self._unlink(path)
                continue
            if name.startswith(CONTROL_PREFIX):
                if isinstance(data, dict) and data.get("control") == "drain":
                    self._draining = True
                    changes += 1
                self._unlink(path)
                continue
            try:
                spec = JobSpec.from_dict(data)
            except ValueError:
                self._unlink(path)
                continue
            # persist-then-unlink: a crash in between re-submits the same
            # job_id, which the store dedupes (idempotency key)
            self.submit(spec)
            self._unlink(path)
            changes += 1
        return changes

    def _poll_running(self, now: float) -> int:
        changes = 0
        for rec in self.store.by_state(JobState.RUNNING):
            jid = rec.job_id
            handle = self._handles.get(jid)
            if handle is None:
                continue  # recovered-orphan bookkeeping already handled
            if handle.done:
                measured = int(getattr(handle, "peak_bytes", 0) or 0)
                self.queue.release(jid)
                self._captured.pop(jid, None)
                self._handles.pop(jid, None)
                if getattr(handle, "error", None) is not None:
                    self._transition(jid, JobState.FAILED, now,
                                     measured_peak_bytes=measured,
                                     error=repr(handle.error))
                else:
                    self._transition(jid, JobState.DONE, now,
                                     measured_peak_bytes=measured)
                changes += 1
            elif jid not in self._refined and len(handle.stats) >= 1:
                # first profiled iteration: refine the reservation from the
                # measured peak — a shrunken conservative bound frees
                # headroom for waiting jobs at the next admission pass
                measured = int(getattr(handle, "peak_bytes", 0) or 0)
                if measured > 0:
                    self.queue.refine(jid, measured)
                    self._refined.add(jid)
                    rec.measured_peak_bytes = measured
                    self.store.put(rec, now)
                    changes += 1
        return changes

    def _try_admit(self, now: float) -> int:
        changes = 0
        for job in self.queue.pop_admissible(now):
            rec = self.store.get(job.job_id)
            if rec is None:
                self.queue.release(job.job_id)
                continue
            self._transition(job.job_id, JobState.ADMITTED, now)
            try:
                handle = self.controller.submit(
                    rec.spec, captured=self._captured.get(job.job_id))
            except Exception as exc:  # noqa: BLE001 - admission stays up
                self.queue.release(job.job_id)
                self._captured.pop(job.job_id, None)
                self._transition(job.job_id, JobState.FAILED, now,
                                 error=f"submit failed: {exc}")
                changes += 1
                continue
            self._handles[job.job_id] = handle
            self._transition(job.job_id, JobState.RUNNING, now)
            changes += 1
        return changes

    # -- lifecycle -----------------------------------------------------------

    @property
    def idle(self) -> bool:
        """No queued, admitted, or running work left."""
        return not self.store.by_state(JobState.QUEUED, JobState.ADMITTED,
                                       JobState.RUNNING)

    def serve_forever(self) -> None:
        """Run until stopped — or, when draining, until the queue is empty."""
        while not self._stop.is_set():
            busy = self.step()
            if self._draining and self.idle:
                break
            if not busy:
                self._stop.wait(self.poll_interval)
        self._write_heartbeat(_time.time(), state="stopped")

    def start(self) -> "SchedulerDaemon":
        """Run the event loop on a background thread."""
        self._thread = threading.Thread(target=self.serve_forever,
                                        daemon=True)
        self._thread.start()
        return self

    def stop(self, timeout: float = 10.0) -> None:
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout)

    def drain(self, timeout: float = 300.0) -> bool:
        """Finish everything queued/running, then stop.  True on empty."""
        self._draining = True
        deadline = _time.time() + timeout
        if self._thread is None:
            while not self.idle and _time.time() < deadline:
                self.step()
                _time.sleep(self.poll_interval)
        else:
            self._thread.join(max(0.0, deadline - _time.time()))
        done = self.idle
        self.stop()
        # final heartbeat: the threaded loop writes its own on exit, the
        # in-process path needs one here so the last metrics refresh (an
        # unthrottled `state` write) reflects the drained store
        if self._thread is None:
            self._write_heartbeat(_time.time(), state="stopped")
        return done

    # -- observability -------------------------------------------------------

    def _write_heartbeat(self, now: float, state: Optional[str] = None) -> None:
        hb = {"pid": os.getpid(), "updated_at": now,
              "state": state or ("draining" if self._draining else "running"),
              "capacity_bytes": self.capacity_bytes,
              "reserved_bytes": self.queue.reserved_bytes,
              "waiting": len(self.queue)}
        tmp = os.path.join(self.root, HEARTBEAT_FILE + ".tmp")
        try:
            with open(tmp, "w", encoding="utf-8") as f:
                json.dump(hb, f, sort_keys=True)
            os.replace(tmp, os.path.join(self.root, HEARTBEAT_FILE))
        except OSError:
            pass  # heartbeat is best-effort observability
        # the Prometheus exposition rides the heartbeat, throttled so
        # gauge derivation stays off the per-tick hot path
        if now - self._last_metrics >= 0.5 or state is not None:
            self._last_metrics = now
            try:
                self._refresh_metrics(now)
                self.metrics.write(self.metrics_path)
            except OSError:
                pass  # same best-effort contract as the heartbeat

    def _refresh_metrics(self, now: float) -> None:
        """Re-derive every gauge from live daemon + controller state."""
        m = self.metrics
        m.gauge("tensile_queue_depth",
                "jobs waiting for admission").set(len(self.queue))
        m.gauge("tensile_capacity_bytes",
                "device byte budget admission reserves against").set(
                    self.capacity_bytes)
        m.gauge("tensile_reserved_bytes",
                "bytes currently reserved by admitted/running jobs").set(
                    self.queue.reserved_bytes)
        jobs = m.gauge("tensile_jobs", "jobs per lifecycle state")
        jobs.clear()
        errs = []
        for rec in self.store.all().values():
            jobs.inc(state=rec.state.value)
            p = rec.predicted_peak_bytes
            meas = rec.measured_peak_bytes
            if p and meas:
                errs.append(abs(p - meas) / meas)
        if errs:
            m.gauge("tensile_admission_precision_ratio",
                    "mean |predicted - measured| / measured peak over "
                    "profiled jobs").set(sum(errs) / len(errs))
        ctl = self.controller
        m.gauge("tensile_replan_count",
                "controller replans since start").set(
                    getattr(ctl, "replan_count", 0))
        m.gauge("tensile_preempt_count",
                "mid-iteration preemptive hot-swap requests").set(
                    getattr(ctl, "preempt_count", 0))
        handles = getattr(ctl, "jobs", None) or {}
        hot = 0
        tps = m.gauge("tensile_serve_tokens_per_sec",
                      "decode throughput of the latest serve report")
        for jid, h in handles.items():
            for st in getattr(h, "stats", []) or []:
                hot += getattr(st, "hot_swaps", 0) or 0
            for st in reversed(getattr(h, "stats", []) or []):
                rate = getattr(st, "tokens_per_s", None)
                if rate is not None:
                    tps.set(rate, job=jid)
                    break
        m.gauge("tensile_hot_swap_count",
                "plan hot-swaps applied by executors").set(hot)
        fails = getattr(ctl, "experience_failures", None)
        if fails is not None:
            m.gauge("tensile_experience_failures",
                    "experience-store operations that failed").set(
                        len(fails))
        events = getattr(ctl, "events", None)
        if events is not None:
            m.gauge("tensile_warn_events",
                    "WARN/ERROR events in the controller event log").set(
                        len(events.warnings()))
        hub = getattr(ctl, "telemetry", None)
        cm = getattr(ctl, "cost_model", None)
        if hub is not None and cm is not None:
            try:
                if hub.jobs():
                    rep = cm.calibration_report(hub)
                    if rep.samples:
                        m.gauge("tensile_calib_err",
                                "mean relative cost-model latency "
                                "error").set(rep.overall)
            except Exception:  # noqa: BLE001 - metrics must not crash
                pass

    def status(self) -> Dict[str, Any]:
        counts: Dict[str, int] = {}
        for rec in self.store.all().values():
            counts[rec.state.value] = counts.get(rec.state.value, 0) + 1
        return {"capacity_bytes": self.capacity_bytes,
                "reserved_bytes": self.queue.reserved_bytes,
                "max_reserved_bytes": self.queue.max_reserved_bytes,
                "waiting": len(self.queue),
                "draining": self._draining,
                "jobs": counts}

    @staticmethod
    def _unlink(path: str) -> None:
        try:
            os.unlink(path)
        except OSError:
            pass
