"""Client side of the scheduler service: filesystem-inbox submission.

``ServiceClient`` lives in a *different* process from the daemon and shares
only the service root directory.  Submission is an atomic rename into
``<root>/inbox/`` (write ``<name>.json.tmp.<pid>``, ``os.replace`` to
``<name>.json``) so the daemon never observes a half-written spec; status
reads the durable job store read-only through the same tolerant parser the
daemon uses.
"""

from __future__ import annotations

import json
import os
import time as _time
from typing import Dict, List, Optional

from .daemon import CONTROL_PREFIX, HEARTBEAT_FILE, INBOX_DIR
from .jobspec import JobSpec, JobState
from .store import JobRecord, JobStore


class ServiceClient:
    def __init__(self, root: str):
        self.root = root
        self.inbox = os.path.join(root, INBOX_DIR)

    # -- submission ----------------------------------------------------------

    def _drop(self, name: str, data: dict) -> None:
        os.makedirs(self.inbox, exist_ok=True)
        final = os.path.join(self.inbox, name + ".json")
        tmp = f"{final}.tmp.{os.getpid()}"
        with open(tmp, "w", encoding="utf-8") as f:
            json.dump(data, f, sort_keys=True)
        os.replace(tmp, final)

    def submit(self, spec: JobSpec) -> str:
        """Drop a spec into the daemon's inbox; returns the job_id."""
        if spec.payload is not None:
            raise ValueError(
                "a JobSpec with an in-process payload cannot cross the "
                "inbox — submit a workload reference instead")
        if not spec.workload and spec.kind != "serve":
            # serve specs may omit the workload — resolution falls back to
            # the builtin "lm" serve factory on the daemon side
            raise ValueError("wire submission requires spec.workload")
        self._drop(spec.job_id, spec.to_dict())
        return spec.job_id

    def drain(self) -> None:
        """Ask the daemon to finish queued work and exit its loop."""
        self._drop(f"{CONTROL_PREFIX}drain-{os.getpid()}-{_time.time_ns()}",
                   {"control": "drain"})

    # -- status --------------------------------------------------------------

    def _store(self) -> JobStore:
        return JobStore(self.root)  # re-reads the file; tolerant parser

    def status(self, job_id: Optional[str] = None
               ) -> Dict[str, JobRecord]:
        records = self._store().all()
        if job_id is not None:
            records = {k: v for k, v in records.items() if k == job_id}
        return records

    def states(self) -> Dict[str, str]:
        return {jid: rec.state.value
                for jid, rec in self._store().all().items()}

    def heartbeat(self) -> Optional[dict]:
        try:
            with open(os.path.join(self.root, HEARTBEAT_FILE),
                      "r", encoding="utf-8") as f:
                return json.load(f)
        except (OSError, ValueError):
            return None

    def daemon_alive(self, stale_after: float = 5.0) -> bool:
        hb = self.heartbeat()
        if hb is None or hb.get("state") == "stopped":
            return False
        return (_time.time() - float(hb.get("updated_at", 0.0))) < stale_after

    # -- blocking helpers ----------------------------------------------------

    def wait(self, job_ids: Optional[List[str]] = None,
             timeout: float = 300.0, poll: float = 0.1
             ) -> Dict[str, JobRecord]:
        """Block until the given jobs (default: all known) are terminal.
        Returns their records; raises TimeoutError when time runs out."""
        deadline = _time.time() + timeout
        while True:
            records = self._store().all()
            targets = {jid: rec for jid, rec in records.items()
                       if job_ids is None or jid in job_ids}
            missing = set(job_ids or []) - set(targets)
            if not missing and targets \
                    and all(r.state.terminal for r in targets.values()):
                return targets
            if _time.time() >= deadline:
                raise TimeoutError(
                    f"jobs not terminal after {timeout}s: "
                    f"{sorted(missing) or [j for j, r in targets.items() if not r.state.terminal]}")
            _time.sleep(poll)
