"""Durable JSON-lines job store for the scheduler daemon.

One file, ``<root>/jobs.jsonl``: a header line pinning the schema, then one
record per job (last record for a ``job_id`` wins, so both whole-file
snapshots and appends replay identically).  Every mutation rewrites the file
through a temp file + atomic ``os.replace`` — the same durability idiom as
``core/experience.py`` — and reads apply the same tolerance rules: corrupt
lines are skipped, a missing/mismatched header degrades to an empty store,
and the daemon never crashes on a damaged file.

Crash recovery (:meth:`JobStore.recover`):

* ``QUEUED`` records are replayed as-is.
* ``ADMITTED`` jobs fall back to ``QUEUED`` — admission is re-decided by the
  live queue against current capacity, never trusted across a crash.
* ``RUNNING`` jobs were orphaned by the dead daemon: re-queued **exactly
  once** (``requeues`` counter); a job orphaned a second time is marked
  ``FAILED`` instead of looping forever.
* Terminal states (``DONE``/``FAILED``/``REJECTED``) are untouched.
"""

from __future__ import annotations

import dataclasses
import json
import os
import threading
from typing import Any, Dict, List, Optional, Tuple

from .jobspec import JobSpec, JobState

STORE_SCHEMA_VERSION = 1


@dataclasses.dataclass
class JobRecord:
    """One job's durable state: the spec plus lifecycle bookkeeping."""

    spec: JobSpec
    state: JobState = JobState.QUEUED
    submitted_at: float = 0.0
    updated_at: float = 0.0
    admitted_at: Optional[float] = None
    started_at: Optional[float] = None
    finished_at: Optional[float] = None
    predicted_peak_bytes: int = 0
    predicted_source: str = ""
    measured_peak_bytes: int = 0
    requeues: int = 0
    error: Optional[str] = None

    @property
    def job_id(self) -> str:
        return self.spec.job_id

    def to_dict(self) -> Dict[str, Any]:
        d = dataclasses.asdict(self)
        d["spec"] = self.spec.to_dict()
        d["state"] = self.state.value
        d["kind"] = "job"
        return d

    @classmethod
    def from_dict(cls, data: Dict[str, Any]) -> "JobRecord":
        spec = JobSpec.from_dict(data["spec"])
        state = JobState(data["state"])
        known = {f.name for f in dataclasses.fields(cls)} - {"spec", "state"}
        kwargs = {k: v for k, v in data.items() if k in known}
        return cls(spec=spec, state=state, **kwargs)


class JobStore:
    """Durable, tolerant job store.  Thread-safe within one process."""

    SCHEMA = STORE_SCHEMA_VERSION

    def __init__(self, root: str):
        self.root = root
        self.path = os.path.join(root, "jobs.jsonl")
        self._lock = threading.RLock()
        self._tmp_serial = 0
        self._records: Dict[str, JobRecord] = self._load()

    # -- persistence ---------------------------------------------------------

    def _load(self) -> Dict[str, JobRecord]:
        try:
            with open(self.path, "r", encoding="utf-8") as f:
                lines = f.read().splitlines()
        except OSError:
            return {}
        parsed: List[Dict[str, Any]] = []
        for line in lines:
            line = line.strip()
            if not line:
                continue
            try:
                rec = json.loads(line)
            except (ValueError, TypeError):
                continue
            if isinstance(rec, dict):
                parsed.append(rec)
        if not parsed:
            return {}
        header = parsed[0]
        if header.get("kind") != "header" or header.get("schema") != self.SCHEMA:
            return {}
        records: Dict[str, JobRecord] = {}
        for rec in parsed[1:]:
            if rec.get("kind") != "job":
                continue
            try:
                jr = JobRecord.from_dict(rec)
            except (ValueError, KeyError, TypeError):
                continue  # skip-not-crash: one bad record loses one job, not all
            records[jr.job_id] = jr
        return records

    def _flush_locked(self) -> None:
        os.makedirs(self.root, exist_ok=True)
        self._tmp_serial += 1
        tmp = (f"{self.path}.tmp.{os.getpid()}."
               f"{threading.get_ident()}.{self._tmp_serial}")
        with open(tmp, "w", encoding="utf-8") as f:
            f.write(json.dumps({"kind": "header", "schema": self.SCHEMA},
                               sort_keys=True) + "\n")
            for jid in sorted(self._records):
                f.write(json.dumps(self._records[jid].to_dict(),
                                   sort_keys=True) + "\n")
        os.replace(tmp, self.path)

    # -- accessors -----------------------------------------------------------

    def get(self, job_id: str) -> Optional[JobRecord]:
        with self._lock:
            return self._records.get(job_id)

    def all(self) -> Dict[str, JobRecord]:
        with self._lock:
            return dict(self._records)

    def by_state(self, *states: JobState) -> List[JobRecord]:
        with self._lock:
            return [r for r in self._records.values() if r.state in states]

    def __contains__(self, job_id: str) -> bool:
        with self._lock:
            return job_id in self._records

    def __len__(self) -> int:
        with self._lock:
            return len(self._records)

    # -- mutation ------------------------------------------------------------

    def put(self, record: JobRecord, now: float = 0.0) -> None:
        """Upsert ``record`` and durably persist the whole store."""
        with self._lock:
            record.updated_at = now
            self._records[record.job_id] = record
            self._flush_locked()

    def transition(self, job_id: str, state: JobState, now: float = 0.0,
                   **updates: Any) -> JobRecord:
        """Move a job to ``state``, stamping the matching timestamp field."""
        with self._lock:
            rec = self._records[job_id]
            rec.state = state
            rec.updated_at = now
            if state is JobState.ADMITTED:
                rec.admitted_at = now
            elif state is JobState.RUNNING:
                rec.started_at = now
            elif state.terminal:
                rec.finished_at = now
            for k, v in updates.items():
                setattr(rec, k, v)
            self._flush_locked()
            return rec

    # -- crash recovery ------------------------------------------------------

    def recover(self, now: float = 0.0) -> Tuple[List[str], List[str], List[str]]:
        """Apply the restart transition rules (see module docstring).

        Returns ``(replayed, requeued_orphans, failed_orphans)`` job-id
        lists: jobs back in QUEUED from QUEUED/ADMITTED, RUNNING orphans
        re-queued (once), and RUNNING orphans that had already burned their
        one re-queue and are now FAILED.
        """
        replayed: List[str] = []
        requeued: List[str] = []
        failed: List[str] = []
        with self._lock:
            for rec in self._records.values():
                if rec.state in (JobState.QUEUED, JobState.ADMITTED):
                    rec.state = JobState.QUEUED
                    rec.admitted_at = None
                    rec.updated_at = now
                    replayed.append(rec.job_id)
                elif rec.state is JobState.RUNNING:
                    if rec.requeues < 1:
                        rec.state = JobState.QUEUED
                        rec.requeues += 1
                        rec.admitted_at = None
                        rec.started_at = None
                        rec.updated_at = now
                        requeued.append(rec.job_id)
                    else:
                        rec.state = JobState.FAILED
                        rec.error = "orphaned while RUNNING after re-queue"
                        rec.finished_at = now
                        rec.updated_at = now
                        failed.append(rec.job_id)
            if replayed or requeued or failed:
                self._flush_locked()
        return replayed, requeued, failed
