"""Scheduler-as-a-service: daemon, admission queue, durable store, client.

The service plane turns the in-process ``GlobalController`` into a
long-lived device owner that independent clients submit jobs to:

    ``jobspec``   — the frozen, serializable ``JobSpec`` wire format and the
                    ``JobState`` lifecycle vocabulary
    ``workloads`` — registry resolving ``spec.workload`` references to
                    ``(step_fn, params, opt_state, batch)`` payloads
    ``queue``     — priority admission by predicted peak vs device capacity
    ``store``     — durable JSON-lines job store with crash recovery
    ``daemon``    — the event loop wrapping ``GlobalController``
    ``client``    — filesystem-inbox submission + status from another process

See docs/architecture.md, "Scheduler as a service".
"""

from .client import ServiceClient
from .daemon import SchedulerDaemon
from .jobspec import JobSpec, JobState, ServeParams, SPEC_SCHEMA_VERSION
from .queue import AdmissionQueue, QueuedJob
from .store import JobRecord, JobStore, STORE_SCHEMA_VERSION
from .workloads import (register_serve_workload, register_workload,
                        registered_serve_workloads, registered_workloads,
                        resolve_serve_workload, resolve_workload)

__all__ = [
    "AdmissionQueue", "JobRecord", "JobSpec", "JobState", "JobStore",
    "QueuedJob", "SchedulerDaemon", "ServeParams", "ServiceClient",
    "SPEC_SCHEMA_VERSION", "STORE_SCHEMA_VERSION",
    "register_serve_workload", "register_workload",
    "registered_serve_workloads", "registered_workloads",
    "resolve_serve_workload", "resolve_workload",
]
