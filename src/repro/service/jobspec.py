"""The redesigned job-submission surface: a frozen, serializable ``JobSpec``.

``JobSpec`` is the single wire format shared by every submission path in the
repo — in-process ``GlobalController.submit(spec)``, the scheduler daemon's
filesystem inbox (``service.client`` / ``service.daemon``), and the scenario
suite (``benchmarks/scenarios.py``).  A spec names *what* to run (a workload
reference resolvable on the daemon side, or an in-process payload), *how much*
(iterations), and the admission-relevant hints (priority, budget hint,
fingerprint).  It deliberately does NOT carry live JAX objects on the wire:
``payload`` is an in-process escape hatch excluded from serialization.

Lifecycle states live here too so the store, queue, daemon and client all
share one vocabulary.
"""

from __future__ import annotations

import dataclasses
import enum
from typing import Any, Dict, Optional, Tuple

# Wire-format schema version.  Bump on breaking field changes; readers treat a
# mismatched schema as absent (same tolerance rule as core/experience.py).
# Schema 2 added `kind` + `serve` (the serving plane); schema-1 specs are
# still readable — they default to kind="train".
SPEC_SCHEMA_VERSION = 2
_READABLE_SCHEMAS = (1, 2)


class JobState(str, enum.Enum):
    """Lifecycle of a job inside the scheduler service.

    QUEUED -> ADMITTED -> RUNNING -> DONE | FAILED
                   \\-> (REJECTED when it can never fit)
    """

    QUEUED = "QUEUED"
    ADMITTED = "ADMITTED"
    RUNNING = "RUNNING"
    DONE = "DONE"
    FAILED = "FAILED"
    REJECTED = "REJECTED"

    @property
    def terminal(self) -> bool:
        return self in (JobState.DONE, JobState.FAILED, JobState.REJECTED)


@dataclasses.dataclass(frozen=True)
class ServeParams:
    """Wire description of a serving job's workload shape.

    ``arch`` names the model config; ``trace`` names a deterministic
    request-arrival generator (``repro.serving.traces.make_trace``) so the
    request mix crosses the wire as a recipe, not a request list.
    """

    arch: str = "tinyllama-1.1b"
    max_sequences: int = 4
    n_requests: int = 8
    prompt_len: int = 8
    gen_len: int = 8
    trace: str = "steady"
    mean_gap: float = 0.002
    block_tokens: int = 4
    seed: int = 0

    def __post_init__(self) -> None:
        for field in ("max_sequences", "n_requests", "prompt_len",
                      "gen_len", "block_tokens"):
            if getattr(self, field) < 1:
                raise ValueError(f"ServeParams.{field} must be >= 1")

    def to_dict(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, data: Dict[str, Any]) -> "ServeParams":
        if not isinstance(data, dict):
            raise ValueError("ServeParams wire form must be a JSON object")
        known = {f.name for f in dataclasses.fields(cls)}
        try:
            return cls(**{k: v for k, v in data.items() if k in known})
        except TypeError as exc:
            raise ValueError(f"malformed ServeParams: {exc}") from exc


@dataclasses.dataclass(frozen=True)
class JobSpec:
    """Frozen, serializable description of one schedulable job.

    Fields
    ------
    job_id:
        Unique id; also the idempotency key at the daemon inbox (a duplicate
        submission of a known non-terminal job_id is ignored).
    kind:
        ``"train"`` (the default; everything before PR 8) or ``"serve"`` —
        a continuous-batching decode job whose KV-cache blocks are the
        schedulable tensors.  Serve jobs resolve their workload through
        :func:`repro.service.workloads.resolve_serve_workload` and carry
        their shape in ``serve``.
    serve:
        :class:`ServeParams` for ``kind="serve"`` jobs (auto-filled with
        defaults when omitted); must be None for train jobs.
    workload:
        Reference the daemon can resolve to ``(step_fn, params, opt_state,
        batch)``: either a name registered via
        :func:`repro.service.workloads.register_workload` (e.g. ``"mlp"``) or
        a ``"module:attr"`` import path to a zero-side-effect factory.
    workload_params:
        Keyword arguments forwarded to the workload factory (sizes, batch,
        seed ...).  Must be JSON-serializable.
    priority:
        Arbiter share weight.  ``None`` defers to the scheduler config
        (``SchedulerConfig.job_priorities`` or 1.0), matching the semantics
        of the deprecated ``launch(..., priority=None)``.
    iterations:
        Training iterations to run once admitted.
    budget_hint_bytes:
        Optional caller-supplied upper bound on peak memory; used by
        admission when no experience fingerprint matches.
    offset_frac:
        Arrival offset in mean-iteration units — used by the scenario suite's
        virtual-time replays; the live daemon ignores it (arrival is when the
        inbox file lands).
    fingerprint:
        Optional precomputed structural fingerprint (``ExperienceStore``
        key).  Normally the controller computes it from the captured
        sequence; a client that already knows it can pin it here.
    schedule:
        When False the job runs unscheduled (vanilla baseline) — used by
        benchmarks.
    payload:
        In-process only: a ``(step_fn, params, opt_state, batch)`` tuple that
        bypasses workload resolution.  Excluded from ``to_dict``; a spec that
        crossed the wire never has one.
    """

    job_id: str
    kind: str = "train"
    serve: Optional[ServeParams] = None
    workload: Optional[str] = None
    workload_params: Dict[str, Any] = dataclasses.field(default_factory=dict)
    priority: Optional[float] = None
    iterations: int = 1
    budget_hint_bytes: Optional[int] = None
    offset_frac: float = 0.0
    fingerprint: Optional[str] = None
    schedule: bool = True
    payload: Optional[Tuple[Any, ...]] = dataclasses.field(
        default=None, repr=False, compare=False
    )

    def __post_init__(self) -> None:
        if not self.job_id or not isinstance(self.job_id, str):
            raise ValueError("JobSpec.job_id must be a non-empty string")
        if self.kind not in ("train", "serve"):
            raise ValueError(
                f"JobSpec.kind must be 'train' or 'serve', got {self.kind!r}")
        if isinstance(self.serve, dict):  # wire form straight off JSON
            object.__setattr__(self, "serve", ServeParams.from_dict(self.serve))
        if self.kind == "serve" and self.serve is None:
            object.__setattr__(self, "serve", ServeParams())
        if self.kind == "train" and self.serve is not None:
            raise ValueError("JobSpec.serve is only valid with kind='serve'")
        if self.iterations < 1:
            raise ValueError(f"JobSpec.iterations must be >= 1, got {self.iterations}")
        if self.priority is not None and self.priority <= 0:
            raise ValueError(f"JobSpec.priority must be > 0, got {self.priority}")
        if self.budget_hint_bytes is not None and self.budget_hint_bytes <= 0:
            raise ValueError("JobSpec.budget_hint_bytes must be > 0 when given")
        if self.payload is not None and len(self.payload) != 4:
            raise ValueError(
                "JobSpec.payload must be (step_fn, params, opt_state, batch)"
            )

    # -- wire format ---------------------------------------------------------

    def to_dict(self) -> Dict[str, Any]:
        """JSON-safe wire form.  ``payload`` never crosses the wire."""
        return {
            "schema": SPEC_SCHEMA_VERSION,
            "job_id": self.job_id,
            "kind": self.kind,
            "serve": self.serve.to_dict() if self.serve else None,
            "workload": self.workload,
            "workload_params": dict(self.workload_params),
            "priority": self.priority,
            "iterations": self.iterations,
            "budget_hint_bytes": self.budget_hint_bytes,
            "offset_frac": self.offset_frac,
            "fingerprint": self.fingerprint,
            "schedule": self.schedule,
        }

    @classmethod
    def from_dict(cls, data: Dict[str, Any]) -> "JobSpec":
        """Inverse of :meth:`to_dict`.

        Unknown keys are ignored (forward compatibility); a schema mismatch
        or a malformed field raises ``ValueError`` so callers can apply the
        skip-not-crash tolerance rule.
        """
        if not isinstance(data, dict):
            raise ValueError("JobSpec wire form must be a JSON object")
        schema = data.get("schema", SPEC_SCHEMA_VERSION)
        if schema not in _READABLE_SCHEMAS:
            raise ValueError(f"unsupported JobSpec schema {schema!r}")
        known = {f.name for f in dataclasses.fields(cls)} - {"payload"}
        kwargs = {k: v for k, v in data.items() if k in known}
        try:
            return cls(**kwargs)
        except TypeError as exc:  # e.g. job_id missing entirely
            raise ValueError(f"malformed JobSpec: {exc}") from exc
