"""Workload registry: resolve a ``JobSpec.workload`` reference to a payload.

A *payload* is the tuple ``(step_fn, params, opt_state, batch)`` that
``GlobalController`` captures and runs.  Clients submit specs naming a
workload instead of shipping live JAX objects, so a daemon in another
process can rebuild the job from the wire form.

Resolution order:

1. ``spec.payload`` — in-process escape hatch, wins outright.
2. A name registered with :func:`register_workload`.
3. A ``"module:attr"`` import path to a factory with the same signature.

Factories take ``**spec.workload_params`` and return the payload tuple.
The builtin ``"mlp"`` workload builds the same tiny MLP train step the test
suite and scenario suite use.

Serving jobs (``spec.kind == "serve"``) resolve through a parallel registry:
a *serve factory* takes the spec's :class:`~repro.service.jobspec.ServeParams`
and returns ``(serving_engine, requests)`` — a
:class:`~repro.serving.engine.ServingEngine` plus the deterministic request
trace it should serve.  The builtin ``"lm"`` serve workload builds both from
the named model config and trace generator.
"""

from __future__ import annotations

import importlib
from typing import Any, Callable, Dict, Tuple

from .jobspec import JobSpec

Payload = Tuple[Any, Any, Any, Any]
WorkloadFactory = Callable[..., Payload]

_REGISTRY: Dict[str, WorkloadFactory] = {}


def register_workload(name: str, factory: WorkloadFactory) -> None:
    """Register ``factory`` under ``name`` (overwrites an existing entry)."""
    if not name or ":" in name:
        raise ValueError(f"invalid workload name {name!r}")
    _REGISTRY[name] = factory


def registered_workloads() -> Tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


def resolve_workload(spec: JobSpec) -> Payload:
    """Resolve ``spec`` to ``(step_fn, params, opt_state, batch)``.

    Raises ``ValueError`` when the spec names nothing resolvable — the daemon
    turns that into a REJECTED job rather than crashing.
    """
    if spec.payload is not None:
        return spec.payload  # type: ignore[return-value]
    if not spec.workload:
        raise ValueError(
            f"job {spec.job_id!r}: spec has neither payload nor workload"
        )
    factory = _REGISTRY.get(spec.workload)
    if factory is None and ":" in spec.workload:
        mod_name, _, attr = spec.workload.partition(":")
        try:
            factory = getattr(importlib.import_module(mod_name), attr)
        except (ImportError, AttributeError) as exc:
            raise ValueError(
                f"job {spec.job_id!r}: cannot import workload "
                f"{spec.workload!r}: {exc}"
            ) from exc
    if factory is None:
        raise ValueError(
            f"job {spec.job_id!r}: unknown workload {spec.workload!r} "
            f"(registered: {', '.join(registered_workloads()) or 'none'})"
        )
    return factory(**dict(spec.workload_params))


# -- serve workloads ---------------------------------------------------------

ServeFactory = Callable[..., Tuple[Any, Any]]

_SERVE_REGISTRY: Dict[str, ServeFactory] = {}


def register_serve_workload(name: str, factory: ServeFactory) -> None:
    """Register a serve factory: ``factory(serve_params) -> (engine,
    requests)``.  Overwrites an existing entry."""
    if not name or ":" in name:
        raise ValueError(f"invalid serve workload name {name!r}")
    _SERVE_REGISTRY[name] = factory


def registered_serve_workloads() -> Tuple[str, ...]:
    return tuple(sorted(_SERVE_REGISTRY))


def resolve_serve_workload(spec: JobSpec) -> Tuple[Any, Any]:
    """Resolve a ``kind="serve"`` spec to ``(serving_engine, requests)``.

    Same tolerance contract as :func:`resolve_workload`: an unresolvable
    spec raises ``ValueError`` and the daemon records it REJECTED.
    """
    if spec.kind != "serve":
        raise ValueError(f"job {spec.job_id!r}: not a serve spec")
    name = spec.workload or "lm"
    factory = _SERVE_REGISTRY.get(name)
    if factory is None and ":" in name:
        mod_name, _, attr = name.partition(":")
        try:
            factory = getattr(importlib.import_module(mod_name), attr)
        except (ImportError, AttributeError) as exc:
            raise ValueError(
                f"job {spec.job_id!r}: cannot import serve workload "
                f"{name!r}: {exc}"
            ) from exc
    if factory is None:
        raise ValueError(
            f"job {spec.job_id!r}: unknown serve workload {name!r} "
            f"(registered: {', '.join(registered_serve_workloads()) or 'none'})"
        )
    return factory(spec.serve)


def make_lm_serving(sp) -> Tuple[Any, Any]:
    """Builtin ``"lm"`` serve workload: a :class:`ServingEngine` over the
    named (reduced) model config plus the named deterministic trace."""
    from ..serving.engine import ServingEngine
    from ..serving.traces import make_trace

    engine = ServingEngine(sp.arch, max_sequences=sp.max_sequences,
                           max_len=sp.prompt_len + sp.gen_len, seed=sp.seed)
    requests = make_trace(sp.trace, sp.n_requests, seed=sp.seed,
                          prompt_len=sp.prompt_len, gen_len=sp.gen_len,
                          mean_gap=sp.mean_gap)
    return engine, requests


register_serve_workload("lm", make_lm_serving)


# -- builtin workloads -------------------------------------------------------


# size-class presets shared with the scenario suite's smoke shapes, so a
# wire submission can say {"size": "medium"} instead of raw layer sizes
MLP_SIZE_PRESETS = {
    "small": ((32, 64, 64, 8), 8),
    "medium": ((64, 128, 128, 8), 16),
    "large": ((64, 256, 256, 8), 16),
}


def make_mlp(sizes=None, batch=None, seed=0, size=None) -> Payload:
    """Tiny MLP + AdamW train step — the repo's canonical smoke workload.

    Either pass explicit ``sizes``/``batch`` or a ``size`` class name from
    :data:`MLP_SIZE_PRESETS`."""
    import jax
    import jax.numpy as jnp

    from ..optim.adam import adamw_init, adamw_update

    if size is not None:
        if size not in MLP_SIZE_PRESETS:
            raise ValueError(f"unknown mlp size class {size!r}")
        preset_sizes, preset_batch = MLP_SIZE_PRESETS[size]
        sizes = sizes or preset_sizes
        batch = batch or preset_batch
    sizes = list(sizes or (32, 64, 64, 8))
    batch = batch or 8
    key = jax.random.PRNGKey(seed)
    params = []
    for i in range(len(sizes) - 1):
        key, k = jax.random.split(key)
        params.append(
            {"w": jax.random.normal(k, (sizes[i], sizes[i + 1])) * 0.02,
             "b": jnp.zeros(sizes[i + 1])}
        )
    opt_state = adamw_init(params)
    x = jax.random.normal(jax.random.PRNGKey(seed + 1), (batch, sizes[0]))
    y = jax.random.normal(jax.random.PRNGKey(seed + 2), (batch, sizes[-1]))

    def forward(ps, inp):
        h = inp
        for i, p in enumerate(ps):
            h = h @ p["w"] + p["b"]
            if i < len(ps) - 1:
                h = jnp.tanh(h)
        return h

    def train_step(ps, opt, data):
        xb, yb = data

        def loss_fn(p):
            return jnp.mean((forward(p, xb) - yb) ** 2)

        loss, grads = jax.value_and_grad(loss_fn)(ps)
        ps, opt = adamw_update(ps, grads, opt, lr=1e-3)
        return ps, opt, loss

    return train_step, params, opt_state, (x, y)


register_workload("mlp", make_mlp)
