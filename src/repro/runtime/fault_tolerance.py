"""Fault tolerance: the restart loop, preemption handling, and failure
injection for tests.

`resilient_train_loop` wraps a step function with:
  * periodic async checkpoints (CheckpointManager),
  * automatic restore-and-continue on step failure (up to max_restarts) —
    a crashed host on a real pod surfaces exactly like this: the
    coordinator restarts the job and every host resumes from the last
    committed step,
  * SIGTERM/preemption → synchronous checkpoint then clean exit
    (maintenance events on TPU pods send exactly this),
  * straggler hooks (runtime.stragglers) fed with per-step host timings.
"""
from __future__ import annotations

import dataclasses
import signal
import time
from typing import Any, Callable, Dict, Optional

import jax

from repro.checkpoint.manager import CheckpointManager
from .stragglers import StragglerMonitor


@dataclasses.dataclass
class FTConfig:
    ckpt_dir: str = "/tmp/repro_ckpt"
    ckpt_every: int = 50
    keep: int = 3
    max_restarts: int = 3
    async_save: bool = True


class Preempted(Exception):
    pass


@dataclasses.dataclass
class TrainResult:
    final_step: int
    restarts: int
    preempted: bool
    metrics_history: list


def resilient_train_loop(step_fn: Callable, state: Any, data_iter,
                         n_steps: int, ft: Optional[FTConfig] = None,
                         data_stream=None,
                         monitor: Optional[StragglerMonitor] = None,
                         fail_at: Optional[Dict[int, int]] = None,
                         install_signal_handler: bool = False) -> TrainResult:
    """state = (params, opt_state).  step_fn(params, opt, batch) ->
    (params, opt, metrics).

    `fail_at` maps step -> how many times to raise there (failure
    injection for the integration tests).
    """
    ft = ft or FTConfig()
    mgr = CheckpointManager(ft.ckpt_dir, keep=ft.keep)
    monitor = monitor or StragglerMonitor(n_hosts=1)
    preempt = {"flag": False}
    if install_signal_handler:
        def _on_term(signum, frame):
            preempt["flag"] = True
        signal.signal(signal.SIGTERM, _on_term)

    start = 0
    latest = mgr.latest_step()
    if latest is not None:
        state, meta = mgr.restore(latest, template=state)
        start = int(meta["step"]) + 1
        if data_stream is not None and "data_state" in meta:
            data_stream.load_state_dict(meta["data_state"])

    restarts = 0
    fail_budget = dict(fail_at or {})
    history = []
    step = start
    while step < n_steps:
        try:
            if preempt["flag"]:
                mgr.wait()
                mgr.save(step - 1, state, _extra(data_stream, step - 1))
                return TrainResult(step - 1, restarts, True, history)
            batch = next(data_iter)
            if fail_budget.get(step, 0) > 0:
                fail_budget[step] -= 1
                raise RuntimeError(f"injected failure at step {step}")
            t0 = time.perf_counter()
            params, opt, metrics = step_fn(state[0], state[1], batch)
            jax.block_until_ready(metrics)
            monitor.record(host=0, step=step,
                           seconds=time.perf_counter() - t0)
            state = (params, opt)
            history.append({k: float(v) for k, v in metrics.items()})
            if ft.ckpt_every and step % ft.ckpt_every == 0 and step > start:
                payload = _extra(data_stream, step)
                if ft.async_save:
                    mgr.save_async(step, state, payload)
                else:
                    mgr.save(step, state, payload)
            step += 1
        except (RuntimeError, jax.errors.JaxRuntimeError) as e:
            restarts += 1
            if restarts > ft.max_restarts:
                raise
            mgr.wait()
            latest = mgr.latest_step()
            if latest is not None:
                state, meta = mgr.restore(latest, template=state)
                step = int(meta["step"]) + 1
                if data_stream is not None and "data_state" in meta:
                    data_stream.load_state_dict(meta["data_state"])
            else:
                step = start
    mgr.wait()
    mgr.save(n_steps - 1, state, _extra(data_stream, n_steps - 1))
    return TrainResult(n_steps - 1, restarts, False, history)


def _extra(data_stream, step: int) -> Dict:
    out: Dict[str, Any] = {}
    if data_stream is not None:
        ds = data_stream.state_dict()
        ds["step"] = step + 1
        out["data_state"] = ds
    return out
