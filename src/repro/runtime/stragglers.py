"""Straggler detection & mitigation.

On a 1000+-node pod, slow hosts (thermal throttling, failing HBM, noisy
neighbours) stretch every synchronous step to the slowest participant.
This module gives the training loop:

  * per-host step-time collection (`record`),
  * robust z-score detection against the rolling fleet median,
  * mitigation hooks: `rebalance()` proposes a data-shard reassignment
    (shrink the straggler's shard), and `should_evict()` flags hosts for
    replacement when they stay slow — the coordinator then triggers the
    elastic-restore path (runtime.elastic).
"""
from __future__ import annotations

import collections
import dataclasses
import statistics
from typing import Deque, Dict, List, Optional, Tuple


@dataclasses.dataclass
class StragglerConfig:
    window: int = 20          # rolling steps per host
    z_threshold: float = 3.0  # robust z-score to flag
    evict_after: int = 10     # consecutive flagged steps before eviction
    min_samples: int = 5


class StragglerMonitor:
    def __init__(self, n_hosts: int, config: Optional[StragglerConfig] = None):
        self.n_hosts = n_hosts
        self.cfg = config or StragglerConfig()
        self.times: Dict[int, Deque[float]] = {
            h: collections.deque(maxlen=self.cfg.window)
            for h in range(n_hosts)}
        self.flag_streak: Dict[int, int] = {h: 0 for h in range(n_hosts)}

    # ------------------------------------------------------------------
    def record(self, host: int, step: int, seconds: float) -> None:
        self.times.setdefault(
            host, collections.deque(maxlen=self.cfg.window)).append(seconds)

    def host_median(self, host: int) -> Optional[float]:
        t = self.times.get(host)
        return statistics.median(t) if t else None

    def stragglers(self) -> List[Tuple[int, float]]:
        """Hosts whose median step time deviates by > z_threshold robust
        z-scores from the fleet median (MAD-based)."""
        meds = {h: self.host_median(h) for h in self.times}
        vals = [m for m in meds.values() if m is not None]
        if len(vals) < max(2, self.cfg.min_samples):
            return []
        fleet = statistics.median(vals)
        mad = statistics.median([abs(v - fleet) for v in vals]) or 1e-9
        out = []
        for h, m in meds.items():
            if m is None or len(self.times[h]) < self.cfg.min_samples:
                continue
            z = 0.6745 * (m - fleet) / mad
            if z > self.cfg.z_threshold:
                out.append((h, z))
                self.flag_streak[h] = self.flag_streak.get(h, 0) + 1
            else:
                self.flag_streak[h] = 0
        return sorted(out, key=lambda x: -x[1])

    # ------------------------------------------------------------------
    def should_evict(self) -> List[int]:
        return [h for h, streak in self.flag_streak.items()
                if streak >= self.cfg.evict_after]

    def rebalance(self, shards_per_host: Dict[int, int]) -> Dict[int, int]:
        """Move one data shard from each straggler to the fastest host —
        classic work-shedding mitigation (applied between steps, when the
        data pipeline can re-slice)."""
        plan = dict(shards_per_host)
        strag = [h for h, _ in self.stragglers()]
        if not strag:
            return plan
        meds = {h: self.host_median(h) or float("inf") for h in plan}
        fastest = min(plan, key=lambda h: meds.get(h, float("inf")))
        for h in strag:
            if plan.get(h, 0) > 1:
                plan[h] -= 1
                plan[fastest] = plan.get(fastest, 0) + 1
        return plan
