"""Elastic scaling: re-mesh and re-shard a live training state.

When the fleet grows or shrinks (preemptions, capacity changes, straggler
eviction), the coordinator rebuilds the mesh over the surviving devices and
the training state must follow.  `reshard_state` moves every leaf onto the
new mesh's shardings (jax.device_put resharding — on real pods this is the
cross-host resharding path; combined with CheckpointManager.restore it also
covers the restart-on-new-topology case).

`plan_elastic_mesh` picks the largest (data × model) grid that preserves
the model-parallel degree when possible (TP degree changes force a weight
re-layout; DP degree changes only re-slice the batch).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Tuple

import jax

from repro.launch.sharding import MeshRules


@dataclasses.dataclass
class ElasticPlan:
    mesh_shape: Tuple[int, ...]
    axes: Tuple[str, ...]
    kept_model_degree: bool
    dp_degree: int
    tp_degree: int


def plan_elastic_mesh(n_devices: int, prev_tp: int) -> ElasticPlan:
    """Largest usable grid: keep TP degree if it divides the new world,
    else the largest power-of-two TP that fits."""
    tp = prev_tp if n_devices % prev_tp == 0 else _largest_pow2_divisor(
        n_devices, prev_tp)
    dp = n_devices // tp
    return ElasticPlan(mesh_shape=(dp, tp), axes=("data", "model"),
                       kept_model_degree=(tp == prev_tp),
                       dp_degree=dp, tp_degree=tp)


def _largest_pow2_divisor(n: int, cap: int) -> int:
    t = 1
    while t * 2 <= cap and n % (t * 2) == 0:
        t *= 2
    return t


def reshard_state(state: Any, axes_tree: Any, new_mesh,
                  cfg=None, fsdp: bool = True) -> Tuple[Any, MeshRules]:
    """Move a pytree onto a new mesh.  Returns (state, new rules)."""
    rules = MeshRules(new_mesh, cfg=cfg, fsdp=fsdp)
    shardings = rules.shardings_for(
        axes_tree, jax.tree.map(lambda x: x, state)) \
        if _has_shapes(state) else rules.param_shardings(axes_tree)
    new_state = jax.tree.map(
        lambda x, s: jax.device_put(jax.numpy.asarray(x), s),
        state, shardings)
    return new_state, rules


def _has_shapes(tree) -> bool:
    leaves = jax.tree.leaves(tree)
    return bool(leaves) and all(hasattr(l, "shape") for l in leaves)
