"""DEPRECATED serving driver — forwards to ``repro.serving.cli``.

The monolithic ``main`` here (model setup + token-by-token prefill + greedy
decode in one function) was dismantled into the serving plane:

* :class:`repro.serving.engine.ServingEngine` — the maxtext-shaped
  ``prefill(prompt) -> insert(slot) -> generate()`` runtime;
* :class:`repro.serving.session.ServeSession` — the continuous-batching
  loop with KV-cache residency scheduling;
* ``repro.serving.cli`` — the flag-parsing entry point.

Kept one release as a shim (same migration pattern as
``GlobalController.launch()`` -> ``submit()``): old flags are translated
where they map (``--batch`` becomes ``--max-sequences``).
"""
from __future__ import annotations

import sys
import warnings


def main(argv=None) -> int:
    warnings.warn(
        "repro.launch.serve is deprecated; use repro.serving.cli (the "
        "ServingEngine-based driver) instead",
        DeprecationWarning, stacklevel=2)
    from repro.serving.cli import main as serving_main
    argv = list(argv) if argv is not None else sys.argv[1:]
    argv = ["--max-sequences" if a == "--batch" else
            a.replace("--batch=", "--max-sequences=", 1) if
            a.startswith("--batch=") else a for a in argv]
    return serving_main(argv)


if __name__ == "__main__":
    raise SystemExit(main())
