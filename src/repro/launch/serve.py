"""Serving driver: batched autoregressive decoding with a KV/SSM cache.

    PYTHONPATH=src python -m repro.launch.serve --arch tinyllama-1.1b \
        --reduced --batch 4 --prompt-len 32 --gen 32

Prefill runs the chunked forward (logits for the last position seed the
first sampled token... greedy here); decode then steps the cache one token
at a time.  The same `serve_step` is what the decode_* dry-run cells lower
on the production mesh.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.launch.mesh import make_host_mesh
from repro.launch.sharding import MeshRules, use_rules
from repro.launch.steps import build_serve_step
from repro.models.registry import get_model


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=32)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
        if cfg.n_experts:
            cfg.moe_impl = "dense"
    api = get_model(cfg)
    mesh = make_host_mesh()
    rules = MeshRules(mesh, cfg=cfg)
    max_len = args.prompt_len + args.gen

    params, _ = api.init(jax.random.PRNGKey(0))
    cache, _ = api.init_cache(args.batch, max_len)
    serve_step = build_serve_step(api, rules)
    with use_rules(rules):
        jitted = jax.jit(serve_step, donate_argnums=(1,))

    key = jax.random.PRNGKey(7)
    prompt = jax.random.randint(key, (args.batch, args.prompt_len), 0,
                                min(cfg.vocab_size, 64))
    extra = {}
    if cfg.enc_dec:
        extra["enc_out"] = jax.random.normal(
            key, (args.batch, max(args.prompt_len // cfg.enc_seq_ratio, 8),
                  cfg.d_model)).astype(cfg.dtype)

    # prefill: feed the prompt token-by-token through the cache (simple and
    # uniform across arch families; chunked prefill is the forward path)
    tok = prompt[:, :1]
    t0 = time.time()
    generated = []
    for i in range(max_len - 1):
        batch = {"tokens": tok, **extra}
        logits, cache = jitted(params, cache, batch, jnp.int32(i))
        nxt = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
        if i + 1 < args.prompt_len:
            tok = prompt[:, i + 1:i + 2]
        else:
            tok = nxt
            generated.append(np.asarray(nxt)[:, 0])
    dt = time.time() - t0
    gen = np.stack(generated, axis=1)
    print(f"[serve] arch={cfg.name} batch={args.batch} "
          f"steps={max_len - 1} ({(max_len - 1) * args.batch / dt:.1f} tok/s)")
    print("[serve] sample generations (token ids):")
    for row in gen[:2]:
        print("   ", row[:16].tolist())
    assert np.isfinite(gen).all()
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
