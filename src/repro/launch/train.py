"""End-to-end training driver.

    PYTHONPATH=src python -m repro.launch.train --arch tinyllama-1.1b \
        --reduced --steps 200 --batch 8 --seq 128

Composes: config → model → mesh/sharding rules → TENSILE memory planning
(remat/offload decisions under a device budget) → data pipeline with
prefetch → resilient train loop (async checkpoints, restart-on-failure,
straggler monitor).  On this container it runs reduced configs on a small
host mesh; the same driver scales to the production mesh on TPU.
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.data.pipeline import DataConfig, Prefetcher, TokenStream
from repro.launch.mesh import make_host_mesh
from repro.launch.sharding import MeshRules, use_rules
from repro.launch.steps import (TrainStepConfig, build_train_step,
                                opt_state_for)
from repro.models.registry import get_model
from repro.runtime.fault_tolerance import FTConfig, resilient_train_loop
from repro.runtime.stragglers import StragglerMonitor


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--tensile-budget-mb", type=float, default=0.0,
                    help="device memory budget; >0 runs the TENSILE "
                         "planner and applies its remat decisions")
    ap.add_argument("--grad-compression", choices=["none", "int8"],
                    default="none")
    ap.add_argument("--d-model", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
        if cfg.n_experts:
            cfg.moe_impl = "dense" if cfg.n_experts <= 8 else "scatter"
    if args.d_model:
        cfg.d_model = args.d_model
    api = get_model(cfg)

    mesh = make_host_mesh()
    rules = MeshRules(mesh, cfg=cfg)
    print(f"[train] arch={cfg.name} params≈{cfg.param_count()/1e6:.1f}M "
          f"mesh={dict(mesh.shape)}")

    params, axes = api.init(jax.random.PRNGKey(0))
    use_comp = args.grad_compression == "int8"
    opt = opt_state_for(params)
    if use_comp:
        from repro.optim.adam import adamw_init
        opt = adamw_init(params, grad_compression=True)

    # ---- TENSILE planning (optional) ---------------------------------
    remat_policy = None
    if args.tensile_budget_mb > 0:
        from repro.core import (capture_train_step, schedule_for_budget)
        from repro.core.jax_integration import make_remat_policy
        from repro.optim.adam import adamw_update

        def probe_step(p, o, batch):
            def loss_of(pp):
                return api.loss(pp, batch)
            loss, grads = jax.value_and_grad(loss_of)(p)
            p2, o2 = adamw_update(p, grads, o, lr=args.lr)
            return p2, o2, loss

        dcfg = DataConfig(seq_len=args.seq, global_batch=args.batch,
                          vocab_size=cfg.vocab_size,
                          frontend=cfg.frontend, n_patches=cfg.n_patches,
                          d_model=cfg.d_model, enc_dec=cfg.enc_dec)
        sample = TokenStream(dcfg).batch_at(0)
        seq, _ = capture_train_step(probe_step, params, opt, sample)
        decisions = schedule_for_budget(
            seq, int(args.tensile_budget_mb * 2**20))
        print(f"[tensile] {decisions.summary()}")
        remat_policy = make_remat_policy(decisions)

    tcfg = TrainStepConfig(
        learning_rate=args.lr,
        grad_compression=("int8" if use_comp else None),
        remat_policy=remat_policy)
    step_fn = build_train_step(api, rules, tcfg)
    p_shard = rules.param_shardings(axes)
    with use_rules(rules):
        jitted = jax.jit(step_fn, donate_argnums=(0, 1))

    dcfg = DataConfig(seq_len=args.seq, global_batch=args.batch,
                      vocab_size=cfg.vocab_size, frontend=cfg.frontend,
                      n_patches=cfg.n_patches, d_model=cfg.d_model,
                      enc_dec=cfg.enc_dec)
    stream = TokenStream(dcfg)
    prefetch = Prefetcher(stream)

    monitor = StragglerMonitor(n_hosts=1)
    ft = FTConfig(ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every)

    t0 = time.time()
    losses = []

    def logging_step(p, o, batch):
        p, o, m = jitted(p, o, batch)
        losses.append(float(m["loss"]))
        if len(losses) % args.log_every == 0:
            dt = time.time() - t0
            print(f"  step {len(losses):5d} loss {losses[-1]:.4f} "
                  f"({len(losses)/dt:.2f} it/s)")
        return p, o, m

    result = resilient_train_loop(
        logging_step, (params, opt), iter(prefetch), args.steps,
        ft=ft, data_stream=stream, monitor=monitor)
    prefetch.close()
    first = np.mean(losses[:10]) if len(losses) >= 10 else losses[0]
    last = np.mean(losses[-10:])
    print(f"[train] done: steps={result.final_step + 1} "
          f"restarts={result.restarts} loss {first:.4f} -> {last:.4f}")
    assert np.isfinite(last), "training diverged"
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
