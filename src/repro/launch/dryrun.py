import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
"""Multi-pod dry-run (deliverable (e)).

For every (architecture × input shape × mesh) cell:
  * build abstract params / optimizer state / batch / cache
    (ShapeDtypeStruct — no allocation),
  * jit the train_step (train_4k) or serve_step (decode_*/long_*) or
    prefill forward (prefill_32k) with full in/out shardings,
  * ``.lower().compile()`` — proving the sharding config is coherent,
  * record ``memory_analysis()`` (fits?), ``cost_analysis()`` (FLOPs/bytes)
    and the collective mix parsed from the compiled HLO (§Roofline inputs),
  * write one JSON artifact per cell under ``experiments/artifacts/``.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch tinyllama-1.1b \
        --shape train_4k --multi-pod both
    PYTHONPATH=src python -m repro.launch.dryrun --all
"""
import argparse
import json
import re
import sys
import time
import traceback
from typing import Any, Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, list_configs, shapes_for, \
    skipped_shapes_for, ALL_SHAPES
from repro.launch.mesh import make_production_mesh
from repro.launch.sharding import MeshRules
from repro.launch.steps import (TrainStepConfig, build_prefill_step,
                                build_serve_step, build_train_step,
                                offloaded_bytes, opt_state_for,
                                opt_state_shardings)
from repro.models.registry import get_model

ARTIFACT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                            "experiments", "artifacts")

# v5e hardware constants (per chip) for §Roofline
PEAK_FLOPS = 197e12        # bf16
HBM_BW = 819e9             # B/s
ICI_BW = 50e9              # B/s per link

_COLL_RE = re.compile(
    r"=\s*([a-z0-9]+)\[([0-9,]*)\]\S*\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(-start|-done)?\(")
_GROUP_RE = re.compile(r"replica_groups=\{\{([0-9,]+)\}")
_GROUP_RE2 = re.compile(r"replica_groups=\[(\d+),(\d+)\]")

_DTYPE_BYTES = {"f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "s8": 1,
                "u8": 1, "pred": 1, "f64": 8, "s64": 8, "u64": 8, "s16": 2,
                "u16": 2, "f8e4m3fn": 1, "f8e5m2": 1, "c64": 8}


def parse_collectives(hlo_text: str) -> Dict[str, Dict[str, float]]:
    """Per-collective-kind: op count, raw tensor bytes, and modeled
    wire bytes per device (ring: all-reduce 2(n-1)/n, gather/scatter
    (n-1)/n, permute 1)."""
    out: Dict[str, Dict[str, float]] = {}
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        dtype, dims, kind, suffix = (m.group(1), m.group(2), m.group(3),
                                     m.group(4))
        if suffix == "-done":
            continue  # counted at the matching -start
        nbytes = _DTYPE_BYTES.get(dtype, 4)
        numel = 1
        if dims:
            for d in dims.split(","):
                if d:
                    numel *= int(d)
        size = numel * nbytes
        g = _GROUP_RE.search(line)
        if g:
            n = len(g.group(1).split(","))
        else:
            g2 = _GROUP_RE2.search(line)
            n = int(g2.group(2)) if g2 else 2
        n = max(n, 2)
        if kind == "all-reduce":
            wire = 2 * size * (n - 1) / n
        elif kind == "collective-permute":
            wire = size
        else:  # all-gather result / reduce-scatter operand / all-to-all
            wire = size * (n - 1) / n
        slot = out.setdefault(kind, {"count": 0, "bytes": 0.0,
                                     "wire_bytes": 0.0})
        slot["count"] += 1
        slot["bytes"] += size
        slot["wire_bytes"] += wire
    return out


def _strip_layer_dim(tree):
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape[1:], s.dtype), tree)


def _is_axes_leaf(x):
    return isinstance(x, tuple) and all(
        isinstance(e, (str, type(None))) for e in x)


def _strip_layer_axes(axes_tree):
    return jax.tree.map(lambda a: tuple(a[1:]) if a and a[0] == "layers"
                        else tuple(a), axes_tree, is_leaf=_is_axes_leaf)


def _act_shard(rules, logical, sds):
    """Sharding for one activation ShapeDtypeStruct (divisibility-checked)."""
    return rules.shardings_for(logical, sds)


def _body_cost(cfg, shape, rules, api, params, batch,
               axes=None) -> Dict[str, Any]:
    """Per-scan-iteration cost of the layer stack.

    XLA's HloCostAnalysis visits a while-loop body ONCE (verified
    empirically), so the main compile undercounts flops/bytes/collectives
    by ~n_repeats×.  We compile the superblock body separately — under the
    same mesh/shardings and matching the real program's remat behaviour
    (grad of a checkpointed body = fwd + recompute-fwd + bwd, exactly the
    per-extra-layer cost of the scanned train step) — and scale by
    (trips − 1).
    """
    from repro.models import transformer as T
    from repro.models import whisper as W
    from repro.models import layers as L

    results = []

    def shard_of(tree, axes_tree=None):
        if axes_tree is not None:
            return rules.shardings_for(axes_tree, tree)
        return rules.batch_sharding(tree)

    def compile_body(fn, *specs, shardings=None):
        L.set_active_rules(rules)
        try:
            jitted = jax.jit(fn, in_shardings=shardings)
            return jitted.lower(*specs).compile()
        finally:
            L.set_active_rules(None)

    dt = jnp.dtype(cfg.dtype)
    if cfg.enc_dec:
        b = shape.global_batch
        s_enc = shape.seq_len
        s_dec = max(shape.seq_len // cfg.enc_seq_ratio, 8)
        if shape.kind == "decode":
            s_enc = max(shape.seq_len // cfg.enc_seq_ratio, 8)
        p_enc = _strip_layer_dim(params["enc_blocks"])
        p_dec = _strip_layer_dim(params["dec_blocks"])
        if axes is not None:
            pe_sh = rules.shardings_for(
                _strip_layer_axes(axes["enc_blocks"]), p_enc)
            pd_sh = rules.shardings_for(
                _strip_layer_axes(axes["dec_blocks"]), p_dec)
        else:
            pe_sh = pd_sh = None

        def act3(sds):
            return _act_shard(rules, ("dp", "seq", None), sds)

        def act2(sds):
            return _act_shard(rules, ("dp", "seq"), sds)
        x_enc = jax.ShapeDtypeStruct((b, s_enc, cfg.d_model), dt)
        x_dec = jax.ShapeDtypeStruct((b, s_dec, cfg.d_model), dt)
        positions_e = jax.ShapeDtypeStruct((b, s_enc), jnp.int32)
        positions_d = jax.ShapeDtypeStruct((b, s_dec), jnp.int32)

        def enc_body(p, x, pos):
            h = L.rmsnorm(x, p["ln1"], cfg.norm_eps)
            from repro.models.attention import attention_block, \
                cross_attention_block
            x = x + attention_block(p["attn"], h, pos, cfg=cfg, causal=False)
            h = L.rmsnorm(x, p["ln2"], cfg.norm_eps)
            return x + L.mlp_apply(p["mlp"], h, cfg.mlp_act)

        def dec_body(p, x, pos, ctx):
            from repro.models.attention import attention_block, \
                cross_attention_block
            h = L.rmsnorm(x, p["ln1"], cfg.norm_eps)
            x = x + attention_block(p["attn"], h, pos, cfg=cfg, causal=True)
            h = L.rmsnorm(x, p["ln_x"], cfg.norm_eps)
            x = x + cross_attention_block(p["xattn"], h, ctx, cfg=cfg)
            h = L.rmsnorm(x, p["ln2"], cfg.norm_eps)
            return x + L.mlp_apply(p["mlp"], h, cfg.mlp_act)

        if shape.kind == "train":
            def enc_loss(p, x, pos):
                return jnp.sum(jax.checkpoint(enc_body)(p, x, pos)
                               .astype(jnp.float32))

            def dec_loss(p, x, pos, ctx):
                return jnp.sum(jax.checkpoint(dec_body)(p, x, pos, ctx)
                               .astype(jnp.float32))
            c1 = compile_body(jax.grad(enc_loss, argnums=(0, 1)),
                              p_enc, x_enc, positions_e,
                              shardings=(pe_sh, act3(x_enc),
                                         act2(positions_e)))
            c2 = compile_body(jax.grad(dec_loss, argnums=(0, 1, 3)),
                              p_dec, x_dec, positions_d, x_enc,
                              shardings=(pd_sh, act3(x_dec),
                                         act2(positions_d), act3(x_enc)))
            results = [(c1, cfg.n_enc_layers - 1), (c2, cfg.n_layers - 1)]
        elif shape.kind == "prefill":
            c1 = compile_body(enc_body, p_enc, x_enc, positions_e,
                              shardings=(pe_sh, act3(x_enc),
                                         act2(positions_e)))
            c2 = compile_body(dec_body, p_dec, x_dec, positions_d, x_enc,
                              shardings=(pd_sh, act3(x_dec),
                                         act2(positions_d), act3(x_enc)))
            results = [(c1, cfg.n_enc_layers - 1), (c2, cfg.n_layers - 1)]
        else:
            c_one = _strip_layer_dim(jax.eval_shape(
                lambda: W.init_cache(cfg, b, shape.seq_len)[0])["self"])
            x1 = jax.ShapeDtypeStruct((b, 1, cfg.d_model), dt)
            s_enc_d = max(shape.seq_len // cfg.enc_seq_ratio, 8)
            ctx = jax.ShapeDtypeStruct((b, s_enc_d, cfg.d_model), dt)
            idx = jax.ShapeDtypeStruct((), jnp.int32)

            def dec1(p, x, c, ctx, index):
                from repro.models.attention import decode_attention_block, \
                    cross_attention_block
                h = L.rmsnorm(x, p["ln1"], cfg.norm_eps)
                mix, c2_ = decode_attention_block(p["attn"], h, c, index,
                                                  cfg=cfg)
                x = x + mix
                h = L.rmsnorm(x, p["ln_x"], cfg.norm_eps)
                x = x + cross_attention_block(p["xattn"], h, ctx, cfg=cfg)
                h = L.rmsnorm(x, p["ln2"], cfg.norm_eps)
                return x + L.mlp_apply(p["mlp"], h, cfg.mlp_act), c2_
            from repro.models.attention import kv_cache_axes
            c_sh = rules.shardings_for(kv_cache_axes(), c_one)
            c1 = compile_body(dec1, p_dec, x1, c_one, ctx, idx,
                              shardings=(pd_sh,
                                         _act_shard(rules, ("dp", None, None), x1),
                                         c_sh,
                                         _act_shard(rules, ("dp", None, None), ctx),
                                         None))
            results = [(c1, cfg.n_layers - 1)]
    else:
        b_tok = batch["tokens"].shape[0]
        s = (shape.seq_len if shape.kind != "decode" else 1)
        if cfg.frontend == "vision_stub" and shape.kind != "decode":
            s = shape.seq_len  # patches + text total
        p_rep = _strip_layer_dim(params["blocks"])
        p_sh = (rules.shardings_for(_strip_layer_axes(axes["blocks"]), p_rep)
                if axes is not None else None)
        x_in = jax.ShapeDtypeStruct((b_tok, s, cfg.d_model), dt)
        positions = jax.ShapeDtypeStruct((b_tok, s), jnp.int32)
        x_sh = _act_shard(rules, ("dp", "seq", None), x_in)
        pos_sh = _act_shard(rules, ("dp", "seq"), positions)

        def body(p, x, pos):
            aux = jnp.zeros((), jnp.float32)
            x, aux = T._apply_superblock(p, x, pos, cfg, aux)
            return x, aux

        if shape.kind == "train":
            def body_loss(p, x, pos):
                y, aux = jax.checkpoint(body)(p, x, pos)
                return jnp.sum(y.astype(jnp.float32)) + aux
            c1 = compile_body(jax.grad(body_loss, argnums=(0, 1)),
                              p_rep, x_in, positions,
                              shardings=(p_sh, x_sh, pos_sh))
        elif shape.kind == "prefill":
            c1 = compile_body(body, p_rep, x_in, positions,
                              shardings=(p_sh, x_sh, pos_sh))
        else:
            cache_full = jax.eval_shape(
                lambda: T.init_cache(cfg, b_tok, shape.seq_len)[0])
            c_rep = _strip_layer_dim(cache_full["blocks"])
            idx = jax.ShapeDtypeStruct((), jnp.int32)

            def dec_body(p, x, c, index):
                outs = {}
                for i, spec in enumerate(cfg.block):
                    x, outs[f"layer{i}"] = T._decode_layer(
                        p[f"layer{i}"], spec, x, c[f"layer{i}"], index, cfg)
                return x, outs
            c_axes = _strip_layer_axes(
                T.init_cache(cfg, 1, 1)[1]["blocks"])
            c_sh = rules.shardings_for(c_axes, c_rep)
            c1 = compile_body(dec_body, p_rep, x_in, c_rep, idx,
                              shardings=(p_sh, x_sh, c_sh, None))
        results = [(c1, cfg.n_repeats - 1)]

    extra_flops = extra_bytes = 0.0
    extra_colls: Dict[str, Dict[str, float]] = {}
    for compiled, scale in results:
        if scale <= 0:
            continue
        ca = compiled.cost_analysis()
        extra_flops += scale * float(ca.get("flops", 0.0))
        extra_bytes += scale * float(ca.get("bytes accessed", 0.0))
        for kind, slot in parse_collectives(compiled.as_text()).items():
            agg = extra_colls.setdefault(kind, {"count": 0, "bytes": 0.0,
                                                "wire_bytes": 0.0})
            agg["count"] += scale * slot["count"]
            agg["bytes"] += scale * slot["bytes"]
            agg["wire_bytes"] += scale * slot["wire_bytes"]
    return {"flops": extra_flops, "bytes": extra_bytes,
            "collectives": extra_colls}


def model_flops_for(cfg, shape) -> float:
    """MODEL_FLOPS: 6·N_active·D for training, 2·N_active·D for inference
    (D = processed tokens)."""
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        if cfg.enc_dec:
            tokens = shape.global_batch * (shape.seq_len
                                           + shape.seq_len // cfg.enc_seq_ratio)
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    # decode: one token per sequence
    return 2.0 * n_active * shape.global_batch


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             donate: bool = True) -> Dict[str, Any]:
    cfg = get_config(arch)
    shape = {s.name: s for s in ALL_SHAPES}[shape_name]
    api = get_model(cfg)
    mesh = make_production_mesh(multi_pod=multi_pod)
    rules = MeshRules(mesh, cfg=cfg)
    chips = int(np.prod(list(mesh.shape.values())))

    t0 = time.time()
    params, axes = api.abstract_params()
    p_shard = rules.param_shardings(axes)

    if shape.kind == "train":
        opt = opt_state_for(params, abstract=True)
        o_shard = opt_state_shardings(rules, p_shard)
        batch = api.input_specs(shape, abstract=True)
        b_shard = rules.batch_sharding(batch)
        step = build_train_step(api, rules, TrainStepConfig())
        jitted = jax.jit(step,
                         in_shardings=(p_shard, o_shard, b_shard),
                         donate_argnums=(0, 1) if donate else ())
        lowered = jitted.lower(params, opt, batch)
        host_bytes = offloaded_bytes(opt)
    elif shape.kind == "prefill":
        batch = api.input_specs(shape, abstract=True)
        b_shard = rules.batch_sharding(batch)
        step = build_prefill_step(api, rules)
        jitted = jax.jit(step, in_shardings=(p_shard, b_shard))
        lowered = jitted.lower(params, batch)
        host_bytes = 0
    else:  # decode
        cache, cache_axes = api.abstract_cache(shape.global_batch,
                                               shape.seq_len)
        c_shard = rules.shardings_for(cache_axes, cache)
        batch = api.decode_input_specs(shape, abstract=True)
        b_shard = rules.batch_sharding(batch)
        step = build_serve_step(api, rules)
        jitted = jax.jit(step,
                         in_shardings=(p_shard, c_shard, b_shard, None),
                         donate_argnums=(1,) if donate else ())
        lowered = jitted.lower(params, cache, batch,
                               jax.ShapeDtypeStruct((), jnp.int32))
        host_bytes = 0

    compiled = lowered.compile()
    compile_s = time.time() - t0

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    hlo = compiled.as_text()
    colls = parse_collectives(hlo)

    # correct the scan-deflation of HloCostAnalysis (body visited once)
    corr = _body_cost(cfg, shape, rules, api, params, batch, axes)
    flops = float(cost.get("flops", 0.0)) + corr["flops"]
    bytes_accessed = float(cost.get("bytes accessed", 0.0)) + corr["bytes"]
    for kind, slot in corr["collectives"].items():
        agg = colls.setdefault(kind, {"count": 0, "bytes": 0.0,
                                      "wire_bytes": 0.0})
        agg["count"] += slot["count"]
        agg["bytes"] += slot["bytes"]
        agg["wire_bytes"] += slot["wire_bytes"]
    wire = sum(c["wire_bytes"] for c in colls.values())

    device_bytes = {
        "arguments": int(mem.argument_size_in_bytes),
        "outputs": int(mem.output_size_in_bytes),
        "temps": int(mem.temp_size_in_bytes),
        "aliased": int(mem.alias_size_in_bytes),
        "generated_code": int(mem.generated_code_size_in_bytes),
    }
    # peak live bytes per device: args + temps + outputs - aliased (donated
    # buffers are reused in place)
    peak = (device_bytes["arguments"] + device_bytes["temps"]
            + device_bytes["outputs"] - device_bytes["aliased"])
    host_per_device = host_bytes // chips

    m_flops = model_flops_for(cfg, shape)
    compute_s = flops / PEAK_FLOPS
    memory_s = bytes_accessed / HBM_BW
    collective_s = wire / ICI_BW

    record = {
        "arch": arch, "shape": shape_name, "kind": shape.kind,
        "mesh": dict(mesh.shape), "chips": chips,
        "multi_pod": multi_pod,
        "compile_seconds": round(compile_s, 2),
        "per_device": device_bytes,
        "per_device_peak_bytes": int(peak),
        "tensile_host_offload_bytes_per_device": int(host_per_device),
        "per_device_peak_after_offload": int(peak - host_per_device),
        "fits_hbm_16g": bool(peak - host_per_device < 16e9),
        "cost": {"flops": flops, "bytes_accessed": bytes_accessed},
        "collectives": colls,
        "roofline": {
            "compute_s": compute_s,
            "memory_s": memory_s,
            "collective_s": collective_s,
            "dominant": max(
                [("compute", compute_s), ("memory", memory_s),
                 ("collective", collective_s)], key=lambda kv: kv[1])[0],
            "model_flops": m_flops,
            "model_flops_global": m_flops,
            "useful_flops_ratio": (m_flops / chips) / flops if flops else 0.0,
        },
    }
    return record


def artifact_path(arch: str, shape: str, multi_pod: bool) -> str:
    os.makedirs(ARTIFACT_DIR, exist_ok=True)
    pod = "pod2" if multi_pod else "pod1"
    return os.path.join(ARTIFACT_DIR, f"{arch}__{shape}__{pod}.json")


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", choices=["0", "1", "both"], default="both")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    archs = list_configs() if (args.all or not args.arch) else [args.arch]
    pods = {"0": [False], "1": [True], "both": [False, True]}[args.multi_pod]
    failures = []
    for arch in archs:
        cfg = get_config(arch)
        shape_names = ([args.shape] if args.shape
                       else [s.name for s in shapes_for(cfg)])
        for sk, reason in skipped_shapes_for(cfg):
            if args.shape in (None, sk.name):
                print(f"[skip] {arch} × {sk.name}: {reason}")
        for shape in shape_names:
            for mp in pods:
                path = artifact_path(arch, shape, mp)
                if args.skip_existing and os.path.exists(path):
                    print(f"[cached] {arch} × {shape} × "
                          f"{'2pod' if mp else '1pod'}")
                    continue
                tag = f"{arch} × {shape} × {'2pod(512)' if mp else '1pod(256)'}"
                try:
                    rec = run_cell(arch, shape, mp)
                    with open(path, "w") as f:
                        json.dump(rec, f, indent=1)
                    r = rec["roofline"]
                    print(f"[ok] {tag}: compile={rec['compile_seconds']}s "
                          f"peak={rec['per_device_peak_bytes']/2**30:.2f}GiB "
                          f"(offload→{rec['per_device_peak_after_offload']/2**30:.2f}) "
                          f"flops={rec['cost']['flops']:.3e} "
                          f"dominant={r['dominant']}")
                except Exception as e:  # noqa: BLE001
                    failures.append((tag, repr(e)))
                    print(f"[FAIL] {tag}: {e}")
                    traceback.print_exc()
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for t, e in failures:
            print(" ", t, e)
        return 1
    print("\nall dry-run cells compiled.")
    return 0


if __name__ == "__main__":
    sys.exit(main())
