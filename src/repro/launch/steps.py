"""Train / serve step builders — where model, optimizer, TENSILE plan and
mesh come together.

`build_train_step` returns a pure step function
    (params, opt_state, batch) -> (params, opt_state, metrics)
with: remat policy from the TENSILE decisions (recompute events), optional
host-offloaded optimizer state (across-iteration swap — the paper's
Fig. 1(c)) on backends with memory spaces, donation of params/opt buffers,
optional int8 error-feedback gradient compression on the cross-pod
exchange, and gradient clipping.

`build_serve_step` returns (params, cache, batch, index) -> (logits, cache)
with the cache donated (decode updates in place).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.core.jax_integration import backend_supports_memory_kinds
from repro.models import layers as _layers
from repro.optim.adam import AdamState, adamw_init, adamw_update
from repro.optim.compression import ef_compress_grads
from .sharding import MeshRules


@dataclasses.dataclass
class TrainStepConfig:
    learning_rate: float = 1e-4
    weight_decay: float = 0.01
    grad_clip_norm: Optional[float] = 1.0
    use_master: bool = False
    grad_compression: Optional[str] = None      # None | "int8"
    offload_opt_state: bool = False             # TENSILE across-iteration
    remat_policy: Optional[Callable] = None     # from TENSILE decisions
    microbatches: int = 1                       # grad accumulation (peak/n)


def build_train_step(api, rules: Optional[MeshRules],
                     tcfg: Optional[TrainStepConfig] = None):
    tcfg = tcfg or TrainStepConfig()

    def train_step(params, opt_state, batch):
        _layers.set_active_rules(rules)
        try:
            def loss_of(p, b):
                return api.loss(p, b, remat_policy=tcfg.remat_policy)

            n_mb = tcfg.microbatches
            if n_mb > 1:
                # gradient accumulation: TENSILE's peak-reduction idea as
                # scheduling-in-time — activation transients shrink by n
                # at the cost of an fp32 gradient accumulator
                mb = jax.tree.map(
                    lambda x: x.reshape((n_mb, x.shape[0] // n_mb)
                                        + x.shape[1:]), batch)
                acc0 = jax.tree.map(
                    lambda p: jnp.zeros(p.shape, jnp.float32), params)

                def body(carry, mslice):
                    acc, ls = carry
                    l, g = jax.value_and_grad(loss_of)(params, mslice)
                    acc = jax.tree.map(
                        lambda a, gg: a + gg.astype(jnp.float32), acc, g)
                    return (acc, ls + l), None

                (acc, loss), _ = jax.lax.scan(
                    body, (acc0, jnp.zeros(())), mb)
                grads = jax.tree.map(lambda a: a / n_mb, acc)
                loss = loss / n_mb
            else:
                loss, grads = jax.value_and_grad(
                    lambda p: loss_of(p, batch))(params)
            if tcfg.grad_compression == "int8":
                grads, opt_state = ef_compress_grads(grads, opt_state)
            new_params, new_opt = adamw_update(
                params, grads, opt_state,
                lr=tcfg.learning_rate, weight_decay=tcfg.weight_decay,
                grad_clip_norm=tcfg.grad_clip_norm)
            metrics = {"loss": loss,
                       "grad_norm": _global_norm(grads)}
            return new_params, new_opt, metrics
        finally:
            _layers.set_active_rules(None)

    return train_step


def build_serve_step(api, rules: Optional[MeshRules]):
    def serve_step(params, cache, batch, index):
        _layers.set_active_rules(rules)
        try:
            logits, new_cache = api.decode(params, batch, cache, index)
            return logits, new_cache
        finally:
            _layers.set_active_rules(None)

    return serve_step


def build_prefill_step(api, rules: Optional[MeshRules]):
    def prefill_step(params, batch):
        _layers.set_active_rules(rules)
        try:
            logits, aux = api.forward(params, batch)
            return logits
        finally:
            _layers.set_active_rules(None)

    return prefill_step


def _global_norm(tree):
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))


# ----------------------------------------------------------------------
# Optimizer-state trees + shardings (incl. TENSILE host offload)
# ----------------------------------------------------------------------
def opt_state_for(params, *, use_master: bool = False,
                  abstract: bool = False) -> AdamState:
    if abstract:
        return jax.eval_shape(
            functools.partial(adamw_init, use_master=use_master), params)
    return adamw_init(params, use_master=use_master)


def opt_state_shardings(rules: MeshRules, param_shardings,
                        *, use_master: bool = False,
                        offload: bool = False):
    """Moments mirror the parameter shardings; the TENSILE across-iteration
    decision places them in pinned_host when the backend supports it
    (otherwise the accounting layer tracks the would-be host bytes)."""
    host_ok = offload and backend_supports_memory_kinds()

    def to_host(s):
        return s.with_memory_kind("pinned_host") if host_ok else s

    mu = jax.tree.map(to_host, param_shardings)
    nu = jax.tree.map(to_host, param_shardings)
    master = jax.tree.map(to_host, param_shardings) if use_master else ()
    return AdamState(step=rules.replicated(), mu=mu, nu=nu, master=master)


def offloaded_bytes(opt_state) -> int:
    """Bytes the TENSILE plan parks on host between steps (moments +
    master): reported by the dry-run accounting when the backend cannot
    express memory spaces (DESIGN.md §2)."""
    total = 0
    for leaf in jax.tree.leaves((opt_state.mu, opt_state.nu,
                                 opt_state.master)):
        shape = getattr(leaf, "shape", ())
        import numpy as np
        total += int(np.prod(shape)) * jnp.dtype(leaf.dtype).itemsize
    return total
