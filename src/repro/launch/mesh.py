"""Production mesh definitions (deliverable (e)).

`make_production_mesh` is a FUNCTION, not a module constant: importing this
module never touches jax device state (required by the dry-run contract).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 = 256 chips per pod; 2 pods = 512 chips multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes,
                         axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_mesh(shape: Tuple[int, ...], axes: Tuple[str, ...]):
    """Arbitrary mesh (smoke tests, elastic re-meshing)."""
    return jax.make_mesh(shape, axes,
                         axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_host_mesh(n_devices: Optional[int] = None, model: int = 2):
    """Small mesh over whatever devices exist (CPU tests)."""
    n = n_devices or len(jax.devices())
    model = min(model, n)
    return make_mesh((n // model, model), ("data", "model"))


def data_axes(mesh) -> Tuple[str, ...]:
    """Axes that shard the batch (everything except 'model')."""
    return tuple(a for a in mesh.axis_names if a != "model")
