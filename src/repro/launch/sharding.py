"""Logical-axis → mesh-axis resolution (the distribution rule table).

Model code annotates parameters and activations with *logical* names; this
module resolves them against a concrete mesh with per-arch fallbacks:

    embed       → FSDP axes (("pod","data")) when fsdp else replicated
    heads       → "model" iff n_heads  % model_size == 0 else replicated
    kv_heads    → "model" iff n_kv_heads % model_size == 0 else replicated
    mlp / vocab / experts / ssm_inner / ssm_heads → "model"
    vocab_gather→ embedding-table rows: replicated (gather stays local)
    dp          → batch axes; tp/ep → "model"; kv_seq → "model" (decode
                  caches are sequence-sharded; flash-decoding combine)
    layers      → never sharded (scan dim)

Head-replication fallback (whisper 8H, gemma 8H/1KV, minitron 24H,
qwen 40H on a 16-way model axis) is deliberate: head_dim-sharding would
psum S² score tiles (DESIGN.md §4).  The cost shows up in the roofline and
is a hillclimbing lever.
"""
from __future__ import annotations

import contextlib
import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models import layers as _layers


@dataclasses.dataclass
class MeshRules:
    mesh: Mesh
    cfg: Any = None
    fsdp: bool = True
    # Megatron-style sequence sharding of inter-block activations over the
    # model axis (perf lever: 16× smaller saved residuals, one extra
    # all-gather per layer)
    act_seq_shard: bool = False
    # TENSILE across-iteration residency: opt state / master on host
    offload_opt_state: bool = False

    def __post_init__(self):
        names = self.mesh.axis_names
        self.model_axis = "model" if "model" in names else None
        self.batch_axes = tuple(a for a in names if a != "model")
        msize = self.mesh.shape.get("model", 1)
        fsdp_axes = self.batch_axes if self.fsdp else None
        cfg = self.cfg

        def fits(n: Optional[int]) -> bool:
            return bool(n) and msize > 0 and n % msize == 0

        self.table: Dict[Optional[str], Any] = {
            None: None,
            "embed": fsdp_axes,
            "embed_tp": "model",
            "mlp": "model",
            "vocab": "model",
            "vocab_gather": fsdp_axes,
            "experts": "model",
            "ssm_inner": "model",
            "ssm_conv": "model",
            "ssm_proj": None,
            "heads": "model" if (cfg is None or fits(cfg.n_heads)) else None,
            "kv_heads": "model" if (cfg is None or fits(cfg.n_kv_heads))
                        else None,
            "layers": None,
            # activation logical axes
            "dp": self.batch_axes,
            "tp": "model" if (cfg is None or fits(getattr(cfg, "n_heads", 0))
                              or True) else None,
            "tp_kv": "model" if (cfg is None or fits(cfg.n_kv_heads)) else None,
            "ep": "model",
            "cap": self.batch_axes,   # MoE capacity dim over data axes
            "kv_seq": "model",
            "seq": "model" if self.act_seq_shard else None,
        }
        # activation "tp" is used on mlp-hidden / logits (always divisible)
        self.table["tp"] = "model"
        if cfg is not None and not fits(cfg.n_heads):
            # replicated-heads fallback: per-head activations unsharded
            self.table["act_heads"] = None
        else:
            self.table["act_heads"] = "model"

    # ------------------------------------------------------------------
    def spec(self, logical: Tuple[Optional[str], ...]) -> P:
        parts = []
        for name in logical:
            parts.append(self.table.get(name, None))
        return P(*parts)

    def sharding(self, logical: Tuple[Optional[str], ...],
                 memory_kind: Optional[str] = None) -> NamedSharding:
        s = NamedSharding(self.mesh, self.spec(logical))
        if memory_kind:
            s = s.with_memory_kind(memory_kind)
        return s

    def param_shardings(self, axes_tree):
        """Map an axes pytree (tuples of logical names) to NamedShardings."""
        def leaf(a):
            return self.sharding(a)
        return jax.tree.map(leaf, axes_tree,
                            is_leaf=_is_axes_leaf)

    def shardings_for(self, axes_tree, shape_tree):
        """Like param_shardings but validated against concrete shapes:
        logical axes whose mesh extent does not divide the dimension fall
        back to replicated (e.g. batch=1 caches in long_500k)."""
        def leaf(a, s):
            parts = []
            for dim, name in zip(s.shape, a):
                m = self.table.get(name, None)
                size = 1
                for ax in ((m,) if isinstance(m, str) else (m or ())):
                    size *= self.mesh.shape[ax]
                parts.append(m if size > 1 and dim % size == 0 else None)
            return NamedSharding(self.mesh, P(*parts))
        return jax.tree.map(leaf, axes_tree, shape_tree,
                            is_leaf=_is_axes_leaf)

    def batch_sharding(self, batch_specs):
        def leaf(s):
            ndim = len(s.shape)
            n = self.n_batch_shards
            first = self.batch_axes if (s.shape and s.shape[0] % max(n, 1) == 0
                                        and n > 1) else None
            return NamedSharding(self.mesh, P(first, *([None] * (ndim - 1))))
        return jax.tree.map(leaf, batch_specs)

    def constrain(self, x, logical) -> Any:
        # drop logical names whose mesh axes do not divide the dim
        parts = []
        for dim, name in zip(x.shape, logical):
            m = self.table.get(name, None)
            size = 1
            for a in ((m,) if isinstance(m, str) else (m or ())):
                size *= self.mesh.shape[a]
            parts.append(m if size and dim % max(size, 1) == 0 else None)
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, P(*parts)))

    def replicated(self) -> NamedSharding:
        return NamedSharding(self.mesh, P())

    @property
    def n_batch_shards(self) -> int:
        n = 1
        for a in self.batch_axes:
            n *= self.mesh.shape[a]
        return n


def _is_axes_leaf(x) -> bool:
    return isinstance(x, tuple) and all(
        isinstance(e, (str, type(None))) for e in x)


@contextlib.contextmanager
def use_rules(rules: Optional[MeshRules]):
    """Install activation-constraint rules for model code."""
    _layers.set_active_rules(rules)
    try:
        yield rules
    finally:
        _layers.set_active_rules(None)
