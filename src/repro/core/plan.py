"""Scheduling plan & events (paper §III-A "Scheduling Plan", §III-C).

Every event is described by a tuple ``(trigger, Δtime)``: the trigger is a
tensor access (we key it by the trigger operator's index) and Δtime the delay
after the trigger's end (paper §III-D Memory Scheduler).  Absolute
``start``/``end`` instants are kept alongside for peak analysis and for the
single-channel reservation, and are recomputed whenever latencies drift.
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Dict, Iterable, List, Optional, Tuple

# how many times any plan rebuilt its busy-interval list (regression tests
# assert one construction per plan version, see SchedulingPlan.busy_intervals)
BUSY_REBUILDS = 0


class EventType(enum.Enum):
    SWAP_OUT = "swap_out"
    SWAP_IN = "swap_in"
    RECOMPUTE = "recompute"
    RELEASE = "release"


@dataclasses.dataclass
class ScheduleEvent:
    event_type: EventType
    tensor_id: str
    job_id: str
    trigger_op: int          # op whose completion triggers the event
    delta: float             # Δtime after trigger end
    start: float             # absolute planned start (seconds on the timeline)
    end: float               # absolute planned end
    size_bytes: int = 0
    # swap-in: the TUA this prefetch must beat; recompute: the TUA needing it
    target_op: Optional[int] = None
    # recompute: ops to re-execute
    recompute_ops: Optional[List[int]] = None
    # True for events scheduled across the iteration boundary (paper Fig 1(c))
    crosses_iteration: bool = False
    # True when the transfer goes through the quantize-on-offload path
    # (kernels/offload_quant): fewer bytes on the DMA channel, plus the
    # quantize/dequantize kernel latency (cost_model.offload_quant_latency)
    compressed: bool = False

    @property
    def duration(self) -> float:
        return self.end - self.start

    def to_dict(self) -> Dict[str, object]:
        d = dataclasses.asdict(self)
        d["event_type"] = self.event_type.value
        return d

    @staticmethod
    def from_dict(d: Dict[str, object]) -> "ScheduleEvent":
        d = dict(d)
        d["event_type"] = EventType(d["event_type"])
        return ScheduleEvent(**d)  # type: ignore[arg-type]


_PLAN_UID = [0]


@dataclasses.dataclass
class SchedulingPlan:
    """Per-job plan S_j: ordered swap/recompute/release events."""

    job_id: str
    events: List[ScheduleEvent] = dataclasses.field(default_factory=list)
    # tensor -> op index after which it may be released (activity analysis +
    # planner-added early releases)
    release_after_op: Dict[str, int] = dataclasses.field(default_factory=dict)
    # metadata for reporting
    planned_peak_bytes: int = 0
    vanilla_peak_bytes: int = 0
    plan_wallclock_s: float = 0.0
    # byte budget this plan was built against: the arbiter-assigned per-job
    # slice under the Global Controller, else the device-wide budget (0 =
    # unconstrained / not recorded)
    budget_bytes: int = 0
    # observation iterations the policy charges before the plan is live
    # (Capuchin's passive-mode epoch; TENSILE/vDNN: 0)
    passive_iterations: int = 0
    # how this plan came to be, when not planned from scratch: one record
    # per incremental replan / safe-point splice, so a hot-swapped plan's
    # lineage (which op it split at, which budgets it moved between) is
    # auditable by tests and reports
    provenance: List[Dict[str, object]] = dataclasses.field(
        default_factory=list)
    # monotone edit counter: every event mutation (add / remove / truncate /
    # rebase) bumps it, so derived per-plan state — the safe-point busy
    # intervals below, the pipeline's incremental sweep caches — can key on
    # (id(plan), version) instead of rescanning the event list.  Not
    # serialized: a from_dict plan starts a fresh lineage at 0.
    version: int = dataclasses.field(default=0, init=False, repr=False,
                                     compare=False)
    # process-unique, never-recycled identity: (uid, version) names this
    # plan's event/release CONTENT (not its reporting metadata), which is
    # what lets whole-report analyze results be memoized without content
    # hashing (id() recycles addresses).  ``copy()`` shares the pair —
    # the copy is content-identical — and the first mutation of either
    # object forks it onto a fresh uid (copy-on-write), so an unchanged
    # replan copy hits the same analyze memo rows as its source.
    uid: int = dataclasses.field(default=0, init=False, repr=False,
                                 compare=False)
    _cow: bool = dataclasses.field(default=False, init=False, repr=False,
                                   compare=False)
    _busy_cache: Optional[tuple] = dataclasses.field(
        default=None, init=False, repr=False, compare=False)

    def __post_init__(self) -> None:
        _PLAN_UID[0] += 1
        self.uid = _PLAN_UID[0]

    def _bump(self) -> None:
        if self._cow:
            _PLAN_UID[0] += 1
            self.uid = _PLAN_UID[0]
            self._cow = False
        self.version += 1

    def add(self, ev: ScheduleEvent) -> None:
        self.events.append(ev)
        self._bump()

    def remove(self, ev: ScheduleEvent) -> None:
        self.events.remove(ev)
        self._bump()

    def truncate(self, n: int) -> None:
        """Drop events[n:] (a pass rolling back a failed attempt).  The
        version bump keeps busy-interval and sweep caches honest — passes
        must use this instead of ``del plan.events[n:]``."""
        if n < len(self.events):
            del self.events[n:]
            self._bump()

    def set_release(self, tid: str, op_idx: int) -> None:
        """Record an early-release point.  Release entries feed the same
        sweep caches as events, so writes go through here for the version
        bump."""
        self.release_after_op[tid] = op_idx
        self._bump()

    def busy_intervals(self, period: float) -> List[Tuple[float, float]]:
        """In-flight transfer spans of this plan, projected into
        ``[0, period)`` with the planner's PeriodicChannel wrapping.
        Cached per (version, period): ``find_safe_points`` historically
        rebuilt this list from scratch on every call even when the plan
        had not changed, which dominated preemptive-replan latency."""
        global BUSY_REBUILDS
        key = (self.version, period)
        if self._busy_cache is not None and self._busy_cache[0] == key:
            return self._busy_cache[1]
        eps = 1e-12
        busy: List[Tuple[float, float]] = []
        for ev in self.events:
            if ev.event_type not in (EventType.SWAP_OUT, EventType.SWAP_IN,
                                     EventType.RECOMPUTE):
                continue
            dur = ev.end - ev.start
            if dur <= eps:
                continue
            busy.extend((s, e)
                        for s, e in wrap_intervals(ev.start, dur, period))
        BUSY_REBUILDS += 1
        self._busy_cache = (key, busy)
        return busy

    def by_type(self, et: EventType) -> List[ScheduleEvent]:
        return [e for e in self.events if e.event_type is et]

    def swap_outs(self) -> List[ScheduleEvent]:
        return self.by_type(EventType.SWAP_OUT)

    def swap_ins(self) -> List[ScheduleEvent]:
        return self.by_type(EventType.SWAP_IN)

    def recomputes(self) -> List[ScheduleEvent]:
        return self.by_type(EventType.RECOMPUTE)

    def swapped_tensors(self) -> List[str]:
        seen, out = set(), []
        for e in self.swap_outs():
            if e.tensor_id not in seen:
                seen.add(e.tensor_id)
                out.append(e.tensor_id)
        return out

    def events_triggered_by(self, op_idx: int) -> List[ScheduleEvent]:
        return [e for e in self.events if e.trigger_op == op_idx]

    def memory_saving_bytes(self) -> int:
        return max(0, self.vanilla_peak_bytes - self.planned_peak_bytes)

    def copy(self) -> "SchedulingPlan":
        """Independent copy (events and release map are duplicated) — the
        starting point of an incremental replan, so the running plan is
        never mutated behind an executor's back."""
        p = SchedulingPlan(job_id=self.job_id)
        p.events = [
            dataclasses.replace(
                e, recompute_ops=(list(e.recompute_ops)
                                  if e.recompute_ops is not None else None))
            for e in self.events]
        p.release_after_op = dict(self.release_after_op)
        p.planned_peak_bytes = self.planned_peak_bytes
        p.vanilla_peak_bytes = self.vanilla_peak_bytes
        p.plan_wallclock_s = self.plan_wallclock_s
        p.budget_bytes = self.budget_bytes
        p.passive_iterations = self.passive_iterations
        p.provenance = [dict(r) for r in self.provenance]
        # content-identical: share (uid, version) until either side
        # mutates, so analyze memo rows built for the source also serve
        # the copy (the common no-change replan case)
        p.uid = self.uid
        p.version = self.version
        p._cow = True
        return p

    def splice(self, new_plan: "SchedulingPlan",
               at_op: int) -> "SchedulingPlan":
        """Safe-point splice: everything this plan already committed to up
        to (and including) trigger op ``at_op`` is kept verbatim — those
        events have fired or are about to under the running iteration —
        and ``new_plan`` governs every later trigger.  Release points
        follow the same rule: a release at or before the splice already
        happened under the old plan; later ones are the new plan's call.
        The result carries a provenance record naming the splice op and
        the budget move, so a hot-swapped plan is auditable."""
        out = SchedulingPlan(job_id=self.job_id)
        kept = [e for e in self.events if e.trigger_op <= at_op]
        adopted = [e for e in new_plan.events if e.trigger_op > at_op]
        out.events = kept + adopted
        out.release_after_op = {
            tid: op for tid, op in self.release_after_op.items()
            if op <= at_op}
        out.release_after_op.update(
            (tid, op) for tid, op in new_plan.release_after_op.items()
            if op > at_op)
        out.planned_peak_bytes = new_plan.planned_peak_bytes
        out.vanilla_peak_bytes = self.vanilla_peak_bytes
        out.budget_bytes = new_plan.budget_bytes
        out.passive_iterations = self.passive_iterations
        out.provenance = [dict(r) for r in self.provenance] \
            + [dict(r) for r in new_plan.provenance] \
            + [{"action": "splice", "at_op": at_op,
                "kept_events": len(kept), "adopted_events": len(adopted),
                "from_budget_bytes": self.budget_bytes,
                "to_budget_bytes": new_plan.budget_bytes}]
        return out

    def to_dict(self) -> Dict[str, object]:
        d = {
            "job_id": self.job_id,
            "events": [e.to_dict() for e in self.events],
            "release_after_op": dict(self.release_after_op),
            "planned_peak_bytes": self.planned_peak_bytes,
            "vanilla_peak_bytes": self.vanilla_peak_bytes,
            "budget_bytes": self.budget_bytes,
        }
        # only when present — the golden seed plans pin the bare shape
        if self.provenance:
            d["provenance"] = [dict(r) for r in self.provenance]
        return d

    @staticmethod
    def from_dict(d: Dict[str, object]) -> "SchedulingPlan":
        p = SchedulingPlan(job_id=str(d["job_id"]))
        p.events = [ScheduleEvent.from_dict(e) for e in d["events"]]  # type: ignore[union-attr]
        p.release_after_op = {str(k): int(v) for k, v in d["release_after_op"].items()}  # type: ignore[union-attr]
        p.planned_peak_bytes = int(d.get("planned_peak_bytes", 0))  # type: ignore[arg-type]
        p.vanilla_peak_bytes = int(d.get("vanilla_peak_bytes", 0))  # type: ignore[arg-type]
        p.budget_bytes = int(d.get("budget_bytes", 0))  # type: ignore[arg-type]
        p.provenance = [dict(r) for r in d.get("provenance", [])]  # type: ignore[union-attr]
        return p


def wrap_intervals(start: float, duration: float,
                   period: float) -> List[List[float]]:
    """Project an absolute interval into period-wrapped pieces: an
    interval crossing the iteration boundary splits into
    ``[s, T) + [0, e-T)`` (steady state repeats every iteration).  Shared
    by the planner's PeriodicChannel bookings and the engine's safe-point
    busy-span detection so the two can never disagree about wrapping."""
    eps = 1e-9
    s = start % period
    out: List[List[float]] = []
    remaining = duration
    while remaining > eps:
        chunk = min(remaining, period - s)
        out.append([s, s + chunk])
        remaining -= chunk
        s = 0.0
    return out


class ChannelReservation:
    """The single PCIe / host-DMA channel (paper §IV-A: "there can only be one
    tensor being swapped at the same time").  Swap events from *all* jobs book
    non-overlapping intervals here.  Sorted + bisect: O(log n) queries (the
    planner issues millions on DenseNet-scale graphs)."""

    def __init__(self):
        self._intervals: List[List[float]] = []  # sorted, non-overlapping
        self._starts: List[float] = []

    def intervals(self) -> List[List[float]]:
        return [list(x) for x in self._intervals]

    def is_free(self, start: float, end: float) -> bool:
        import bisect
        i = bisect.bisect_right(self._starts, start)
        # neighbour on the left may still cover `start`
        if i > 0 and self._intervals[i - 1][1] > start + 1e-12:
            return False
        if i < len(self._intervals) and self._intervals[i][0] < end - 1e-12:
            return False
        return True

    def book(self, start: float, end: float) -> None:
        import bisect
        if not self.is_free(start, end):
            raise ValueError(f"channel interval [{start}, {end}] already booked")
        i = bisect.bisect_right(self._starts, start)
        self._intervals.insert(i, [start, end])
        self._starts.insert(i, start)

    def release(self, start: float, end: float) -> None:
        i = self._intervals.index([start, end])
        self._intervals.pop(i)
        self._starts.pop(i)

    def free_slots(self, lo: float, hi: float, duration: float) -> List[List[float]]:
        """Maximal free intervals within [lo, hi] long enough for `duration`."""
        if hi - lo < duration - 1e-12:
            return []
        slots: List[List[float]] = []
        cur = lo
        for s, e in self._intervals:
            if e <= lo or s >= hi:
                continue
            if s - cur >= duration - 1e-12:
                slots.append([cur, min(s, hi)])
            cur = max(cur, e)
            if cur >= hi:
                break
        if hi - cur >= duration - 1e-12:
            slots.append([cur, hi])
        return [x for x in slots if x[1] - x[0] >= duration - 1e-12]

    def earliest_fit(self, lo: float, hi: float, duration: float) -> Optional[float]:
        slots = self.free_slots(lo, hi, duration)
        return slots[0][0] if slots else None

    def latest_fit(self, lo: float, hi: float, duration: float) -> Optional[float]:
        slots = self.free_slots(lo, hi, duration)
        return slots[-1][1] - duration if slots else None


@dataclasses.dataclass
class MachineProfile:
    """Hardware constants used by the planner & simulator.

    Defaults describe the TPU v5e target of this repo; the CPU-container
    benchmarks calibrate `compute_flops`/`mem_bw` from measurements instead.
    """

    device_memory_bytes: int = 16 * 2 ** 30          # v5e HBM per chip
    host_link_bw: float = 16e9                       # host<->device DMA (B/s)
    host_link_latency: float = 15e-6                 # per-transfer setup
    compute_flops: float = 197e12                    # bf16 peak / chip
    mem_bw: float = 819e9                            # HBM B/s
    ici_bw: float = 50e9                             # per ICI link B/s
    swap_compression: float = 1.0                    # <1.0 with offload_quant
    # int8 quantize-on-offload (kernels/offload_quant): bytes-on-wire ratio
    # for a float32 tensor incl. per-block scales, (1 + 4/BLOCK) / 4
    offload_quant_ratio: float = (1.0 + 4.0 / 512.0) / 4.0
    # effective quantize/dequantize kernel throughput (B/s of source tensor);
    # calibrated via cost_model.offload_quant_bw on real devices
    offload_quant_bw: float = 400e9
    # per-extra-member cost of a coalesced DMA batch (descriptor fixup):
    # batching n transfers replaces (n-1) host_link_latency setups with
    # (n-1) of these — the term DmaChannel.acquire_batch books against
    dma_batch_overhead: float = 2e-6

    def swap_time(self, size_bytes: int) -> float:
        eff = size_bytes * self.swap_compression
        return self.host_link_latency + eff / self.host_link_bw

    def batched_swap_time(self, sizes) -> float:
        """One coalesced DMA batch: a single per-transfer setup, the
        summed payload at link bandwidth, plus ``dma_batch_overhead`` per
        extra member."""
        sizes = list(sizes)
        if not sizes:
            return 0.0
        eff = sum(sizes) * self.swap_compression
        return (self.host_link_latency + eff / self.host_link_bw
                + self.dma_batch_overhead * (len(sizes) - 1))

    def compressed_swap_time(self, size_bytes: int) -> float:
        """One direction of the quantize-on-offload path: the kernel reads
        the tensor and writes int8 + scales, then the DMA carries the
        compressed bytes (§optimization beyond the paper)."""
        quant = size_bytes / self.offload_quant_bw
        wire = size_bytes * self.offload_quant_ratio / self.host_link_bw
        return self.host_link_latency + quant + wire

    def transfer_time(self, size_bytes: int, compressed: bool = False) -> float:
        return (self.compressed_swap_time(size_bytes) if compressed
                else self.swap_time(size_bytes))


def merge_plans(plans: Iterable[SchedulingPlan]) -> Dict[str, SchedulingPlan]:
    return {p.job_id: p for p in plans}
