"""Shared memory-event engine: one residency/channel/event-semantics core
for BOTH the discrete-event simulator and the interpreting executor.

The paper's framework has exactly one memory model — device residency changes
at the five situations of §IV-B, transfers serialize on one host-DMA channel
(§IV-A), plan events fire as (trigger op, Δt) pairs (§III-D), and a prefetch
that misses its TUA degrades to a passive swap-in stall.  The seed
implemented that model twice (simulator.py and executor.py), which is the
main source of sim-vs-real drift.  This module owns it once:

  * ``DeviceLedger``    — byte-exact device residency accounting keyed by
                          (job, storage): idempotent alloc/free, global and
                          per-job peaks, OOM counting, timeline.
  * ``DmaChannel``      — the single host<->device transfer channel, usable
                          in *virtual time* (``acquire``: FIFO busy-until,
                          conflict counting — simulator) and in *real time*
                          (``transfer``: lock-serialized callable — executor).
  * ``JobContext``      — per-job static indices (storage aliasing, planned
                          sizes, trigger->events, last use) + the host-store
                          set, and the shared DECISION RULES: when a planned
                          event applies vs is skipped, when an operand needs
                          a passive swap-in, when a tensor auto-releases.
  * ``MemoryEngine``    — bundles ledger + channel + jobs and records an
                          ``EngineTrace`` of every decision, so a simulated
                          run and a real run of the same plan can be checked
                          for *identical* residency behaviour (the parity
                          test in tests/test_engine_parity.py).
  * ``find_safe_points``— the *safe points* of a (job, plan) pair: op
                          boundaries where no planned swap/recompute is in
                          flight on the DmaChannel and modeled residency is
                          at a local minimum.  A new plan may be hot-swapped
                          in at a safe point without tearing the iteration
                          (preemptive mid-iteration slice shrinking).

Runtimes stay thin: the simulator advances a virtual clock, the executor
moves real arrays; everything they *decide* comes from here.
"""
from __future__ import annotations

import dataclasses
import threading
import time as _time
from typing import Callable, Dict, List, Optional, Set, Tuple

import numpy as np

from .access import AccessSequence, TensorKind
from .peak_analysis import PERSISTENT_KINDS, storage_of
from .plan import (EventType, MachineProfile, ScheduleEvent,
                   SchedulingPlan, wrap_intervals)
from .telemetry import TelemetryHub


# ----------------------------------------------------------------------
# Residency accounting
# ----------------------------------------------------------------------
class DeviceLedger:
    """Logical device-memory accounting shared by every job on the device.

    Keyed by (job_id, storage): an alloc of an already-resident storage and a
    free of an absent one are no-ops (the five-situation model makes both
    legal races), so double counting is impossible by construction.
    """

    def __init__(self, capacity_bytes: Optional[int] = None,
                 trace: Optional["EngineTrace"] = None,
                 telemetry: Optional[TelemetryHub] = None):
        self.capacity = capacity_bytes
        self.used = 0
        self.peak = 0
        self.oom_events = 0
        self.lock = threading.Lock()
        # measured-telemetry plane: every residency mutation is mirrored
        # into the hub, so the executor's measured timeline and the
        # simulator's virtual one are ordered identically by construction
        self.telemetry = telemetry
        self.timeline: List[Tuple[float, int]] = []
        # per-job usage over time — what "is job j inside its slice at
        # instant t" questions (time-to-within-budget) are answered from.
        # Recorded only for VIRTUAL-time mutations (an explicit `t`, i.e.
        # bounded simulator runs); the real executor's wall-clock path
        # skips it, so long-running jobs don't grow an unread time series
        # under the ledger lock.
        self.job_timeline: Dict[str, List[Tuple[float, int]]] = {}
        self.trace = trace
        self._resident: Dict[Tuple[str, str], int] = {}
        self._job_bytes: Dict[str, int] = {}
        self._job_peak: Dict[str, int] = {}

    # -- queries -------------------------------------------------------
    def is_resident(self, job_id: str, storage: str) -> bool:
        return (job_id, storage) in self._resident

    def resident_bytes(self, job_id: str, storage: str) -> int:
        return self._resident.get((job_id, storage), 0)

    def job_bytes(self, job_id: str) -> int:
        return self._job_bytes.get(job_id, 0)

    def job_peak(self, job_id: str) -> int:
        return self._job_peak.get(job_id, 0)

    def resident_storages(self, job_id: str) -> List[str]:
        return [st for j, st in self._resident if j == job_id]

    # -- mutations -----------------------------------------------------
    def alloc(self, job_id: str, storage: str, nbytes: int,
              t: Optional[float] = None) -> bool:
        """Returns True if bytes were actually added (not already resident)."""
        with self.lock:
            key = (job_id, storage)
            if key in self._resident:
                return False
            self._resident[key] = nbytes
            self.used += nbytes
            if self.capacity is not None and self.used > self.capacity:
                self.oom_events += 1
            self.peak = max(self.peak, self.used)
            jb = self._job_bytes.get(job_id, 0) + nbytes
            self._job_bytes[job_id] = jb
            self._job_peak[job_id] = max(self._job_peak.get(job_id, 0), jb)
            now = t if t is not None else _time.perf_counter()
            self.timeline.append((now, self.used))
            if t is not None:
                self.job_timeline.setdefault(job_id, []).append((t, jb))
            if self.trace is not None:
                self.trace.record("alloc", job_id, storage)
            if self.telemetry is not None:
                self.telemetry.record_residency(job_id, storage, "alloc",
                                                jb, t)
            return True

    def free(self, job_id: str, storage: str,
             t: Optional[float] = None) -> int:
        """Returns the bytes freed (0 if the storage was not resident)."""
        with self.lock:
            key = (job_id, storage)
            if key not in self._resident:
                return 0
            nbytes = self._resident.pop(key)
            self.used -= nbytes
            jb = self._job_bytes.get(job_id, 0) - nbytes
            self._job_bytes[job_id] = jb
            now = t if t is not None else _time.perf_counter()
            self.timeline.append((now, self.used))
            if t is not None:
                self.job_timeline.setdefault(job_id, []).append((t, jb))
            if self.trace is not None:
                self.trace.record("free", job_id, storage)
            if self.telemetry is not None:
                self.telemetry.record_residency(job_id, storage, "free",
                                                jb, t)
            return nbytes

    def view(self, job_id: str,
             budget_bytes: Optional[int] = None) -> "JobLedgerView":
        """A per-job window onto this shared ledger (multi-workload
        controller: one DeviceLedger, one view per live job)."""
        return JobLedgerView(self, job_id, budget_bytes)


class JobLedgerView:
    """One job's window onto the shared ``DeviceLedger``.

    The Global Controller's BudgetArbiter assigns every live job a slice of
    the device-wide budget; this view pairs that slice with the job's live
    accounting so passes, tests and reports can ask "is job j inside its
    arbiter share?" without reaching around the ledger.  It is a *view*:
    all mutation still goes through the one shared ledger, so cross-job
    invariants (global peak, OOM counting) cannot be bypassed.
    """

    def __init__(self, ledger: DeviceLedger, job_id: str,
                 budget_bytes: Optional[int] = None):
        self.ledger = ledger
        self.job_id = job_id
        self.budget_bytes = budget_bytes

    # -- queries (job-scoped) ------------------------------------------
    @property
    def used(self) -> int:
        return self.ledger.job_bytes(self.job_id)

    @property
    def peak(self) -> int:
        return self.ledger.job_peak(self.job_id)

    def is_resident(self, job_id: str, storage: str) -> bool:
        """Residency-oracle signature (JobContext.input_action compatible);
        answers only for the owning job."""
        return job_id == self.job_id \
            and self.ledger.is_resident(job_id, storage)

    def resident_storages(self) -> List[str]:
        return self.ledger.resident_storages(self.job_id)

    # -- budget arithmetic ---------------------------------------------
    @property
    def headroom(self) -> Optional[int]:
        if self.budget_bytes is None:
            return None
        return self.budget_bytes - self.used

    @property
    def over_budget(self) -> bool:
        return self.budget_bytes is not None and self.used > self.budget_bytes

    # -- mutations (delegate; job pinned) ------------------------------
    def alloc(self, storage: str, nbytes: int,
              t: Optional[float] = None) -> bool:
        return self.ledger.alloc(self.job_id, storage, nbytes, t)

    def free(self, storage: str, t: Optional[float] = None) -> int:
        return self.ledger.free(self.job_id, storage, t)


# ----------------------------------------------------------------------
# The single host-DMA channel
# ----------------------------------------------------------------------
class DmaChannel:
    """One transfer at a time across every job (paper §IV-A).

    Virtual time (simulator): ``acquire(t, dur)`` books the next free slot
    FIFO and counts cross-job conflicts.  Real time (executor): ``transfer``
    serializes actual copies behind one lock and accounts busy seconds.

    Coalescing (off by default, so default bookings are byte-identical to
    the single-transfer channel): adjacent same-direction bookings that
    land within ``coalesce_window`` of the open tail batch merge into ONE
    batched transfer — the group pays a single fixup latency plus
    ``batch_overhead_s`` per extra member instead of a full per-transfer
    setup each.  ``acquire_batch`` books an explicit cohort the same way,
    and ``transfer_batch`` is the real-time analogue: several copies under
    one channel hold (one launch on the wire).
    """

    def __init__(self, coalesce: bool = False, coalesce_window: float = 0.0,
                 batch_overhead_s: float = 0.0):
        # virtual-time state
        self.busy_until = 0.0
        self.conflicts = 0
        # most recent acquire, for best-effort refunds:
        # (busy_until before it, slot start, slot end)
        self._last_acquire: Optional[Tuple[float, float, float]] = None
        # coalescing config + the open tail batch eligible for merging:
        # (direction, batch start, batch end, member count)
        self.coalesce = bool(coalesce)
        self.coalesce_window = float(coalesce_window)
        self.batch_overhead_s = float(batch_overhead_s)
        self._tail_batch: Optional[Tuple[str, float, float, int]] = None
        self.batched_transfers = 0    # coalesced groups (2+ members)
        self.coalesced_bookings = 0   # member bookings folded into groups
        self.saved_fixup_s = 0.0      # virtual seconds of fixup elided
        # real-time state
        self.lock = threading.Lock()
        self.busy_s = 0.0
        # optional observability tap (instant events for batch merges)
        self.recorder = None

    def acquire(self, t: float, dur: float, direction: Optional[str] = None,
                fixup: float = 0.0) -> Tuple[float, float]:
        if (self.coalesce and direction is not None
                and self._tail_batch is not None):
            d, s, e, n = self._tail_batch
            if (d == direction and abs(e - self.busy_until) < 1e-12
                    and t <= e + self.coalesce_window + 1e-12):
                # merge into the open batch: pay the payload plus the
                # per-member batch overhead, not another fixup latency
                payload = max(dur - fixup, 0.0) + self.batch_overhead_s
                self.busy_until = e + payload
                self._tail_batch = (d, s, self.busy_until, n + 1)
                self._last_acquire = (e, e, self.busy_until)
                if n == 1:
                    self.batched_transfers += 1
                    self.coalesced_bookings += 1  # the member that opened it
                self.coalesced_bookings += 1
                self.saved_fixup_s += max(fixup - self.batch_overhead_s, 0.0)
                if self.recorder is not None:
                    self.recorder.instant("dma_batch_merge", e,
                                          direction=d, members=n + 1)
                return e, self.busy_until
        prev = self.busy_until
        if t < self.busy_until:
            self.conflicts += 1
            t = self.busy_until
        self.busy_until = t + dur
        self._last_acquire = (prev, t, t + dur)
        if self.coalesce:
            self._tail_batch = ((direction, t, t + dur, 1)
                                if direction is not None else None)
        return t, t + dur

    def acquire_batch(self, t: float, payload_durs, fixup: float = 0.0,
                      direction: Optional[str] = None,
                      member_overhead: Optional[float] = None
                      ) -> Tuple[float, float]:
        """Book one coalesced slot for an explicit same-direction cohort:
        a single ``fixup`` latency, the summed payload durations, and a
        per-extra-member overhead.  Returns the batch (start, end)."""
        durs = list(payload_durs)
        if not durs:
            return t, t
        over = (self.batch_overhead_s if member_overhead is None
                else float(member_overhead))
        if len(durs) == 1:
            return self.acquire(t, fixup + durs[0],
                                direction=direction, fixup=fixup)
        dur = fixup + sum(durs) + over * (len(durs) - 1)
        prev = self.busy_until
        if t < self.busy_until:
            self.conflicts += 1
            t = self.busy_until
        self.busy_until = t + dur
        self._last_acquire = (prev, t, t + dur)
        if self.coalesce:
            self._tail_batch = ((direction, t, t + dur, len(durs))
                                if direction is not None else None)
        self.batched_transfers += 1
        self.coalesced_bookings += len(durs)
        self.saved_fixup_s += max(fixup - over, 0.0) * (len(durs) - 1)
        if self.recorder is not None:
            self.recorder.instant("dma_batch", t, direction=direction,
                                  members=len(durs))
        return t, t + dur

    def try_refund(self, start: float, end: float) -> bool:
        """Best-effort cancellation of a virtual-time booking: only the
        most recent (tail) slot can be refunded — the channel is a FIFO
        scalar, earlier slots already have later bookings queued behind
        them.  Refunding the most recent acquire restores the exact
        pre-booking state; an older tail slot shrinks to its start.  Used
        when an incremental replan cancels a swap-in that was booked but
        has not started at the safe point."""
        if self._last_acquire is not None:
            prev, s, e = self._last_acquire
            if abs(s - start) < 1e-12 and abs(e - end) < 1e-12 \
                    and abs(self.busy_until - end) < 1e-12:
                self.busy_until = prev
                self._last_acquire = None
                return True
        if abs(self.busy_until - end) < 1e-12 and start < end:
            self.busy_until = start
            return True
        return False

    def transfer(self, fn: Callable):
        with self.lock:
            t0 = _time.perf_counter()
            out = fn()
            self.busy_s += _time.perf_counter() - t0
            return out

    def transfer_batch(self, fns) -> list:
        """Run several copies under ONE channel hold — the real-time form
        of a coalesced batch: a single acquisition of the wire covers the
        whole cohort instead of one lock round-trip per member."""
        with self.lock:
            t0 = _time.perf_counter()
            out = [fn() for fn in fns]
            self.busy_s += _time.perf_counter() - t0
            if len(out) > 1:
                self.batched_transfers += 1
                self.coalesced_bookings += len(out)
            return out


class ResidencyView:
    """Minimal residency oracle the decision rules consult.  DeviceLedger is
    one (the simulator's); the executor supplies a view over its own value
    store, because under the multi-workload controller the global ledger
    outlives a single iteration's executor instance."""

    def __init__(self, store):
        self._store = store

    def is_resident(self, job_id: str, storage: str) -> bool:
        return storage in self._store


# ----------------------------------------------------------------------
# Decision trace (sim-vs-real parity)
# ----------------------------------------------------------------------
@dataclasses.dataclass
class TraceRecord:
    action: str          # alloc|free|swap_out|swap_in|passive_in|recompute|release|skip
    job_id: str
    storage: str

    def key(self) -> Tuple[str, str, str]:
        return (self.action, self.job_id, self.storage)


class EngineTrace:
    """Ordered record of residency decisions; two runs of the same plan on
    the same engine semantics must produce identical traces."""

    def __init__(self):
        self.records: List[TraceRecord] = []
        self.lock = threading.Lock()
        # paused while a runtime does harness work outside the modeled
        # iteration (e.g. the executor materializing outputs to return
        # them to Python — steady state would leave them on host)
        self.paused = False

    def record(self, action: str, job_id: str, storage: str) -> None:
        if self.paused:
            return
        with self.lock:
            self.records.append(TraceRecord(action, job_id, storage))

    def keys(self) -> List[Tuple[str, str, str]]:
        return [r.key() for r in self.records]


# ----------------------------------------------------------------------
# Per-job context: static indices + host store + decision rules
# ----------------------------------------------------------------------
# what an operator must do about a not-yet-resident input
INPUT_RESIDENT = "resident"          # nothing to do
INPUT_AWAIT_PREFETCH = "await"       # planned swap-in in flight: stall on it
INPUT_PASSIVE_SWAP_IN = "passive"    # host copy exists: blocking swap-in
INPUT_RECOMPUTE = "recompute"        # regenerate from the producer op


class JobContext:
    """Everything the engine knows statically about one job's plan, plus the
    host-store set that evolves as the plan runs."""

    def __init__(self, seq: AccessSequence,
                 plan: Optional[SchedulingPlan] = None,
                 offset: float = 0.0):
        self.seq = seq
        self.plan = plan
        self.offset = offset
        self.job_id = seq.job_id

        # storage aliasing + planned byte sizes (max over aliases)
        self.storage: Dict[str, str] = {}
        self.sizes: Dict[str, int] = {}
        for t in seq.tensors.values():
            st = storage_of(t)
            self.storage[t.tid] = st
            self.sizes[st] = max(self.sizes.get(st, 0), t.size_bytes)

        # last use per *storage* (max over aliases; §IV-B situation 5)
        self.last_use: Dict[str, int] = {}
        for tid, idx in seq.activity_analysis().items():
            st = self.storage.get(tid, tid)
            self.last_use[st] = max(self.last_use.get(st, -1), idx)

        # storages that persist across iterations / must not auto-release
        self.protected: Set[str] = set()
        for t in seq.tensors.values():
            if (t.kind in PERSISTENT_KINDS or t.updates is not None
                    or t.kind is TensorKind.OUTPUT):
                self.protected.add(storage_of(t))

        # plan indices
        self.by_trigger: Dict[int, List[ScheduleEvent]] = {}
        self.recompute_for: Dict[str, ScheduleEvent] = {}
        self.set_plan(plan)

        # host-store membership (the data lives there; values are runtime-
        # specific — the simulator keeps none, the executor keeps arrays)
        self.host: Set[str] = set()
        # storages whose host copy went through the quantize-on-offload
        # path — fetching them back pays the compressed transfer time
        self.host_compressed: Set[str] = set()

    def set_plan(self, plan: Optional[SchedulingPlan]) -> None:
        """(Re)bind the plan and rebuild its trigger indices.  Called at
        construction and at a safe-point hot-swap: the runtime splices a
        new plan mid-iteration, and because the new plan's events at or
        before the splice op are identical to the old one's, every decision
        already taken stays valid — only future triggers change.  The host
        store and sizes are state of the *job*, not the plan, and carry
        over untouched."""
        self.plan = plan
        self.by_trigger = {}
        self.recompute_for = {}
        if plan:
            for ev in plan.events:
                self.by_trigger.setdefault(ev.trigger_op, []).append(ev)
                if ev.event_type is EventType.RECOMPUTE:
                    self.recompute_for[self.st(ev.tensor_id)] = ev

    # -- helpers -------------------------------------------------------
    def st(self, tid: str) -> str:
        return self.storage.get(tid, tid)

    def size_of(self, tid_or_storage: str) -> int:
        st = self.st(tid_or_storage)
        return self.sizes.get(st, 0)

    def events_triggered_by(self, op_idx: int) -> List[ScheduleEvent]:
        return self.by_trigger.get(op_idx, [])

    # -- decision rules (THE shared semantics) -------------------------
    def input_action(self, residency, tid: str,
                     prefetch_inflight: bool = False) -> str:
        """What must happen before an operator may read `tid` (paper
        Executor semantics: prefetch-wait, else passive swap-in, else
        recompute from the producer).  `residency` is any object with
        ``is_resident(job_id, storage)`` — the DeviceLedger or an
        executor's ResidencyView."""
        st = self.st(tid)
        if residency.is_resident(self.job_id, st):
            return INPUT_RESIDENT
        if prefetch_inflight:
            return INPUT_AWAIT_PREFETCH
        if st in self.host:
            return INPUT_PASSIVE_SWAP_IN
        return INPUT_RECOMPUTE

    def should_auto_release(self, tid: str, op_idx: int,
                            free_at_last_use: bool = True) -> bool:
        """Situation 5: free after the storage's last access — unless the
        plan overrides the release point, the tensor persists across
        iterations (params/opt-state/updated aliases), or it is a job
        output."""
        st = self.st(tid)
        if self.plan is not None:
            rel_op = self.plan.release_after_op.get(tid)
            if rel_op is not None:
                return rel_op == op_idx
        if not free_at_last_use:
            return False
        return self.last_use.get(st) == op_idx and st not in self.protected

    def event_applies(self, residency, ev: ScheduleEvent) -> bool:
        """Skip rules shared by sim and executor: a swap-out needs a device
        copy; a swap-in needs a host copy and no device copy (iteration-0
        cold start of a cross-iteration plan has neither); a planned release
        is only safe when a host copy or a recompute event can restore the
        value; a recompute only fires when the value is absent."""
        st = self.st(ev.tensor_id)
        resident = residency.is_resident(self.job_id, st)
        if ev.event_type is EventType.SWAP_OUT:
            return resident
        if ev.event_type is EventType.SWAP_IN:
            return (not resident) and st in self.host
        if ev.event_type is EventType.RELEASE:
            return resident and (st in self.host or st in self.recompute_for)
        if ev.event_type is EventType.RECOMPUTE:
            return not resident
        return False


# ----------------------------------------------------------------------
# Safe points: where a plan may be hot-swapped mid-iteration
# ----------------------------------------------------------------------
@dataclasses.dataclass
class SafePoint:
    """An op boundary where a job's plan can be spliced without tearing
    the iteration: no planned transfer or recompute spans the instant, and
    modeled residency is at a local minimum (so eager swap-outs scheduled
    from here act on a quiescent footprint)."""

    op_idx: int          # boundary right after this operator completes
    time: float          # job-local instant (seq.op_end[op_idx])
    resident_bytes: int  # modeled device residency at the boundary


def _measured_safe_points(seq: AccessSequence, telemetry: TelemetryHub,
                          min_iterations: int) -> Optional[List[SafePoint]]:
    """Safe points from the MEASURED residency timeline: op boundaries
    that, in each of the last ``min_iterations`` completed iterations,
    were quiescent (no recorded transfer in flight across the measured
    completion instant) and at a non-strict local minimum of the measured
    per-boundary residency.  Returns None when fewer than
    ``min_iterations`` instrumented iterations exist — the caller falls
    back to the modeled ledger (cold start, paper §IV-C blending)."""
    job_id = seq.job_id
    n = len(seq.operators)
    if n <= 1:
        return []
    done = telemetry.iterations(job_id)
    if done < min_iterations:
        return None
    common: Optional[set] = None
    res_sum: Dict[int, int] = {}
    for it in range(done - min_iterations, done):
        resident = telemetry.measured_boundary_residency(job_id, it, n)
        quiescent = telemetry.quiescent_boundaries(job_id, it, n)
        if resident is None or quiescent is None:
            return None                      # iteration not instrumented
        ok = set()
        qset = set(quiescent)
        for k in range(n - 1):               # final op == iteration boundary
            if k not in qset:
                continue
            left = resident[k - 1] if k > 0 else resident[k]
            right = resident[k + 1]
            if resident[k] <= left and resident[k] <= right:
                ok.add(k)
        common = ok if common is None else (common & ok)
        for k in ok:
            res_sum[k] = res_sum.get(k, 0) + resident[k]
    if not common:
        return []
    return [SafePoint(op_idx=k, time=seq.op_end[k],
                      resident_bytes=res_sum[k] // min_iterations)
            for k in sorted(common)]


def find_safe_points(seq: AccessSequence,
                     plan: Optional[SchedulingPlan] = None,
                     free_at_last_use: bool = True,
                     source: str = "modeled",
                     telemetry: Optional[TelemetryHub] = None,
                     min_iterations: int = 2) -> List[SafePoint]:
    """Safe points of one (job, plan) pair, in op order.

    A boundary after op k qualifies when (1) no swap/recompute event of the
    plan is in flight across ``op_end[k]`` — a splice must not orphan a
    transfer already booked on the DmaChannel — and (2) the residency the
    plan models at that instant is a local minimum (non-strict, so flat
    plateaus qualify).  The final op is excluded: that boundary is the
    iteration boundary, which is the non-preemptive case.  Cross-iteration
    events are wrapped modulo the iteration period, mirroring the planner's
    PeriodicChannel bookings.

    ``source="measured"`` detects the same two conditions from the
    TelemetryHub's measured records instead of the modeled ledger; below
    ``min_iterations`` of instrumented iterations (or with no hub at all)
    it falls back to the modeled path — the paper's §IV-C cold-start
    blending applied to safe-point detection.

    The modeled path is a vectorized numpy sweep over the job's SoA event
    buffers (shared with ``peak_analysis.analyze``); the busy-interval
    list is cached on the plan per ``SchedulingPlan.version``.
    ``_reference_safe_points`` keeps the original per-event scan for the
    equivalence tests.
    """
    if source == "measured" and telemetry is not None:
        measured = _measured_safe_points(seq, telemetry, min_iterations)
        if measured is not None:
            return measured

    from .peak_analysis import _effective_mask, _seq_arrays

    eps = 1e-12
    n = len(seq.operators)
    if n <= 1:
        return []
    T = max(seq.iteration_time, eps)

    # (1) in-flight intervals of the plan, projected into [0, T) with the
    # same wrapping the planner's PeriodicChannel books with (cached on
    # the plan; rebuilt only when plan.version moves)
    busy = plan.busy_intervals(T) if plan is not None else []

    # (2) modeled residency at every op boundary: effective-event cumsum
    # (idempotent alloc/free — exactly the ledger semantics), then one
    # searchsorted per boundary instead of the per-event scan
    t, o, d, k_ids, _rel, _base = _seq_arrays(seq, plan, free_at_last_use)
    op_end = np.asarray(seq.op_end[:n], dtype=np.float64)
    if len(t):
        eff = _effective_mask(k_ids, d)
        mem = np.cumsum(np.where(eff, d, 0))
        cnt = np.searchsorted(t, op_end + eps, side="right")
        resident = np.where(cnt > 0, mem[np.maximum(cnt - 1, 0)], 0)
    else:
        resident = np.zeros(n, dtype=np.int64)

    # (3) local-minimum + not-busy filter over boundaries 0..n-2 (the
    # final op is the iteration boundary — the non-preemptive case)
    r = resident
    left = np.empty(n - 1, dtype=r.dtype)
    left[0] = r[0]
    left[1:] = r[:-2] if n > 2 else r[:0]
    ok = (r[:-1] <= left) & (r[:-1] <= r[1:])
    if busy:
        # covered iff some interval has s < t_k - eps AND e > t_k + eps:
        # sort by start, prefix-max of ends, one searchsorted per boundary
        bs = np.asarray([s for s, _ in busy], dtype=np.float64)
        be = np.asarray([e for _, e in busy], dtype=np.float64)
        srt = np.argsort(bs, kind="stable")
        bs, be = bs[srt], be[srt]
        pmax_e = np.maximum.accumulate(be)
        tk = op_end[:n - 1]
        ns = np.searchsorted(bs, tk - eps, side="left")
        covered = (ns > 0) & (pmax_e[np.maximum(ns - 1, 0)] > tk + eps)
        ok &= ~covered
    return [SafePoint(op_idx=int(kk), time=float(op_end[kk]),
                      resident_bytes=int(r[kk]))
            for kk in np.flatnonzero(ok)]


def _reference_safe_points(seq: AccessSequence,
                           plan: Optional[SchedulingPlan] = None,
                           free_at_last_use: bool = True) -> List[SafePoint]:
    """The original per-event modeled safe-point scan, kept verbatim as
    the semantic reference for the vectorized path above (equivalence
    tests assert identical SafePoint lists).  Not on any hot path."""
    from .peak_analysis import build_events

    eps = 1e-12
    n = len(seq.operators)
    if n <= 1:
        return []
    T = max(seq.iteration_time, eps)

    busy: List[Tuple[float, float]] = []
    if plan is not None:
        for ev in plan.events:
            if ev.event_type not in (EventType.SWAP_OUT, EventType.SWAP_IN,
                                     EventType.RECOMPUTE):
                continue
            dur = ev.end - ev.start
            if dur <= eps:
                continue
            busy.extend((s, e) for s, e in wrap_intervals(ev.start, dur, T))

    events = sorted(build_events(seq, plan, free_at_last_use=free_at_last_use),
                    key=lambda e: (e.time, e.order))
    resident = [0] * n
    live: Dict[str, int] = {}
    mem = 0
    ei = 0
    for k in range(n):
        t_k = seq.op_end[k]
        while ei < len(events) and events[ei].time <= t_k + eps:
            e = events[ei]
            ei += 1
            if e.delta > 0:
                if e.storage not in live:
                    live[e.storage] = e.delta
                    mem += e.delta
            elif e.storage in live:
                mem -= live.pop(e.storage)
        resident[k] = mem

    out: List[SafePoint] = []
    for k in range(n - 1):
        t_k = seq.op_end[k]
        if any(s < t_k - eps and t_k < e - eps for s, e in busy):
            continue
        left = resident[k - 1] if k > 0 else resident[k]
        right = resident[k + 1]
        if resident[k] <= left and resident[k] <= right:
            out.append(SafePoint(op_idx=k, time=t_k,
                                 resident_bytes=resident[k]))
    return out


# ----------------------------------------------------------------------
# Engine: ledger + channel + jobs + event timing
# ----------------------------------------------------------------------
def event_duration(profile: MachineProfile, ev: ScheduleEvent) -> float:
    """Planned transfer duration of a swap event.  The planner stamps
    ``start``/``end`` from the cost model (incl. the quantize-on-offload
    latency for compressed events); fall back to the profile for
    hand-constructed events."""
    if ev.end > ev.start:
        return ev.end - ev.start
    return profile.transfer_time(ev.size_bytes, compressed=ev.compressed)


class MemoryEngine:
    """The one memory model both runtimes execute against."""

    def __init__(self, profile: Optional[MachineProfile] = None,
                 capacity_bytes: Optional[int] = None,
                 ledger: Optional[DeviceLedger] = None,
                 channel: Optional[DmaChannel] = None,
                 trace: bool = False,
                 telemetry: Optional[TelemetryHub] = None):
        self.profile = profile or MachineProfile()
        self.trace = EngineTrace() if trace else None
        self.ledger = ledger or DeviceLedger(capacity_bytes, trace=self.trace)
        if trace and self.ledger.trace is None:
            self.ledger.trace = self.trace
        self.channel = channel or DmaChannel()
        self.jobs: Dict[str, JobContext] = {}
        self.telemetry: Optional[TelemetryHub] = None
        # optional observability tap: None (the default) keeps every
        # hook at a single attribute check
        self.recorder = None
        if telemetry is not None:
            self.attach_telemetry(telemetry)

    def attach_telemetry(self, hub: TelemetryHub) -> None:
        """Bind the measured-telemetry hub: residency mutations on the
        ledger mirror into it from here on (both runtimes emit through
        this single point, so record ordering stays parity-testable)."""
        self.telemetry = hub
        if self.ledger.telemetry is None:
            self.ledger.telemetry = hub
        if self.recorder is not None and hub._recorder is None:
            hub.attach_recorder(self.recorder)

    def attach_recorder(self, recorder) -> None:
        """Bind a trace recorder to every tap this engine owns: the
        telemetry hub's publish point, the DMA channel's batch events,
        and the runtimes' hot-swap instants (which read
        ``engine.recorder``).  Attach order vs ``attach_telemetry`` does
        not matter — whichever lands second propagates."""
        self.recorder = recorder
        self.channel.recorder = recorder
        if self.telemetry is not None and self.telemetry._recorder is None:
            self.telemetry.attach_recorder(recorder)

    def add_job(self, seq: AccessSequence,
                plan: Optional[SchedulingPlan] = None,
                offset: float = 0.0) -> JobContext:
        job = JobContext(seq, plan, offset)
        self.jobs[job.job_id] = job
        return job

    def job(self, job_id: str) -> JobContext:
        return self.jobs[job_id]

    # -- traced wrappers (decision + accounting in one place) ----------
    def record(self, action: str, job: JobContext, storage: str) -> None:
        if self.trace is not None:
            self.trace.record(action, job.job_id, storage)

    def complete_swap_out(self, job: JobContext, storage: str,
                          t: Optional[float] = None,
                          compressed: bool = False) -> int:
        """Eviction lands: host copy exists, device copy freed."""
        job.host.add(storage)
        if compressed:
            job.host_compressed.add(storage)
        else:
            job.host_compressed.discard(storage)
        self.record("swap_out", job, storage)
        return self.ledger.free(job.job_id, storage, t)

    def complete_swap_in(self, job: JobContext, storage: str,
                         t: Optional[float] = None,
                         passive: bool = False) -> bool:
        """Prefetch (or passive fetch) lands: device copy restored.  The
        host copy is retained — later planned release+swap-in pairs reuse
        it (paper: 'swap-in the rest of accesses greedily')."""
        self.record("passive_in" if passive else "swap_in", job, storage)
        return self.ledger.alloc(job.job_id, storage,
                                 job.sizes.get(storage, 0), t)

    def event_duration(self, ev: ScheduleEvent) -> float:
        return event_duration(self.profile, ev)
