"""The measured-telemetry plane: one sink for everything the runtimes
measure, and the queries every consumer of *measured* (not modeled) time
reads through.

TENSILE's across-iteration scheduling stays correct because runtime
measurements are folded back into the plan (EWMA latency correction,
paper §IV-E).  Before this module, only the scheduler's latency table was
corrected — safe-point detection, swap-window sizing and arbiter splits
all ran on modeled numbers.  ``TelemetryHub`` makes measurement a
first-class plane of the architecture:

  producers (one record schema, two clocks)
    * ``JaxprExecutor``  — per-op wall-clock latencies, per-transfer DMA
      durations (full-precision and compressed), stall events, and the
      per-job residency timeline (via the shared ``DeviceLedger`` hook),
      all in *real* time.
    * ``simulator.simulate`` — the SAME record shapes stamped in
      *virtual* time, so the two runtimes stay parity-testable
      (tests/test_engine_parity.py asserts identical schemas and
      identical residency-event ordering).

  consumers (each one a layer that used to read modeled numbers)
    * ``cost_model``   — ``CostModel.recalibrate`` re-fits the
      ``DeviceCalibration`` throughput constants online from hub op
      samples; ``calibration_report`` exposes per-primitive error.
    * ``engine.find_safe_points(source="measured")`` — quiescent local
      minima detected from the measured residency timeline, falling back
      to the modeled ledger below ``min_iterations`` of samples
      (cold-start blending, paper §IV-C).
    * ``SwapPlanner(telemetry=...)`` — swap windows sized from the
      measured DMA bandwidth instead of the profile constant.
    * ``BudgetArbiter`` — the ``eor-learned`` policy re-splits budgets by
      each job's measured stall share; drift replans trigger on
      ``drift_ratio`` instead of scheduler-private EWMA deltas.

The hub is append-only and thread-safe; producers never block on
consumers.  Records are grouped by the producing job's iteration counter
(``end_iteration`` advances it), so consumers can reason per-iteration —
the unit the paper's plans repeat over.
"""
from __future__ import annotations

import dataclasses
import threading
import time as _time
from typing import Dict, List, Optional, Tuple

_EPS = 1e-12


# ----------------------------------------------------------------------
# Record shapes — identical for both runtimes (`t` is virtual seconds in
# the simulator, seconds since hub creation in the executor)
# ----------------------------------------------------------------------
@dataclasses.dataclass
class OpSample:
    """One operator execution: measured latency + the static cost-model
    features (flops / bytes) needed to recalibrate throughput constants."""

    job_id: str
    iteration: int
    op_idx: int
    prim: str
    latency_s: float
    flops: float
    bytes_accessed: float
    t: float                 # instant the op COMPLETED


@dataclasses.dataclass
class TransferSample:
    """One host<->device DMA transfer (planned prefetch, eviction, or a
    passive swap-in stall fetch), full-precision or compressed."""

    job_id: str
    iteration: int
    storage: str
    direction: str           # "out" | "in"
    size_bytes: int
    duration_s: float
    compressed: bool
    passive: bool
    t: float                 # transfer START


@dataclasses.dataclass
class StallSample:
    """Compute blocked on memory: a late prefetch awaited or a passive
    swap-in serialized in front of an operator."""

    job_id: str
    iteration: int
    op_idx: int
    cause: str               # "await_prefetch" | "passive_in"
    duration_s: float
    t: float


@dataclasses.dataclass
class ResidencySample:
    """One byte-accounting mutation of the job's device residency,
    emitted by the shared ``DeviceLedger`` — so the executor's measured
    timeline and the simulator's virtual one are ordered identically by
    construction."""

    job_id: str
    iteration: int
    storage: str
    action: str              # "alloc" | "free"
    resident_bytes: int      # the JOB's bytes right after the mutation
    t: float


def record_schemas() -> Dict[str, Tuple[str, ...]]:
    """Field names per record type — the parity test asserts both
    runtimes emit exactly these shapes."""
    return {
        "op": tuple(f.name for f in dataclasses.fields(OpSample)),
        "transfer": tuple(f.name for f in dataclasses.fields(TransferSample)),
        "stall": tuple(f.name for f in dataclasses.fields(StallSample)),
        "residency": tuple(f.name
                           for f in dataclasses.fields(ResidencySample)),
    }


@dataclasses.dataclass
class IterationView:
    """One job-iteration's worth of records, time-aligned for safe-point
    detection: op completion instants, transfer busy intervals, and the
    residency timeline."""

    op_end: Dict[int, float]                 # op_idx -> completion instant
    transfers: List[Tuple[float, float]]     # busy [start, end) intervals
    residency: List[Tuple[float, int]]       # (t, job resident bytes)


# ----------------------------------------------------------------------
# The hub
# ----------------------------------------------------------------------
class TelemetryHub:
    """Single sink for measured runtime telemetry, shared by every job on
    a device (the Global Controller owns one per engine).

    ``clock`` is metadata only — "real" (executor wall clock, relative to
    hub creation) or "virtual" (simulator seconds); record shapes and
    query semantics are identical, which is what keeps the two runtimes
    parity-testable.
    """

    def __init__(self, clock: str = "real", ewma_alpha: float = 0.3):
        self.clock = clock
        self.ewma_alpha = ewma_alpha
        self._t0 = _time.perf_counter()
        self._lock = threading.Lock()
        # like EngineTrace.paused: a runtime doing harness work outside
        # the modeled iteration (e.g. materializing outputs) pauses
        # recording so steady-state telemetry is not polluted.  The flag
        # is PER-THREAD: under the multi-job controller one executor's
        # pause must not drop records from jobs running on other threads
        self._local = threading.local()
        self.ops: Dict[str, List[OpSample]] = {}
        self.transfers: Dict[str, List[TransferSample]] = {}
        self.stalls: Dict[str, List[StallSample]] = {}
        self.residency: Dict[str, List[ResidencySample]] = {}
        self._iter: Dict[str, int] = {}
        # per-job EWMA-corrected measured latency per op (paper §IV-E,
        # maintained incrementally as samples arrive)
        self._ewma: Dict[str, Dict[int, float]] = {}
        # optional observability tap: a TraceRecorder sees every sample
        # at its single publish point below.  None (the default) keeps
        # the hot path at one attribute check per record.
        self._recorder = None

    def attach_recorder(self, recorder) -> None:
        """Forward every published sample to a trace recorder."""
        self._recorder = recorder

    # -- pause (per-thread) --------------------------------------------
    @property
    def paused(self) -> bool:
        return getattr(self._local, "paused", False)

    @paused.setter
    def paused(self, value: bool) -> None:
        self._local.paused = bool(value)

    # -- buffered appends (per-thread, executor hot path) --------------
    # A producer that records per op can opt into buffering: record_*
    # calls append fully-stamped samples to a thread-local list without
    # touching the hub lock, and ``flush()`` publishes them — in emission
    # order, under ONE lock acquisition — at op boundaries.  Samples are
    # stamped (clock + iteration index) at record time, so buffering
    # changes only lock traffic, never record content or order.
    @property
    def buffering(self) -> bool:
        return getattr(self._local, "buffer", None) is not None

    def _buffer(self):
        return getattr(self._local, "buffer", None)

    def begin_buffering(self) -> None:
        if self._buffer() is None:
            self._local.buffer = []

    def flush(self) -> None:
        buf = self._buffer()
        if not buf:
            return
        self._local.buffer = []
        with self._lock:
            for kind, s in buf:
                self._publish(kind, s)

    def end_buffering(self) -> None:
        self.flush()
        self._local.buffer = None

    def _publish(self, kind: str, s) -> None:
        """Append one stamped sample to its stream (hub lock held)."""
        if kind == "op":
            self.ops.setdefault(s.job_id, []).append(s)
            ew = self._ewma.setdefault(s.job_id, {})
            old = ew.get(s.op_idx)
            ew[s.op_idx] = s.latency_s if old is None else (
                self.ewma_alpha * s.latency_s
                + (1 - self.ewma_alpha) * old)
        elif kind == "transfer":
            self.transfers.setdefault(s.job_id, []).append(s)
        elif kind == "stall":
            self.stalls.setdefault(s.job_id, []).append(s)
        else:
            self.residency.setdefault(s.job_id, []).append(s)
        rec = self._recorder
        if rec is not None:
            rec.on_sample(kind, s)

    # -- clock ---------------------------------------------------------
    def now(self) -> float:
        return _time.perf_counter() - self._t0

    def _stamp(self, t: Optional[float]) -> float:
        return self.now() if t is None else t

    def _it(self, job_id: str) -> int:
        return self._iter.get(job_id, 0)

    # -- producers -----------------------------------------------------
    def record_op(self, job_id: str, op_idx: int, latency_s: float,
                  prim: str = "", flops: float = 0.0,
                  bytes_accessed: float = 0.0,
                  t: Optional[float] = None) -> None:
        if self.paused:
            return
        s = OpSample(job_id, self._it(job_id), op_idx, prim, latency_s,
                     flops, bytes_accessed, self._stamp(t))
        buf = self._buffer()
        if buf is not None:
            buf.append(("op", s))
            return
        with self._lock:
            self._publish("op", s)

    def record_transfer(self, job_id: str, storage: str, direction: str,
                        size_bytes: int, duration_s: float,
                        compressed: bool = False, passive: bool = False,
                        t: Optional[float] = None) -> None:
        if self.paused:
            return
        s = TransferSample(job_id, self._it(job_id), storage, direction,
                           int(size_bytes), duration_s, compressed, passive,
                           self._stamp(t))
        buf = self._buffer()
        if buf is not None:
            buf.append(("transfer", s))
            return
        with self._lock:
            self._publish("transfer", s)

    def record_stall(self, job_id: str, op_idx: int, duration_s: float,
                     cause: str, t: Optional[float] = None) -> None:
        if self.paused:
            return
        s = StallSample(job_id, self._it(job_id), op_idx, cause, duration_s,
                        self._stamp(t))
        buf = self._buffer()
        if buf is not None:
            buf.append(("stall", s))
            return
        with self._lock:
            self._publish("stall", s)

    def record_residency(self, job_id: str, storage: str, action: str,
                         resident_bytes: int,
                         t: Optional[float] = None) -> None:
        if self.paused:
            return
        s = ResidencySample(job_id, self._it(job_id), storage, action,
                            int(resident_bytes), self._stamp(t))
        buf = self._buffer()
        if buf is not None:
            buf.append(("residency", s))
            return
        with self._lock:
            self._publish("residency", s)

    def end_iteration(self, job_id: str) -> int:
        """Mark the job's iteration boundary; records after this carry
        the next iteration index.  Returns the completed count."""
        self.flush()
        with self._lock:
            n = self._iter.get(job_id, 0) + 1
            self._iter[job_id] = n
            return n

    # -- queries: latency ----------------------------------------------
    def iterations(self, job_id: str) -> int:
        """Completed (fully recorded) iterations of the job."""
        return self._iter.get(job_id, 0)

    def jobs(self) -> List[str]:
        with self._lock:
            seen = (set(self.ops) | set(self.transfers)
                    | set(self.stalls) | set(self.residency))
            return sorted(seen)

    def has_samples(self, job_id: str) -> bool:
        """Whether the job has produced any measured records yet — the
        arbiter's learned policies fall back to persisted experience
        priors for jobs that have not."""
        with self._lock:
            return bool(self.ops.get(job_id) or self.stalls.get(job_id))

    def op_summary(self, job_id: str) -> Dict[str, Dict[str, float]]:
        """Per-primitive distilled latency fit of one job's op samples:
        ``{prim: {n, flops, bytes, latency_s}}`` with the three numeric
        fields as MEANS — the persistent form the experience store keeps
        per fingerprint (enough to re-fit throughput constants without
        replaying raw samples)."""
        with self._lock:
            acc: Dict[str, Dict[str, float]] = {}
            for s in self.ops.get(job_id, ()):
                d = acc.setdefault(s.prim or "?", {
                    "n": 0.0, "flops": 0.0, "bytes": 0.0, "latency_s": 0.0})
                d["n"] += 1
                d["flops"] += s.flops
                d["bytes"] += s.bytes_accessed
                d["latency_s"] += s.latency_s
        for d in acc.values():
            n = max(d["n"], 1.0)
            d["flops"] /= n
            d["bytes"] /= n
            d["latency_s"] /= n
        return acc

    def op_latencies(self, job_id: str) -> Dict[int, float]:
        """EWMA-corrected measured latency per op index (§IV-E)."""
        with self._lock:
            return dict(self._ewma.get(job_id, {}))

    def latency_sum(self, job_id: str) -> float:
        with self._lock:
            return sum(self._ewma.get(job_id, {}).values())

    def drift_ratio(self, job_id: str, baseline_sum: float) -> float:
        """Relative drift of the measured (EWMA) iteration latency vs the
        sum the current plan was built from — the replan trigger that
        used to live in scheduler-private EWMA deltas (§IV-E)."""
        s = self.latency_sum(job_id)
        if not s:
            return 0.0
        if baseline_sum <= 0:
            return float("inf")
        return abs(s - baseline_sum) / baseline_sum

    # -- queries: transfers --------------------------------------------
    def measured_bandwidth(self, compressed: bool = False,
                           min_samples: int = 3,
                           min_bytes: int = 1) -> Optional[float]:
        """Effective DMA bandwidth (source bytes per second) over every
        recorded transfer of the given path; None below ``min_samples``
        (cold start — callers fall back to the profile constant)."""
        with self._lock:
            tot_b = tot_s = 0.0
            n = 0
            for recs in self.transfers.values():
                for r in recs:
                    if r.compressed != compressed or r.size_bytes < min_bytes:
                        continue
                    tot_b += r.size_bytes
                    tot_s += r.duration_s
                    n += 1
        if n < min_samples or tot_s <= _EPS:
            return None
        return tot_b / tot_s

    def transfer_totals(self, compressed: bool = False,
                        min_bytes: int = 1,
                        job_id: Optional[str] = None
                        ) -> Tuple[int, int, float]:
        """(transfers, source bytes, busy seconds) over recorded
        transfers of the given path — hub-wide by default, one job's
        with ``job_id`` — the cumulative form the experience store
        persists so a future cold start can seed ``measured_bandwidth``
        before any live sample exists."""
        with self._lock:
            tot_b = 0
            tot_s = 0.0
            n = 0
            streams = ([self.transfers.get(job_id, [])]
                       if job_id is not None
                       else list(self.transfers.values()))
            for recs in streams:
                for r in recs:
                    if r.compressed != compressed or r.size_bytes < min_bytes:
                        continue
                    tot_b += r.size_bytes
                    tot_s += r.duration_s
                    n += 1
        return n, tot_b, tot_s

    def total_op_samples(self) -> int:
        """Hub-wide op-sample count, read under the hub lock (callers
        must not iterate ``ops`` themselves while producers insert)."""
        with self._lock:
            return sum(len(v) for v in self.ops.values())

    # -- queries: stalls / EOR -----------------------------------------
    def stall_share(self, job_id: str) -> float:
        """Fraction of the job's measured time lost to memory stalls:
        stall seconds / (op seconds + stall seconds).  0.0 with no
        samples — a cold job bids the neutral weight."""
        with self._lock:
            op_s = sum(s.latency_s for s in self.ops.get(job_id, ()))
            st_s = sum(s.duration_s for s in self.stalls.get(job_id, ()))
        tot = op_s + st_s
        return st_s / tot if tot > _EPS else 0.0

    def measured_eor(self, job_id: str) -> float:
        """Measured extra-overhead ratio: stall time over pure compute
        time — the runtime analogue of the paper's EOR, per job."""
        with self._lock:
            op_s = sum(s.latency_s for s in self.ops.get(job_id, ()))
            st_s = sum(s.duration_s for s in self.stalls.get(job_id, ()))
        return st_s / op_s if op_s > _EPS else 0.0

    # -- queries: residency --------------------------------------------
    def residency_timeline(self, job_id: str) -> List[Tuple[float, int]]:
        with self._lock:
            return [(r.t, r.resident_bytes)
                    for r in self.residency.get(job_id, ())]

    def residency_keys(self, job_id: str) -> List[Tuple[str, str]]:
        """(action, storage) in emission order — what the sim-vs-real
        parity test compares."""
        with self._lock:
            return [(r.action, r.storage)
                    for r in self.residency.get(job_id, ())]

    # -- queries: per-iteration views ----------------------------------
    def iteration_view(self, job_id: str,
                       iteration: int) -> Optional[IterationView]:
        """Time-aligned records of one completed iteration, or None when
        the iteration has no op samples (not instrumented)."""
        with self._lock:
            ops = [s for s in self.ops.get(job_id, ())
                   if s.iteration == iteration]
            if not ops:
                return None
            op_end = {}
            for s in ops:
                op_end[s.op_idx] = s.t
            transfers = [(r.t, r.t + r.duration_s)
                         for r in self.transfers.get(job_id, ())
                         if r.iteration == iteration]
            residency = [(r.t, r.resident_bytes)
                         for r in self.residency.get(job_id, ())
                         if r.iteration <= iteration]
        # residency carries over iterations: keep only the last sample
        # at-or-before the window plus everything inside it
        lo = min(op_end.values()) if op_end else 0.0
        inside = [(t, b) for t, b in residency if t >= lo - _EPS]
        before = [(t, b) for t, b in residency if t < lo - _EPS]
        if before:
            inside.insert(0, before[-1])
        return IterationView(op_end=op_end, transfers=transfers,
                             residency=inside)

    def measured_boundary_residency(
            self, job_id: str, iteration: int,
            n_ops: int) -> Optional[List[int]]:
        """The job's measured resident bytes at every op boundary of one
        iteration (last residency sample at or before each op's measured
        completion instant); None when the iteration is missing ops."""
        view = self.iteration_view(job_id, iteration)
        if view is None or len(view.op_end) < n_ops:
            return None
        out: List[int] = []
        # stable sort on time ONLY: an op's allocs and frees share one
        # stamp (the op's end instant), and emission order — not byte
        # count — decides which value the boundary settles at
        res = sorted(view.residency, key=lambda r: r[0])
        cur = res[0][1] if res else 0
        ri = 0
        for k in range(n_ops):
            t_k = view.op_end.get(k)
            if t_k is None:
                return None
            while ri < len(res) and res[ri][0] <= t_k + _EPS:
                cur = res[ri][1]
                ri += 1
            out.append(cur)
        return out

    def quiescent_boundaries(self, job_id: str, iteration: int,
                             n_ops: int) -> Optional[List[int]]:
        """Op boundaries of one iteration with NO measured transfer in
        flight across the completion instant — the measured analogue of
        the modeled busy-interval check in ``engine.find_safe_points``."""
        view = self.iteration_view(job_id, iteration)
        if view is None or len(view.op_end) < n_ops:
            return None
        out: List[int] = []
        for k in range(n_ops):
            t_k = view.op_end.get(k)
            if t_k is None:
                return None
            if any(s < t_k - _EPS and t_k < e - _EPS
                   for s, e in view.transfers):
                continue
            out.append(k)
        return out
