"""Composable planning passes + the Pipeline that drives them (paper §IV-F).

TENSILE's core claim (Alg. 3) is that swap and recomputation are
*interchangeable actions scheduled per-tensor under one peak-analysis loop*.
This module makes that loop a first-class, policy-agnostic driver:

  * ``PlanningPass``  — the protocol every planning strategy implements:
        ``run(seq, plan, report, profile) -> plan``
    plus an incremental interface (``setup``/``gate``/``step``) the Pipeline
    uses to interleave passes one greedy action at a time, exactly as
    Algorithm 3 interleaves swapping and recomputation.
  * ``Pipeline``      — owns the convergence loop (patience, minimum
    improvement, iteration cap) and the vanilla/planned peak bookkeeping.
    Passes are tried in order; a pass that can no longer make progress is
    retired; the loop ends when no gated pass remains or the peak stagnates.

Every policy in the repo — the paper's TENSILE scheduler and both
reproduced baselines — is now a pass configuration over this one engine:

    vanilla  = Pipeline([])
    vdnn     = Pipeline([VdnnSwapPass])
    capuchin = Pipeline([PassiveProfilePass, SwapPass(style="capuchin"),
                         RecomputePass(style="capuchin")])
    tensile  = Pipeline([SwapPass(), RecomputePass()], cross_iteration=True)
    tensile+compressed-offload
             = Pipeline([SwapPass(), CompressedOffloadPass(),
                         RecomputePass()], cross_iteration=True)
    tensile+priority
             = Pipeline([PriorityPass(), RecomputePass()],
                        cross_iteration=True)
    tensile+autoscale
             = Pipeline([SwapPass(), BudgetAutoscalePass(),
                         RecomputePass()], cross_iteration=True)

The two cross-job pipelines plan against *arbiter-assigned per-job budgets*
(``SchedulerConfig.per_job_budget_bytes``, filled in by the Global
Controller's ``BudgetArbiter`` on every launch/finish/drift replan) instead
of the full device: ``PriorityPass`` picks swap victims from the
lowest-priority over-share jobs first, and ``BudgetAutoscalePass`` keeps
swapping the most over-budget job until every job fits its assigned slice.

New policies are one-file additions: implement the protocol, register a
configuration in ``PIPELINES``.
"""
from __future__ import annotations

import dataclasses
import time as _time
from typing import Callable, Dict, List, Optional, Sequence, Tuple, Union

from .access import AccessSequence, AccessType, TensorKind
from .peak_analysis import (PERSISTENT_KINDS, PeakReport, WindowSweep,
                            analyze, storage_of)
from .plan import (EventType, MachineProfile, ScheduleEvent, SchedulingPlan)
from .recompute_planner import RecomputePlanner, plan_one_recompute
from .swap_planner import SwapPlanner, plan_one_swap

HEAVY_OPS = {"dot_general", "conv_general_dilated"}


# ----------------------------------------------------------------------
# Configuration + result (Alg. 3 knobs; shared with MemoryScheduler)
# ----------------------------------------------------------------------
@dataclasses.dataclass
class SchedulerConfig:
    memory_budget_bytes: Optional[int] = None   # None: device size from profile
    max_swap_ratio: float = 1.0                 # per-job MSR limit (can be dict)
    per_job_swap_ratio: Optional[Dict[str, float]] = None
    min_improvement: float = 5e-4               # 0.05 % (paper Alg 3)
    patience_iters: int = 100
    patience_window: int = 3
    update_threshold: float = 0.2               # latency-drift replan trigger
    ewma_alpha: float = 0.3
    max_iterations: int = 10000
    # quantize-on-offload: only tensors at or below this size take the
    # compressed path (confines int8 error to small peak contributors)
    compressed_max_bytes: int = 64 * 2 ** 20
    # cross-job arbitration (filled in by the Global Controller's
    # BudgetArbiter on every launch/finish/drift replan): per-job byte
    # budgets the pipelines plan against instead of the full device, and
    # per-job priority weights (default 1.0) PriorityPass uses to pick
    # swap victims from low-priority jobs first
    per_job_budget_bytes: Optional[Dict[str, int]] = None
    job_priorities: Optional[Dict[str, float]] = None
    # when the arbiter shrinks a live job's slice: "boundary" applies the
    # new plan at the next iteration boundary (the paper's rule), "preempt"
    # additionally hot-swaps an incremental remainder plan in at the job's
    # next safe point (engine.find_safe_points), so the slice is respected
    # mid-iteration instead of an iteration later
    arbiter_mode: str = "boundary"


@dataclasses.dataclass
class ScheduleResult:
    plans: Dict[str, SchedulingPlan]
    initial_report: PeakReport
    final_report: PeakReport
    iterations: int
    swaps_scheduled: int
    recomputes_scheduled: int
    plan_wallclock_s: float
    pass_steps: Dict[str, int] = dataclasses.field(default_factory=dict)

    @property
    def memory_saving_ratio(self) -> float:
        """MSR against the merged vanilla peak (paper §V-A)."""
        v = self.initial_report.peak_bytes
        return (v - self.final_report.peak_bytes) / v if v else 0.0


@dataclasses.dataclass
class PipelineState:
    """Everything passes share while a Pipeline converges."""
    jobs: Dict[str, AccessSequence]
    plans: Dict[str, SchedulingPlan]
    profile: MachineProfile
    config: SchedulerConfig
    offsets: Dict[str, float]
    budget: int
    cross_iteration: bool = True
    shared: Dict[str, object] = dataclasses.field(default_factory=dict)
    # arbiter-assigned per-job byte budgets; empty = every job plans
    # against the shared device-wide `budget` (single-job / legacy mode)
    job_budgets: Dict[str, int] = dataclasses.field(default_factory=dict)

    def priority_of(self, job_id: str) -> float:
        return (self.config.job_priorities or {}).get(job_id, 1.0)

    @staticmethod
    def solo(seq: AccessSequence, plan: SchedulingPlan,
             profile: Optional[MachineProfile],
             config: Optional[SchedulerConfig] = None) -> "PipelineState":
        profile = profile or MachineProfile()
        cfg = config or SchedulerConfig()
        return PipelineState(
            jobs={seq.job_id: seq}, plans={seq.job_id: plan},
            profile=profile, config=cfg, offsets={},
            budget=(cfg.memory_budget_bytes
                    if cfg.memory_budget_bytes is not None
                    else profile.device_memory_bytes))


def _solo_report(state: "PipelineState", job_id: str,
                 cache: Dict[str, Tuple[Tuple[int, int], PeakReport]]
                 ) -> PeakReport:
    """A job's own-timeline peak report, cached until its plan changes —
    the arbiter passes consult it once per greedy step per offender, and
    only the job whose plan was just modified ever goes stale."""
    plan = state.plans[job_id]
    key = (len(plan.events), len(plan.release_after_op))
    hit = cache.get(job_id)
    if hit is not None and hit[0] == key:
        return hit[1]
    rep = analyze([state.jobs[job_id]], plans={job_id: plan})
    cache[job_id] = (key, rep)
    return rep


def over_budget_jobs(state: "PipelineState",
                     report: PeakReport) -> Dict[str, int]:
    """job -> excess bytes over its arbiter-assigned budget.  Per-job peaks
    bound the global peak (at any instant each job holds at most its own
    peak), so driving every excess to zero certifies the device budget."""
    out: Dict[str, int] = {}
    for j, b in state.job_budgets.items():
        excess = report.per_job_peak.get(j, 0) - b
        if excess > 0:
            out[j] = excess
    return out


# ----------------------------------------------------------------------
# The pass protocol
# ----------------------------------------------------------------------
class PlanningPass:
    """A composable planning strategy.

    Protocol: ``run(seq, plan, report, profile) -> plan`` plans one job to
    exhaustion.  Pipelines use the finer-grained hooks instead — ``setup``
    binds the pass to the job set, ``gate`` says whether it may act under
    the current report, ``step`` performs ONE greedy action and returns
    whether it changed any plan — so several passes interleave under one
    convergence loop (Alg. 3's swap/recompute interleaving generalized).
    """

    name = "pass"
    kind = "swap"          # counted as swap or recompute in ScheduleResult

    def setup(self, state: PipelineState) -> None:
        self.state = state

    def gate(self, report: PeakReport) -> bool:
        return True

    def step(self, report: PeakReport) -> bool:
        raise NotImplementedError

    def run(self, seq: AccessSequence, plan: SchedulingPlan,
            report: PeakReport,
            profile: Optional[MachineProfile] = None) -> SchedulingPlan:
        """Standalone single-job entry point (the protocol)."""
        self.setup(PipelineState.solo(seq, plan, profile))
        while self.gate(report) and self.step(report):
            report = analyze([seq], plans={seq.job_id: plan})
        return plan


# ----------------------------------------------------------------------
# TENSILE passes (Algorithms 1 & §IV-D wrapped as passes)
# ----------------------------------------------------------------------
class SwapPass(PlanningPass):
    """Greedy swap scheduling (paper Alg. 1): one MPT tensor per step,
    largest first across all jobs.  ``style="capuchin"`` instead replays the
    swap half of the Capuchin candidate walk prepared by
    PassiveProfilePass."""

    name = "swap"
    kind = "swap"

    def __init__(self, style: str = "tensile"):
        self.style = style

    def setup(self, state: PipelineState) -> None:
        super().setup(state)
        cfg = state.config
        if self.style == "tensile":
            self.planners = {
                j: SwapPlanner(state.jobs[j], state.plans[j], state.profile,
                               (cfg.per_job_swap_ratio or {}).get(
                                   j, cfg.max_swap_ratio),
                               cross_iteration=state.cross_iteration,
                               telemetry=state.shared.get("telemetry"),
                               experience=state.shared.get("experience"))
                for j in state.jobs}

    def step(self, report: PeakReport) -> bool:
        if self.style == "capuchin":
            return _capuchin_step(self.state, want="swap")
        return plan_one_swap(self.planners, report)


class RecomputePass(PlanningPass):
    """MSPS-ranked recomputation (paper §IV-D): gated on the predicted peak
    still exceeding the budget, runs only after swapping is exhausted (the
    Pipeline's pass order encodes that, exactly like Alg. 3)."""

    name = "recompute"
    kind = "recompute"

    def __init__(self, style: str = "tensile"):
        self.style = style

    def setup(self, state: PipelineState) -> None:
        super().setup(state)
        self._solo_cache: Dict[str, Tuple[Tuple[int, int], PeakReport]] = {}
        if self.style == "tensile":
            exp = state.shared.get("experience")
            self.planners = {
                j: RecomputePlanner(state.jobs[j], state.plans[j],
                                    experience=exp)
                for j in state.jobs}

    def gate(self, report: PeakReport) -> bool:
        if self.style == "capuchin":
            return True
        # over the device budget, or (under the arbiter) any job over its
        # assigned slice — swap passes retire first, so recomputation is the
        # remaining lever to certify the split
        return report.peak_bytes >= self.state.budget \
            or bool(over_budget_jobs(self.state, report))

    def step(self, report: PeakReport) -> bool:
        if self.style == "capuchin":
            return _capuchin_step(self.state, want="recompute")
        if plan_one_recompute(self.planners, report):
            return True
        # arbiter mode: a job can violate its slice away from the global
        # peak instant; retry against the offenders' solo reports
        state = self.state
        over = over_budget_jobs(state, report)
        for job_id in sorted(over, key=lambda j: -over[j]):
            rep_j = _solo_report(state, job_id, self._solo_cache)
            if plan_one_recompute({job_id: self.planners[job_id]}, rep_j):
                return True
        return False


class CompressedOffloadPass(PlanningPass):
    """Beyond-paper policy: tensors still causing the peak after plain
    swapping get another chance through the Pallas quantize-on-offload path
    (kernels/offload_quant) — the channel booking shrinks to the compressed
    transfer time (plan.MachineProfile.compressed_swap_time, calibrated by
    cost_model.offload_quant_latency), so windows too tight for a full-
    precision swap can still hide an int8 copy.  Restricted to tensors at or
    below ``compressed_max_bytes`` to confine quantization error."""

    name = "compressed-offload"
    kind = "swap"

    def setup(self, state: PipelineState) -> None:
        super().setup(state)
        self.planners = None   # built lazily: picks up prior passes' events

    def _build(self) -> None:
        state = self.state
        cfg = state.config
        self.planners = {
            j: SwapPlanner(state.jobs[j], state.plans[j], state.profile,
                           (cfg.per_job_swap_ratio or {}).get(
                               j, cfg.max_swap_ratio),
                           cross_iteration=state.cross_iteration,
                           compressed=True,
                           max_tensor_bytes=cfg.compressed_max_bytes,
                           telemetry=state.shared.get("telemetry"),
                           experience=state.shared.get("experience"))
            for j in state.jobs}

    def step(self, report: PeakReport) -> bool:
        if self.planners is None:
            self._build()
        state = self.state
        seqs = list(state.jobs.values())
        # a swap pair can also EXTEND residency (the swap-in supersedes the
        # activity-analysis release), so unlike plain Alg-1 greed each
        # compressed step is verified against the peak and rolled back if
        # it does not help; rejected tensors stay marked and are not retried
        while True:
            before = {j: len(state.plans[j].events) for j in state.plans}
            if not plan_one_swap(self.planners, report):
                return False
            new_report = analyze(seqs, plans=state.plans,
                                 offsets=state.offsets)
            # strict improvement only: a zero-saving compressed swap still
            # costs two transfers plus a lossy int8 round trip
            if new_report.peak_bytes < report.peak_bytes:
                return True
            for j, n in before.items():
                plan = state.plans[j]
                added = plan.events[n:]
                for ev in added:
                    if ev.event_type in (EventType.SWAP_OUT,
                                         EventType.SWAP_IN):
                        try:
                            self.planners[j].channel.release(
                                ev.start, ev.duration)
                        except ValueError:
                            pass
                    plan.remove(ev)


# ----------------------------------------------------------------------
# Cross-job arbitration passes (ROADMAP: cross-job priority + budget
# autoscaling) — plan against arbiter-assigned per-job budgets
# ----------------------------------------------------------------------
def _build_swap_planners(state: PipelineState) -> Dict[str, "SwapPlanner"]:
    cfg = state.config
    return {
        j: SwapPlanner(state.jobs[j], state.plans[j], state.profile,
                       (cfg.per_job_swap_ratio or {}).get(
                           j, cfg.max_swap_ratio),
                       cross_iteration=state.cross_iteration,
                       telemetry=state.shared.get("telemetry"),
                       experience=state.shared.get("experience"))
        for j in state.jobs}


class PriorityPass(PlanningPass):
    """Priority-weighted swap scheduling: like SwapPass, but the victim
    order is cross-job-aware.  Jobs exceeding their arbiter-assigned budget
    are tried first, lowest priority first (largest tensor within a job);
    jobs inside their share are only touched once no over-share job can
    make progress — so a high-priority job keeps (at least) its weighted
    slice of the device while low-priority jobs absorb the swapping."""

    name = "priority-swap"
    kind = "swap"

    def setup(self, state: PipelineState) -> None:
        super().setup(state)
        self.planners = _build_swap_planners(state)

    def _victim_order(self, report: PeakReport):
        state = self.state
        over = over_budget_jobs(state, report)
        # when no per-job budgets were assigned every job counts as "over"
        # (pure priority ordering over the whole MPT)
        def tier(job_id: str) -> int:
            if not state.job_budgets:
                return 0
            return 0 if job_id in over else 1
        return sorted(
            report.peak_tensors,
            key=lambda t: (tier(t[1]), state.priority_of(t[1]), -t[2]))

    def step(self, report: PeakReport) -> bool:
        for storage_id, job_id, _size in self._victim_order(report):
            pl = self.planners.get(job_id)
            if pl is None:
                continue
            for tid in pl.alias_candidates.get(storage_id, ()):
                if pl.try_swap_tensor(tid, report.peak_time):
                    return True
        return False


class BudgetAutoscalePass(PlanningPass):
    """Budget autoscaling enforcement: while any job's per-job peak exceeds
    its arbiter-assigned slice, swap one tensor from the most over-budget
    job.  Runs after plain SwapPass retires (pipeline order), so it only
    adds the job-targeted swaps global largest-first greed missed; planners
    are built lazily to pick up the earlier passes' channel bookings."""

    name = "budget-autoscale"
    kind = "swap"

    def setup(self, state: PipelineState) -> None:
        super().setup(state)
        self.planners: Optional[Dict[str, SwapPlanner]] = None
        self._solo_cache: Dict[str, Tuple[Tuple[int, int], PeakReport]] = {}

    def gate(self, report: PeakReport) -> bool:
        return bool(over_budget_jobs(self.state, report))

    def step(self, report: PeakReport) -> bool:
        if self.planners is None:
            self.planners = _build_swap_planners(self.state)
        state = self.state
        over = over_budget_jobs(state, report)
        for job_id in sorted(over, key=lambda j: -over[j]):
            pl = self.planners.get(job_id)
            if pl is None:
                continue
            # a job's budget violation peaks at ITS OWN peak instant, which
            # need not coincide with the merged global peak — target the
            # job's solo report (per-job residency is plan-local, so the
            # solo peak equals the job's per_job_peak in the merged one)
            rep_j = _solo_report(state, job_id, self._solo_cache)
            for storage_id, _owner, _size in rep_j.peak_tensors:
                for tid in pl.alias_candidates.get(storage_id, ()):
                    if pl.try_swap_tensor(tid, rep_j.peak_time):
                        return True
        return False


class PreemptiveReplanPass(PlanningPass):
    """Incremental mid-iteration replan (safe-point plan hot-swap).

    Used by ``Pipeline.replan_from``: each job's plan is a *copy of the
    plan currently executing*, and this pass may only add events strictly
    after the job's safe point (``state.shared["replan_from_op"]``) — the
    prefix has already run, so the runtime can splice the result in at the
    safe point without tearing the iteration.  Victims are driven to their
    (shrunken) arbiter slice by eager swap-outs: the SwapPlanner's
    ``not_before`` pins every new event into the remainder window and
    earliest-fit placement lands the swap-out right at the safe point.

    Peaks are judged on the *remainder window* ``[t_safe, T)`` of each
    job's own timeline: bytes resident before the safe point are history
    this pass cannot undo, but they persist into the window, so the
    windowed per-job peak is exactly "will job j fit its new slice from
    the splice on".

    When the windowed swap budget is infeasible — no eager swap-out pair
    fits the remainder of the DMA channel — the pass may emit RECOMPUTE
    actions instead (release now, regenerate at the next use), gated by
    the same per-step windowed-peak verification and rolled back when
    they do not strictly improve the window.
    """

    name = "preemptive-replan"
    kind = "swap"

    def setup(self, state: PipelineState) -> None:
        super().setup(state)
        cfg = state.config
        self.from_op: Dict[str, int] = dict(
            state.shared.get("replan_from_op", {}))
        self.from_time: Dict[str, float] = {}
        self.planners: Dict[str, SwapPlanner] = {}
        self.rec_planners: Dict[str, RecomputePlanner] = {}
        self._window_cache: Dict[str, Tuple[Tuple[int, int], PeakReport]] = {}
        # per-job incremental sweeps; Pipeline.replan_from shares its
        # cross-replan cache through state.shared so a job's frozen prefix
        # survives consecutive replans at the same safe point
        self._sweeps: Dict[str, WindowSweep] = state.shared.setdefault(
            "window_sweeps", {})
        for j, op in self.from_op.items():
            seq = state.jobs.get(j)
            if seq is None:
                continue
            # new events must TRIGGER strictly after the safe-point op —
            # the splice happens after op `op`'s events fired, so anything
            # keyed to trigger <= op would never run.  The planner's
            # trigger mapping assigns trigger k to starts in
            # [op_end[k], op_end[k+1]), so a start at or after
            # op_end[op+1] gets trigger >= op+1 — hence the +1.
            nxt = min(op + 1, len(seq.op_end) - 1)
            t0 = seq.op_end[nxt] if seq.op_end else 0.0
            self.from_time[j] = t0
            pl = SwapPlanner(
                seq, state.plans[j], state.profile,
                (cfg.per_job_swap_ratio or {}).get(j, cfg.max_swap_ratio),
                cross_iteration=state.cross_iteration,
                not_before=t0,
                telemetry=state.shared.get("telemetry"),
                experience=state.shared.get("experience"))
            # tensors the running plan already swaps are eligible AGAIN:
            # under the shrunken slice an extra eviction + re-fetch pair in
            # the remainder window is exactly the lever left (runtime skip
            # rules make duplicate events at the same trigger harmless)
            pl.swapped.clear()
            self.planners[j] = pl

    def _window_report(self, job_id: str) -> PeakReport:
        seq = self.state.jobs[job_id]
        plan = self.state.plans[job_id]
        key = (plan.version, len(plan.release_after_op))
        hit = self._window_cache.get(job_id)
        if hit is not None and hit[0] == key:
            return hit[1]
        sweep = self._sweeps.get(job_id)
        if sweep is None:
            sweep = self._sweeps[job_id] = WindowSweep(
                free_at_last_use=True)
        rep = sweep.report(seq, plan, self.from_time[job_id],
                           seq.iteration_time + 1e-12)
        self._window_cache[job_id] = (key, rep)
        return rep

    def _excess(self, job_id: str) -> int:
        budget = self.state.job_budgets.get(job_id)
        if budget is None:
            return 0
        # single-job report: the window-restricted global peak IS the job's
        # windowed peak (per_job_peak ignores the window by design)
        rep = self._window_report(job_id)
        return max(0, rep.peak_bytes - budget)

    def gate(self, report: Optional[PeakReport]) -> bool:
        return any(self._excess(j) > 0 for j in self.planners)

    def step(self, report: Optional[PeakReport]) -> bool:
        over = {j: e for j in self.planners
                if (e := self._excess(j)) > 0}
        for job_id in sorted(over, key=lambda j: -over[j]):
            pl = self.planners[job_id]
            plan = self.state.plans[job_id]
            rep = self._window_report(job_id)
            for storage_id, _owner, _size in rep.peak_tensors:
                for tid in pl.alias_candidates.get(storage_id, ()):
                    n0 = len(plan.events)
                    if not pl.try_swap_tensor(tid, rep.peak_time):
                        continue
                    # a swap pair can also EXTEND residency (the re-fetch
                    # supersedes releases), so — like CompressedOffloadPass
                    # — every step is verified against the windowed peak
                    # and rolled back when it does not strictly improve;
                    # the tensor stays marked and is not retried
                    self._window_cache.pop(job_id, None)
                    if self._window_report(job_id).peak_bytes \
                            < rep.peak_bytes:
                        return True
                    for ev in plan.events[n0:]:
                        if ev.event_type in (EventType.SWAP_OUT,
                                             EventType.SWAP_IN):
                            try:
                                pl.channel.release(ev.start, ev.duration)
                            except ValueError:
                                pass
                    plan.truncate(n0)
                    self._window_cache.pop(job_id, None)
            # the windowed swap budget is infeasible for this job (no
            # eager swap-out pair fits the remaining channel time):
            # recomputation is the lever left — release now, regenerate
            # at the next use, same per-step peak verification
            if self._try_recompute(job_id, rep):
                return True
        return False

    def _try_recompute(self, job_id: str,
                       rep: PeakReport) -> bool:
        """One recompute action strictly inside the remainder window,
        verified against the windowed peak and rolled back when it does
        not strictly improve (rejected tensors stay marked)."""
        plan = self.state.plans[job_id]
        rp = self.rec_planners.get(job_id)
        if rp is None:
            rp = self.rec_planners[job_id] = RecomputePlanner(
                self.state.jobs[job_id], plan,
                experience=self.state.shared.get("experience"))
        from_op = self.from_op.get(job_id, -1)
        for cand in rp.candidates(rep):
            # both events must TRIGGER strictly after the safe-point op —
            # anything at or before it would never fire post-splice
            if (cand.release_after_op <= from_op
                    or max(cand.target_op - 1, 0) <= from_op):
                continue
            n0 = len(plan.events)
            rp.apply(cand)
            self._window_cache.pop(job_id, None)
            if self._window_report(job_id).peak_bytes < rep.peak_bytes:
                return True
            plan.truncate(n0)
            self._window_cache.pop(job_id, None)
        return False


# ----------------------------------------------------------------------
# vDNN_conv (Rhu et al., MICRO'16) as a one-shot pass
# ----------------------------------------------------------------------
class VdnnSwapPass(PlanningPass):
    """*Layer* granularity: offload the feature maps of the heavy
    ("conv-like") layers after their forward use, static swap-in (prefetch
    when the previous backward layer starts).  No recomputation, no
    Opt-phase events, single-workload design — one shot per job."""

    name = "vdnn-swap"
    kind = "swap"

    def setup(self, state: PipelineState) -> None:
        super().setup(state)
        self._done = False

    def step(self, report: PeakReport) -> bool:
        if self._done:
            return False
        self._done = True
        changed = False
        for j, seq in self.state.jobs.items():
            changed |= self._plan_job(seq, self.state.plans[j],
                                      self.state.profile)
        return changed

    @staticmethod
    def _plan_job(seq: AccessSequence, plan: SchedulingPlan,
                  profile: MachineProfile) -> bool:
        changed = False
        # vDNN offloads the feature maps flowing through heavy layers:
        # tensors produced by OR consumed by a conv-like op in the forward
        # pass and reused much later (their backward consumer).
        heavy_io: set = set()
        for op in seq.operators:
            if op.name in HEAVY_OPS:
                heavy_io.update(op.inputs)
                heavy_io.update(op.outputs)
        min_gap = max(4, len(seq.operators) // 10)
        # vDNN's framework manages layer activations: the feature maps
        # flowing through its layers are freed after their last (backward)
        # use — but nothing else is (tensors inside a "layer" and optimizer
        # interim tensors are invisible to layer granularity; paper §II).
        last_use = seq.activity_analysis()
        for tid, spec in seq.tensors.items():
            if spec.kind is TensorKind.ACTIVATION and tid in heavy_io:
                plan.set_release(tid, last_use[tid])
                changed = True
        for tid, spec in seq.tensors.items():
            if spec.kind is not TensorKind.ACTIVATION or tid not in heavy_io:
                continue
            accs = seq.tensor_accesses(tid)
            tga = seq.tga(tid)
            if tga is None:
                continue
            tuas = [a for a in accs if a.access_type is AccessType.TUA]
            # feature map reused much later (backward): the vDNN candidates
            later = [a for a in tuas if a.op_idx > tga.op_idx + min_gap]
            if not later:
                continue
            first_fwd_use_end = (tuas[0].end_time if tuas else tga.end_time)
            back = later[-1]
            dur = profile.swap_time(spec.size_bytes)
            out_start = max(tga.end_time, first_fwd_use_end)
            # static prefetch trigger: one op before the backward consumer
            prefetch_op = max(back.op_idx - 1, tga.op_idx)
            in_start = seq.op_start[prefetch_op]
            if in_start <= out_start + dur:
                continue  # vDNN skips maps it cannot prefetch in time
            plan.add(ScheduleEvent(
                event_type=EventType.SWAP_OUT, tensor_id=tid,
                job_id=seq.job_id, trigger_op=tga.op_idx,
                delta=out_start - tga.end_time, start=out_start,
                end=out_start + dur, size_bytes=spec.size_bytes))
            plan.add(ScheduleEvent(
                event_type=EventType.SWAP_IN, tensor_id=tid,
                job_id=seq.job_id, trigger_op=prefetch_op, delta=0.0,
                start=in_start, end=in_start + dur,
                size_bytes=spec.size_bytes, target_op=back.op_idx))
            changed = True
        return changed


# ----------------------------------------------------------------------
# Capuchin (Peng et al., ASPLOS'20): passive profiling + candidate walk
# ----------------------------------------------------------------------
@dataclasses.dataclass
class _CapuchinAction:
    job_id: str
    mode: str                      # "swap" | "recompute"
    events: List[ScheduleEvent]


class PassiveProfilePass(PlanningPass):
    """Capuchin's observation epoch: one passive-mode iteration per job
    (counted into its overhead by the benchmarks), after which the eviction
    candidates and their swap-vs-recompute decisions are fixed — Capuchin
    schedules *within* one iteration from per-job profiles, so each job is
    profiled independently of the merged timeline."""

    name = "passive-profile"
    kind = "swap"

    def setup(self, state: PipelineState) -> None:
        super().setup(state)
        self._done = False

    def step(self, report: PeakReport) -> bool:
        if self._done:
            return False
        self._done = True
        actions: List[_CapuchinAction] = []
        for j, seq in self.state.jobs.items():
            actions.extend(_capuchin_decisions(
                seq, self.state.budget, self.state.profile))
        self.state.shared["capuchin_actions"] = actions
        for plan in self.state.plans.values():
            plan.passive_iterations = 1
        return True


def _capuchin_decisions(seq: AccessSequence, budget_bytes: int,
                        profile: MachineProfile) -> List["_CapuchinAction"]:
    """The Capuchin candidate walk: evict peak-contributing activations,
    largest first, until the predicted need is covered; per candidate,
    choose swap when the transfer hides under the compute between the
    eviction and the next access, else recompute by MSPS.  Decisions depend
    only on the passive profile, so they are fixed up front; the Pipeline
    applies them one per step through SwapPass/RecomputePass."""
    report = analyze([seq])
    cands: List[Tuple[str, int]] = []
    for sid, job, size in report.peak_tensors:
        spec = None
        for t in seq.tensors.values():
            if storage_of(t) == sid and t.kind is TensorKind.ACTIVATION:
                spec = t
                break
        if spec is not None:
            cands.append((spec.tid, size))

    actions: List[_CapuchinAction] = []
    freed = 0
    need = max(0, report.peak_bytes - budget_bytes)
    for tid, size in cands:
        if freed >= need:
            break
        spec = seq.tensors[tid]
        accs = seq.tensor_accesses(tid)
        tuas = [a for a in accs if a.access_type is AccessType.TUA]
        tga = seq.tga(tid)
        if tga is None or not tuas:
            continue
        # the idle window between the access before the peak and the next
        prev, nxt = tga, None
        for a in tuas:
            if prev.end_time <= report.peak_time <= a.time:
                nxt = a
                break
            prev = a
        if nxt is None:
            continue
        dur = profile.swap_time(spec.size_bytes)
        window = nxt.time - prev.end_time
        if window >= 2 * dur:
            # swap: out right after prev, in right before nxt ("free" —
            # hidden under compute)
            actions.append(_CapuchinAction(seq.job_id, "swap", [
                ScheduleEvent(
                    event_type=EventType.SWAP_OUT, tensor_id=tid,
                    job_id=seq.job_id, trigger_op=prev.op_idx, delta=0.0,
                    start=prev.end_time, end=prev.end_time + dur,
                    size_bytes=spec.size_bytes),
                ScheduleEvent(
                    event_type=EventType.SWAP_IN, tensor_id=tid,
                    job_id=seq.job_id, trigger_op=max(nxt.op_idx - 1, 0),
                    delta=0.0, start=nxt.time - dur, end=nxt.time,
                    size_bytes=spec.size_bytes, target_op=nxt.op_idx)]))
            freed += size
        else:
            # recompute if producer is cheap (high MSPS) and inputs persist
            producer = seq.operators[tga.op_idx]
            inputs_ok = all(
                seq.tensors[i].kind in PERSISTENT_KINDS
                or (seq.last_access(i)
                    and seq.last_access(i).end_time >= nxt.time)
                for i in producer.inputs if i in seq.tensors)
            if not inputs_ok:
                continue
            actions.append(_CapuchinAction(seq.job_id, "recompute", [
                ScheduleEvent(
                    event_type=EventType.RELEASE, tensor_id=tid,
                    job_id=seq.job_id, trigger_op=prev.op_idx, delta=0.0,
                    start=prev.end_time, end=prev.end_time,
                    size_bytes=spec.size_bytes),
                ScheduleEvent(
                    event_type=EventType.RECOMPUTE, tensor_id=tid,
                    job_id=seq.job_id, trigger_op=max(nxt.op_idx - 1, 0),
                    delta=0.0, start=nxt.time - producer.latency,
                    end=nxt.time, size_bytes=spec.size_bytes,
                    target_op=nxt.op_idx, recompute_ops=[tga.op_idx])]))
            freed += size
    return actions


def _capuchin_step(state: PipelineState, want: str) -> bool:
    """Apply the next prepared Capuchin action of the wanted mode."""
    actions = state.shared.get("capuchin_actions", [])
    key = f"capuchin_cursor_{want}"
    i = state.shared.get(key, 0)
    while i < len(actions):
        act = actions[i]
        i += 1
        if act.mode != want:
            continue
        state.shared[key] = i
        for ev in act.events:
            state.plans[act.job_id].add(ev)
        return True
    state.shared[key] = i
    return False


# ----------------------------------------------------------------------
# The Pipeline: Algorithm 3's convergence loop over ordered passes
# ----------------------------------------------------------------------
PassSpec = Union[PlanningPass, type]


class Pipeline:
    """Ordered passes under one peak-analysis convergence loop.

    Per iteration the first still-active pass whose ``gate`` admits the
    current report takes one greedy step; a pass whose step makes no change
    is retired.  Stops when no pass is eligible, when the iteration cap is
    hit, or when the average peak reduction over ``patience_window``
    iterations falls below ``min_improvement`` after ``patience_iters``
    iterations (paper Alg 3 line 4).
    """

    def __init__(self, passes: Sequence[PassSpec], *,
                 name: str = "pipeline",
                 cross_iteration: bool = False,
                 profile: Optional[MachineProfile] = None,
                 config: Optional[SchedulerConfig] = None,
                 free_at_last_use: bool = True,
                 passive_iterations: int = 0,
                 telemetry=None,
                 experience=None):
        self.pass_specs = list(passes)
        self.name = name
        self.cross_iteration = cross_iteration
        self.profile = profile or MachineProfile()
        self.config = config or SchedulerConfig()
        # evaluation semantics of the policy's host framework:
        # vDNN/vanilla platforms have no activity-analysis releases
        self.free_at_last_use = free_at_last_use
        self.passive_iterations = passive_iterations
        # measured-telemetry plane: a TelemetryHub here is handed to every
        # pass via state.shared["telemetry"], so swap windows are sized
        # from measured DMA bandwidth once samples exist (None = modeled
        # constants, byte-reproducible plans)
        self.telemetry = telemetry
        # experience plane: an ExperienceStore here (1) seeds each job's
        # plan from the store's best verified cached plan — Alg.-3
        # convergence then starts from prior-run experience instead of an
        # empty plan — and (2) hands stored DMA bandwidth to every
        # SwapPlanner via state.shared["experience"].  None (the default)
        # keeps cold planning byte-reproducible.
        self.experience = experience
        # per-job incremental window sweeps carried ACROSS replan_from
        # calls: a WindowSweep re-freezes itself whenever its
        # preconditions break (timeline version, safe point, prefix
        # events), so persisting it here just lets consecutive replans of
        # an unchanged job reuse the frozen prefix aggregates
        self._window_sweeps: Dict[str, WindowSweep] = {}

    def _instantiate(self) -> List[PlanningPass]:
        return [p() if isinstance(p, type) else p for p in self.pass_specs]

    # ------------------------------------------------------------------
    def plan(self, seqs: Sequence[AccessSequence],
             offsets: Optional[Dict[str, float]] = None) -> ScheduleResult:
        t0 = _time.perf_counter()
        cfg = self.config
        offsets = offsets or {}
        jobs = {s.job_id: s for s in seqs}
        plans = {j: SchedulingPlan(job_id=j) for j in jobs}
        budget = (cfg.memory_budget_bytes
                  if cfg.memory_budget_bytes is not None
                  else self.profile.device_memory_bytes)
        job_budgets = {j: b for j, b in
                       (cfg.per_job_budget_bytes or {}).items() if j in jobs}
        # warm boot (experience plane): seed each job's plan from the
        # store's best cached plan for this pipeline, REBASED onto the
        # current timeline and RE-VERIFIED against the job's current
        # budget inside lookup_plan — a failed verification (e.g. the
        # budget shrank) returns None and the job plans cold.  Seeded
        # plans carry a "warm-boot" provenance record; the convergence
        # loop below continues from them (SwapPlanner re-books their
        # channel events on setup).
        warm_booted: set = set()
        if self.experience is not None:
            for j, s in jobs.items():
                try:
                    cached = self.experience.lookup_plan(
                        s, self.name, job_budgets.get(j, budget),
                        profile=self.profile)
                except Exception:   # noqa: BLE001 - corrupt store: cold plan
                    cached = None
                if cached is not None:
                    plans[j] = cached
                    warm_booted.add(j)
        state = PipelineState(jobs=jobs, plans=plans, profile=self.profile,
                              config=cfg, offsets=dict(offsets),
                              budget=budget,
                              cross_iteration=self.cross_iteration,
                              job_budgets=job_budgets)
        if self.telemetry is not None:
            state.shared["telemetry"] = self.telemetry
        if self.experience is not None:
            state.shared["experience"] = self.experience
        passes = self._instantiate()
        for p in passes:
            p.setup(state)

        # vanilla normalizer (paper platform: no free-at-last-use)
        initial = analyze(seqs, plans=None, offsets=offsets,
                          free_at_last_use=False)
        # working reports use the policy's own platform semantics —
        # vanilla/vdnn frameworks have no activity-analysis releases
        falu = self.free_at_last_use

        def _score(rep: PeakReport) -> int:
            # convergence signal: the global peak PLUS any remaining
            # per-job slice violations — autoscale steps reduce a job's
            # solo peak without necessarily moving the merged peak, and
            # must not read as stagnation (0 extra when no arbiter split)
            return rep.peak_bytes + sum(
                over_budget_jobs(state, rep).values())

        report = analyze(seqs, plans=plans, offsets=offsets,
                         free_at_last_use=falu)
        history: List[int] = [_score(report)]
        active = [True] * len(passes)
        # a fully warm-booted job set whose verified cached plans already
        # respect the device budget and every per-job slice IS a converged
        # artifact (it was the END state of a prior convergence): adopt it
        # as-is instead of re-running Alg. 3 — this is what makes
        # time-to-first-feasible-plan collapse on recurring workloads
        if warm_booted and warm_booted == set(jobs) \
                and report.peak_bytes <= budget \
                and not over_budget_jobs(state, report):
            active = [False] * len(passes)
        steps: Dict[str, int] = {p.name: 0 for p in passes}
        iters = 0

        while any(active):
            if iters >= cfg.max_iterations:
                break
            # paper Alg 3 line 4: early stop on stagnation
            if iters > cfg.patience_iters and len(history) > cfg.patience_window:
                prev = history[-cfg.patience_window - 1]
                cur = history[-1]
                if prev > 0 and (prev - cur) / prev < cfg.min_improvement:
                    break
            idx = next((i for i, p in enumerate(passes)
                        if active[i] and p.gate(report)), None)
            if idx is None:
                break
            if passes[idx].step(report):
                steps[passes[idx].name] += 1
            else:
                active[idx] = False
            report = analyze(seqs, plans=plans, offsets=offsets,
                             free_at_last_use=falu)
            history.append(_score(report))
            iters += 1

        wall = _time.perf_counter() - t0
        for j in jobs:
            plans[j].vanilla_peak_bytes = initial.per_job_peak.get(j, 0)
            plans[j].planned_peak_bytes = report.per_job_peak.get(j, 0)
            plans[j].plan_wallclock_s = wall
            plans[j].budget_bytes = state.job_budgets.get(j, budget)
        # counts reflect the PLANS, not the pass bookkeeping: one per
        # distinct swapped tensor (seed semantics) / recompute event
        n_swaps = sum(len(p.swapped_tensors()) for p in plans.values())
        n_recs = sum(len(p.recomputes()) for p in plans.values())
        return ScheduleResult(
            plans=plans, initial_report=initial, final_report=report,
            iterations=iters, swaps_scheduled=n_swaps,
            recomputes_scheduled=n_recs, plan_wallclock_s=wall,
            pass_steps=steps)

    # ------------------------------------------------------------------
    def replan_from(self, seqs: Sequence[AccessSequence],
                    prior_plans: Dict[str, SchedulingPlan],
                    steps: Union[int, Dict[str, int]],
                    budgets: Optional[Dict[str, int]] = None
                    ) -> ScheduleResult:
        """Incremental replan for the REMAINDER of the current iteration
        (preemptive mid-iteration slice shrinking).

        ``steps[job]`` is the safe-point op the runtime will splice at
        (engine.find_safe_points); ``budgets`` the new per-job slices
        (default: the config's ``per_job_budget_bytes``).  Each returned
        plan is a copy of the prior plan extended with eager swap-outs
        placed strictly after the safe point — the prefix is byte-identical
        to the running plan by construction, so
        ``prior.splice(new, step) == new`` and the simulator/executor can
        adopt it mid-iteration via ``JobContext.set_plan`` without tearing
        the iteration.  Every plan carries a ``replan_from`` provenance
        record (safe-point op, old/new budget, events added).
        """
        t0 = _time.perf_counter()
        cfg = self.config
        jobs = {s.job_id: s for s in seqs}
        if isinstance(steps, int):
            steps = {j: steps for j in jobs}
        plans: Dict[str, SchedulingPlan] = {}
        prior_n: Dict[str, int] = {}
        for j in jobs:
            prior = prior_plans.get(j)
            plans[j] = prior.copy() if prior is not None \
                else SchedulingPlan(job_id=j)
            prior_n[j] = len(plans[j].events)
        budget = (cfg.memory_budget_bytes
                  if cfg.memory_budget_bytes is not None
                  else self.profile.device_memory_bytes)
        job_budgets = dict(budgets) if budgets else {
            j: b for j, b in (cfg.per_job_budget_bytes or {}).items()
            if j in jobs}
        state = PipelineState(jobs=jobs, plans=plans, profile=self.profile,
                              config=cfg, offsets={}, budget=budget,
                              cross_iteration=self.cross_iteration,
                              job_budgets=job_budgets)
        if self.telemetry is not None:
            state.shared["telemetry"] = self.telemetry
        if self.experience is not None:
            state.shared["experience"] = self.experience
        state.shared["replan_from_op"] = {j: op for j, op in steps.items()
                                          if j in jobs}
        # drop sweeps of jobs that no longer exist, keep live ones warm
        self._window_sweeps = {j: sw for j, sw in self._window_sweeps.items()
                               if j in jobs}
        state.shared["window_sweeps"] = self._window_sweeps
        initial = analyze(seqs, plans={j: prior_plans.get(j) for j in jobs
                                       if prior_plans.get(j) is not None},
                          free_at_last_use=self.free_at_last_use)
        p = PreemptiveReplanPass()
        p.setup(state)
        iters = 0
        n_steps = 0
        while iters < cfg.max_iterations and p.gate(None):
            if not p.step(None):
                break
            n_steps += 1
            iters += 1
        wall = _time.perf_counter() - t0
        final = analyze(seqs, plans=plans,
                        free_at_last_use=self.free_at_last_use)
        for j, plan in plans.items():
            old_budget = plan.budget_bytes
            plan.budget_bytes = job_budgets.get(j, old_budget)
            plan.planned_peak_bytes = final.per_job_peak.get(j, 0)
            plan.plan_wallclock_s = wall
            plan.provenance.append({
                "action": "replan_from", "op": steps.get(j),
                "from_budget_bytes": old_budget,
                "to_budget_bytes": plan.budget_bytes,
                "prior_events": prior_n[j],
                "added_events": len(plan.events) - prior_n[j]})
        n_swaps = sum(len(pl.swapped_tensors()) for pl in plans.values())
        n_recs = sum(len(pl.recomputes()) for pl in plans.values())
        return ScheduleResult(
            plans=plans, initial_report=initial, final_report=final,
            iterations=iters, swaps_scheduled=n_swaps,
            recomputes_scheduled=n_recs, plan_wallclock_s=wall,
            pass_steps={p.name: n_steps})


# ----------------------------------------------------------------------
# Policy registry: every planner in the repo, by name
# ----------------------------------------------------------------------
def _vanilla(profile=None, config=None) -> Pipeline:
    return Pipeline([], name="vanilla", profile=profile, config=config,
                    free_at_last_use=False)


def _vdnn(profile=None, config=None) -> Pipeline:
    return Pipeline([VdnnSwapPass], name="vdnn", profile=profile,
                    config=config, free_at_last_use=False)


def _capuchin(profile=None, config=None) -> Pipeline:
    return Pipeline([PassiveProfilePass(), SwapPass(style="capuchin"),
                     RecomputePass(style="capuchin")],
                    name="capuchin", profile=profile, config=config,
                    passive_iterations=1)


def _tensile(profile=None, config=None) -> Pipeline:
    return Pipeline([SwapPass(), RecomputePass()], name="tensile",
                    cross_iteration=True, profile=profile, config=config)


def _tensile_compressed(profile=None, config=None) -> Pipeline:
    return Pipeline([SwapPass(), CompressedOffloadPass(), RecomputePass()],
                    name="tensile+compressed-offload", cross_iteration=True,
                    profile=profile, config=config)


def _tensile_priority(profile=None, config=None) -> Pipeline:
    return Pipeline([PriorityPass(), RecomputePass()],
                    name="tensile+priority", cross_iteration=True,
                    profile=profile, config=config)


def _tensile_autoscale(profile=None, config=None) -> Pipeline:
    return Pipeline([SwapPass(), BudgetAutoscalePass(), RecomputePass()],
                    name="tensile+autoscale", cross_iteration=True,
                    profile=profile, config=config)


PIPELINES: Dict[str, Callable[..., Pipeline]] = {
    "vanilla": _vanilla,
    "vdnn": _vdnn,
    "capuchin": _capuchin,
    "tensile": _tensile,
    "tensile+compressed-offload": _tensile_compressed,
    "tensile+priority": _tensile_priority,
    "tensile+autoscale": _tensile_autoscale,
}


def build_pipeline(name: str,
                   profile: Optional[MachineProfile] = None,
                   config: Optional[SchedulerConfig] = None) -> Pipeline:
    try:
        factory = PIPELINES[name]
    except KeyError:
        raise KeyError(f"unknown pipeline {name!r}; "
                       f"known: {sorted(PIPELINES)}") from None
    return factory(profile=profile, config=config)
