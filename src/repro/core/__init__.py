"""TENSILE core: tensor-granularity memory scheduling for multi-workload
JAX systems (Zhang et al., 2021), adapted for TPU.

Public API:
    capture / capture_train_step  — jaxpr → Tensor Access Sequence
    Pipeline / build_pipeline / PIPELINES — composable planning passes
                                    (vanilla/vdnn/capuchin/tensile/
                                     tensile+compressed-offload by name)
    MemoryScheduler / schedule_single — Algorithm 3 (tensile pipeline)
    analyze / vanilla_peak        — Algorithm 2 (peak analysis)
    MemoryEngine / DeviceLedger / DmaChannel — the shared memory-event
                                    engine both runtimes execute against
    TelemetryHub                  — the measured-telemetry plane: one sink
                                    for op/transfer/stall/residency records
                                    from both runtimes; consumers replace
                                    modeled time with measured time
    ExperienceStore / fingerprint — the experience plane: persistent
                                    cross-run store (distilled telemetry,
                                    recalibrated calibration, verified plan
                                    cache per job fingerprint) so recurring
                                    workloads warm-boot instead of cold-start
    simulate / evaluate           — discrete-event metrics (MSR/EOR/CBR)
    JaxprExecutor                 — interpreting executor with real host swap
    GlobalController              — multi-workload runtime (paper Fig. 3)
    baselines                     — vanilla / vDNN_conv / Capuchin wrappers
    schedule_for_budget           — plan → compiled-path decisions

See docs/architecture.md for the engine + pass-pipeline layering.
"""
from .access import (AccessSequence, AccessType, Operator, Phase, TensorKind,
                     TensorSpec, format_bytes)
from .baselines import capuchin_plan, vanilla_plan, vdnn_conv_plan
from .cost_model import (CalibrationReport, CostModel, DeviceCalibration,
                         EWMATracker, LatencyMLP, calibrate_cpu)
from .engine import (DeviceLedger, DmaChannel, EngineTrace, JobContext,
                     JobLedgerView, MemoryEngine, SafePoint, find_safe_points)
from .executor import (DeviceAccountant, ExecutionStats, JaxprExecutor,
                       SwapChannel, reference_outputs)
from .experience import (CalibrationRecord, ExperienceEntry, ExperienceStore,
                         PlanRecord, TelemetrySummary, budget_bucket,
                         device_identity, fingerprint, sequence_signature)
from .graph_capture import CaptureSpec, capture, capture_train_step
from .jax_integration import (TensileDecisions, backend_supports_memory_kinds,
                              checkpoint_name, make_remat_policy,
                              plan_decisions, schedule_for_budget)
from .multiplexer import (ARBITER_MODES, ARBITER_POLICIES, BudgetArbiter,
                          CapturedJob, GlobalController, JobFailedError,
                          JobHandle)
from .passes import (PIPELINES, BudgetAutoscalePass, CompressedOffloadPass,
                     PassiveProfilePass, Pipeline, PlanningPass,
                     PreemptiveReplanPass, PriorityPass, RecomputePass,
                     SwapPass, VdnnSwapPass, build_pipeline)
from .peak_analysis import PeakReport, analyze, unroll, vanilla_peak
from .plan import (ChannelReservation, EventType, MachineProfile,
                   ScheduleEvent, SchedulingPlan)
from .recompute_planner import RecomputePlanner
from .scheduler import (MemoryScheduler, ScheduleResult, SchedulerConfig,
                        schedule_single)
from .simulator import PlanUpdate, SimResult, evaluate, simulate
from .swap_planner import PeriodicChannel, SwapPlanner
from .telemetry import (IterationView, OpSample, ResidencySample,
                        StallSample, TelemetryHub, TransferSample,
                        record_schemas)

__all__ = [n for n in dir() if not n.startswith("_")]
