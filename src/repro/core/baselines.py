"""Baseline planners reproduced for the paper's comparisons (§V-A).

* **vanilla** — no scheduling: free-at-last-use only (the normalizer for all
  metrics; VMP / VTC in the paper).
* **vDNN_conv** (Rhu et al., MICRO'16) — *layer* granularity: offload the
  feature maps of the heavy ("conv-like") layers after their forward use,
  static swap-in (prefetch when the previous backward layer starts).  No
  recomputation, no Opt-phase events, single-workload design.
* **Capuchin** (Peng et al., ASPLOS'20) — *tensor* granularity: requires one
  passive-mode observation iteration (counted into its overhead), then
  schedules swap for tensors whose transfer hides under compute and
  recompute (by MSPS) otherwise.  Within-iteration only: updated parameters
  and optimizer state are never scheduled, so cross-iteration prefetch is
  impossible (the gap TENSILE closes).

Both baselines are driven through the same simulator as TENSILE so the
comparison isolates the *scheduling policy*, exactly as the paper argues
("what we want to compare is the scheduling algorithm itself ... run on the
same platform").
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

from .access import AccessSequence, AccessType, TensorKind
from .peak_analysis import PERSISTENT_KINDS, analyze, storage_of
from .plan import (EventType, MachineProfile, ScheduleEvent, SchedulingPlan)

HEAVY_OPS = {"dot_general", "conv_general_dilated"}


def vanilla_plan(seq: AccessSequence) -> SchedulingPlan:
    return SchedulingPlan(job_id=seq.job_id)


# ----------------------------------------------------------------------
def vdnn_conv_plan(seq: AccessSequence,
                   profile: Optional[MachineProfile] = None) -> SchedulingPlan:
    """vDNN_conv: swap out every heavy-layer forward feature map right after
    the layer finishes; swap it back when the op *before* its backward
    consumer starts (static one-layer lookahead prefetch)."""
    profile = profile or MachineProfile()
    plan = SchedulingPlan(job_id=seq.job_id)
    # vDNN offloads the feature maps flowing through heavy layers: tensors
    # produced by OR consumed by a conv-like op in the forward pass and
    # reused much later (their backward consumer).
    heavy_io: set = set()
    for op in seq.operators:
        if op.name in HEAVY_OPS:
            heavy_io.update(op.inputs)
            heavy_io.update(op.outputs)
    min_gap = max(4, len(seq.operators) // 10)
    # vDNN's framework manages layer activations: the feature maps flowing
    # through its layers are freed after their last (backward) use — but
    # nothing else is (tensors inside a "layer" and optimizer interim
    # tensors are invisible to layer granularity; paper §II).
    last_use = seq.activity_analysis()
    for tid, spec in seq.tensors.items():
        if spec.kind is TensorKind.ACTIVATION and tid in heavy_io:
            plan.release_after_op[tid] = last_use[tid]
    for tid, spec in seq.tensors.items():
        if spec.kind is not TensorKind.ACTIVATION or tid not in heavy_io:
            continue
        accs = seq.tensor_accesses(tid)
        tga = seq.tga(tid)
        if tga is None:
            continue
        tuas = [a for a in accs if a.access_type is AccessType.TUA]
        # feature map reused much later (backward): the vDNN candidate set
        later = [a for a in tuas if a.op_idx > tga.op_idx + min_gap]
        if not later:
            continue
        first_fwd_use_end = (tuas[0].end_time if tuas else tga.end_time)
        back = later[-1]
        dur = profile.swap_time(spec.size_bytes)
        out_start = max(tga.end_time, first_fwd_use_end)
        # static prefetch trigger: one op before the backward consumer
        prefetch_op = max(back.op_idx - 1, tga.op_idx)
        in_start = seq.op_start[prefetch_op]
        if in_start <= out_start + dur:
            continue  # vDNN skips maps it cannot prefetch in time
        plan.add(ScheduleEvent(
            event_type=EventType.SWAP_OUT, tensor_id=tid, job_id=seq.job_id,
            trigger_op=tga.op_idx, delta=out_start - tga.end_time,
            start=out_start, end=out_start + dur, size_bytes=spec.size_bytes))
        plan.add(ScheduleEvent(
            event_type=EventType.SWAP_IN, tensor_id=tid, job_id=seq.job_id,
            trigger_op=prefetch_op, delta=0.0, start=in_start,
            end=in_start + dur, size_bytes=spec.size_bytes,
            target_op=back.op_idx))
    return plan


# ----------------------------------------------------------------------
@dataclasses.dataclass
class CapuchinResult:
    plan: SchedulingPlan
    passive_iterations: int = 1   # observation epoch (passive mode)


def capuchin_plan(seq: AccessSequence,
                  budget_bytes: int,
                  profile: Optional[MachineProfile] = None) -> CapuchinResult:
    """Capuchin: after passive observation, evict peak-contributing tensors;
    choose swap when the transfer hides under the compute between the
    eviction and the next access, else recompute by MSPS.  Schedules only
    within one iteration and only F/B-phase tensors."""
    profile = profile or MachineProfile()
    plan = SchedulingPlan(job_id=seq.job_id)
    report = analyze([seq])
    # candidates: activations resident at the peak, largest first
    cands: List[Tuple[str, int]] = []
    for sid, job, size in report.peak_tensors:
        spec = None
        for t in seq.tensors.values():
            if storage_of(t) == sid and t.kind is TensorKind.ACTIVATION:
                spec = t
                break
        if spec is not None:
            cands.append((spec.tid, size))

    freed = 0
    need = max(0, report.peak_bytes - budget_bytes)
    for tid, size in cands:
        if freed >= need:
            break
        spec = seq.tensors[tid]
        accs = seq.tensor_accesses(tid)
        tuas = [a for a in accs if a.access_type is AccessType.TUA]
        tga = seq.tga(tid)
        if tga is None or not tuas:
            continue
        # the idle window between the access before the peak and the next one
        prev, nxt = tga, None
        for a in tuas:
            if prev.end_time <= report.peak_time <= a.time:
                nxt = a
                break
            prev = a
        if nxt is None:
            continue
        dur = profile.swap_time(spec.size_bytes)
        window = nxt.time - prev.end_time
        if window >= 2 * dur:
            # swap: out right after prev, in right before nxt ("free" —
            # hidden under compute)
            plan.add(ScheduleEvent(
                event_type=EventType.SWAP_OUT, tensor_id=tid,
                job_id=seq.job_id, trigger_op=prev.op_idx, delta=0.0,
                start=prev.end_time, end=prev.end_time + dur,
                size_bytes=spec.size_bytes))
            plan.add(ScheduleEvent(
                event_type=EventType.SWAP_IN, tensor_id=tid,
                job_id=seq.job_id, trigger_op=max(nxt.op_idx - 1, 0),
                delta=0.0, start=nxt.time - dur, end=nxt.time,
                size_bytes=spec.size_bytes, target_op=nxt.op_idx))
            freed += size
        else:
            # recompute if producer is cheap (high MSPS) and inputs persist
            producer = seq.operators[tga.op_idx]
            inputs_ok = all(
                seq.tensors[i].kind in PERSISTENT_KINDS
                or (seq.last_access(i) and seq.last_access(i).end_time >= nxt.time)
                for i in producer.inputs if i in seq.tensors)
            if not inputs_ok:
                continue
            plan.add(ScheduleEvent(
                event_type=EventType.RELEASE, tensor_id=tid,
                job_id=seq.job_id, trigger_op=prev.op_idx, delta=0.0,
                start=prev.end_time, end=prev.end_time,
                size_bytes=spec.size_bytes))
            plan.add(ScheduleEvent(
                event_type=EventType.RECOMPUTE, tensor_id=tid,
                job_id=seq.job_id, trigger_op=max(nxt.op_idx - 1, 0),
                delta=0.0, start=nxt.time - producer.latency, end=nxt.time,
                size_bytes=spec.size_bytes, target_op=nxt.op_idx,
                recompute_ops=[tga.op_idx]))
            freed += size
    return CapuchinResult(plan=plan)
