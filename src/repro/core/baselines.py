"""Baseline planners reproduced for the paper's comparisons (§V-A).

All three baselines are now *pass configurations* over the same pipeline
engine that drives TENSILE (see ``passes.PIPELINES``), so the comparison
isolates the scheduling policy exactly as the paper argues ("what we want to
compare is the scheduling algorithm itself ... run on the same platform"):

* **vanilla** — ``Pipeline([])``: no scheduling, free-at-last-use only (the
  normalizer for all metrics; VMP / VTC in the paper).
* **vDNN_conv** (Rhu et al., MICRO'16) — ``Pipeline([VdnnSwapPass])``:
  *layer* granularity, static prefetch, no recomputation, no Opt-phase
  events, single-workload design.
* **Capuchin** (Peng et al., ASPLOS'20) — ``Pipeline([PassiveProfilePass,
  SwapPass(style="capuchin"), RecomputePass(style="capuchin")])``: *tensor*
  granularity after one passive observation iteration (counted into its
  overhead), swap when the transfer hides under compute, recompute (by MSPS)
  otherwise.  Within-iteration only: updated parameters and optimizer state
  are never scheduled, so cross-iteration prefetch is impossible (the gap
  TENSILE closes).

This module keeps the seed's functional entry points as thin wrappers so
existing callers and benchmarks are unaffected.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from .access import AccessSequence
from .passes import HEAVY_OPS, SchedulerConfig, build_pipeline
from .plan import MachineProfile, SchedulingPlan

__all__ = ["HEAVY_OPS", "CapuchinResult", "capuchin_plan", "vanilla_plan",
           "vdnn_conv_plan"]


def vanilla_plan(seq: AccessSequence) -> SchedulingPlan:
    return build_pipeline("vanilla").plan([seq]).plans[seq.job_id]


def vdnn_conv_plan(seq: AccessSequence,
                   profile: Optional[MachineProfile] = None) -> SchedulingPlan:
    """vDNN_conv: swap out every heavy-layer forward feature map right after
    the layer finishes; swap it back when the op *before* its backward
    consumer starts (static one-layer lookahead prefetch)."""
    pipe = build_pipeline("vdnn", profile=profile)
    return pipe.plan([seq]).plans[seq.job_id]


@dataclasses.dataclass
class CapuchinResult:
    plan: SchedulingPlan
    passive_iterations: int = 1   # observation epoch (passive mode)


def capuchin_plan(seq: AccessSequence,
                  budget_bytes: int,
                  profile: Optional[MachineProfile] = None) -> CapuchinResult:
    """Capuchin: after passive observation, evict peak-contributing tensors;
    choose swap when the transfer hides under the compute between the
    eviction and the next access, else recompute by MSPS.  Schedules only
    within one iteration and only F/B-phase tensors."""
    pipe = build_pipeline(
        "capuchin", profile=profile,
        config=SchedulerConfig(memory_budget_bytes=budget_bytes))
    plan = pipe.plan([seq]).plans[seq.job_id]
    return CapuchinResult(plan=plan,
                          passive_iterations=max(plan.passive_iterations, 1))
