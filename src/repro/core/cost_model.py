"""Operator latency estimation (paper §IV-C) and the cold-start predictor.

Three layers, used in this order:
  1. **Analytic model** — per-primitive FLOPs / bytes from the jaxpr equation,
     latency = max(flops/peak_flops, bytes/mem_bw) scaled by a utilization
     factor.  Available before anything has ever run (cold start floor).
  2. **MLP predictor** — the paper's light 3-layer MLP mapping
     <input dims…, op params…, device utilization> → latency, trained on
     measured samples collected at system initialization.  Implemented in
     pure JAX (no framework), trained with the repo's own Adam.
  3. **EWMA correction** — at runtime, measured latencies are folded in with
     an exponentially weighted moving average (paper §IV-E); this dominates
     once a job is past its first steps.
"""
from __future__ import annotations

import dataclasses
import math
import time as _time
from typing import Dict, Optional, Tuple

import numpy as np

# row-block width of the quantize-on-offload Pallas kernel
# (kernels/offload_quant.BLOCK; duplicated so this module stays jax-free)
OFFLOAD_QUANT_BLOCK = 512

ELEMENTWISE_FLOPS = {
    "add": 1, "sub": 1, "mul": 1, "div": 1, "max": 1, "min": 1, "neg": 1,
    "exp": 8, "log": 8, "tanh": 10, "logistic": 10, "erf": 10, "rsqrt": 4,
    "sqrt": 4, "pow": 10, "integer_pow": 2, "abs": 1, "sign": 1,
    "floor": 1, "ceil": 1, "round": 1, "is_finite": 1, "and": 1, "or": 1,
    "xor": 1, "not": 1, "select_n": 1, "clamp": 2, "add_any": 1, "cos": 8,
    "sin": 8, "eq": 1, "ne": 1, "ge": 1, "gt": 1, "le": 1, "lt": 1,
}


def _numel(aval) -> int:
    try:
        return int(np.prod(aval.shape)) if aval.shape else 1
    except Exception:
        return 1


def _nbytes(aval) -> int:
    try:
        return _numel(aval) * np.dtype(aval.dtype).itemsize
    except Exception:
        return 0


@dataclasses.dataclass
class DeviceCalibration:
    """Effective throughput of the executing device.  Defaults are calibrated
    for this container's CPU at import time of the benchmarks (cheap matmul /
    memcpy probes); the TPU target constants live in plan.MachineProfile.

    Beyond the import-time probes, the constants recalibrate ONLINE from
    measured telemetry: ``CostModel.recalibrate(hub)`` folds every new
    TelemetryHub op sample (measured latency + the op's static
    flops/bytes) into ``flops`` / ``mem_bw`` with an EWMA, so the model
    tracks the device it is actually running on instead of the device it
    was probed on."""
    flops: float = 5e10
    mem_bw: float = 1e10
    overhead_s: float = 2e-6


@dataclasses.dataclass
class CalibrationReport:
    """How well the (re)calibrated analytic model predicts the measured
    latencies in a TelemetryHub: mean relative error overall and per
    primitive.  Exposed so the benchmarks/CI can gate on calibration
    quality (`calib_err` in BENCH_scenarios.json)."""

    overall: float                       # mean |pred - measured| / measured
    per_primitive: Dict[str, float]
    samples: int


def _clamped(estimate: float, current: float, limit: float = 16.0) -> float:
    """Bound a single-sample throughput point-estimate to within
    ``limit``x of the current constant: one outlier (GC pause, cold
    cache) must not move the calibration by orders of magnitude — the
    EWMA then walks toward a persistent shift over several samples."""
    return min(max(estimate, current / limit), current * limit)


class CostModel:
    def __init__(self, calib: Optional[DeviceCalibration] = None,
                 experience=None):
        # warm boot (experience plane): with no explicit calibration, an
        # attached ExperienceStore supplies the constants persisted by a
        # prior run's recalibration — capture-time latency estimates then
        # flow through measured experience instead of probe defaults.
        # An explicit `calib` always wins (the caller knows better).
        if calib is None and experience is not None:
            try:
                calib = experience.device_calibration()
            except Exception:   # noqa: BLE001 - corrupt store: cold boot
                calib = None
        self.calib = calib or DeviceCalibration()
        self.experience = experience
        self.mlp: Optional["LatencyMLP"] = None
        self.utilization: float = 0.0  # 0..1, "GPU usage" analogue
        # recalibration cursor per job: only hub samples newer than this
        # are folded in on the next recalibrate() call
        self._recal_cursor: Dict[str, int] = {}

    # ------------------------------------------------------------------
    def eqn_cost(self, eqn) -> Tuple[float, float]:
        """(flops, bytes) for one jaxpr equation."""
        prim = eqn.primitive.name
        out_avals = [v.aval for v in eqn.outvars]
        in_avals = [v.aval for v in eqn.invars if hasattr(v, "aval")]
        out_n = sum(_numel(a) for a in out_avals)
        in_b = sum(_nbytes(a) for a in in_avals)
        out_b = sum(_nbytes(a) for a in out_avals)
        bts = in_b + out_b
        if prim == "dot_general":
            dnums = eqn.params["dimension_numbers"]
            (lc, rc), (lb, rb) = dnums
            lhs = in_avals[0]
            contract = 1
            for d in lc:
                contract *= lhs.shape[d]
            flops = 2.0 * out_n * contract
        elif prim in ("conv_general_dilated",):
            rhs = in_avals[1]
            flops = 2.0 * out_n * _numel(rhs) / max(rhs.shape[-1], 1)
        elif prim in ("reduce_sum", "reduce_max", "reduce_min", "reduce_prod",
                      "argmax", "argmin", "cumsum", "cumlogsumexp", "cummax"):
            flops = float(sum(_numel(a) for a in in_avals))
        elif prim in ("custom_jvp_call", "custom_vjp_call", "pjit", "closed_call",
                      "remat", "checkpoint", "scan", "while", "cond"):
            # estimate nested jaxpr cost
            flops, extra_b = self._call_cost(eqn)
            bts = max(bts, extra_b)
        else:
            flops = float(out_n) * ELEMENTWISE_FLOPS.get(prim, 1)
        return flops, float(bts)

    def _call_cost(self, eqn) -> Tuple[float, float]:
        flops, bts = 0.0, 0.0
        for key in ("jaxpr", "call_jaxpr", "cond_jaxpr", "body_jaxpr", "fun_jaxpr"):
            sub = eqn.params.get(key)
            if sub is None:
                continue
            jaxpr = getattr(sub, "jaxpr", sub)
            for e in getattr(jaxpr, "eqns", []):
                f, b = self.eqn_cost(e)
                flops += f
                bts += b
        for key in ("branches",):
            for sub in eqn.params.get(key, ()):
                jaxpr = getattr(sub, "jaxpr", sub)
                for e in getattr(jaxpr, "eqns", []):
                    f, b = self.eqn_cost(e)
                    flops += f
                    bts += b
        n_iter = eqn.params.get("length", 1) if eqn.primitive.name == "scan" else 1
        return flops * n_iter, bts * n_iter

    # ------------------------------------------------------------------
    def offload_quant_latency(self, size_bytes: int) -> float:
        """Latency of the quantize-on-offload Pallas kernel
        (kernels/offload_quant: per 1×512 tile, absmax → scale → int8 pack).

        The kernel is bandwidth-bound: it reads the source tensor once and
        writes int8 + one fp32 scale per block (≈1.25× the source bytes
        moved for fp32 input), plus a small per-block issue overhead.  Used
        by CompressedOffloadPass to price the compressed swap path and to
        calibrate MachineProfile.offload_quant_bw."""
        c = self.calib
        block_bytes = 4 * OFFLOAD_QUANT_BLOCK
        blocks = max(1, math.ceil(size_bytes / block_bytes))
        moved = size_bytes * (1.0 + 0.25 + 4.0 / block_bytes)
        return c.overhead_s + moved / c.mem_bw + blocks * 2e-9

    def offload_quant_bandwidth(self, probe_bytes: int = 16 << 20) -> float:
        """Effective source-bytes/s of the quantize path — plug into
        MachineProfile.offload_quant_bw so the planner's compressed swap
        times match this device."""
        return probe_bytes / max(self.offload_quant_latency(probe_bytes),
                                 1e-12)

    # ------------------------------------------------------------------
    def dma_batch_latency(self, sizes, profile) -> float:
        """Modeled latency of one coalesced DMA batch (the runtime's
        ``DmaChannel.acquire_batch`` booking): one link setup, the summed
        payload at link bandwidth, and ``profile.dma_batch_overhead`` per
        extra member."""
        return profile.batched_swap_time(sizes)

    def dma_batch_saving(self, n_members: int, profile) -> float:
        """Latency saved by coalescing ``n_members`` adjacent transfers
        into one batch: (n-1) per-transfer setups collapse to (n-1)
        per-member descriptor fixups.  The serving plane's batched
        evict/fetch cohorts are priced with exactly this term."""
        if n_members <= 1:
            return 0.0
        return (n_members - 1) * max(
            profile.host_link_latency - profile.dma_batch_overhead, 0.0)

    # ------------------------------------------------------------------
    def latency(self, flops: float, bytes_accessed: float,
                prim_name: str = "") -> float:
        """Roofline latency under current utilization; if the MLP predictor
        is trained, blend it in (cold-start path, paper §IV-C)."""
        c = self.calib
        slowdown = 1.0 + self.utilization  # contended device runs slower
        base = c.overhead_s + slowdown * max(flops / c.flops,
                                             bytes_accessed / c.mem_bw)
        if self.mlp is not None:
            pred = self.mlp.predict_one(flops, bytes_accessed, self.utilization)
            if pred > 0:
                return float(0.5 * base + 0.5 * pred)
        return float(base)

    # ------------------------------------------------------------------
    # Online recalibration from measured telemetry (the §IV-E feedback
    # loop widened from per-op latencies to the throughput constants)
    # ------------------------------------------------------------------
    def recalibrate(self, hub, alpha: float = 0.5,
                    report: bool = True) -> Optional["CalibrationReport"]:
        """Fold every NEW TelemetryHub op sample into the calibration:
        each measured (flops, bytes, latency) triple yields a point
        estimate of the constant its roofline term is bound by — the
        classification uses the current calibration, so consistent
        samples contract both constants geometrically toward the device's
        effective throughput.  Samples already consumed (per-job cursor)
        are skipped, so the controller can call this after every
        iteration at O(new samples) cost.  Returns the post-update
        ``calibration_report`` — unless ``report=False``, which keeps the
        whole call O(new samples) for per-iteration callers (the report
        re-scans every sample)."""
        c = self.calib
        for job_id in hub.jobs():
            samples = hub.ops.get(job_id, ())
            start = self._recal_cursor.get(job_id, 0)
            for s in samples[start:]:
                eff = s.latency_s - c.overhead_s
                if eff <= 0 or (s.flops <= 0 and s.bytes_accessed <= 0):
                    continue
                if eff < 0.25 * s.latency_s:
                    # overhead-dominated sample: measurement jitter of
                    # the same order as `eff` would make the throughput
                    # estimate unbounded — no signal, skip it
                    continue
                if s.flops / c.flops >= s.bytes_accessed / c.mem_bw:
                    est = _clamped(s.flops / eff, c.flops)
                    c.flops = (1 - alpha) * c.flops + alpha * est
                else:
                    est = _clamped(s.bytes_accessed / eff, c.mem_bw)
                    c.mem_bw = (1 - alpha) * c.mem_bw + alpha * est
            self._recal_cursor[job_id] = len(samples)
        return self.calibration_report(hub) if report else None

    def calibration_report(self, hub) -> "CalibrationReport":
        """Per-primitive relative error of the analytic model against the
        hub's measured latencies (utilization-free prediction: the error
        isolates the throughput constants, not the contention factor)."""
        util, self.utilization = self.utilization, 0.0
        try:
            errs: Dict[str, list] = {}
            for job_id in hub.jobs():
                for s in hub.ops.get(job_id, ()):
                    if s.latency_s <= 0 or (s.flops <= 0
                                            and s.bytes_accessed <= 0):
                        continue
                    pred = self.latency(s.flops, s.bytes_accessed, s.prim)
                    rel = abs(pred - s.latency_s) / s.latency_s
                    errs.setdefault(s.prim or "?", []).append(rel)
        finally:
            self.utilization = util
        per_prim = {p: sum(v) / len(v) for p, v in errs.items()}
        n = sum(len(v) for v in errs.values())
        overall = (sum(sum(v) for v in errs.values()) / n) if n else 0.0
        return CalibrationReport(overall=overall, per_primitive=per_prim,
                                 samples=n)


# ======================================================================
# The paper's 3-layer MLP latency predictor, in pure JAX.
# ======================================================================
class LatencyMLP:
    """Predicts log-latency from <log flops, log bytes, utilization>.

    The paper feeds raw input dims + op params; flops/bytes are a sufficient
    statistic of those for roofline-dominated ops and keep the model
    op-agnostic.  3 layers, as in the paper.
    """

    def __init__(self, hidden: int = 32, seed: int = 0):
        import jax
        import jax.numpy as jnp
        self.jnp = jnp
        self.jax = jax
        k = jax.random.PRNGKey(seed)
        k1, k2, k3 = jax.random.split(k, 3)
        s = 1 / math.sqrt(3)
        self.params = {
            "w1": jax.random.normal(k1, (3, hidden)) * s,
            "b1": jnp.zeros((hidden,)),
            "w2": jax.random.normal(k2, (hidden, hidden)) / math.sqrt(hidden),
            "b2": jnp.zeros((hidden,)),
            "w3": jax.random.normal(k3, (hidden, 1)) / math.sqrt(hidden),
            "b3": jnp.zeros((1,)),
        }
        self._jit_pred = jax.jit(self._forward)

    @staticmethod
    def featurize(flops: np.ndarray, bytes_: np.ndarray,
                  util: np.ndarray) -> np.ndarray:
        return np.stack([np.log1p(flops) / 30.0, np.log1p(bytes_) / 30.0,
                         util], axis=-1).astype(np.float32)

    def _forward(self, params, x):
        jnp = self.jnp
        h = jnp.tanh(x @ params["w1"] + params["b1"])
        h = jnp.tanh(h @ params["w2"] + params["b2"])
        return (h @ params["w3"] + params["b3"])[..., 0]

    def fit(self, flops: np.ndarray, bytes_: np.ndarray, util: np.ndarray,
            latency_s: np.ndarray, steps: int = 2000, lr: float = 3e-3) -> float:
        """Train on measured samples; returns training R² on log-latency."""
        jax, jnp = self.jax, self.jnp
        x = jnp.asarray(self.featurize(flops, bytes_, util))
        y = jnp.asarray(np.log(np.maximum(latency_s, 1e-9)).astype(np.float32))

        def loss_fn(p):
            pred = self._forward(p, x)
            return jnp.mean((pred - y) ** 2)

        from repro.optim.adam import adamw_init, adamw_update
        state = adamw_init(self.params)
        p = self.params
        vg = jax.jit(jax.value_and_grad(loss_fn))

        @jax.jit
        def step(p, state):
            l, g = jax.value_and_grad(loss_fn)(p)
            p, state = adamw_update(p, g, state, lr=lr, weight_decay=0.0)
            return p, state, l

        for _ in range(steps):
            p, state, l = step(p, state)
        self.params = p
        pred = np.asarray(self._forward(p, x))
        yn = np.asarray(y)
        ss_res = float(np.sum((pred - yn) ** 2))
        ss_tot = float(np.sum((yn - yn.mean()) ** 2)) or 1e-12
        return 1.0 - ss_res / ss_tot

    def predict_one(self, flops: float, bytes_: float, util: float) -> float:
        x = self.jnp.asarray(self.featurize(
            np.array([flops]), np.array([bytes_]), np.array([util])))
        return float(np.exp(np.asarray(self._jit_pred(self.params, x))[0]))

    def r2(self, flops, bytes_, util, latency_s) -> float:
        x = self.jnp.asarray(self.featurize(np.asarray(flops), np.asarray(bytes_),
                                            np.asarray(util)))
        pred = np.asarray(self._jit_pred(self.params, x))
        y = np.log(np.maximum(np.asarray(latency_s), 1e-9))
        ss_res = float(np.sum((pred - y) ** 2))
        ss_tot = float(np.sum((y - y.mean()) ** 2)) or 1e-12
        return 1.0 - ss_res / ss_tot


class EWMATracker:
    """Runtime latency correction (paper §IV-E)."""

    def __init__(self, alpha: float = 0.3):
        self.alpha = alpha
        self.values: Dict[int, float] = {}
        self._hub_cursor: Dict[str, int] = {}

    def update(self, op_idx: int, measured: float) -> float:
        old = self.values.get(op_idx)
        new = measured if old is None else (
            self.alpha * measured + (1 - self.alpha) * old)
        self.values[op_idx] = new
        return new

    def ingest(self, hub, job_id: str) -> int:
        """Fold every NEW TelemetryHub op sample of the job into the
        tracker (per-job cursor, O(new samples)); returns how many were
        consumed.  This is the hub-fed path of §IV-E — the tracker no
        longer needs the executor to hand it latency lists directly."""
        samples = hub.ops.get(job_id, ())
        start = self._hub_cursor.get(job_id, 0)
        for s in samples[start:]:
            self.update(s.op_idx, s.latency_s)
        self._hub_cursor[job_id] = len(samples)
        return len(samples) - start

    def drift_ratio(self, baseline_sum: float) -> float:
        s = sum(self.values.values())
        if baseline_sum <= 0:
            return float("inf")
        return abs(s - baseline_sum) / baseline_sum


def calibrate_cpu(n: int = 256) -> DeviceCalibration:
    """Measure this container's effective matmul flops + memcpy bandwidth so
    the analytic model predicts realistic CPU latencies for the benchmarks."""
    a = np.random.rand(n, n).astype(np.float32)
    b = np.random.rand(n, n).astype(np.float32)
    t0 = _time.perf_counter()
    reps = 20
    for _ in range(reps):
        a @ b
    dt = (_time.perf_counter() - t0) / reps
    flops = 2 * n ** 3 / max(dt, 1e-9)
    big = np.random.rand(4 << 20).astype(np.float32)
    t0 = _time.perf_counter()
    for _ in range(10):
        big.copy()
    bw = 10 * big.nbytes * 2 / max(_time.perf_counter() - t0, 1e-9)
    return DeviceCalibration(flops=flops, mem_bw=bw)
