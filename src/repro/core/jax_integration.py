"""TENSILE plan → compiled-path JAX artifacts (the production integration).

The interpreter path executes plans event-by-event; at pod scale the same
decisions must be *baked into* the compiled step function instead:

  * recompute decisions  → a `jax.checkpoint` policy over
    `checkpoint_name`-tagged activations (XLA rematerializes them in the
    backward pass — the compiled equivalent of a Recompute event);
  * swap decisions on activations → offloaded saveables
    (`save_and_offload_only_these_names`) where the backend supports memory
    spaces;
  * Opt-phase across-iteration swaps → optimizer-state / master-weight
    pytree leaves placed in `pinned_host` shardings between steps (the
    paper's Fig. 1(c), as residency rather than as events).

CPU caveat (documented in DESIGN.md §2): XLA's CPU SPMD partitioner rejects
`annotate_device_placement`, so on this container `backend_supports_memory_kinds()`
is False and offload decisions degrade to accounting (reported bytes move to
the host ledger; the dry-run compiles without the annotations).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Dict, FrozenSet, Optional

import jax
from jax import ad_checkpoint

from .access import AccessSequence, TensorKind
from .plan import MachineProfile, SchedulingPlan
from .scheduler import MemoryScheduler, SchedulerConfig


@functools.lru_cache(maxsize=4)
def backend_supports_memory_kinds(platform: Optional[str] = None) -> bool:
    """Probe: can this backend compile a host-offload annotation under SPMD?"""
    try:
        dev = jax.devices(platform)[0] if platform else jax.devices()[0]
        if dev.platform == "cpu":
            # the CPU SPMD partitioner rejects annotate_device_placement
            # (verified empirically; see DESIGN.md §2)
            return False
        kinds = getattr(dev, "addressable_memories", lambda: [])()
        return any(getattr(m, "kind", "") == "pinned_host" for m in kinds)
    except Exception:
        return False


@dataclasses.dataclass(frozen=True)
class TensileDecisions:
    """Distilled plan for the compiled path."""
    remat_names: FrozenSet[str] = frozenset()     # recompute these activations
    offload_names: FrozenSet[str] = frozenset()   # host-offload these
    save_names: FrozenSet[str] = frozenset()      # keep these resident
    offload_opt_state: bool = False               # Opt-phase across-iteration
    offload_master: bool = False
    device_peak_estimate: int = 0
    host_bytes_estimate: int = 0

    def summary(self) -> str:
        return (f"remat={sorted(self.remat_names)} "
                f"offload={sorted(self.offload_names)} "
                f"opt_host={self.offload_opt_state} "
                f"master_host={self.offload_master}")


def make_remat_policy(decisions: TensileDecisions, offload: bool = False):
    """Checkpoint policy implementing the plan's keep/recompute/offload split.

    Tag activations in the model with `jax.ad_checkpoint.checkpoint_name`;
    names in `save_names` stay resident, names in `offload_names` go to host
    (TPU) or stay resident (CPU fallback), everything else rematerializes.
    """
    save = set(decisions.save_names)
    off = set(decisions.offload_names)
    if offload and backend_supports_memory_kinds():
        return jax.checkpoint_policies.save_and_offload_only_these_names(
            names_which_can_be_saved=sorted(save),
            names_which_can_be_offloaded=sorted(off),
            offload_src="device", offload_dst="pinned_host")
    return jax.checkpoint_policies.save_only_these_names(
        *sorted(save | off))


def opt_state_sharding(base_sharding, *, host: bool):
    """Place an optimizer-state leaf on host when supported (the Opt-phase
    across-iteration swap of paper Fig. 1(c) as a residency decision)."""
    if not host or not backend_supports_memory_kinds():
        return base_sharding
    return base_sharding.with_memory_kind("pinned_host")


# ----------------------------------------------------------------------
def plan_decisions(seq: AccessSequence, plan: SchedulingPlan,
                   name_of_tensor: Optional[Dict[str, str]] = None,
                   ) -> TensileDecisions:
    """Summarize a planned schedule into compiled-path decisions.

    `name_of_tensor` maps captured tensor ids to checkpoint_name tags; when
    absent, decisions are expressed per tensor-kind (opt-state/master
    offload + biggest-activation names by shape signature).
    """
    remat, offload = set(), set()
    opt_host = False
    host_bytes = 0
    for ev in plan.events:
        spec = seq.tensors.get(ev.tensor_id)
        if spec is None:
            continue
        from .plan import EventType
        if ev.event_type is EventType.RECOMPUTE:
            tag = (name_of_tensor or {}).get(ev.tensor_id,
                                             _shape_tag(spec))
            remat.add(tag)
        elif ev.event_type is EventType.SWAP_OUT:
            if spec.kind in (TensorKind.OPT_STATE,) or spec.updates:
                opt_host = True
                host_bytes += spec.size_bytes
            else:
                tag = (name_of_tensor or {}).get(ev.tensor_id,
                                                 _shape_tag(spec))
                offload.add(tag)
                host_bytes += spec.size_bytes
    return TensileDecisions(
        remat_names=frozenset(remat), offload_names=frozenset(offload),
        offload_opt_state=opt_host,
        device_peak_estimate=plan.planned_peak_bytes,
        host_bytes_estimate=host_bytes)


def _shape_tag(spec) -> str:
    return f"{spec.kind.value}:{'x'.join(map(str, spec.shape))}:{spec.dtype}"


# ----------------------------------------------------------------------
def schedule_for_budget(seq: AccessSequence, budget_bytes: int,
                        profile: Optional[MachineProfile] = None,
                        ) -> TensileDecisions:
    """One-call entry: plan a captured step under a device-memory budget and
    return the compiled-path decisions."""
    sched = MemoryScheduler(
        profile or MachineProfile(),
        SchedulerConfig(memory_budget_bytes=budget_bytes))
    sched.register_job(seq)
    res = sched.schedule()
    return plan_decisions(seq, res.plans[seq.job_id])


def checkpoint_name(x, name: str):
    """Re-export for model code (tag activations for policy decisions)."""
    return ad_checkpoint.checkpoint_name(x, name)
