"""Recomputation scheduling (paper §IV-D).

Driven by ``passes.RecomputePass`` under the Pipeline's convergence loop:
runs after swapping is exhausted (pass order) and only if the predicted peak
still exceeds the memory budget (the pass's gate).  Candidates are restricted to tensors that have **never
been released or swapped** (so a recomputation never cascades into further
swap-ins/recomputes), whose producer's inputs are still resident at the
recompute instant.  Candidates are ranked by Capuchin's MSPS metric:

    MSPS = memory_saving / recomputation_time
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

from .access import AccessSequence, AccessType, TensorKind
from .peak_analysis import PERSISTENT_KINDS, PeakReport, storage_of
from .plan import EventType, ScheduleEvent, SchedulingPlan


@dataclasses.dataclass
class RecomputeCandidate:
    tensor_id: str
    job_id: str
    size_bytes: int
    recompute_time: float
    release_after_op: int   # TUA after which the tensor is dropped
    target_op: int          # TUA needing the regenerated value
    producer_op: int

    @property
    def msps(self) -> float:
        return self.size_bytes / max(self.recompute_time, 1e-12)


class RecomputePlanner:
    def __init__(self, seq: AccessSequence, plan: SchedulingPlan,
                 experience=None):
        self.seq = seq
        self.plan = plan
        self.recomputed: set = {
            e.tensor_id for e in plan.events
            if e.event_type is EventType.RECOMPUTE}
        # per-fingerprint memo of the MSPS statics (ExperienceStore
        # attached): identical candidate stream, skips the per-call
        # re-derivation of TGA/TUA structure for every tensor
        self._ps = None
        if experience is not None:
            try:
                self._ps = experience.pass_state(seq)
            except Exception:   # noqa: BLE001 - corrupt store: cold path
                self._ps = None
        if self._ps is None:
            from .experience import default_pass_state
            self._ps = default_pass_state(seq)

    # ------------------------------------------------------------------
    def _touched(self) -> set:
        """Tensors already scheduled (swap or early release) — recomputing
        them could cascade (paper: apply only to never-released accesses)."""
        touched = set(self.plan.release_after_op)
        for e in self.plan.events:
            touched.add(e.tensor_id)
        return touched

    def _inputs_resident_at(self, op_idx: int, when: float,
                            touched: set) -> bool:
        """All producer inputs must still be resident at the recompute
        instant: persistent, or activations whose last use is later and which
        are untouched by the plan."""
        op = self.seq.operators[op_idx]
        for tid in op.inputs:
            spec = self.seq.tensors.get(tid)
            if spec is None:
                continue
            if spec.kind in PERSISTENT_KINDS or spec.kind is TensorKind.INPUT:
                if tid in touched:
                    return False
                continue
            last = self.seq.last_access(tid)
            if last is None or last.end_time < when or tid in touched:
                return False
        return True

    def _eligible(self) -> List[tuple]:
        """(tid, spec, tga, TUAs, recompute_time) for every activation
        with a producer and at least one use, in ``seq.tensors`` order —
        from the per-fingerprint memo when available."""
        seq = self.seq
        if self._ps is not None:
            return self._ps.recompute_statics(seq)
        out = []
        for tid, spec in seq.tensors.items():
            if spec.kind is not TensorKind.ACTIVATION:
                continue
            accs = seq.tensor_accesses(tid)
            tuas = [a for a in accs if a.access_type is AccessType.TUA]
            tga = seq.tga(tid)
            if tga is None or len(tuas) < 1:
                continue
            out.append((tid, spec, tga, tuas,
                        max(seq.operators[tga.op_idx].latency, 1e-12)))
        return out

    # ------------------------------------------------------------------
    def candidates(self, report: PeakReport) -> List[RecomputeCandidate]:
        seq = self.seq
        touched = self._touched()
        out: List[RecomputeCandidate] = []
        peak_ids = {sid for sid, j, _ in report.peak_tensors
                    if j == seq.job_id}
        for tid, spec, tga, tuas, rec_time in self._eligible():
            if (tid in touched or tid in self.recomputed
                    or storage_of(spec) not in peak_ids):
                continue
            # the release/recompute gap must cover the peak instant
            prev_end, target = None, None
            cursor = tga
            for a in tuas:
                if cursor.end_time <= report.peak_time <= a.time:
                    prev_end, target = cursor, a
                    break
                cursor = a
            if target is None:
                continue
            if not self._inputs_resident_at(tga.op_idx, target.time, touched):
                continue
            out.append(RecomputeCandidate(
                tensor_id=tid, job_id=seq.job_id, size_bytes=spec.size_bytes,
                recompute_time=rec_time,
                release_after_op=cursor.op_idx, target_op=target.op_idx,
                producer_op=tga.op_idx))
        out.sort(key=lambda c: -c.msps)
        return out

    def apply(self, cand: RecomputeCandidate) -> ScheduleEvent:
        seq = self.seq
        rel_time = seq.op_end[cand.release_after_op]
        tgt_time = seq.op_start[cand.target_op]
        rel = ScheduleEvent(
            event_type=EventType.RELEASE, tensor_id=cand.tensor_id,
            job_id=seq.job_id, trigger_op=cand.release_after_op, delta=0.0,
            start=rel_time, end=rel_time, size_bytes=cand.size_bytes)
        rec = ScheduleEvent(
            event_type=EventType.RECOMPUTE, tensor_id=cand.tensor_id,
            job_id=seq.job_id, trigger_op=max(cand.target_op - 1, 0),
            delta=0.0, start=max(tgt_time - cand.recompute_time, rel_time),
            end=tgt_time, size_bytes=cand.size_bytes,
            target_op=cand.target_op, recompute_ops=[cand.producer_op])
        self.plan.add(rel)
        self.plan.add(rec)
        self.recomputed.add(cand.tensor_id)
        return rec


def plan_one_recompute(planners: Dict[str, RecomputePlanner],
                       report: PeakReport) -> bool:
    best: Optional[Tuple[float, RecomputePlanner, RecomputeCandidate]] = None
    for pl in planners.values():
        for cand in pl.candidates(report):
            if best is None or cand.msps > best[0]:
                best = (cand.msps, pl, cand)
            break  # candidates are sorted; first is this job's best
    if best is None:
        return False
    _, pl, cand = best
    pl.apply(cand)
    return True
