"""The experience plane: a persistent cross-run store for warm-boot
scheduling (ROADMAP: "a persisted telemetry store so cold starts can
warm-boot from a prior run's hub").

TENSILE's central claim over SuperNeurons/Capuchin-style schedulers is
solving the **cold-start problem**: producing a good plan *before* a job
has run, from experience gathered on prior runs of the same (recurring)
workload — the paper's in-database ML setting.  Everything this repro
measures today dies with the process: the ``TelemetryHub``, the online
recalibrated ``DeviceCalibration``, and the converged plans.  This module
persists the *distilled* form of all three, keyed by a structural **job
fingerprint**, so the next process starts from experience instead of
probe constants:

  fingerprint(seq)           op kinds + tensor shapes/dtypes/kinds +
                             wiring, hashed — invariant across processes
                             and parameter VALUES, different across
                             shape/topology changes, salted by the device
                             identity (experience must not cross device
                             classes)
        │
        ▼
  ExperienceStore            versioned JSON-lines files under a
                             configurable root (``<root>/v1/<fp>.jsonl``),
                             one entry per fingerprint holding
                               * a TelemetrySummary (per-primitive latency
                                 fits, measured DMA bandwidth, stall
                                 share, measured peak),
                               * the recalibrated DeviceCalibration, and
                               * the best known SchedulingPlan per
                                 (budget-bucket, pipeline) with its
                                 achieved peak / EOR,
                             plus one device-level record (calibration +
                             transfer totals) for consumers that exist
                             before any fingerprint does
        │
        ▼
  warm-boot consumers        CostModel(experience=...) starts from the
                             persisted calibration; SwapPlanner seeds
                             ``measured_bandwidth`` from stored transfer
                             summaries; Pipeline.plan consults the plan
                             cache (re-verified against the CURRENT
                             budget before trust); BudgetArbiter priors
                             stand in for live telemetry on cold jobs;
                             GlobalController flushes distilled
                             experience back on job finish.

Trust rules — warm boot must never be less safe than cold boot:

  * a cached plan is **rebased** onto the current sequence timeline
    (triggers are op-keyed; deltas scale with the iteration time) and
    **re-verified** through the peak-analysis simulator against the
    current budget; any structural mismatch or a peak above budget falls
    back to cold planning;
  * a corrupt file, an unreadable line, or a schema-version mismatch
    silently degrades to a cold boot — the store can never crash a run;
  * writers are concurrency-safe: every flush is read-merge-replace with an
    atomic ``os.replace`` and last-writer-wins semantics over monotonic
    sample counts, so multiple controller processes may share one store.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import math
import os
import threading
import time as _time
from typing import Dict, Iterator, List, Optional, Tuple

from .access import AccessSequence
from .cost_model import DeviceCalibration
from .peak_analysis import analyze
from .plan import EventType, MachineProfile, SchedulingPlan

SCHEMA_VERSION = 1

# a stored bandwidth estimate is trusted only past this many transfers
# (mirrors TelemetryHub.measured_bandwidth's live threshold)
MIN_BANDWIDTH_SAMPLES = 3


# ----------------------------------------------------------------------
# Fingerprints
# ----------------------------------------------------------------------
def sequence_signature(seq: AccessSequence) -> Dict[str, object]:
    """The structural identity of a captured job: operator kinds and their
    tensor wiring, plus every tensor's shape/dtype/kind/aliasing.  No
    latencies (they vary with calibration), no parameter values, no job
    id — two captures of the same step function on the same shapes
    produce the same signature in any process."""
    return {
        "ops": [[op.name, list(op.inputs), list(op.outputs)]
                for op in seq.operators],
        "tensors": {tid: [list(t.shape), t.dtype, t.kind.value, t.updates]
                    for tid, t in sorted(seq.tensors.items())},
        "initial_resident": list(seq.initial_resident),
    }


# fingerprint is an O(n) json dump + sha256 over the whole signature and
# sits on every warm-boot lookup / telemetry flush; the signature is
# structural (no latencies), so one computation per sequence object is
# enough — keyed by the sequence's unique serial
_FP_CACHE: Dict[Tuple[int, str], str] = {}


def fingerprint(seq: AccessSequence, device_id: str = "default") -> str:
    """Structural job fingerprint, salted by the device identity (a store
    is per device class: experience measured on one device must not
    warm-boot a different one) and the store schema version."""
    key = (getattr(seq, "serial", id(seq)), device_id)
    hit = _FP_CACHE.get(key)
    if hit is not None:
        return hit
    sig = {"schema": SCHEMA_VERSION, "device": device_id,
           "job": sequence_signature(seq)}
    blob = json.dumps(sig, sort_keys=True, separators=(",", ":"))
    fp = hashlib.sha256(blob.encode()).hexdigest()
    if len(_FP_CACHE) > 512:
        _FP_CACHE.clear()
    _FP_CACHE[key] = fp
    return fp


def device_identity(profile: MachineProfile) -> str:
    """Coarse device-class identity from the profile's construction-time
    constants (NOT the online-recalibrated values, which drift)."""
    return (f"flops={profile.compute_flops:.3g};bw={profile.mem_bw:.3g};"
            f"link={profile.host_link_bw:.3g};"
            f"mem={profile.device_memory_bytes}")


def budget_bucket(budget_bytes: int) -> int:
    """Geometric budget bucket (~25 % wide): the plan-cache key quantizes
    the budget so near-identical budgets share one best-plan slot; the
    CURRENT budget is always re-verified exactly at lookup."""
    if budget_bytes <= 0:
        return -1
    return int(round(math.log(budget_bytes, 1.25)))


# ----------------------------------------------------------------------
# Stored records
# ----------------------------------------------------------------------
@dataclasses.dataclass
class TelemetrySummary:
    """Distilled TelemetryHub state for one fingerprint: enough to seed
    every live consumer, small enough to persist."""

    samples: int = 0                 # op samples folded in
    iterations: int = 0              # completed instrumented iterations
    # per-primitive latency fit: n / mean flops / mean bytes / mean latency
    per_prim: Dict[str, Dict[str, float]] = dataclasses.field(
        default_factory=dict)
    # measured DMA path totals (source bytes, busy seconds, transfers)
    dma: Dict[str, Dict[str, float]] = dataclasses.field(
        default_factory=dict)
    stall_share: float = 0.0
    measured_eor: float = 0.0
    peak_bytes: int = 0              # measured per-job peak, bytes
    updated_at: float = 0.0

    def bandwidth(self, compressed: bool = False) -> Optional[float]:
        d = self.dma.get("compressed" if compressed else "full")
        if not d or d.get("n", 0) < MIN_BANDWIDTH_SAMPLES:
            return None
        if d.get("seconds", 0.0) <= 0:
            return None
        return d["bytes"] / d["seconds"]


@dataclasses.dataclass
class CalibrationRecord:
    flops: float
    mem_bw: float
    overhead_s: float
    samples: int = 0
    updated_at: float = 0.0

    def to_calibration(self) -> DeviceCalibration:
        return DeviceCalibration(flops=self.flops, mem_bw=self.mem_bw,
                                 overhead_s=self.overhead_s)


@dataclasses.dataclass
class PlanRecord:
    """Best known plan for one (pipeline, budget-bucket) slot."""

    pipeline: str
    bucket: int
    budget_bytes: int
    peak_bytes: int                  # achieved (certified) peak
    eor: Optional[float]
    samples: int
    iteration_time: float            # timeline the plan was built on
    plan: Dict[str, object]          # SchedulingPlan.to_dict()
    updated_at: float = 0.0

    @property
    def key(self) -> str:
        return f"{self.pipeline}@{self.bucket}"


@dataclasses.dataclass
class DeviceRecord:
    """Device-level experience: the latest recalibrated constants and the
    DMA transfer totals — consumers that exist before any job fingerprint
    does (CostModel construction, SwapPlanner window sizing) read this."""

    calibration: Optional[CalibrationRecord] = None
    transfers: Dict[str, Dict[str, float]] = dataclasses.field(
        default_factory=dict)
    updated_at: float = 0.0

    def bandwidth(self, compressed: bool = False) -> Optional[float]:
        d = self.transfers.get("compressed" if compressed else "full")
        if not d or d.get("n", 0) < MIN_BANDWIDTH_SAMPLES:
            return None
        if d.get("seconds", 0.0) <= 0:
            return None
        return d["bytes"] / d["seconds"]


@dataclasses.dataclass
class DriftRecord:
    """One sim-vs-measured drift comparison (observability plane).

    Appended per finished comparison by ``DriftMonitor`` through
    ``ExperienceStore.record_drift``; readers that predate the record
    kind skip it (unknown kinds are ignored by ``_entry_of``), so this
    is an additive schema extension, not a version bump."""

    t: float
    job_id: str
    predicted_peak: int
    measured_peak: int
    peak_drift: float
    eor_drift: Optional[float] = None
    sp_drift: Optional[float] = None


# drift history kept per fingerprint (a bounded time series, not a log)
DRIFT_HISTORY_LIMIT = 64


@dataclasses.dataclass
class ExperienceEntry:
    """Everything persisted for one job fingerprint."""

    fingerprint: str
    telemetry: Optional[TelemetrySummary] = None
    calibration: Optional[CalibrationRecord] = None
    plans: Dict[str, PlanRecord] = dataclasses.field(default_factory=dict)
    drift: List[DriftRecord] = dataclasses.field(default_factory=list)

    @property
    def updated_at(self) -> float:
        stamps = [r.updated_at for r in
                  [self.telemetry, self.calibration, *self.plans.values()]
                  if r is not None]
        return max(stamps, default=0.0)

    @property
    def samples(self) -> int:
        return self.telemetry.samples if self.telemetry else 0


# ----------------------------------------------------------------------
# Merge rules: last-writer-wins over MONOTONIC sample counts
# ----------------------------------------------------------------------
def _merge_telemetry(a: Optional[TelemetrySummary],
                     b: Optional[TelemetrySummary]
                     ) -> Optional[TelemetrySummary]:
    if a is None:
        return b
    if b is None:
        return a
    # the record with more samples wins wholesale (a hub accumulates, so
    # a later flush from the same run always has >= samples; across runs
    # the richer history wins); measured peaks stay monotone max
    win, lose = (a, b) if (a.samples, a.updated_at) >= (b.samples,
                                                        b.updated_at) else (b, a)
    win = dataclasses.replace(
        win, per_prim={p: dict(d) for p, d in win.per_prim.items()},
        dma={p: dict(d) for p, d in win.dma.items()})
    win.peak_bytes = max(win.peak_bytes, lose.peak_bytes)
    return win


def _merge_calibration(a: Optional[CalibrationRecord],
                       b: Optional[CalibrationRecord]
                       ) -> Optional[CalibrationRecord]:
    if a is None:
        return b
    if b is None:
        return a
    return a if (a.samples, a.updated_at) >= (b.samples, b.updated_at) else b


def _better_plan(a: Optional[PlanRecord], b: PlanRecord) -> PlanRecord:
    """Lower certified peak wins; ties go to the record with more samples
    behind it, then the newer one."""
    if a is None:
        return b
    ka = (a.peak_bytes, -a.samples, -a.updated_at)
    kb = (b.peak_bytes, -b.samples, -b.updated_at)
    return a if ka <= kb else b


def _merge_entries(a: Optional[ExperienceEntry],
                   b: ExperienceEntry) -> ExperienceEntry:
    if a is None:
        return b
    out = ExperienceEntry(fingerprint=a.fingerprint or b.fingerprint)
    out.telemetry = _merge_telemetry(a.telemetry, b.telemetry)
    out.calibration = _merge_calibration(a.calibration, b.calibration)
    out.plans = dict(a.plans)
    for key, rec in b.plans.items():
        out.plans[key] = _better_plan(out.plans.get(key), rec)
    # drift history: union by (t, job_id), time-ordered, bounded
    seen = set()
    drift: List[DriftRecord] = []
    for rec in sorted(a.drift + b.drift, key=lambda r: (r.t, r.job_id)):
        key = (rec.t, rec.job_id)
        if key not in seen:
            seen.add(key)
            drift.append(rec)
    out.drift = drift[-DRIFT_HISTORY_LIMIT:]
    return out


def _merge_device(a: Optional[DeviceRecord],
                  b: Optional[DeviceRecord]) -> Optional[DeviceRecord]:
    if a is None:
        return b
    if b is None:
        return a
    out = DeviceRecord()
    out.calibration = _merge_calibration(a.calibration, b.calibration)
    # transfer totals: the record with more transfers behind it wins (the
    # totals are cumulative within a run, not across runs — summing two
    # flushes of the same hub would double-count)
    for path in set(a.transfers) | set(b.transfers):
        da, db = a.transfers.get(path), b.transfers.get(path)
        if da is None or (db is not None and db.get("n", 0) >= da.get("n", 0)):
            out.transfers[path] = dict(db)
        else:
            out.transfers[path] = dict(da)
    out.updated_at = max(a.updated_at, b.updated_at)
    return out


# ----------------------------------------------------------------------
# Distillation from a live TelemetryHub
# ----------------------------------------------------------------------
def distill_telemetry(hub, job_id: str,
                      peak_bytes: int = 0) -> TelemetrySummary:
    """Fold one job's hub records into the persistent summary shape.
    Transfer totals are filtered to THIS job — a multi-job hub must not
    leak other jobs' transfers into a per-workload record (the hub-wide
    totals live in the device-level record instead)."""
    per_prim = hub.op_summary(job_id)
    samples = sum(int(d.get("n", 0)) for d in per_prim.values())
    dma: Dict[str, Dict[str, float]] = {}
    for path, compressed in (("full", False), ("compressed", True)):
        n, nbytes, seconds = hub.transfer_totals(compressed=compressed,
                                                 job_id=job_id)
        if n:
            dma[path] = {"n": float(n), "bytes": float(nbytes),
                         "seconds": float(seconds)}
    measured_peak = max([peak_bytes]
                        + [b for _t, b in hub.residency_timeline(job_id)])
    return TelemetrySummary(
        samples=samples, iterations=hub.iterations(job_id),
        per_prim=per_prim, dma=dma,
        stall_share=hub.stall_share(job_id),
        measured_eor=hub.measured_eor(job_id),
        peak_bytes=int(measured_peak), updated_at=_time.time())


# ----------------------------------------------------------------------
# (De)serialization — one typed JSON line per record
# ----------------------------------------------------------------------
def _records_of(entry: ExperienceEntry) -> List[Dict[str, object]]:
    recs: List[Dict[str, object]] = [
        {"kind": "header", "schema": SCHEMA_VERSION,
         "fingerprint": entry.fingerprint}]
    if entry.telemetry is not None:
        recs.append({"kind": "telemetry",
                     **dataclasses.asdict(entry.telemetry)})
    if entry.calibration is not None:
        recs.append({"kind": "calibration",
                     **dataclasses.asdict(entry.calibration)})
    for rec in entry.plans.values():
        recs.append({"kind": "plan", **dataclasses.asdict(rec)})
    for rec in entry.drift[-DRIFT_HISTORY_LIMIT:]:
        recs.append({"kind": "drift", **dataclasses.asdict(rec)})
    return recs


def _entry_of(fp: str,
              records: List[Dict[str, object]]) -> ExperienceEntry:
    entry = ExperienceEntry(fingerprint=fp)
    for rec in records:
        kind = rec.get("kind")
        body = {k: v for k, v in rec.items() if k != "kind"}
        try:
            if kind == "telemetry":
                entry.telemetry = _merge_telemetry(
                    entry.telemetry, TelemetrySummary(**body))
            elif kind == "calibration":
                entry.calibration = _merge_calibration(
                    entry.calibration, CalibrationRecord(**body))
            elif kind == "plan":
                pr = PlanRecord(**body)
                entry.plans[pr.key] = _better_plan(entry.plans.get(pr.key),
                                                   pr)
            elif kind == "drift":
                entry.drift.append(DriftRecord(**body))
        except TypeError:
            continue        # unknown field layout: skip the record
    entry.drift.sort(key=lambda r: (r.t, r.job_id))
    del entry.drift[:-DRIFT_HISTORY_LIMIT]
    return entry


# ----------------------------------------------------------------------
# Per-fingerprint pass state (in-memory planner memoization)
# ----------------------------------------------------------------------
@dataclasses.dataclass
class JobPassState:
    """In-memory per-fingerprint planning state.

    Every SwapPlanner/RecomputePlanner construction re-derives the same
    structural inputs — storage alias candidates, the swappable-tensor
    count, activity analysis, the recompute-eligibility statics behind
    the MSPS ranking — and every analyze call needs the job's base event
    arrays.  Replans of a known job hit this memo instead (the raw-speed
    tentpole's warm path); all fields are read-only to consumers.
    Timeline-scoped members (base arrays, recompute statics) are keyed by
    (sequence serial, timeline version) and drop automatically when the
    timeline is rebuilt."""

    fingerprint: str
    alias_candidates: Dict[str, List[str]]
    swappable_total: int
    release_ops: Dict[str, int]
    _tv_key: Optional[Tuple[int, int]] = None
    _bases: Dict[bool, object] = dataclasses.field(default_factory=dict)
    _recompute_statics: Optional[List[tuple]] = None

    def _roll(self, seq: AccessSequence) -> None:
        key = (seq.serial, seq._timeline_version)
        if self._tv_key != key:
            self._tv_key = key
            self._bases = {}
            self._recompute_statics = None

    def job_base(self, seq: AccessSequence,
                 free_at_last_use: bool = True):
        """The job's cached SoA base event buffers, pinned here so a
        warm job survives the global base-cache's eviction sweeps."""
        from .peak_analysis import _job_base
        self._roll(seq)
        b = self._bases.get(free_at_last_use)
        if b is None:
            b = self._bases[free_at_last_use] = _job_base(
                seq, free_at_last_use)
        return b

    def recompute_statics(self, seq: AccessSequence) -> List[tuple]:
        """Per-tensor statics of the MSPS ranking — (tid, spec, tga,
        TUAs, recompute_time) for every activation with a producer and at
        least one use — in ``seq.tensors`` iteration order, so consuming
        them reproduces the uncached candidate order exactly."""
        from .access import AccessType, TensorKind
        self._roll(seq)
        if self._recompute_statics is None:
            out = []
            for tid, spec in seq.tensors.items():
                if spec.kind is not TensorKind.ACTIVATION:
                    continue
                accs = seq.tensor_accesses(tid)
                tuas = [a for a in accs if a.access_type is AccessType.TUA]
                tga = seq.tga(tid)
                if tga is None or len(tuas) < 1:
                    continue
                out.append((tid, spec, tga, tuas,
                            max(seq.operators[tga.op_idx].latency, 1e-12)))
            self._recompute_statics = out
        return self._recompute_statics


def build_pass_state(seq: AccessSequence, fp: str) -> JobPassState:
    from .peak_analysis import storage_of
    alias: Dict[str, List[str]] = {}
    for t in seq.tensors.values():
        alias.setdefault(storage_of(t), []).append(t.tid)
    for cands in alias.values():
        cands.sort(key=lambda tid: seq.tensors[tid].updates is None)
    swappable = max(1, sum(1 for t in seq.tensors.values()
                           if len(seq.tensor_accesses(t.tid)) >= 1))
    return JobPassState(fingerprint=fp, alias_candidates=alias,
                        swappable_total=swappable,
                        release_ops=dict(seq.activity_analysis()))


# storeless fallback: pipelines without an ExperienceStore get the same
# structural memo, keyed by sequence serial (the structural members only
# depend on the graph, which is fixed for a sequence's lifetime; the
# timeline-scoped members roll themselves via JobPassState._roll).  No
# fingerprint hash is computed on this path.
_DEFAULT_PASS_STATE: Dict[int, JobPassState] = {}


def default_pass_state(seq: AccessSequence) -> JobPassState:
    serial = getattr(seq, "serial", None)
    if serial is None:
        return build_pass_state(seq, "")
    ps = _DEFAULT_PASS_STATE.get(serial)
    if ps is None:
        if len(_DEFAULT_PASS_STATE) > 256:
            _DEFAULT_PASS_STATE.clear()
        ps = _DEFAULT_PASS_STATE[serial] = build_pass_state(seq, "")
    return ps


# ----------------------------------------------------------------------
# The store
# ----------------------------------------------------------------------
class ExperienceStore:
    """Versioned on-disk experience store.

    Layout: ``<root>/v<SCHEMA_VERSION>/<fingerprint>.jsonl`` — one
    JSON-lines file per fingerprint (header line + one line per typed
    record) — plus one ``device-<id>.jsonl`` for device-level experience.
    Reads are tolerant (corrupt lines skipped, corrupt/mismatched files
    read as absent); writes are read-merge-replace with ``os.replace``
    atomicity, so concurrent writers interleave safely and merge rules
    keep sample counts monotone.
    """

    SCHEMA = SCHEMA_VERSION

    def __init__(self, root: str, device_id: str = "default"):
        self.root = os.path.abspath(os.path.expanduser(str(root)))
        self.device_id = device_id
        self.dir = os.path.join(self.root, f"v{self.SCHEMA}")
        self._lock = threading.Lock()
        self._pending: Dict[str, ExperienceEntry] = {}
        self._pending_device: Optional[DeviceRecord] = None
        self._tmp_serial = 0
        # in-memory (never persisted) per-fingerprint planner memo
        self._pass_state: Dict[str, JobPassState] = {}

    # -- identity ------------------------------------------------------
    def fingerprint(self, seq: AccessSequence) -> str:
        return fingerprint(seq, device_id=self.device_id)

    def pass_state(self, seq: AccessSequence) -> JobPassState:
        """The in-memory ``JobPassState`` memo for this job — planners
        constructed with this store fetch their structural inputs here
        instead of re-deriving them (identical values either way; the
        memo only changes speed, not decisions)."""
        fp = self.fingerprint(seq)
        with self._lock:
            ps = self._pass_state.get(fp)
            if ps is None:
                if len(self._pass_state) > 256:
                    self._pass_state.clear()
                ps = self._pass_state[fp] = build_pass_state(seq, fp)
            return ps

    def _path(self, fp: str) -> str:
        return os.path.join(self.dir, f"{fp}.jsonl")

    def _device_path(self) -> str:
        tag = hashlib.sha256(self.device_id.encode()).hexdigest()[:12]
        return os.path.join(self.dir, f"device-{tag}.jsonl")

    # -- tolerant reads ------------------------------------------------
    def _read_records(self, path: str) -> Optional[List[Dict[str, object]]]:
        """All parseable records of one file, or None when the file is
        missing, unreadable, or its header names a different schema —
        warm boot silently degrades to cold, never crashes."""
        try:
            with open(path, "r", encoding="utf-8") as f:
                lines = f.read().splitlines()
        except OSError:
            return None
        records: List[Dict[str, object]] = []
        for line in lines:
            line = line.strip()
            if not line:
                continue
            try:
                rec = json.loads(line)
            except (ValueError, TypeError):
                continue
            if not isinstance(rec, dict):
                continue
            records.append(rec)
        if not records:
            return None
        header = records[0]
        if header.get("kind") != "header" \
                or header.get("schema") != self.SCHEMA:
            return None
        return records[1:]

    def get(self, fp: str) -> Optional[ExperienceEntry]:
        recs = self._read_records(self._path(fp))
        if recs is None:
            return None
        entry = _entry_of(fp, recs)
        if entry.telemetry is None and entry.calibration is None \
                and not entry.plans and not entry.drift:
            return None
        return entry

    def device_record(self) -> Optional[DeviceRecord]:
        recs = self._read_records(self._device_path())
        if recs is None:
            return None
        dev = DeviceRecord()
        for rec in recs:
            body = {k: v for k, v in rec.items() if k != "kind"}
            try:
                if rec.get("kind") == "calibration":
                    dev.calibration = _merge_calibration(
                        dev.calibration, CalibrationRecord(**body))
                elif rec.get("kind") == "transfers":
                    dev = _merge_device(dev, DeviceRecord(
                        transfers=body.get("transfers", {}),
                        updated_at=body.get("updated_at", 0.0)))
            except TypeError:
                continue
        if dev.calibration is None and not dev.transfers:
            return None
        return dev

    def fingerprints(self) -> List[str]:
        try:
            names = os.listdir(self.dir)
        except OSError:
            return []
        return sorted(n[:-6] for n in names
                      if n.endswith(".jsonl") and not n.startswith("device-"))

    def entries(self) -> Iterator[Tuple[str, ExperienceEntry]]:
        for fp in self.fingerprints():
            entry = self.get(fp)
            if entry is not None:
                yield fp, entry

    # -- warm-boot queries ---------------------------------------------
    def device_calibration(self) -> Optional[DeviceCalibration]:
        """The persisted recalibrated constants — ``CostModel`` starts
        from these instead of probe defaults when samples exist."""
        dev = self.device_record()
        if dev is None or dev.calibration is None \
                or dev.calibration.samples <= 0:
            return None
        return dev.calibration.to_calibration()

    def bandwidth(self, compressed: bool = False) -> Optional[float]:
        """Stored effective DMA bandwidth (source bytes/s) of the given
        path — SwapPlanner's window sizing falls back to this between a
        cold start and the first live transfer samples."""
        dev = self.device_record()
        if dev is not None:
            bw = dev.bandwidth(compressed=compressed)
            if bw:
                return bw
        return None

    def lookup_plan(self, seq: AccessSequence, pipeline: str,
                    budget_bytes: int,
                    profile: Optional[MachineProfile] = None
                    ) -> Optional[SchedulingPlan]:
        """Best stored plan for this job under this pipeline that the
        CURRENT budget admits: candidates (best certified peak first) are
        rebased onto the current timeline and re-verified through peak
        analysis; the first one whose verified peak fits the budget is
        returned (with a ``warm-boot`` provenance record).  None — and a
        cold plan — on any mismatch."""
        fp = self.fingerprint(seq)
        entry = self.get(fp)
        if entry is None or not entry.plans:
            return None
        profile = profile or MachineProfile()
        cands = sorted((r for r in entry.plans.values()
                        if r.pipeline == pipeline),
                       key=lambda r: (r.peak_bytes, -r.samples))
        for rec in cands:
            plan = _rebase_plan(rec, seq, profile)
            if plan is None:
                continue
            try:
                rep = analyze([seq], plans={seq.job_id: plan})
            except Exception:   # noqa: BLE001 - malformed plan: fall back
                continue
            if rep.peak_bytes > budget_bytes:
                continue        # the budget shrank below what this plan
                # certifies: reject, plan cold
            plan.planned_peak_bytes = rep.peak_bytes
            plan.budget_bytes = budget_bytes
            plan.provenance.append({
                "action": "warm-boot", "fingerprint": fp,
                "pipeline": pipeline, "bucket": rec.bucket,
                "stored_budget_bytes": rec.budget_bytes,
                "budget_bytes": budget_bytes,
                "verified_peak_bytes": rep.peak_bytes})
            return plan
        return None

    def prior(self, seq: AccessSequence) -> Optional[TelemetrySummary]:
        """Stored telemetry summary for a job that has not produced live
        samples yet — the BudgetArbiter's eor-learned / peak policies
        read stall share and measured peak from here on cold starts."""
        entry = self.get(self.fingerprint(seq))
        return entry.telemetry if entry is not None else None

    def predicted_peak(self, seq: AccessSequence
                       ) -> Optional[Tuple[int, str]]:
        """Peak-prediction query for admission control: the best stored
        estimate of this job's peak bytes, with its provenance.

        Preference order: the *measured* peak a prior run's telemetry
        distilled (``"experience"``), else the smallest *certified* peak
        among stored verified plans (``"experience-plan"``).  Returns None
        for an unknown fingerprint — admission then falls back to the cost
        model's conservative bound (``GlobalController.predict_peak``)."""
        entry = self.get(self.fingerprint(seq))
        if entry is None:
            return None
        ts = entry.telemetry
        if ts is not None and ts.peak_bytes > 0:
            return int(ts.peak_bytes), "experience"
        certified = [r.peak_bytes for r in entry.plans.values()
                     if r.peak_bytes > 0]
        if certified:
            return int(min(certified)), "experience-plan"
        return None

    # -- recording (in-memory until flush) -----------------------------
    def record_job(self, fp: str, *, seq: AccessSequence, hub, job_id: str,
                   plan: Optional[SchedulingPlan] = None,
                   pipeline: Optional[str] = None,
                   peak_bytes: int = 0,
                   calib: Optional[DeviceCalibration] = None,
                   calib_samples: int = 0,
                   eor: Optional[float] = None) -> None:
        """Distill one finished job's experience: telemetry summary, the
        recalibrated calibration, and (when a plan ran) the plan-cache
        candidate.  Nothing touches disk until ``flush()``."""
        ts = distill_telemetry(hub, job_id, peak_bytes=peak_bytes)
        now = _time.time()
        with self._lock:
            ent = self._pending.setdefault(fp, ExperienceEntry(fp))
            ent.telemetry = _merge_telemetry(ent.telemetry, ts)
            if calib is not None:
                ent.calibration = _merge_calibration(
                    ent.calibration,
                    CalibrationRecord(flops=calib.flops, mem_bw=calib.mem_bw,
                                      overhead_s=calib.overhead_s,
                                      samples=calib_samples, updated_at=now))
            if plan is not None and pipeline \
                    and (plan.events or plan.release_after_op):
                budget = int(plan.budget_bytes or 0)
                rec = PlanRecord(
                    pipeline=pipeline, bucket=budget_bucket(budget),
                    budget_bytes=budget,
                    peak_bytes=int(plan.planned_peak_bytes
                                   or peak_bytes or 0),
                    eor=(eor if eor is not None
                         else ts.measured_eor or None),
                    samples=ts.samples,
                    iteration_time=float(seq.iteration_time),
                    plan=plan.to_dict(), updated_at=now)
                ent.plans[rec.key] = _better_plan(ent.plans.get(rec.key),
                                                  rec)
        self.record_device(calib=calib, samples=calib_samples, hub=hub)

    def record_drift(self, fp: str, sample) -> None:
        """Append one sim-vs-measured drift comparison to the
        fingerprint's bounded history.  ``sample`` is anything with the
        DriftRecord field surface (the obs plane's ``DriftSample``
        qualifies).  Nothing touches disk until ``flush()``."""
        rec = DriftRecord(
            t=float(getattr(sample, "t", 0.0)),
            job_id=str(getattr(sample, "job_id", "") or ""),
            predicted_peak=int(sample.predicted_peak),
            measured_peak=int(sample.measured_peak),
            peak_drift=float(sample.peak_drift),
            eor_drift=getattr(sample, "eor_drift", None),
            sp_drift=getattr(sample, "sp_drift", None))
        with self._lock:
            ent = self._pending.setdefault(fp, ExperienceEntry(fp))
            ent.drift.append(rec)
            del ent.drift[:-DRIFT_HISTORY_LIMIT]

    def drift_history(self, fp: str) -> List[DriftRecord]:
        """Persisted + pending drift samples for a fingerprint, time
        ordered, bounded to the history limit."""
        out: List[DriftRecord] = []
        ent = self.get(fp)
        if ent is not None:
            out.extend(ent.drift)
        with self._lock:
            pend = self._pending.get(fp)
            if pend is not None:
                out.extend(pend.drift)
        out.sort(key=lambda r: (r.t, r.job_id))
        return out[-DRIFT_HISTORY_LIMIT:]

    def record_device(self, calib: Optional[DeviceCalibration] = None,
                      samples: int = 0, hub=None) -> None:
        now = _time.time()
        dev = DeviceRecord(updated_at=now)
        if calib is not None:
            dev.calibration = CalibrationRecord(
                flops=calib.flops, mem_bw=calib.mem_bw,
                overhead_s=calib.overhead_s, samples=samples,
                updated_at=now)
        if hub is not None:
            for path, compressed in (("full", False), ("compressed", True)):
                n, nbytes, seconds = hub.transfer_totals(
                    compressed=compressed)
                if n:
                    dev.transfers[path] = {"n": float(n),
                                           "bytes": float(nbytes),
                                           "seconds": float(seconds)}
        with self._lock:
            self._pending_device = _merge_device(self._pending_device, dev)

    # -- atomic flush --------------------------------------------------
    def _atomic_write(self, path: str,
                      records: List[Dict[str, object]]) -> None:
        os.makedirs(self.dir, exist_ok=True)
        with self._lock:
            self._tmp_serial += 1
            serial = self._tmp_serial
        tmp = (f"{path}.tmp.{os.getpid()}."
               f"{threading.get_ident()}.{serial}")
        with open(tmp, "w", encoding="utf-8") as f:
            for rec in records:
                f.write(json.dumps(rec, sort_keys=True) + "\n")
        os.replace(tmp, path)

    def flush(self) -> List[str]:
        """Merge every pending entry into the on-disk store.  Each file
        is read-merge-replace: the disk state read at flush time is
        merged with the pending entry (monotonic sample counts, best
        plan per slot) and written whole via an atomic ``os.replace`` —
        two processes flushing the same fingerprint cannot corrupt the
        file, and the loser of the race loses at most its own delta.
        Returns the fingerprints written."""
        with self._lock:
            pending = self._pending
            pending_dev = self._pending_device
            self._pending = {}
            self._pending_device = None
        written: List[str] = []
        for fp, entry in pending.items():
            disk = self.get(fp)
            merged = _merge_entries(disk, entry) if disk else entry
            self._atomic_write(self._path(fp), _records_of(merged))
            written.append(fp)
        if pending_dev is not None:
            merged_dev = _merge_device(self.device_record(), pending_dev)
            recs: List[Dict[str, object]] = [
                {"kind": "header", "schema": self.SCHEMA,
                 "fingerprint": f"device:{self.device_id}"}]
            if merged_dev.calibration is not None:
                recs.append({"kind": "calibration",
                             **dataclasses.asdict(merged_dev.calibration)})
            if merged_dev.transfers:
                recs.append({"kind": "transfers",
                             "transfers": merged_dev.transfers,
                             "updated_at": merged_dev.updated_at})
            self._atomic_write(self._device_path(), recs)
        return written

    # -- maintenance (tools/experience.py) -----------------------------
    def prune(self, min_samples: int = 0,
              max_age_days: Optional[float] = None) -> List[str]:
        """Drop entries below a sample floor or older than the age cap;
        returns the fingerprints removed."""
        cutoff = (None if max_age_days is None
                  else _time.time() - max_age_days * 86400.0)
        dropped: List[str] = []
        for fp in self.fingerprints():
            entry = self.get(fp)
            stale = entry is None \
                or entry.samples < min_samples \
                or (cutoff is not None and entry.updated_at < cutoff)
            if stale:
                try:
                    os.remove(self._path(fp))
                    dropped.append(fp)
                except OSError:
                    pass
        return dropped

    def export_bundle(self) -> Dict[str, object]:
        """One portable JSON document holding the whole store (for moving
        experience between machines of the same device class)."""
        bundle: Dict[str, object] = {
            "schema": self.SCHEMA, "device_id": self.device_id,
            "entries": {}, "device": None}
        for fp, entry in self.entries():
            bundle["entries"][fp] = _records_of(entry)[1:]  # sans header
        dev = self.device_record()
        if dev is not None:
            recs: List[Dict[str, object]] = []
            if dev.calibration is not None:
                recs.append({"kind": "calibration",
                             **dataclasses.asdict(dev.calibration)})
            if dev.transfers:
                recs.append({"kind": "transfers",
                             "transfers": dev.transfers,
                             "updated_at": dev.updated_at})
            bundle["device"] = recs
        return bundle

    def import_bundle(self, bundle: Dict[str, object]) -> int:
        """Merge an exported bundle into this store (same merge rules as
        concurrent flushes); returns the number of entries imported.
        A schema mismatch imports nothing."""
        if not isinstance(bundle, dict) \
                or bundle.get("schema") != self.SCHEMA:
            return 0
        n = 0
        for fp, recs in (bundle.get("entries") or {}).items():
            if not isinstance(recs, list):
                continue
            entry = _entry_of(str(fp), [r for r in recs
                                        if isinstance(r, dict)])
            with self._lock:
                cur = self._pending.get(fp)
                self._pending[fp] = _merge_entries(cur, entry) \
                    if cur else entry
            n += 1
        dev_recs = bundle.get("device")
        if isinstance(dev_recs, list):
            dev = DeviceRecord()
            for rec in dev_recs:
                if not isinstance(rec, dict):
                    continue
                body = {k: v for k, v in rec.items() if k != "kind"}
                try:
                    if rec.get("kind") == "calibration":
                        dev.calibration = _merge_calibration(
                            dev.calibration, CalibrationRecord(**body))
                    elif rec.get("kind") == "transfers":
                        dev.transfers.update(body.get("transfers", {}))
                        dev.updated_at = max(dev.updated_at,
                                             body.get("updated_at", 0.0))
                except TypeError:
                    continue
            with self._lock:
                self._pending_device = _merge_device(self._pending_device,
                                                     dev)
        self.flush()
        return n


# ----------------------------------------------------------------------
# Plan rebase (store timeline -> current timeline)
# ----------------------------------------------------------------------
def _rebase_plan(rec: PlanRecord, seq: AccessSequence,
                 profile: MachineProfile) -> Optional[SchedulingPlan]:
    """Project a stored plan onto the current sequence timeline.

    Events are (trigger op, Δt)-keyed, so the op anchors transfer across
    processes; absolute instants are recomputed from the CURRENT op-end
    times, with Δt scaled by the iteration-time ratio (a uniformly
    slower/faster calibration stretches every gap by the same factor)
    and transfer durations re-derived from the profile.  Any structural
    mismatch — an op index out of range, an unknown tensor, a size that
    changed — rejects the plan (None): the fingerprint should have
    prevented this, so a mismatch means the store entry is stale."""
    try:
        plan = SchedulingPlan.from_dict(rec.plan)
    except Exception:   # noqa: BLE001 - malformed stored plan
        return None
    n = len(seq.operators)
    scale = (seq.iteration_time / rec.iteration_time
             if rec.iteration_time > 0 else 1.0)
    for ev in plan.events:
        if not (-1 <= ev.trigger_op < n):
            return None
        if ev.target_op is not None and not (0 <= ev.target_op < n):
            return None
        spec = seq.tensors.get(ev.tensor_id)
        if spec is None or spec.size_bytes != ev.size_bytes:
            return None
        trig_end = seq.op_end[ev.trigger_op] if ev.trigger_op >= 0 else 0.0
        # (trigger, Δt) wraps modulo the iteration period; the stored
        # absolute start recovers which period copy the event lives in
        # (an Opt-phase swap-in scheduled across the boundary, paper
        # Fig. 1(c), belongs to the next iteration's prefix)
        k = int(ev.start // rec.iteration_time) if rec.iteration_time > 0 \
            else 0
        start = k * seq.iteration_time + trig_end \
            + max(ev.delta, 0.0) * scale
        if ev.event_type in (EventType.SWAP_OUT, EventType.SWAP_IN):
            # transfer durations are physical (link bandwidth), not
            # compute-scaled: re-derive them from the profile
            dur = profile.transfer_time(ev.size_bytes,
                                        compressed=ev.compressed)
        else:
            # recompute/release durations follow the compute timeline
            dur = max(ev.end - ev.start, 0.0) * scale
        ev.delta = max(ev.delta, 0.0) * scale
        ev.start, ev.end = start, start + dur
    plan._bump()               # in-place rebase: invalidate derived caches
    for tid, op in plan.release_after_op.items():
        if tid not in seq.tensors or not (0 <= op < n):
            return None
    plan.vanilla_peak_bytes = 0
    return plan
