"""Tensor-access data model (paper §III).

A *job* is a static compute graph G(V, E): operators V manipulating tensors E.
A *workload* / *Tensor Access Sequence* (TAS) is the topologically ordered
sequence of tensor accesses; each operator contributes Tensor Using Accesses
(TUA) for its inputs at its start and Tensor Generating Accesses (TGA) for its
outputs at its end.  Times on the sequence come from the cost model and are
re-estimated as measured latencies drift (paper §IV-E).
"""
from __future__ import annotations

import dataclasses
import enum
import math
from typing import Dict, Iterable, List, Optional, Sequence, Tuple


class AccessType(enum.Enum):
    TGA = "TGA"  # tensor generating access (producer finishes -> tensor exists)
    TUA = "TUA"  # tensor using access (consumer starts -> tensor must be resident)


class Phase(enum.Enum):
    FB = "fb"    # forward/backward propagation phase
    OPT = "opt"  # optimizer phase (paper Fig. 1)


class TensorKind(enum.Enum):
    INPUT = "input"            # model inputs (placeholder TGA, paper §III-A)
    PARAM = "param"            # trainable parameter
    OPT_STATE = "opt_state"    # optimizer interim tensors (Adam moments)
    ACTIVATION = "activation"  # interim results of the F/B phase
    GRAD = "grad"
    OUTPUT = "output"          # job outputs (loss, new params...)


@dataclasses.dataclass
class TensorSpec:
    """A tensor in E, identified by the producing var name."""

    tid: str
    size_bytes: int
    shape: Tuple[int, ...] = ()
    dtype: str = "float32"
    kind: TensorKind = TensorKind.ACTIVATION
    job_id: str = "job0"
    # The paper treats an updated parameter as a logically-new tensor that
    # aliases the old parameter's storage; `updates` names the tensor whose
    # storage this one reuses (new_param.updates == old_param.tid).
    updates: Optional[str] = None

    def __post_init__(self):
        self.size_bytes = int(self.size_bytes)

    @property
    def is_updated_param(self) -> bool:
        return self.updates is not None


@dataclasses.dataclass
class Operator:
    """A node in V.  `latency` is (re-)estimated by the cost model."""

    idx: int
    name: str
    inputs: Tuple[str, ...]
    outputs: Tuple[str, ...]
    latency: float = 0.0
    flops: float = 0.0
    bytes_accessed: float = 0.0
    phase: Phase = Phase.FB
    params: Dict[str, object] = dataclasses.field(default_factory=dict)
    job_id: str = "job0"


@dataclasses.dataclass
class TensorAccess:
    """One access a_j^i on the sequence (paper §III-A)."""

    tensor_id: str
    op_idx: int
    access_type: AccessType
    time: float = 0.0       # trigger instant (TUA: op start; TGA: op end)
    end_time: float = 0.0   # when the access stops pinning the tensor
    job_id: str = "job0"
    # ordinal of this access among the tensor's accesses (0 == its TGA)
    seq_index: int = 0

    @property
    def is_tga(self) -> bool:
        return self.access_type is AccessType.TGA


_SEQ_SERIAL = [0]


class AccessSequence:
    """A workload: operators in topological order + derived access timeline."""

    def __init__(self, job_id: str, operators: Sequence[Operator],
                 tensors: Dict[str, TensorSpec],
                 initial_resident: Optional[Iterable[str]] = None):
        _SEQ_SERIAL[0] += 1
        self.serial = _SEQ_SERIAL[0]   # unique cache identity (id() recycles)
        self.job_id = job_id
        self.operators: List[Operator] = list(operators)
        self.tensors: Dict[str, TensorSpec] = dict(tensors)
        # Tensors in device memory at iteration start (paper Alg 2 line 1):
        # model inputs + parameters not swapped out from the last iteration.
        if initial_resident is None:
            initial_resident = [t.tid for t in tensors.values()
                                if t.kind in (TensorKind.INPUT, TensorKind.PARAM,
                                              TensorKind.OPT_STATE)]
        self.initial_resident: List[str] = list(initial_resident)
        self.accesses: List[TensorAccess] = []
        self.accesses_by_tensor: Dict[str, List[TensorAccess]] = {}
        self.op_start: List[float] = []
        self.op_end: List[float] = []
        self.iteration_time: float = 0.0
        self.rebuild_timeline()

    # ------------------------------------------------------------------
    _timeline_version: int = 0

    def rebuild_timeline(self, start_time: float = 0.0) -> None:
        """Recompute op start/end instants and the TAS from `Operator.latency`.

        Jobs execute their operators sequentially in topological order
        (paper §III-A: "the framework executes the operators of W_j in
        topological order").
        """
        self.op_start, self.op_end = [], []
        t = start_time
        for op in self.operators:
            self.op_start.append(t)
            t += max(op.latency, 0.0)
            self.op_end.append(t)
        self.iteration_time = t - start_time

        accesses: List[TensorAccess] = []
        for op in self.operators:
            for tid in op.inputs:
                if tid in self.tensors:
                    accesses.append(TensorAccess(
                        tensor_id=tid, op_idx=op.idx, access_type=AccessType.TUA,
                        time=self.op_start[op.idx], end_time=self.op_end[op.idx],
                        job_id=self.job_id))
            for tid in op.outputs:
                if tid in self.tensors:
                    accesses.append(TensorAccess(
                        tensor_id=tid, op_idx=op.idx, access_type=AccessType.TGA,
                        time=self.op_end[op.idx], end_time=self.op_end[op.idx],
                        job_id=self.job_id))
        accesses.sort(key=lambda a: (a.time, a.access_type is AccessType.TUA,
                                     a.op_idx))
        by_tensor: Dict[str, List[TensorAccess]] = {}
        for a in accesses:
            by_tensor.setdefault(a.tensor_id, []).append(a)
        for tid, accs in by_tensor.items():
            accs.sort(key=lambda a: (a.time, not a.is_tga))
            for i, a in enumerate(accs):
                a.seq_index = i
        self.accesses = accesses
        self.accesses_by_tensor = by_tensor
        self._timeline_version = getattr(self, "_timeline_version", 0) + 1

    # ------------------------------------------------------------------
    def set_latencies(self, latencies: Sequence[float]) -> None:
        assert len(latencies) == len(self.operators)
        for op, lat in zip(self.operators, latencies):
            op.latency = float(lat)
        self.rebuild_timeline()

    def tensor_accesses(self, tid: str) -> List[TensorAccess]:
        return self.accesses_by_tensor.get(tid, [])

    def last_access(self, tid: str) -> Optional[TensorAccess]:
        accs = self.tensor_accesses(tid)
        return accs[-1] if accs else None

    def first_tua(self, tid: str) -> Optional[TensorAccess]:
        for a in self.tensor_accesses(tid):
            if not a.is_tga:
                return a
        return None

    def first_tua_after(self, tid: str, time: float) -> Optional[TensorAccess]:
        for a in self.tensor_accesses(tid):
            if not a.is_tga and a.time >= time - 1e-12:
                return a
        return None

    def tga(self, tid: str) -> Optional[TensorAccess]:
        for a in self.tensor_accesses(tid):
            if a.is_tga:
                return a
        return None

    # ------------------------------------------------------------------
    def clone(self, job_id: str) -> "AccessSequence":
        """Deep-enough copy under a new job id (multi-job benchmarks reuse
        one traced workload without re-tracing)."""
        ops = [dataclasses.replace(op, job_id=job_id)
               for op in self.operators]
        tensors = {tid: dataclasses.replace(t, job_id=job_id)
                   for tid, t in self.tensors.items()}
        return AccessSequence(job_id, ops, tensors,
                              initial_resident=list(self.initial_resident))

    # ------------------------------------------------------------------
    def total_tensor_bytes(self) -> int:
        return sum(t.size_bytes for t in self.tensors.values())

    def activity_analysis(self) -> Dict[str, int]:
        """Last-use op index per tensor (release point; paper Alg 3 line 2).

        Cached per timeline version — the engine's JobContext and the
        planning passes each re-derive it on every (re)plan, and the
        result only changes when the timeline is rebuilt.  Callers treat
        the returned dict as read-only."""
        cached = getattr(self, "_activity_cache", None)
        if cached is not None and cached[0] == self._timeline_version:
            return cached[1]
        last_use: Dict[str, int] = {}
        for a in self.accesses:
            last_use[a.tensor_id] = max(last_use.get(a.tensor_id, -1), a.op_idx)
        self._activity_cache = (self._timeline_version, last_use)
        return last_use

    def __len__(self) -> int:
        return len(self.operators)

    def __repr__(self) -> str:  # pragma: no cover
        return (f"AccessSequence({self.job_id}, ops={len(self.operators)}, "
                f"tensors={len(self.tensors)}, "
                f"iter={self.iteration_time * 1e3:.2f}ms, "
                f"bytes={self.total_tensor_bytes() / 2**20:.1f}MiB)")


def format_bytes(n: float) -> str:
    if n <= 0:
        return "0B"
    units = ["B", "KiB", "MiB", "GiB", "TiB"]
    k = min(int(math.log(n, 1024)), len(units) - 1)
    return f"{n / 1024 ** k:.2f}{units[k]}"
