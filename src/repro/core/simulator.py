"""Discrete-event execution simulator.

Executes one or more jobs against their scheduling plans on a modeled
machine: sequential operators per job, a single shared host-DMA channel for
swaps (global exclusivity — cross-job conflicts queue), passive swap-ins when
a prefetch misses its TUA (stall, counted as extra overhead), recompute time
added inline, and exact byte accounting of device residency.

Outputs the paper's metrics:
    MSR = (VMP - EMP) / VMP      memory saving ratio
    EOR = (ETC - VTC) / VTC      extra overhead ratio
    CBR = MSR / EOR              cost-benefit ratio
measured against the vanilla (no scheduling) run of the same jobs.
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import Dict, List, Optional, Sequence, Tuple

from .access import AccessSequence, TensorKind
from .peak_analysis import PERSISTENT_KINDS, storage_of
from .plan import EventType, MachineProfile, ScheduleEvent, SchedulingPlan


@dataclasses.dataclass
class SimResult:
    peak_bytes: int
    per_job_time: Dict[str, float]
    per_job_peak: Dict[str, int]
    total_time: float
    stall_time: float
    passive_swap_ins: int
    swap_conflicts: int
    timeline: List[Tuple[float, int]]

    def msr(self, vanilla: "SimResult") -> float:
        v = vanilla.peak_bytes
        return (v - self.peak_bytes) / v if v else 0.0

    def eor(self, vanilla: "SimResult") -> float:
        v = vanilla.total_time
        return (self.total_time - v) / v if v else 0.0

    def cbr(self, vanilla: "SimResult") -> float:
        e = self.eor(vanilla)
        m = self.msr(vanilla)
        if e <= 0:
            return float("inf") if m > 0 else 0.0
        return m / e


class _Channel:
    """Physically exclusive transfer channel; requests queue FIFO."""

    def __init__(self):
        self.busy_until = 0.0
        self.conflicts = 0

    def acquire(self, t: float, dur: float) -> Tuple[float, float]:
        if t < self.busy_until:
            self.conflicts += 1
            t = self.busy_until
        self.busy_until = t + dur
        return t, t + dur


class _JobState:
    def __init__(self, seq: AccessSequence, plan: Optional[SchedulingPlan],
                 iterations: int, offset: float):
        self.seq = seq
        self.plan = plan
        self.iterations = iterations
        self.offset = offset
        self.op_ptr = 0
        self.iter = 0
        self.resident: Dict[str, int] = {}
        self.host: set = set()
        self.done = False
        self.finish_time = 0.0
        self.peak = 0
        # events indexed by trigger op for quick lookup
        self.by_trigger: Dict[int, List[ScheduleEvent]] = {}
        if plan:
            for ev in plan.events:
                self.by_trigger.setdefault(ev.trigger_op, []).append(ev)
        self.last_use = seq.activity_analysis()
        # pending swap-ins landing later (time, tensor)
        self.swap_in_done: Dict[str, float] = {}

    def mem(self) -> int:
        return sum(self.resident.values())


def simulate(seqs: Sequence[AccessSequence],
             plans: Optional[Dict[str, SchedulingPlan]] = None,
             profile: Optional[MachineProfile] = None,
             iterations: int = 2,
             offsets: Optional[Dict[str, float]] = None,
             free_at_last_use: bool = True) -> SimResult:
    """Run `iterations` training iterations of every job concurrently.

    `free_at_last_use=False` reproduces the vanilla platform (nothing is
    released before iteration end — paper §V-A normalizer)."""
    profile = profile or MachineProfile()
    plans = plans or {}
    offsets = offsets or {}
    channel = _Channel()

    jobs = {s.job_id: _JobState(s, plans.get(s.job_id), iterations,
                                offsets.get(s.job_id, 0.0))
            for s in seqs}

    global_mem = 0
    peak = 0
    stall = 0.0
    passive = 0
    timeline: List[Tuple[float, int]] = []

    def bump(job: _JobState, storage: str, size: int, t: float):
        """size > 0 allocates (idempotent); size < 0 frees (idempotent)."""
        nonlocal global_mem, peak
        if size > 0:
            if storage in job.resident:
                return
            job.resident[storage] = size
            global_mem += size
        else:
            if storage not in job.resident:
                return
            global_mem -= job.resident.pop(storage)
        peak = max(peak, global_mem)
        job.peak = max(job.peak, job.mem())
        timeline.append((t, global_mem))

    # initialize residency
    for job in jobs.values():
        for tid in job.seq.initial_resident:
            spec = job.seq.tensors.get(tid)
            if spec is None:
                continue
            st = storage_of(spec)
            # cross-iteration plans start steady state: tensors with a
            # crossing swap-in arrive via that swap-in, except iteration 0
            bump(job, st, spec.size_bytes, job.offset)

    # event queue: (time, seqno, kind, job_id, payload)
    q: List[Tuple[float, int, str, str, object]] = []
    seqno = 0

    def push(t: float, kind: str, job_id: str, payload=None):
        nonlocal seqno
        heapq.heappush(q, (t, seqno, kind, job_id, payload))
        seqno += 1

    for job_id, job in jobs.items():
        push(job.offset, "op", job_id, 0)

    sizes: Dict[Tuple[str, str], int] = {}
    for job in jobs.values():
        for spec in job.seq.tensors.values():
            st = storage_of(spec)
            key = (job.seq.job_id, st)
            sizes[key] = max(sizes.get(key, 0), spec.size_bytes)

    while q:
        t, _, kind, job_id, payload = heapq.heappop(q)
        job = jobs[job_id]
        seq = job.seq

        if kind == "swap_in_done":
            st = payload  # type: ignore[assignment]
            bump(job, st, sizes[(job_id, st)], t)
            job.host.discard(st)  # host copy retained logically; resident now
            job.swap_in_done.pop(st, None)
            continue
        if kind == "swap_out_done":
            st = payload  # type: ignore[assignment]
            job.host.add(st)
            bump(job, st, -1, t)
            continue
        if kind != "op":
            continue

        op_idx = payload  # type: ignore[assignment]
        op = seq.operators[op_idx]

        # ---- ensure inputs resident (passive swap-in on miss) ----------
        start = t
        for tid in op.inputs:
            spec = seq.tensors.get(tid)
            if spec is None:
                continue
            st = storage_of(spec)
            if st in job.resident:
                continue
            if st in job.swap_in_done:
                # prefetch in flight but late: wait for it
                wait_until = job.swap_in_done[st]
                stall_d = max(0.0, wait_until - start)
                stall += stall_d
                start = max(start, wait_until)
                bump(job, st, sizes[(job_id, st)], start)
                job.swap_in_done.pop(st, None)
                passive += 1
            elif st in job.host:
                # passive swap-in: block on the channel (paper: Capuchin-style
                # passive mode overhead — what TENSILE avoids)
                dur = profile.swap_time(sizes[(job_id, st)])
                s0, s1 = channel.acquire(start, dur)
                stall += (s1 - start)
                start = s1
                bump(job, st, sizes[(job_id, st)], start)
                passive += 1
            # else: never materialized (recompute plans re-run producer);
            # treat as recompute-on-demand below via plan events

        # ---- run the op -------------------------------------------------
        end = start + op.latency
        # recompute events targeting this op run inline before it
        if job.plan:
            for ev in job.plan.events:
                if (ev.event_type is EventType.RECOMPUTE
                        and ev.target_op == op_idx):
                    st = storage_of(seq.tensors[ev.tensor_id])
                    if st not in job.resident:
                        rc = sum(seq.operators[i].latency
                                 for i in (ev.recompute_ops or []))
                        end += rc
                        bump(job, st, sizes[(job_id, st)], start)

        # ---- allocate outputs -------------------------------------------
        for tid in op.outputs:
            spec = seq.tensors.get(tid)
            if spec is None:
                continue
            if spec.updates is not None:
                continue  # aliases old storage
            bump(job, storage_of(spec), spec.size_bytes, end)

        # ---- releases (activity analysis + plan) -------------------------
        for tid in op.inputs + op.outputs:
            spec = seq.tensors.get(tid)
            if spec is None:
                continue
            st = storage_of(spec)
            rel_op = (job.plan.release_after_op.get(tid)
                      if job.plan else None)
            if rel_op is not None and rel_op == op_idx:
                bump(job, st, -1, end)
                continue
            if (free_at_last_use
                    and job.last_use.get(tid) == op_idx
                    and spec.kind not in PERSISTENT_KINDS
                    and spec.updates is None
                    and st not in job.host):
                bump(job, st, -1, end)

        # ---- plan events triggered by this op -----------------------------
        if job.plan:
            for ev in job.by_trigger.get(op_idx, []):
                if ev.event_type is EventType.SWAP_OUT:
                    st = storage_of(seq.tensors[ev.tensor_id])
                    if st not in job.resident:
                        continue
                    dur = profile.swap_time(ev.size_bytes)
                    s0, s1 = channel.acquire(end + max(ev.delta, 0.0), dur)
                    push(s1, "swap_out_done", job_id, st)
                elif ev.event_type is EventType.SWAP_IN:
                    st = storage_of(seq.tensors[ev.tensor_id])
                    if st in job.resident or st not in job.host:
                        # still resident (swap-out in flight) or nothing on
                        # host yet (iteration-0 cold start): skip prefetch
                        continue
                    dur = profile.swap_time(ev.size_bytes)
                    s0, s1 = channel.acquire(end + max(ev.delta, 0.0), dur)
                    job.swap_in_done[st] = s1
                    push(s1, "swap_in_done", job_id, st)
                elif ev.event_type is EventType.RELEASE:
                    st = storage_of(seq.tensors[ev.tensor_id])
                    # only release if a host copy (or recompute plan) covers it
                    if st in job.host or ev.tensor_id in {
                            e.tensor_id for e in job.plan.recomputes()}:
                        bump(job, st, -1, end)

        # ---- advance ------------------------------------------------------
        nxt = op_idx + 1
        if nxt < len(seq.operators):
            push(end, "op", job_id, nxt)
        else:
            if not free_at_last_use:
                # vanilla platform: iteration-end free of non-persistent
                for st in list(job.resident):
                    if not _persistent_storage(seq, st):
                        bump(job, st, -1, end)
            job.iter += 1
            if job.iter < job.iterations:
                push(end, "op", job_id, 0)
            else:
                job.done = True
                job.finish_time = end

    per_job_time = {j: (job.finish_time - job.offset) / max(job.iterations, 1)
                    for j, job in jobs.items()}
    per_job_peak = {j: job.peak for j, job in jobs.items()}
    total = max((job.finish_time for job in jobs.values()), default=0.0)
    return SimResult(
        peak_bytes=peak, per_job_time=per_job_time, per_job_peak=per_job_peak,
        total_time=total, stall_time=stall, passive_swap_ins=passive,
        swap_conflicts=channel.conflicts, timeline=timeline)


def _persistent_storage(seq: AccessSequence, st: str) -> bool:
    spec = seq.tensors.get(st)
    return spec is not None and (spec.kind in PERSISTENT_KINDS
                                 or spec.updates is not None)


def evaluate(seqs: Sequence[AccessSequence],
             plans: Optional[Dict[str, SchedulingPlan]],
             profile: Optional[MachineProfile] = None,
             iterations: int = 3,
             offsets: Optional[Dict[str, float]] = None,
             free_at_last_use: bool = True,
             ) -> Dict[str, float]:
    """Run scheduled vs vanilla and report the paper's metrics.  The
    vanilla run frees nothing until iteration end (the paper's platform);
    scheduled runs get activity-analysis releases (Alg 3 line 2) unless
    the method's own framework lacks them (vDNN: swap-only)."""
    vanilla = simulate(seqs, None, profile, iterations, offsets,
                       free_at_last_use=False)
    sched = simulate(seqs, plans, profile, iterations, offsets,
                     free_at_last_use=free_at_last_use)
    msr = sched.msr(vanilla)
    eor = sched.eor(vanilla)
    return {
        "MSR": msr, "EOR": eor,
        "CBR": sched.cbr(vanilla),
        "vanilla_peak": vanilla.peak_bytes, "peak": sched.peak_bytes,
        "vanilla_time": vanilla.total_time, "time": sched.total_time,
        "stall_time": sched.stall_time,
        "passive_swap_ins": sched.passive_swap_ins,
        "swap_conflicts": sched.swap_conflicts,
    }
