"""Discrete-event execution simulator, driven by the shared MemoryEngine.

Executes one or more jobs against their scheduling plans on a modeled
machine: sequential operators per job, the engine's single host-DMA channel
for swaps (global exclusivity — cross-job conflicts queue), passive swap-ins
when a prefetch misses its TUA (stall, counted as extra overhead), recompute
time added inline, and the engine's byte-exact residency ledger.

All residency *decisions* (when a planned event applies, when an operand
needs a passive swap-in, when a tensor auto-releases) come from
``engine.JobContext`` — the same rules the interpreting executor runs — so
simulated and real runs of a plan agree by construction.  The simulator owns
only what is genuinely virtual: the clock, transfer completion times, and
stall accounting.

Two transfer modes:
  * ``async`` (default) — transfers overlap compute; completions land at
    their channel-scheduled instant (the paper's Swap Executor).
  * ``sync``  — transfers execute inline at their trigger, serializing with
    compute; mirrors the executor's deterministic sync mode and is what the
    sim-vs-real parity test runs.

Outputs the paper's metrics:
    MSR = (VMP - EMP) / VMP      memory saving ratio
    EOR = (ETC - VTC) / VTC      extra overhead ratio
    CBR = MSR / EOR              cost-benefit ratio
measured against the vanilla (no scheduling) run of the same jobs.
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import Dict, List, Optional, Sequence, Tuple, Union

from .access import AccessSequence
from .engine import (INPUT_AWAIT_PREFETCH, INPUT_PASSIVE_SWAP_IN,
                     INPUT_RECOMPUTE, INPUT_RESIDENT, JobContext, MemoryEngine)
from .peak_analysis import PERSISTENT_KINDS
from .plan import EventType, MachineProfile, SchedulingPlan
from .telemetry import TelemetryHub


@dataclasses.dataclass
class PlanUpdate:
    """A pending plan change for one running job (preemptive arbitration).

    ``mode="boundary"`` is the paper's rule: the new plan applies right
    before the next iteration starts.  ``mode="safe-point"`` hot-swaps it
    mid-iteration at the first eligible safe point — an op boundary in
    ``safe_ops`` (from ``engine.find_safe_points`` against the *running*
    plan) reached at or after ``at_time`` with no transfer of this job in
    flight.  A safe-point update that finds no eligible point before the
    job's next iteration boundary is discarded there (``applied_time``
    stays None): its remainder plan is stale once the boundary plan takes
    over, and it must not block updates queued behind it.  The simulator
    stamps ``applied_time``/``applied_op`` when a swap lands, so
    scenarios can report the splice latency.
    """

    at_time: float
    plan: SchedulingPlan
    mode: str = "safe-point"            # "safe-point" | "boundary"
    safe_ops: Optional[frozenset] = None
    applied_time: Optional[float] = None
    applied_op: Optional[int] = None    # -1 == applied at the boundary


@dataclasses.dataclass
class SimResult:
    peak_bytes: int
    per_job_time: Dict[str, float]
    per_job_peak: Dict[str, int]
    total_time: float
    stall_time: float
    passive_swap_ins: int
    swap_conflicts: int
    timeline: List[Tuple[float, int]]
    trace: Optional[List[Tuple[str, str, str]]] = None
    # (applied_time, applied_op) per job for every plan update that landed
    plan_swaps: Dict[str, List[Tuple[float, int]]] = \
        dataclasses.field(default_factory=dict)
    # booked-but-unstarted prefetches cancelled when a safe-point splice
    # revised swap-INs already on the DmaChannel
    canceled_swap_ins: int = 0

    def msr(self, vanilla: "SimResult") -> float:
        v = vanilla.peak_bytes
        return (v - self.peak_bytes) / v if v else 0.0

    def eor(self, vanilla: "SimResult") -> float:
        v = vanilla.total_time
        return (self.total_time - v) / v if v else 0.0

    def cbr(self, vanilla: "SimResult") -> float:
        e = self.eor(vanilla)
        m = self.msr(vanilla)
        if e <= 0:
            return float("inf") if m > 0 else 0.0
        return m / e


class _JobClock:
    """Virtual-time state the engine does not own: op cursor, iteration
    count, pending prefetch landing times, queued plan updates."""

    def __init__(self, ctx: JobContext, iterations: int,
                 updates: Optional[List[PlanUpdate]] = None):
        self.ctx = ctx
        self.iterations = iterations
        self.iter = 0
        self.done = False
        self.finish_time = 0.0
        # storage -> completion time of an in-flight planned swap-in
        self.swap_in_at: Dict[str, float] = {}
        # storage -> channel-scheduled START of that swap-in (a booked
        # transfer that has not started yet may be cancelled at a splice)
        self.swap_in_start: Dict[str, float] = {}
        # storage -> identity token of its pending swap_in_done event;
        # cancelled tokens make the event a no-op when it pops
        self.swap_in_token: Dict[str, int] = {}
        self.canceled_tokens: set = set()
        # async swap-outs still in flight (a safe-point splice must wait)
        self.inflight_out = 0
        self.arrived = False    # job_lifecycle: initial residents landed
        self.updates = sorted(updates or [], key=lambda u: u.at_time)


def simulate(seqs: Sequence[AccessSequence],
             plans: Optional[Dict[str, SchedulingPlan]] = None,
             profile: Optional[MachineProfile] = None,
             iterations: Union[int, Dict[str, int]] = 2,
             offsets: Optional[Dict[str, float]] = None,
             free_at_last_use: bool = True,
             job_lifecycle: bool = False,
             transfer_mode: str = "async",
             engine: Optional[MemoryEngine] = None,
             plan_updates: Optional[Dict[str, List[PlanUpdate]]] = None,
             telemetry: Optional[TelemetryHub] = None
             ) -> SimResult:
    """Run `iterations` training iterations of every job concurrently.
    `iterations` may be a per-job dict (dynamic-workload scenarios: short
    jobs finish and leave while long jobs keep running).

    `plan_updates[job_id]` queues mid-run plan changes (PlanUpdate):
    boundary-mode updates land right before the next iteration, safe-point
    updates hot-swap the job's plan at the first eligible safe point.

    `telemetry` attaches a TelemetryHub: the simulator then emits the SAME
    record shapes as the real executor — op latencies, transfer durations,
    stalls, residency mutations — stamped in virtual time, so both
    runtimes stay parity-testable and every measured-telemetry consumer
    can be exercised against the simulator.

    `free_at_last_use=False` reproduces the vanilla platform (nothing is
    released before iteration end — paper §V-A normalizer).

    `job_lifecycle=True` models each job as a process with a lifetime:
    its initial residents are allocated when it ARRIVES (at its offset, in
    event order — not eagerly at sim construction, which would count a
    late-admitted job's parameters against the device from t=0), and when
    it completes its final iteration every byte it still holds is
    returned, with in-flight transfers landing as no-ops.  Service-plane
    scenarios need this — admission takes a job's reservation at admit
    time and releases it at exit, so the modeled device must do the same.
    Default off: the legacy accounting (eager initial residency, residual
    bytes after finish) is what every pre-existing benchmark row was
    recorded under."""
    plans = plans or {}
    offsets = offsets or {}
    plan_updates = plan_updates or {}
    eng = engine or MemoryEngine(profile)
    if telemetry is not None:
        eng.attach_telemetry(telemetry)
    hub = eng.telemetry
    profile = eng.profile

    jobs: Dict[str, _JobClock] = {}
    for s in seqs:
        ctx = eng.add_job(s, plans.get(s.job_id), offsets.get(s.job_id, 0.0))
        # dict form must name every job — a silent default would mask a
        # typo'd job id with quietly-wrong peak/EOR numbers
        iters = (iterations[s.job_id] if isinstance(iterations, dict)
                 else iterations)
        jobs[s.job_id] = _JobClock(ctx, iters,
                                   plan_updates.get(s.job_id))

    stall = 0.0
    passive = 0
    canceled_swap_ins = 0

    # initial residency (paper Alg 2 line 1) — under job_lifecycle it is
    # deferred to each job's arrival event so the ledger's running total
    # stays ordered in virtual time
    if not job_lifecycle:
        for job in jobs.values():
            ctx = job.ctx
            for tid in ctx.seq.initial_resident:
                if tid in ctx.seq.tensors:
                    eng.ledger.alloc(ctx.job_id, ctx.st(tid),
                                     ctx.size_of(tid), ctx.offset)

    # event queue: (time, seqno, kind, job_id, payload)
    q: List[Tuple[float, int, str, str, object]] = []
    seqno = 0

    def push(t: float, kind: str, job_id: str, payload=None):
        nonlocal seqno
        heapq.heappush(q, (t, seqno, kind, job_id, payload))
        seqno += 1

    for job_id, job in jobs.items():
        push(job.ctx.offset, "op", job_id, 0)

    while q:
        t, _, kind, job_id, payload = heapq.heappop(q)
        job = jobs[job_id]
        ctx = job.ctx
        seq = ctx.seq

        if kind == "swap_in_done":
            st, token, s0, dur, compressed, nbytes = payload  # type: ignore[misc]
            if token in job.canceled_tokens:
                # booking was revised away at a safe-point splice before
                # the transfer started: the completion is a no-op
                job.canceled_tokens.discard(token)
                continue
            if job_lifecycle and job.done:
                # the job exited while this prefetch was on the wire: the
                # landing bytes have nowhere to go — drop the completion
                job.swap_in_at.pop(st, None)
                job.swap_in_start.pop(st, None)
                job.swap_in_token.pop(st, None)
                continue
            if hub is not None:
                hub.record_transfer(job_id, st, "in", nbytes, dur,
                                    compressed=compressed, t=s0)
            eng.complete_swap_in(ctx, st, t)
            job.swap_in_at.pop(st, None)
            job.swap_in_start.pop(st, None)
            job.swap_in_token.pop(st, None)
            continue
        if kind == "swap_out_done":
            st, compressed = payload  # type: ignore[misc]
            if job_lifecycle and job.done:
                # device side already freed wholesale at exit
                job.inflight_out -= 1
                continue
            eng.complete_swap_out(ctx, st, t, compressed=compressed)
            job.inflight_out -= 1
            continue
        if kind != "op":
            continue

        op_idx = payload  # type: ignore[assignment]
        op = seq.operators[op_idx]

        if job_lifecycle and not job.arrived:
            # process arrival: the job's parameters land on device now
            job.arrived = True
            for tid in seq.initial_resident:
                if tid in seq.tensors:
                    eng.ledger.alloc(ctx.job_id, ctx.st(tid),
                                     ctx.size_of(tid), t)

        # ---- ensure inputs resident (engine decision; paper Executor) --
        start = t
        for tid in op.inputs:
            if tid not in seq.tensors:
                continue
            st = ctx.st(tid)
            action = ctx.input_action(eng.ledger, tid,
                                      prefetch_inflight=st in job.swap_in_at)
            if action is INPUT_RESIDENT:
                continue
            if action is INPUT_AWAIT_PREFETCH:
                # prefetch in flight but late: wait for it
                wait_until = job.swap_in_at.pop(st)
                job.swap_in_start.pop(st, None)
                wait = max(0.0, wait_until - start)
                stall += wait
                if hub is not None and wait > 0:
                    hub.record_stall(job_id, op_idx, wait,
                                     "await_prefetch", t=start)
                start = max(start, wait_until)
                eng.complete_swap_in(ctx, st, start, passive=True)
                passive += 1
            elif action is INPUT_PASSIVE_SWAP_IN:
                # passive swap-in: block on the channel (Capuchin-style
                # overhead — what TENSILE's planned prefetch avoids)
                compressed = st in ctx.host_compressed
                dur = profile.transfer_time(
                    ctx.size_of(tid), compressed=compressed)
                s0, s1 = eng.channel.acquire(
                    start, dur, direction="in",
                    fixup=profile.host_link_latency)
                if hub is not None:
                    hub.record_transfer(job_id, st, "in",
                                        ctx.size_of(tid), dur,
                                        compressed=compressed,
                                        passive=True, t=s0)
                    hub.record_stall(job_id, op_idx, s1 - start,
                                     "passive_in", t=start)
                stall += s1 - start
                start = s1
                eng.complete_swap_in(ctx, st, start, passive=True)
                passive += 1
            # INPUT_RECOMPUTE: never materialized — a planned recompute
            # event regenerates it at its trigger; nothing to charge here
            # (the TGA allocation below models on-demand regeneration).

        # ---- run the op -------------------------------------------------
        end = start + op.latency
        if hub is not None:
            hub.record_op(job_id, op_idx, op.latency, prim=op.name,
                          flops=op.flops, bytes_accessed=op.bytes_accessed,
                          t=end)

        # ---- allocate outputs (TGA; updated params alias old storage, so
        # the storage-keyed alloc is a no-op while the old copy is resident)
        for tid in op.outputs:
            if tid not in seq.tensors:
                continue
            eng.ledger.alloc(ctx.job_id, ctx.st(tid), ctx.size_of(tid), end)

        # ---- releases (plan override + activity analysis) ---------------
        for tid in op.inputs + op.outputs:
            if tid not in seq.tensors:
                continue
            if ctx.should_auto_release(tid, op_idx, free_at_last_use):
                eng.record("release", ctx, ctx.st(tid))
                eng.ledger.free(ctx.job_id, ctx.st(tid), end)

        # ---- plan events triggered by this op ---------------------------
        for ev in ctx.events_triggered_by(op_idx):
            st = ctx.st(ev.tensor_id)
            if not ctx.event_applies(eng.ledger, ev):
                continue
            if ev.event_type is EventType.SWAP_OUT:
                dur = eng.event_duration(ev)
                s0, s1 = eng.channel.acquire(
                    end + max(ev.delta, 0.0), dur, direction="out",
                    fixup=profile.host_link_latency)
                if hub is not None:
                    hub.record_transfer(job_id, st, "out", ev.size_bytes,
                                        dur, compressed=ev.compressed,
                                        t=s0)
                if transfer_mode == "sync":
                    end = max(end, s1)
                    eng.complete_swap_out(ctx, st, end,
                                          compressed=ev.compressed)
                else:
                    job.inflight_out += 1
                    push(s1, "swap_out_done", job_id, (st, ev.compressed))
            elif ev.event_type is EventType.SWAP_IN:
                dur = eng.event_duration(ev)
                s0, s1 = eng.channel.acquire(
                    end + max(ev.delta, 0.0), dur, direction="in",
                    fixup=profile.host_link_latency)
                if transfer_mode == "sync":
                    if hub is not None:
                        hub.record_transfer(job_id, st, "in",
                                            ev.size_bytes, dur,
                                            compressed=ev.compressed,
                                            t=s0)
                    end = max(end, s1)
                    eng.complete_swap_in(ctx, st, end)
                else:
                    # the transfer is recorded into the hub only at
                    # COMPLETION: a booking cancelled at a safe-point
                    # splice must not leave a phantom busy interval in
                    # the measured plane
                    job.swap_in_at[st] = s1
                    job.swap_in_start[st] = s0
                    token = seqno  # unique: push() bumps it next
                    job.swap_in_token[st] = token
                    push(s1, "swap_in_done", job_id,
                         (st, token, s0, dur, ev.compressed,
                          ev.size_bytes))
            elif ev.event_type is EventType.RELEASE:
                eng.record("release", ctx, st)
                eng.ledger.free(ctx.job_id, st, end)
            elif ev.event_type is EventType.RECOMPUTE:
                # re-execute the producer chain inline (serial job)
                rc = sum(seq.operators[i].latency
                         for i in (ev.recompute_ops or []))
                end += rc
                eng.record("recompute", ctx, st)
                eng.ledger.alloc(ctx.job_id, st, ctx.size_of(ev.tensor_id),
                                 end)

        # ---- plan hot-swap at a safe point ------------------------------
        # after this op's events: the splice adopts the new plan's triggers
        # for every LATER op, the prefix already ran identically.  Every
        # due update is scanned — a safe-point update must not be blocked
        # by a boundary update queued ahead of it — and the LAST eligible
        # one wins (it was built to supersede its predecessors); the
        # superseded ones are dropped.  A swap-IN already booked on the
        # channel no longer pins the plan: a booking whose transfer has
        # not STARTED by the splice instant is cancelled (and the channel
        # tail refunded best-effort) so the new plan can re-book it; only
        # a transfer physically on the wire defers the splice.
        started_in = any(s0 <= end + 1e-12
                         for s0 in job.swap_in_start.values())
        if job.updates and not started_in and job.inflight_out == 0:
            hit = None
            for i, upd in enumerate(job.updates):
                if upd.at_time > end + 1e-12:
                    break
                if upd.mode == "safe-point" \
                        and (upd.safe_ops is None or op_idx in upd.safe_ops):
                    hit = i
            if hit is not None:
                upd = job.updates[hit]
                # cancel unstarted booked swap-ins, newest booking first
                # (the FIFO channel can only refund its tail)
                for st_c, s0 in sorted(job.swap_in_start.items(),
                                       key=lambda kv: -kv[1]):
                    s1 = job.swap_in_at.pop(st_c, None)
                    token = job.swap_in_token.pop(st_c, None)
                    if token is not None:
                        job.canceled_tokens.add(token)
                    if s1 is not None:
                        eng.channel.try_refund(s0, s1)
                    canceled_swap_ins += 1
                job.swap_in_start.clear()
                ctx.set_plan(upd.plan)
                upd.applied_time, upd.applied_op = end, op_idx
                if eng.recorder is not None:
                    eng.recorder.instant("hot_swap", end, job_id=job_id,
                                         site="safe-point", op_idx=op_idx)
                # superseded SAFE-POINT updates are dropped; pending
                # boundary updates survive — a spliced remainder plan is
                # only certified for this iteration's window, so the full
                # boundary plan must still land at the boundary drain
                job.updates = [u for i, u in enumerate(job.updates)
                               if i > hit or u.mode == "boundary"]

        # ---- advance ------------------------------------------------------
        nxt = op_idx + 1
        if nxt < len(seq.operators):
            push(end, "op", job_id, nxt)
        else:
            if not free_at_last_use:
                # vanilla platform: iteration-end free of non-persistent
                for st in eng.ledger.resident_storages(ctx.job_id):
                    if not _persistent_storage(seq, st):
                        eng.ledger.free(ctx.job_id, st, end)
            job.iter += 1
            if hub is not None:
                hub.end_iteration(job_id)
            # boundary-mode plan pickup: "right before computing the next
            # batch of data" (paper §III-D).  ALL due updates drain here:
            # a safe-point update whose window has passed is obsolete (the
            # boundary plan supersedes the mid-iteration shrink it never
            # managed to apply), and of several due boundary updates only
            # the NEWEST takes effect — each was built to replace its
            # predecessors.
            last_boundary = None
            while job.updates and job.updates[0].at_time <= end + 1e-12:
                upd = job.updates.pop(0)
                if upd.mode == "boundary":
                    last_boundary = upd
            if last_boundary is not None:
                ctx.set_plan(last_boundary.plan)
                last_boundary.applied_time = end
                last_boundary.applied_op = -1
                if eng.recorder is not None:
                    eng.recorder.instant("hot_swap", end, job_id=job_id,
                                         site="boundary", op_idx=-1)
            if job.iter < job.iterations:
                push(end, "op", job_id, 0)
            else:
                job.done = True
                job.finish_time = end
                if job_lifecycle:
                    # process exit: return every byte the job still holds
                    for st in eng.ledger.resident_storages(ctx.job_id):
                        eng.ledger.free(ctx.job_id, st, end)

    per_job_time = {j: (job.finish_time - job.ctx.offset)
                    / max(job.iterations, 1)
                    for j, job in jobs.items()}
    per_job_peak = {j: eng.ledger.job_peak(j) for j in jobs}
    total = max((job.finish_time for job in jobs.values()), default=0.0)
    plan_swaps = {
        j: [(u.applied_time, u.applied_op)
            for u in plan_updates.get(j, []) if u.applied_time is not None]
        for j in jobs if plan_updates.get(j)}
    return SimResult(
        peak_bytes=eng.ledger.peak, per_job_time=per_job_time,
        per_job_peak=per_job_peak, total_time=total, stall_time=stall,
        passive_swap_ins=passive, swap_conflicts=eng.channel.conflicts,
        timeline=list(eng.ledger.timeline),
        trace=eng.trace.keys() if eng.trace else None,
        plan_swaps=plan_swaps, canceled_swap_ins=canceled_swap_ins)


def _persistent_storage(seq: AccessSequence, st: str) -> bool:
    spec = seq.tensors.get(st)
    return spec is not None and (spec.kind in PERSISTENT_KINDS
                                 or spec.updates is not None)


def evaluate(seqs: Sequence[AccessSequence],
             plans: Optional[Dict[str, SchedulingPlan]],
             profile: Optional[MachineProfile] = None,
             iterations: Union[int, Dict[str, int]] = 3,
             offsets: Optional[Dict[str, float]] = None,
             free_at_last_use: bool = True,
             ) -> Dict[str, float]:
    """Run scheduled vs vanilla and report the paper's metrics.  The
    vanilla run frees nothing until iteration end (the paper's platform);
    scheduled runs get activity-analysis releases (Alg 3 line 2) unless
    the method's own framework lacks them (vDNN: swap-only)."""
    vanilla = simulate(seqs, None, profile, iterations, offsets,
                       free_at_last_use=False)
    sched = simulate(seqs, plans, profile, iterations, offsets,
                     free_at_last_use=free_at_last_use)
    msr = sched.msr(vanilla)
    eor = sched.eor(vanilla)
    return {
        "MSR": msr, "EOR": eor,
        "CBR": sched.cbr(vanilla),
        "vanilla_peak": vanilla.peak_bytes, "peak": sched.peak_bytes,
        "vanilla_time": vanilla.total_time, "time": sched.total_time,
        "stall_time": sched.stall_time,
        "passive_swap_ins": sched.passive_swap_ins,
        "swap_conflicts": sched.swap_conflicts,
    }
